"""AOT pipeline: lower every L2 graph to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids so text round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (`make artifacts`); the rust binary is then
self-contained. A manifest (artifacts/manifest.tsv) records, per artifact:
name, entry function, input shapes/dtypes, output shapes/dtypes, so the
rust runtime can discover and validate executables without parsing HLO.

Usage: python -m compile.aot --outdir ../artifacts [--sizes 32,64,128]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Worker-product slots in the decode executable: 14 algorithm products +
# 2 PSMMs (the paper's full 16-node configuration).
DECODE_SLOTS = 16


def to_hlo_text(lowered) -> str:
    """jax lowered -> XLA HLO text via stablehlo (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_spec(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def graphs_for_size(bs: int):
    """(name, fn, arg_specs) for every artifact at block size bs."""
    f32 = jnp.float32
    n = 2 * bs
    return [
        (
            f"worker_task_bs{bs}",
            lambda ca, a4, cb, b4: (model.worker_task(ca, a4, cb, b4),),
            [_spec((4,), f32), _spec((4, bs, bs), f32),
             _spec((4,), f32), _spec((4, bs, bs), f32)],
        ),
        (
            f"decode_combine_bs{bs}",
            lambda w, p: (model.decode_combine(w, p),),
            [_spec((DECODE_SLOTS,), f32),
             _spec((DECODE_SLOTS, bs, bs), f32)],
        ),
        (
            f"strassen_once_bs{bs}",
            lambda a4, b4: (model.strassen_once(a4, b4),),
            [_spec((4, bs, bs), f32), _spec((4, bs, bs), f32)],
        ),
        (
            f"winograd_once_bs{bs}",
            lambda a4, b4: (model.winograd_once(a4, b4),),
            [_spec((4, bs, bs), f32), _spec((4, bs, bs), f32)],
        ),
        (
            f"matmul_n{n}",
            lambda a, b: (model.matmul(a, b),),
            [_spec((n, n), f32), _spec((n, n), f32)],
        ),
    ]


def lower_all(outdir: str, sizes: list[int]) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    manifest_rows = []
    written = []
    for bs in sizes:
        for name, fn, specs in graphs_for_size(bs):
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            path = os.path.join(outdir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            outs = jax.eval_shape(fn, *specs)
            manifest_rows.append(
                "\t".join([
                    name,
                    f"{name}.hlo.txt",
                    ";".join(_fmt_spec(s) for s in specs),
                    ";".join(_fmt_spec(s) for s in outs),
                ])
            )
            written.append(path)
            print(f"  wrote {path} ({len(text)} chars)")
    mpath = os.path.join(outdir, "manifest.tsv")
    with open(mpath, "w") as f:
        f.write("# name\tfile\tinputs\toutputs\n")
        f.write("\n".join(manifest_rows) + "\n")
    written.append(mpath)
    print(f"  wrote {mpath} ({len(manifest_rows)} artifacts)")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--sizes", default="32,64,128",
                    help="comma-separated block sizes")
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    lower_all(args.outdir, sizes)


if __name__ == "__main__":
    main()
