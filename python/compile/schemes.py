"""The paper's two Strassen-like algorithms as coefficient tables.

Block convention: the paper writes C = A^T B and labels the blocks of A^T.
We call the left operand M (= A^T), with blocks in row-major order
[M11, M12, M21, M22]; likewise B and C. So C11 = M11 B11 + M12 B21 etc.

Each sub-matrix multiplication (one worker task) is a pair of signed
coefficient 4-vectors (ca, cb):  product = (sum ca_i M_i)(sum cb_j B_j).
Each output block is a signed integer combination of the 7 products.

These tables are the single Python-side source of truth; the rust side
(rust/src/algorithms/) defines the same tables and both are independently
validated against dense matmul, which anchors them to the paper's eqs.
(1)-(4).
"""

from __future__ import annotations

# Block index order: 11, 12, 21, 22.
M11, M12, M21, M22 = range(4)
B11, B12, B21, B22 = range(4)


def _vec(**kw) -> list[int]:
    v = [0, 0, 0, 0]
    names = {"m11": 0, "m12": 1, "m21": 2, "m22": 3,
             "b11": 0, "b12": 1, "b21": 2, "b22": 3}
    for k, s in kw.items():
        v[names[k]] = s
    return v


# --- Strassen (paper's S1..S7) -------------------------------------------
# S1 = (M11+M22)(B11+B22)          S5 = (M11+M12) B22
# S2 = (M21+M22) B11               S6 = (M21-M11)(B11+B12)
# S3 = M11 (B12-B22)               S7 = (M12-M22)(B21+B22)
# S4 = M22 (B21-B11)
STRASSEN_PRODUCTS = [
    (_vec(m11=1, m22=1), _vec(b11=1, b22=1)),   # S1
    (_vec(m21=1, m22=1), _vec(b11=1)),          # S2
    (_vec(m11=1), _vec(b12=1, b22=-1)),         # S3
    (_vec(m22=1), _vec(b21=1, b11=-1)),         # S4
    (_vec(m11=1, m12=1), _vec(b22=1)),          # S5
    (_vec(m21=1, m11=-1), _vec(b11=1, b12=1)),  # S6
    (_vec(m12=1, m22=-1), _vec(b21=1, b22=1)),  # S7
]

# C blocks from S products, paper eqs. (1)-(4):
# C11 = S1+S4-S5+S7; C12 = S3+S5; C21 = S2+S4; C22 = S1-S2+S3+S6
STRASSEN_OUTPUT = [
    [1, 0, 0, 1, -1, 0, 1],   # C11
    [0, 0, 1, 0, 1, 0, 0],    # C12
    [0, 1, 0, 1, 0, 0, 0],    # C21
    [1, -1, 1, 0, 0, 1, 0],   # C22
]

# --- Winograd (paper's W1..W7) -------------------------------------------
# W1 = M11 B11                     W5 = (M21+M22)(B12-B11)
# W2 = M12 B21                     W6 = (M11+M12-M21-M22) B22
# W3 = M22 (B11-B12-B21+B22)       W7 = (M11-M21-M22)(B11-B12+B22)
# W4 = (M11-M21)(B22-B12)
WINOGRAD_PRODUCTS = [
    (_vec(m11=1), _vec(b11=1)),                              # W1
    (_vec(m12=1), _vec(b21=1)),                              # W2
    (_vec(m22=1), _vec(b11=1, b12=-1, b21=-1, b22=1)),       # W3
    (_vec(m11=1, m21=-1), _vec(b22=1, b12=-1)),              # W4
    (_vec(m21=1, m22=1), _vec(b12=1, b11=-1)),               # W5
    (_vec(m11=1, m12=1, m21=-1, m22=-1), _vec(b22=1)),       # W6
    (_vec(m11=1, m21=-1, m22=-1), _vec(b11=1, b12=-1, b22=1)),  # W7
]

# C11 = W1+W2; C12 = W1+W5+W6-W7; C21 = W1-W3+W4-W7; C22 = W1+W4+W5-W7
WINOGRAD_OUTPUT = [
    [1, 1, 0, 0, 0, 0, 0],     # C11
    [1, 0, 0, 0, 1, 1, -1],    # C12
    [1, 0, -1, 1, 0, 0, -1],   # C21
    [1, 0, 0, 1, 1, 0, -1],    # C22
]

# --- PSMMs (paper §IV) ----------------------------------------------------
# PSMM-1 = S3 + W4 = M21 (B12 - B22); PSMM-2 = copy of W2.
PSMM_PRODUCTS = [
    (_vec(m21=1), _vec(b12=1, b22=-1)),  # PSMM-1
    (_vec(m12=1), _vec(b21=1)),          # PSMM-2 (= W2)
]

# The combined 16-task set, in dispatch order S1..S7, W1..W7, P1, P2.
ALL_PRODUCTS = STRASSEN_PRODUCTS + WINOGRAD_PRODUCTS + PSMM_PRODUCTS
TASK_NAMES = [f"S{i}" for i in range(1, 8)] + \
             [f"W{i}" for i in range(1, 8)] + ["P1", "P2"]
