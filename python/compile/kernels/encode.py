"""L1 Pallas kernels: encoder / decoder linear combinations.

The paper's master node *encodes* each worker task as a signed sum of the
four sub-blocks of an operand (e.g. S1's left operand is A11 + A22), and
*decodes* the result matrix C as a rational combination of finished worker
products (eqs. (1)-(8) and the 52 searched local relations).

Both are bandwidth-bound elementwise reductions over a stacked operand,
fused into a single Pallas kernel so no intermediate (bs, bs) temporaries
are materialized: one pass over HBM, coefficients resident in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(c_ref, x_ref, o_ref, *, terms: int):
    """o = sum_t c[t] * x[t] over a (tm, tn) tile; the t-loop is unrolled
    (terms is static), which XLA fuses into a single vectorized expression."""
    acc = c_ref[0] * x_ref[0]
    for t in range(1, terms):
        acc = acc + c_ref[t] * x_ref[t]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def combine(c, x, *, tm: int | None = None, tn: int | None = None):
    """Weighted sum over the leading axis: sum_t c[t] * x[t].

    c: (T,) coefficients; x: (T, m, n) stacked blocks -> (m, n).
    Serves both the encoder (T=4, c in {-1,0,1}) and the decoder
    (T=#tasks, c rational, cast to the compute dtype).
    """
    (terms,) = c.shape
    t2, m, n = x.shape
    if terms != t2:
        raise ValueError(f"coeff/operand mismatch: {c.shape} vs {x.shape}")
    from .matmul import default_tile

    tm = tm or default_tile(m)
    tn = tn or default_tile(n)
    if m % tm or n % tn:
        raise ValueError(f"tiles ({tm},{tn}) must divide ({m},{n})")
    c = c.astype(x.dtype)
    return pl.pallas_call(
        functools.partial(_combine_kernel, terms=terms),
        grid=(m // tm, n // tn),
        in_specs=[
            # Coefficients: one tiny vector broadcast to every program.
            pl.BlockSpec((terms,), lambda i, j: (0,)),
            # Full stack of blocks, tiled over the trailing dims.
            pl.BlockSpec((terms, tm, tn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(c, x)


def _encode_mm_kernel(ca_ref, a_ref, cb_ref, b_ref, o_ref, *, nk: int,
                      terms: int):
    """Fused encode+matmul tile: (sum ca[t] A[t]) @ (sum cb[t] B[t]).

    Encoding happens on the VMEM-resident tiles right before they are fed
    to the MXU, so the signed sums are never written back to HBM.
    """
    xa = ca_ref[0] * a_ref[0]
    for t in range(1, terms):
        xa = xa + ca_ref[t] * a_ref[t]
    xb = cb_ref[0] * b_ref[0]
    for t in range(1, terms):
        xb = xb + cb_ref[t] * b_ref[t]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xa, xb, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def encoded_matmul(ca, a4, cb, b4, *, tm: int | None = None,
                   tn: int | None = None, tk: int | None = None):
    """The generic worker task, fused: (sum_i ca[i] A_i) @ (sum_j cb[j] B_j).

    ca, cb: (4,) signed coefficients; a4, b4: (4, bs, bs) stacked blocks.
    Every one of the paper's 16 sub-computations (S1..S7, W1..W7, the two
    PSMMs) is this executable with different runtime coefficients.
    """
    ta, m, k = a4.shape
    tb, k2, n = b4.shape
    if k != k2 or ca.shape != (ta,) or cb.shape != (tb,):
        raise ValueError(
            f"bad shapes: ca{ca.shape} a4{a4.shape} cb{cb.shape} b4{b4.shape}")
    from .matmul import default_tile

    tm = tm or default_tile(m)
    tn = tn or default_tile(n)
    tk = tk or default_tile(k)
    if m % tm or n % tn or k % tk:
        raise ValueError(f"tiles ({tm},{tn},{tk}) must divide ({m},{n},{k})")
    nk = k // tk
    dtype = jnp.promote_types(a4.dtype, b4.dtype)
    ca = ca.astype(dtype)
    cb = cb.astype(dtype)
    return pl.pallas_call(
        functools.partial(_encode_mm_kernel, nk=nk, terms=ta),
        grid=(m // tm, n // tn, nk),
        in_specs=[
            pl.BlockSpec((ta,), lambda i, j, kk: (0,)),
            pl.BlockSpec((ta, tm, tk), lambda i, j, kk: (0, i, kk)),
            pl.BlockSpec((tb,), lambda i, j, kk: (0,)),
            pl.BlockSpec((tb, tk, tn), lambda i, j, kk: (0, kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=True,
    )(ca, a4, cb, b4)
