"""L1 Pallas kernel: tiled block matrix multiplication.

This is the compute hot-spot of the paper's system: every worker node
executes exactly one sub-matrix multiplication of shape (bs, bs) x (bs, bs).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is
(m-tiles, n-tiles, k-tiles); each (i, j) program owns an output tile that
stays resident while program_id(2) sweeps the contraction dimension —
the classic MXU-friendly schedule, with the HBM -> VMEM movement expressed
through BlockSpec index maps rather than CUDA threadblocks.

All pallas_call sites use interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers to plain HLO that the rust
runtime executes unmodified.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (tm, tn) output tile; program_id(2) sweeps the k dimension.

    The output BlockSpec maps every k step to the same (i, j) tile, so the
    tile acts as the accumulator (VMEM-resident on real hardware).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def default_tile(dim: int, cap: int = 128) -> int:
    """Largest power-of-two tile <= cap that divides dim (>= 1)."""
    t = 1
    while t * 2 <= cap and dim % (t * 2) == 0:
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul(x, y, *, tm: int | None = None, tn: int | None = None,
           tk: int | None = None):
    """Tiled Pallas matmul: x @ y.

    x: (m, k), y: (k, n). Tile sizes must divide the respective dims;
    defaults pick the largest power-of-two divisor capped at 128 (the MXU
    systolic array edge).
    """
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    tm = tm or default_tile(m)
    tn = tn or default_tile(n)
    tk = tk or default_tile(k)
    if m % tm or n % tn or k % tk:
        raise ValueError(f"tiles ({tm},{tn},{tk}) must divide ({m},{n},{k})")
    nk = k // tk
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // tm, n // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,
    )(x, y)


def vmem_bytes(tm: int, tn: int, tk: int, itemsize: int = 4) -> int:
    """Estimated VMEM footprint of one program instance (double-buffered
    operand tiles + output/accumulator tile), for the §Perf roofline table."""
    operands = 2 * (tm * tk + tk * tn) * itemsize  # double buffering
    out = tm * tn * max(itemsize, 4)  # accumulate at >= f32
    return operands + out


def mxu_utilization_estimate(tm: int, tn: int, tk: int) -> float:
    """Fraction of the 128x128 MXU a (tm, tn, tk) tile keeps busy.

    The systolic array processes 128x128 output stationary tiles; smaller
    tiles under-fill the array in each dimension.
    """
    return min(tm, 128) * min(tn, 128) / (128.0 * 128.0)
