"""Pure-jnp oracles for the Pallas kernels (build-time correctness only).

Every kernel in this package has a reference implementation here; pytest
asserts allclose between kernel and oracle across a hypothesis-driven
sweep of shapes, tiles and dtypes (python/tests/test_kernel.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, y):
    """Reference for kernels.matmul.matmul."""
    return jnp.matmul(x, y)


def combine_ref(c, x):
    """Reference for kernels.encode.combine: sum_t c[t] * x[t]."""
    return jnp.tensordot(c.astype(x.dtype), x, axes=1)


def encoded_matmul_ref(ca, a4, cb, b4):
    """Reference for kernels.encode.encoded_matmul."""
    dtype = jnp.promote_types(a4.dtype, b4.dtype)
    left = jnp.tensordot(ca.astype(dtype), a4.astype(dtype), axes=1)
    right = jnp.tensordot(cb.astype(dtype), b4.astype(dtype), axes=1)
    return jnp.matmul(left, right)
