"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

Everything here is build-time only. `aot.py` lowers these functions to HLO
text once per block size; the rust coordinator loads and runs the
artifacts via PJRT and never imports Python.

Graphs
------
worker_task(ca, a4, cb, b4)    the generic worker executable: one encoded
                               sub-matrix multiplication. All 16 of the
                               paper's tasks (S1..S7, W1..W7, P1, P2) are
                               this graph with different coefficients.
decode_combine(w, p)           master-side decode: rational combination of
                               up to 16 finished worker products -> one C
                               block.
strassen_once / winograd_once  single-node one-level Strassen-like MM
                               (7 Pallas products + block assembly) —
                               baselines and cross-checks.
matmul(a, b)                   plain Pallas matmul (naive baseline).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import schemes
from .kernels.encode import combine, encoded_matmul
from .kernels.matmul import matmul as pallas_matmul


def worker_task(ca, a4, cb, b4):
    """(sum_i ca[i] M_i) @ (sum_j cb[j] B_j), fused encode+matmul kernel.

    ca, cb: (4,) f32; a4, b4: (4, bs, bs) f32. Returns (bs, bs).
    """
    return encoded_matmul(ca, a4, cb, b4)


def decode_combine(w, p):
    """sum_t w[t] * p[t]: stack of worker products -> one C block.

    w: (T,) f32 decode weights (zero for unfinished workers);
    p: (T, bs, bs) f32 products (zero-filled rows for unfinished workers).
    """
    return combine(w, p)


def matmul(a, b):
    """Plain tiled Pallas matmul (the naive single-node baseline)."""
    return pallas_matmul(a, b)


def _one_level(products_tbl, output_tbl, a4, b4):
    """Generic one-level Strassen-like MM from a coefficient table.

    a4, b4: (4, bs, bs) blocks [X11, X12, X21, X22] of M (= A^T) and B.
    Returns (4, bs, bs) blocks of C. Each of the 7 products uses the fused
    encoded-matmul kernel; block assembly uses the combine kernel.
    """
    prods = []
    for ca, cb in products_tbl:
        prods.append(worker_task(jnp.asarray(ca, a4.dtype), a4,
                                 jnp.asarray(cb, b4.dtype), b4))
    pstack = jnp.stack(prods)  # (7, bs, bs)
    cblocks = [combine(jnp.asarray(row, pstack.dtype), pstack)
               for row in output_tbl]
    return jnp.stack(cblocks)  # (4, bs, bs)


def strassen_once(a4, b4):
    """One level of Strassen (paper's S1..S7, eqs. (1)-(4))."""
    return _one_level(schemes.STRASSEN_PRODUCTS, schemes.STRASSEN_OUTPUT,
                      a4, b4)


def winograd_once(a4, b4):
    """One level of Winograd (paper's W1..W7)."""
    return _one_level(schemes.WINOGRAD_PRODUCTS, schemes.WINOGRAD_OUTPUT,
                      a4, b4)


def split_blocks(x):
    """(n, n) -> (4, n/2, n/2) blocks [X11, X12, X21, X22]."""
    n = x.shape[0]
    h = n // 2
    return jnp.stack([x[:h, :h], x[:h, h:], x[h:, :h], x[h:, h:]])


def join_blocks(b):
    """(4, h, h) -> (2h, 2h)."""
    return jnp.concatenate([
        jnp.concatenate([b[0], b[1]], axis=1),
        jnp.concatenate([b[2], b[3]], axis=1),
    ], axis=0)


def strassen_mm(a, b):
    """Full one-level Strassen multiply of square matrices via Pallas."""
    return join_blocks(strassen_once(split_blocks(a), split_blocks(b)))


def winograd_mm(a, b):
    """Full one-level Winograd multiply of square matrices via Pallas."""
    return join_blocks(winograd_once(split_blocks(a), split_blocks(b)))
