"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, tile sizes and dtypes; every case asserts
allclose against compile/kernels/ref.py. This is the CORE correctness
signal for the compute layer — the rust runtime executes exactly these
kernels (lowered to HLO) on its hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.encode import combine, encoded_matmul
from compile.kernels.matmul import default_tile, matmul, vmem_bytes

jax.config.update("jax_enable_x64", True)


def _rng(seed):
    return np.random.default_rng(seed)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- matmul

@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24, 32, 48]),
    k=st.sampled_from([8, 16, 24, 40]),
    n=st.sampled_from([8, 16, 24, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_shapes(m, k, n, seed):
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    y = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    got = matmul(x, y)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    tm=st.sampled_from([4, 8, 16]),
    tn=st.sampled_from([4, 8, 16]),
    tk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tile_sweep(tm, tn, tk, seed):
    m, k, n = 32, 32, 32
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    y = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    got = matmul(x, y, tm=tm, tn=tn, tk=tk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    r = _rng(7)
    x = jnp.asarray(r.standard_normal((16, 16)), dtype)
    y = jnp.asarray(r.standard_normal((16, 16)), dtype)
    got = matmul(x, y)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float64),
        np.asarray(ref.matmul_ref(x, y), np.float64), **_tol(dtype))


def test_matmul_rejects_bad_contraction():
    x = jnp.zeros((4, 5))
    y = jnp.zeros((6, 4))
    with pytest.raises(ValueError, match="contraction"):
        matmul(x, y)


def test_matmul_rejects_nondividing_tiles():
    x = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="divide"):
        matmul(x, x, tm=3)


def test_default_tile():
    assert default_tile(128) == 128
    assert default_tile(96) == 32
    assert default_tile(24) == 8
    assert default_tile(7) == 1
    assert default_tile(256, cap=128) == 128


def test_vmem_estimate_fits_16mb_for_default_tiles():
    # The §Perf roofline sanity check: a (128,128,128) f32 schedule uses
    # ~0.25 MiB VMEM per program — far below the ~16 MiB budget.
    assert vmem_bytes(128, 128, 128) < 16 * 2**20


# ---------------------------------------------------------------- combine

@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(1, 16),
    m=st.sampled_from([8, 16, 24]),
    n=st.sampled_from([8, 16, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_matches_ref(t, m, n, seed):
    r = _rng(seed)
    c = jnp.asarray(r.integers(-2, 3, t), jnp.float32)
    x = jnp.asarray(r.standard_normal((t, m, n)), jnp.float32)
    np.testing.assert_allclose(combine(c, x), ref.combine_ref(c, x),
                               rtol=2e-5, atol=2e-5)


def test_combine_zero_coeffs_is_zero():
    x = jnp.ones((4, 8, 8), jnp.float32)
    c = jnp.zeros((4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(combine(c, x)),
                                  np.zeros((8, 8), np.float32))


def test_combine_mismatched_raises():
    with pytest.raises(ValueError, match="mismatch"):
        combine(jnp.zeros((3,)), jnp.zeros((4, 8, 8)))


# --------------------------------------------------------- encoded_matmul

@settings(max_examples=30, deadline=None)
@given(
    bs=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_encoded_matmul_matches_ref(bs, seed):
    r = _rng(seed)
    ca = jnp.asarray(r.integers(-1, 2, 4), jnp.float32)
    cb = jnp.asarray(r.integers(-1, 2, 4), jnp.float32)
    a4 = jnp.asarray(r.standard_normal((4, bs, bs)), jnp.float32)
    b4 = jnp.asarray(r.standard_normal((4, bs, bs)), jnp.float32)
    got = encoded_matmul(ca, a4, cb, b4)
    np.testing.assert_allclose(got, ref.encoded_matmul_ref(ca, a4, cb, b4),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_encoded_matmul_tiled_grid(seed):
    # Force a non-trivial (2, 2, 2) grid so the k-accumulation and block
    # index maps are actually exercised.
    r = _rng(seed)
    bs = 16
    ca = jnp.asarray(r.integers(-1, 2, 4), jnp.float32)
    cb = jnp.asarray(r.integers(-1, 2, 4), jnp.float32)
    a4 = jnp.asarray(r.standard_normal((4, bs, bs)), jnp.float32)
    b4 = jnp.asarray(r.standard_normal((4, bs, bs)), jnp.float32)
    got = encoded_matmul(ca, a4, cb, b4, tm=8, tn=8, tk=8)
    np.testing.assert_allclose(got, ref.encoded_matmul_ref(ca, a4, cb, b4),
                               rtol=2e-4, atol=2e-4)


def test_encoded_matmul_is_fused_equivalent_of_two_step():
    # encode-then-matmul == fused kernel (the L2/L1 contract).
    r = _rng(3)
    ca = jnp.asarray([1, 0, 0, 1], jnp.float32)
    cb = jnp.asarray([1, 0, 0, 1], jnp.float32)
    a4 = jnp.asarray(r.standard_normal((4, 16, 16)), jnp.float32)
    b4 = jnp.asarray(r.standard_normal((4, 16, 16)), jnp.float32)
    two_step = matmul(combine(ca, a4), combine(cb, b4))
    fused = encoded_matmul(ca, a4, cb, b4)
    np.testing.assert_allclose(fused, two_step, rtol=2e-5, atol=2e-5)
