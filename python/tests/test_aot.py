"""AOT pipeline tests: HLO-text lowering, manifest format, shape specs.

These exercise `compile.aot` without writing the full artifact set
(single small block size into a temp dir), verifying the contract the
rust runtime depends on: parseable HLO text per graph + a 4-column TSV
manifest whose shapes match jax.eval_shape.
"""

import os

import jax.numpy as jnp
import pytest

from compile import aot


def test_to_hlo_text_produces_parseable_entry():
    import jax

    lowered = jax.jit(lambda x: (x @ x + 1.0,)).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    # XLA HLO text always has a module header and an ENTRY computation.
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True -> tuple root
    assert "tuple" in text


def test_graphs_for_size_cover_all_artifacts():
    graphs = aot.graphs_for_size(16)
    names = [g[0] for g in graphs]
    assert names == [
        "worker_task_bs16",
        "decode_combine_bs16",
        "strassen_once_bs16",
        "winograd_once_bs16",
        "matmul_n32",
    ]


def test_lower_all_writes_artifacts_and_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    written = aot.lower_all(out, [8])
    files = sorted(os.listdir(out))
    assert "manifest.tsv" in files
    assert "worker_task_bs8.hlo.txt" in files
    assert "matmul_n16.hlo.txt" in files
    assert len(written) == 6  # 5 graphs + manifest

    with open(os.path.join(out, "manifest.tsv")) as f:
        lines = [l.rstrip("\n") for l in f if not l.startswith("#")]
    assert len(lines) == 5
    for line in lines:
        name, fname, inputs, outputs = line.split("\t")
        assert fname == f"{name}.hlo.txt"
        assert os.path.exists(os.path.join(out, fname))
        # shape spec format: dtype[dims];...
        for spec in (inputs + ";" + outputs).split(";"):
            assert spec.startswith("float32["), spec
            assert spec.endswith("]")

    row = {l.split("\t")[0]: l.split("\t") for l in lines}
    assert row["worker_task_bs8"][2] == (
        "float32[4];float32[4,8,8];float32[4];float32[4,8,8]"
    )
    assert row["worker_task_bs8"][3] == "float32[8,8]"
    assert row["decode_combine_bs8"][2] == "float32[16];float32[16,8,8]"


def test_decode_slots_match_paper_max_configuration():
    # 14 products + 2 PSMMs = 16 decode slots.
    assert aot.DECODE_SLOTS == 16


@pytest.mark.parametrize("bs", [8, 16])
def test_lowered_worker_task_is_backend_agnostic_hlo(tmp_path, bs):
    """The HLO must not contain Mosaic custom-calls (interpret=True)."""
    out = str(tmp_path / "a")
    aot.lower_all(out, [bs])
    with open(os.path.join(out, f"worker_task_bs{bs}.hlo.txt")) as f:
        text = f.read()
    assert "mosaic" not in text.lower(), "TPU custom-call leaked into HLO"
    assert "custom-call" not in text.lower() or "topk" in text.lower()
