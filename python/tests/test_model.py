"""L2 model correctness: Strassen/Winograd graphs vs dense matmul.

These tests anchor the coefficient tables in compile/schemes.py to the
ground truth (jnp.matmul): if either the products or the output
combinations deviated from the paper's eqs. (1)-(4), these would fail.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, schemes

jax.config.update("jax_enable_x64", True)


def _rand(seed, shape, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       dtype)


def _dense_from_blocks(b4):
    return np.asarray(model.join_blocks(b4))


# ------------------------------------------------------------- one level

@settings(max_examples=20, deadline=None)
@given(bs=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_strassen_once_matches_dense(bs, seed):
    a4 = _rand(seed, (4, bs, bs))
    b4 = _rand(seed + 1, (4, bs, bs))
    c4 = model.strassen_once(a4, b4)
    want = _dense_from_blocks(a4) @ _dense_from_blocks(b4)
    np.testing.assert_allclose(_dense_from_blocks(c4), want, rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(bs=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_winograd_once_matches_dense(bs, seed):
    a4 = _rand(seed, (4, bs, bs))
    b4 = _rand(seed + 1, (4, bs, bs))
    c4 = model.winograd_once(a4, b4)
    want = _dense_from_blocks(a4) @ _dense_from_blocks(b4)
    np.testing.assert_allclose(_dense_from_blocks(c4), want, rtol=2e-4,
                               atol=2e-4)


def test_strassen_and_winograd_agree():
    a4 = _rand(11, (4, 8, 8))
    b4 = _rand(12, (4, 8, 8))
    np.testing.assert_allclose(model.strassen_once(a4, b4),
                               model.winograd_once(a4, b4),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_full_mm_wrappers(n, seed):
    a = _rand(seed, (n, n))
    b = _rand(seed + 1, (n, n))
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(model.strassen_mm(a, b), want, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(model.winograd_mm(a, b), want, rtol=2e-4,
                               atol=2e-4)


def test_split_join_roundtrip():
    x = _rand(5, (16, 16))
    np.testing.assert_array_equal(
        np.asarray(model.join_blocks(model.split_blocks(x))), np.asarray(x))


# ----------------------------------------------------------- worker task

@settings(max_examples=15, deadline=None)
@given(task=st.integers(0, 15), seed=st.integers(0, 2**31 - 1))
def test_every_paper_task_via_worker_executable(task, seed):
    """Each of the 16 tasks (S1..S7, W1..W7, P1, P2) through the generic
    worker graph equals its bilinear-form expansion."""
    bs = 8
    a4 = _rand(seed, (4, bs, bs))
    b4 = _rand(seed + 1, (4, bs, bs))
    ca, cb = schemes.ALL_PRODUCTS[task]
    got = model.worker_task(jnp.asarray(ca, jnp.float32), a4,
                            jnp.asarray(cb, jnp.float32), b4)
    left = sum(ca[i] * np.asarray(a4[i]) for i in range(4))
    right = sum(cb[j] * np.asarray(b4[j]) for j in range(4))
    np.testing.assert_allclose(got, left @ right, rtol=2e-4, atol=2e-4)


def test_psmm1_identity():
    """PSMM-1 == S3 + W4 == M21 (B12 - B22) (paper §IV)."""
    bs = 8
    a4 = _rand(21, (4, bs, bs))
    b4 = _rand(22, (4, bs, bs))

    def run(idx):
        ca, cb = schemes.ALL_PRODUCTS[idx]
        return np.asarray(model.worker_task(
            jnp.asarray(ca, jnp.float32), a4, jnp.asarray(cb, jnp.float32),
            b4))

    s3, w4, p1 = run(2), run(10), run(14)
    np.testing.assert_allclose(p1, s3 + w4, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        p1, np.asarray(a4[2]) @ (np.asarray(b4[1]) - np.asarray(b4[3])),
        rtol=2e-4, atol=2e-4)


def test_psmm2_is_w2():
    bs = 8
    a4 = _rand(31, (4, bs, bs))
    b4 = _rand(32, (4, bs, bs))
    assert schemes.ALL_PRODUCTS[15] == schemes.ALL_PRODUCTS[8]  # P2 == W2


# ---------------------------------------------------------------- decode

def test_decode_combine_recovers_c11_from_strassen():
    """C11 = S1 + S4 - S5 + S7 through the decode executable graph."""
    bs = 8
    a4 = _rand(41, (4, bs, bs))
    b4 = _rand(42, (4, bs, bs))
    prods = []
    for ca, cb in schemes.ALL_PRODUCTS:
        prods.append(model.worker_task(jnp.asarray(ca, jnp.float32), a4,
                                       jnp.asarray(cb, jnp.float32), b4))
    p = jnp.stack(prods)  # (16, bs, bs)
    w = np.zeros(16, np.float32)
    for i, coef in enumerate(schemes.STRASSEN_OUTPUT[0]):
        w[i] = coef
    c11 = model.decode_combine(jnp.asarray(w), p)
    want = (np.asarray(a4[0]) @ np.asarray(b4[0])
            + np.asarray(a4[1]) @ np.asarray(b4[2]))
    np.testing.assert_allclose(c11, want, rtol=2e-4, atol=2e-4)


def test_decode_combine_recovers_all_blocks_from_winograd():
    bs = 8
    a4 = _rand(51, (4, bs, bs))
    b4 = _rand(52, (4, bs, bs))
    prods = [model.worker_task(jnp.asarray(ca, jnp.float32), a4,
                               jnp.asarray(cb, jnp.float32), b4)
             for ca, cb in schemes.ALL_PRODUCTS]
    p = jnp.stack(prods)
    dense = _dense_from_blocks(a4) @ _dense_from_blocks(b4)
    want4 = model.split_blocks(jnp.asarray(dense, jnp.float32))
    for blk in range(4):
        w = np.zeros(16, np.float32)
        for i, coef in enumerate(schemes.WINOGRAD_OUTPUT[blk]):
            w[7 + i] = coef
        got = model.decode_combine(jnp.asarray(w), p)
        np.testing.assert_allclose(got, want4[blk], rtol=2e-4, atol=2e-4)
