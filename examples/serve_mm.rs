//! End-to-end driver (the EXPERIMENTS.md e2e run): serve a stream of
//! multiply requests through the full stack — rust coordinator
//! dispatching encoded block products to 16 workers running the AOT
//! Pallas kernel through PJRT — with stragglers injected, and compare
//! latency/throughput against 2-copy replication AND against the
//! sequential depth-1 master (the multiplexed coordinator's win).
//!
//! Run (PJRT, needs `make artifacts`):
//!   cargo run --release --example serve_mm
//! Native fallback (no artifacts needed):
//!   cargo run --release --example serve_mm -- --backend native
//! Options: --jobs N --n N --p-straggle P --straggle-ms MS --p-e P
//!          --depth D (in-flight jobs, default 4)

use std::path::Path;
use std::time::Duration;

use ft_strassen::cli::Args;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::config::BackendKind;
use ft_strassen::coordinator::master::MasterConfig;
use ft_strassen::coordinator::server::{MmServer, ServerConfig, ServerReport};
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::runtime::service::ComputeService;

#[allow(clippy::too_many_arguments)]
fn run_scheme(
    name: &str,
    set: TaskSet,
    backend: Backend,
    jobs: usize,
    n: usize,
    fault: FaultPlan,
    seed: u64,
    depth: usize,
) -> ServerReport {
    let mut server = MmServer::new(
        set,
        backend,
        ServerConfig {
            master: MasterConfig {
                deadline: Duration::from_secs(10),
                fault,
                seed,
                fallback_local: true,
                collect_all: false,
            },
            queue_cap: 4096,
            inflight_depth: depth,
        },
    );
    let report = server.run_workload(jobs, n, seed).expect("workload");
    println!(
        "{:22} {:7.2} jobs/s   mean {:9.3?}  p95 {:9.3?}   decoded {}  fallback {}  mean-workers {:.1}",
        name,
        report.throughput_jobs_per_s,
        report.mean_latency,
        report.p95_latency,
        report.decoded,
        report.fell_back,
        report.mean_finished_workers
    );
    server.shutdown();
    report
}

fn main() {
    let args = Args::from_env(&[]).expect("args");
    let jobs = args.get_parsed_or("jobs", 64usize).expect("jobs");
    let n = args.get_parsed_or("n", 256usize).expect("n");
    let p_straggle = args.get_parsed_or("p-straggle", 0.15f64).expect("p-straggle");
    let straggle_ms = args.get_parsed_or("straggle-ms", 40u64).expect("straggle-ms");
    let p_e = args.get_parsed_or("p-e", 0.02f64).expect("p-e");
    let seed = args.get_parsed_or("seed", 1u64).expect("seed");
    let depth = args.get_parsed_or("depth", 4usize).expect("depth").max(1);
    let backend_kind = BackendKind::parse(args.get_or("backend", "pjrt")).expect("backend");

    let (backend, _svc) = match backend_kind {
        BackendKind::Native => (Backend::Native, None),
        BackendKind::Pjrt => {
            let dir = args.get_or("artifacts", "artifacts");
            match ComputeService::spawn(Path::new(dir), &[n / 2]) {
                Ok(svc) => {
                    println!("pjrt backend: {}", svc.handle().platform().unwrap());
                    (Backend::Pjrt(svc.handle()), Some(svc))
                }
                Err(e) => {
                    println!("pjrt unavailable ({e}); falling back to native backend");
                    (Backend::Native, None)
                }
            }
        }
    };

    let fault = FaultPlan {
        p_fail: p_e,
        p_straggle,
        delay: Duration::from_millis(straggle_ms),
    };
    println!(
        "serving {jobs} jobs of {n}x{n} f32 multiply at depth {depth}; faults: \
         p_fail={p_e}, p_straggle={p_straggle} ({straggle_ms}ms)\n"
    );

    let r_sw2 = run_scheme(
        "S+W + 2 PSMM (16)",
        TaskSet::strassen_winograd(2),
        backend.clone(),
        jobs,
        n,
        fault,
        seed,
        depth,
    );
    let r_rep2 = run_scheme(
        "Strassen x2 (14)",
        TaskSet::replication(&ft_strassen::algorithms::strassen(), 2),
        backend.clone(),
        jobs,
        n,
        fault,
        seed,
        depth,
    );
    let r_rep3 = run_scheme(
        "Strassen x3 (21)",
        TaskSet::replication(&ft_strassen::algorithms::strassen(), 3),
        backend.clone(),
        jobs,
        n,
        fault,
        seed,
        depth,
    );
    // The multiplexing win: the same scheme served sequentially (only
    // worth running when the main runs were actually multiplexed).
    let r_seq = if depth > 1 {
        Some(run_scheme(
            "S+W + 2 PSMM depth=1",
            TaskSet::strassen_winograd(2),
            backend,
            jobs,
            n,
            fault,
            seed,
            1,
        ))
    } else {
        None
    };

    println!("\nsummary:");
    println!(
        "  decode success: S+W+2PSMM {}/{jobs}, x2 {}/{jobs}, x3 {}/{jobs}",
        r_sw2.decoded, r_rep2.decoded, r_rep3.decoded
    );
    println!(
        "  S+W+2PSMM achieves x3-class decode rates with 16 vs 21 nodes (-24%),\n  \
         and beats x2 at equal node count class (paper's claim)."
    );
    if let Some(r_seq) = r_seq {
        println!(
            "  multiplexing: depth {depth} serves {:.2} jobs/s vs {:.2} sequential ({:.2}x)",
            r_sw2.throughput_jobs_per_s,
            r_seq.throughput_jobs_per_s,
            r_sw2.throughput_jobs_per_s / r_seq.throughput_jobs_per_s.max(1e-9)
        );
    }
}
