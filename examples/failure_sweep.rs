//! Regenerates Fig. 2: reconstruction-failure probability vs node
//! failure probability for all six schemes — analytically (eqs. (9)/(10)
//! + computed FC(k)) and by Monte Carlo — plus the paper's headline
//! comparison (16-node S+W+2PSMM vs 21-node 3-copy Strassen) and the
//! shifted-exponential straggler extension (`--latency`).
//!
//! Run: `cargo run --release --example failure_sweep [-- --trials 200000 --latency]`

use ft_strassen::bench::plot::{ascii_loglog, Series};
use ft_strassen::cli::Args;
use ft_strassen::coding::fc::{fc_table, DecodeOracle};
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::failure_probability;
use ft_strassen::sim::latency::LatencyModel;
use ft_strassen::sim::montecarlo::MonteCarlo;

fn pe_grid(points: usize) -> Vec<f64> {
    let (lo, hi) = (5e-3f64.ln(), 0.5f64.ln());
    (0..points)
        .map(|i| (lo + (hi - lo) * i as f64 / (points - 1) as f64).exp())
        .collect()
}

fn main() {
    let args = Args::from_env(&["latency"]).expect("args");
    let trials = args.get_parsed_or("trials", 200_000u64).expect("trials");
    let points = args.get_parsed_or("points", 9usize).expect("points");
    let seed = args.get_parsed_or("seed", 1u64).expect("seed");

    let schemes = TaskSet::fig2_schemes();
    let grid = pe_grid(points);

    println!("=== Fig. 2: P_f vs p_e (theory | Monte Carlo, {trials} trials) ===\n");
    let mut series = Vec::new();
    for ts in &schemes {
        let fc = fc_table(ts);
        let oracle = DecodeOracle::build(ts);
        println!("{} (M = {} nodes):", ts.name, ts.num_tasks());
        let mut pts = Vec::new();
        for &p in &grid {
            let theory = failure_probability(&fc, p);
            let mc = MonteCarlo::new(trials, seed)
                .failure_probability(p, ts.num_tasks(), |m| oracle.is_decodable(m));
            let sigmas = if mc.std_err > 0.0 {
                (mc.mean - theory).abs() / mc.std_err
            } else {
                0.0
            };
            println!(
                "  p_e={p:7.4}  theory={theory:.4e}  mc={:.4e} (±{:.1e}, {:.1}σ)",
                mc.mean, mc.std_err, sigmas
            );
            pts.push((p, theory));
        }
        series.push(Series::new(ts.name.clone(), pts));
        println!();
    }
    println!("{}", ascii_loglog(&series, 72, 24));

    // Headline: proposed 16-node vs 21-node 3-copy.
    let sw2 = fc_table(&TaskSet::strassen_winograd(2));
    let s3 = fc_table(&schemes[5]);
    println!("=== headline (paper §IV) ===");
    println!("nodes: S+W+2PSMM = {}, Strassen x3 = {} (-24%)", sw2.m, s3.m);
    for p in [0.01, 0.05, 0.1, 0.2] {
        let a = failure_probability(&sw2, p);
        let b = failure_probability(&s3, p);
        println!("  p_e={p:5.2}: P_f(S+W+2) = {a:.3e}, P_f(Sx3) = {b:.3e}, ratio {:.2}", a / b);
    }

    if args.flag("latency") {
        println!("\n=== straggler extension (paper §V future work) ===");
        println!("shifted-exponential completion times (shift 1.0, rate 1.0):");
        let model = LatencyModel::ShiftedExp { shift: 1.0, rate: 1.0 };
        let mc = MonteCarlo::new(trials.min(50_000), seed);
        for ts in &schemes {
            let oracle = DecodeOracle::build(ts);
            let est = mc.mean_completion_time(&model, ts.num_tasks(), |finished| {
                let failed = !finished & ((1u64 << ts.num_tasks()) - 1);
                oracle.is_decodable(failed)
            });
            println!(
                "  {:16} mean time-to-decode = {:.4} (±{:.4}) over {} nodes",
                ts.name, est.mean, est.std_err, ts.num_tasks()
            );
        }
    }
}
