//! Quickstart: one fault-tolerant multiply with the paper's full
//! configuration (Strassen + Winograd + 2 PSMMs, 16 worker nodes), with
//! nodes randomly killed and straggling — and the answer still exact.
//!
//! Run: `cargo run --release --example quickstart`
//! (uses the native backend so it works before `make artifacts`).

use std::time::Duration;

use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coordinator::master::{Master, MasterConfig};
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::sim::rng::Rng;

fn main() {
    let n = 256;
    let mut rng = Rng::seeded(42);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);

    // The paper's proposed 16-node configuration.
    let scheme = TaskSet::strassen_winograd(2);
    println!(
        "scheme: {} ({} worker nodes; 3-copy replication would need 21)",
        scheme.name,
        scheme.num_tasks()
    );

    let mut master = Master::new(
        scheme,
        Backend::Native,
        MasterConfig {
            deadline: Duration::from_secs(5),
            // Every dispatch: 12% chance a node dies, 20% it straggles.
            fault: FaultPlan {
                p_fail: 0.12,
                p_straggle: 0.20,
                delay: Duration::from_millis(200),
            },
            seed: 7,
            fallback_local: true,
            collect_all: false,
        },
    );

    for job in 0..4 {
        let (c, report) = master.multiply(&a, &b).expect("multiply");
        let want = a.matmul(&b);
        println!(
            "job {job}: {:?} total, decodable after {:?}; used {}/{} workers \
             (killed {}, straggling {}), fell_back={}, rel_err={:.2e}",
            report.elapsed,
            report.time_to_decodable,
            report.finished,
            report.dispatched,
            report.injected_failures,
            report.injected_stragglers,
            report.fell_back,
            c.rel_error(&want),
        );
        assert!(c.approx_eq(&want, 1e-3), "decode must be exact");
    }

    println!("\nmaster metrics:\n{}", master.metrics.snapshot());
    master.shutdown();
}
