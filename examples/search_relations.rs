//! Regenerates the paper's search artifacts:
//! * eqs. (1)–(8) and the full local-relation enumeration ("52
//!   independent relations", §IV),
//! * Table II (the additional C11 relations),
//! * the PSMM selection (PSMM-1 = M21(B12−B22) = S3+W4, PSMM-2 = copy of
//!   W2 — §IV).
//!
//! Run: `cargo run --release --example search_relations [-- --max-k 8]`

use ft_strassen::algebra::form::{BilinearForm, Target};
use ft_strassen::cli::Args;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::search::psmm::{select_psmms, uncoverable_pairs};
use ft_strassen::search::relations::{independent_rank, relations_for_target, weight_histogram};
use ft_strassen::search::searchlp::{search_lp, SearchOptions};

fn main() {
    let args = Args::from_env(&[]).expect("args");
    let max_k = args.get_parsed_or("max-k", 8usize).expect("max-k");

    let ts = TaskSet::strassen_winograd(0);
    let names = ts.names();
    let forms = ts.forms();

    let t0 = std::time::Instant::now();
    let res = search_lp(&forms, &SearchOptions { max_k, ..Default::default() });
    let elapsed = t0.elapsed();

    println!("=== Algorithm 1 over S1..S7 ∪ W1..W7 (K <= {max_k}) ===");
    println!(
        "{} local relations, {} parity candidates, search time {elapsed:?}",
        res.num_relations(),
        res.parities.len()
    );
    println!(
        "linear rank of the relation set: {} (= 18 symbols - joint form rank 10)",
        independent_rank(&res.relations, forms.len())
    );
    // The paper reports "52 independent relations"; enumeration counts
    // depend on the K bound and the minimality convention — print both.
    for k in [6usize, 7, 8] {
        let min = search_lp(
            &forms,
            &SearchOptions { max_k: k, minimal_only: true, collect_parities: false },
        )
        .num_relations();
        let all = search_lp(
            &forms,
            &SearchOptions { max_k: k, minimal_only: false, collect_parities: false },
        )
        .num_relations();
        println!("  K<={k}: {min} minimal relations, {all} unfiltered");
    }
    let hist = weight_histogram(&res.relations, max_k);
    print!("relations by weight:");
    for (w, c) in hist.iter().enumerate().filter(|(_, &c)| c > 0) {
        print!(" k={w}:{c}");
    }
    println!("\n");

    println!("--- paper eqs. (1)-(4) (within one algorithm) ---");
    for t in Target::ALL {
        let single = search_lp(
            &TaskSet::replication(&ft_strassen::algorithms::strassen(), 1).forms(),
            &SearchOptions::default(),
        );
        for r in single.for_target(t) {
            println!("  {}", r.render(&["S1", "S2", "S3", "S4", "S5", "S6", "S7"]));
        }
    }

    println!("\n--- Table II: all local relations for C11 (joint set) ---");
    for r in relations_for_target(&res, Target::C11) {
        println!("  {}", r.render(&names));
    }

    println!("\n--- uncoverable failure pairs without PSMMs (§IV) ---");
    for (i, j) in uncoverable_pairs(&forms) {
        println!("  ({}, {})", names[i], names[j]);
    }

    println!("\n--- PSMM selection (greedy over Algorithm 1 parities) ---");
    let psmms = select_psmms(&forms, 2, &SearchOptions::default());
    for (i, p) in psmms.iter().enumerate() {
        println!("  greedy PSMM-{}: {}", i + 1, p.render(&forms, &names));
    }
    // The paper's exact choices (used by TaskSet::strassen_winograd):
    let paper_p1 = BilinearForm::from_uv(&[0, 0, 1, 0], &[0, 1, 0, -1]);
    let paper_p2 = BilinearForm::from_uv(&[0, 1, 0, 0], &[0, 0, 1, 0]);
    println!("  paper  PSMM-1: {paper_p1}  (= S3 + W4)");
    println!("  paper  PSMM-2: {paper_p2}  (= copy of W2)");

    // Both the greedy's and the paper's PSMM-1 repair (S3, W5): verify.
    let repairs = |f: BilinearForm, i: usize, j: usize| {
        let mut ext = forms.clone();
        ext.push(f);
        let n = ext.len();
        ft_strassen::search::psmm::decodable(&ext, (0..n).filter(|&k| k != i && k != j))
    };
    assert!(repairs(paper_p1, 2, 11), "paper PSMM-1 repairs (S3, W5)");
    assert!(repairs(psmms[0].form(&forms), 2, 11), "greedy PSMM-1 repairs (S3, W5)");
    println!(
        "\nboth PSMM-1 choices repair the (S3, W5) pair ✓ \
         (the paper's choice is pinned in TaskSet::strassen_winograd)"
    );
}
