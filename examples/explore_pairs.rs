//! §V exploration: can a different Strassen-like partner beat the
//! published Strassen+Winograd pairing?
//!
//! Samples validity-preserving variants of Winograd (sign flips, product
//! permutations, operand swaps — all Brent-verified) and scores each
//! joint 14-node configuration by fatal pair/triple counts; prints the
//! distribution and the best finds.
//!
//! Run: `cargo run --release --example explore_pairs [-- --samples 200 --seed 1]`

use std::collections::BTreeMap;

use ft_strassen::algorithms::{strassen, winograd};
use ft_strassen::cli::Args;
use ft_strassen::search::pair_explorer::explore;
use ft_strassen::sim::rng::Rng;

fn main() {
    let args = Args::from_env(&[]).expect("args");
    let samples = args.get_parsed_or("samples", 200usize).expect("samples");
    let seed = args.get_parsed_or("seed", 1u64).expect("seed");
    let mut rng = Rng::seeded(seed);

    let t0 = std::time::Instant::now();
    let (published, all) = explore(&strassen(), &winograd(), samples, &mut rng);
    println!(
        "explored {samples} Winograd variants against fixed Strassen in {:?}\n",
        t0.elapsed()
    );
    println!(
        "published pair: FC(2)={} FC(3)={} joint-rank={}",
        published.score.fatal_pairs, published.score.fatal_triples, published.joint_rank
    );

    let mut histo: BTreeMap<(u64, u64), usize> = BTreeMap::new();
    for c in &all {
        *histo.entry((c.score.fatal_pairs, c.score.fatal_triples)).or_default() += 1;
    }
    println!("\nscore distribution over sampled variants (FC2, FC3) -> count:");
    for ((f2, f3), count) in &histo {
        println!("  FC(2)={f2:2} FC(3)={f3:3}  x{count}");
    }

    let best = &all[0];
    println!(
        "\nbest sampled: FC(2)={} FC(3)={} joint-rank={}",
        best.score.fatal_pairs, best.score.fatal_triples, best.joint_rank
    );
    if best.score < published.score {
        println!("-> found a pairing strictly better than the published one!");
        for (i, p) in best.partner.products.iter().enumerate() {
            println!("   W'{} : u={:?} v={:?}", i + 1, p.u, p.v);
        }
    } else {
        println!(
            "-> no sampled symmetry-variant beats the published pairing; \
             consistent with the paper leaving better pairs to future work \
             (a strictly better partner needs a genuinely different 7-mult \
             algorithm, not a symmetry image)."
        );
    }
}
