//! ASCII log-log plotting for terminal rendering of the paper's Fig. 2
//! (P_f vs p_e curves) and other sweeps.

/// One named curve.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    /// (x, y) points; non-positive values are dropped in log scale.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Series { name: name.into(), points }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render series on a log-log grid of `width` x `height` characters.
pub fn ascii_loglog(series: &[Series], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|&(x, y)| x > 0.0 && y > 0.0)
        .collect();
    if pts.is_empty() {
        return "(no positive data to plot)\n".to_string();
    }
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    let (lx0, lx1) = (x0.log10(), x1.log10());
    let (ly0, ly1) = (y0.log10(), y1.log10());
    let sx = if lx1 > lx0 { (width - 1) as f64 / (lx1 - lx0) } else { 0.0 };
    let sy = if ly1 > ly0 { (height - 1) as f64 / (ly1 - ly0) } else { 0.0 };

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let cx = ((x.log10() - lx0) * sx).round() as usize;
            let cy = ((y.log10() - ly0) * sy).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("y: {y0:.2e} .. {y1:.2e} (log)\n"));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("x: {x0:.2e} .. {x1:.2e} (log)\n"));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_marks_and_legend() {
        let s = vec![
            Series::new("a", vec![(0.01, 0.1), (0.1, 0.5)]),
            Series::new("b", vec![(0.01, 0.001), (0.1, 0.01)]),
        ];
        let plot = ascii_loglog(&s, 40, 10);
        assert!(plot.contains('*'));
        assert!(plot.contains('o'));
        assert!(plot.contains("a\n"));
        assert!(plot.contains("b\n"));
    }

    #[test]
    fn empty_data_is_graceful() {
        let s = vec![Series::new("empty", vec![(0.0, 0.0)])];
        assert!(ascii_loglog(&s, 20, 5).contains("no positive data"));
    }

    #[test]
    fn monotone_curve_renders_monotone() {
        // Visual invariant: for a strictly increasing curve, the topmost
        // mark is at the rightmost column.
        let pts: Vec<(f64, f64)> = (1..=10).map(|i| (i as f64, (i * i) as f64)).collect();
        let plot = ascii_loglog(&[Series::new("sq", pts)], 30, 12);
        let lines: Vec<&str> = plot.lines().collect();
        // first grid line (top) should contain the mark near the right edge
        let top = lines[1];
        let pos = top.rfind('*').expect("top row should contain a point");
        assert!(pos > 20, "top point at col {pos}");
    }
}
