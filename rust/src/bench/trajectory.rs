//! Append-only JSON-array trajectory files at the repo root
//! (`BENCH_e2e.json`, `BENCH_kernel.json`, `BENCH_recursive.json`,
//! `BENCH_serve.json`, `BENCH_sim.json`):
//! one entry per recorded bench run, so the perf trajectory is
//! trackable across PRs.
//!
//! The file format is a plain JSON array of objects. [`append_entry`]
//! splices a new entry before the closing bracket (starting a fresh
//! array for a missing or malformed file), and
//! [`append_to_repo_root`] resolves the repo root from the crate
//! manifest directory — independent of the bench binary's working
//! directory, which is what previously made `BENCH_e2e.json` land
//! nowhere when benches ran from an unexpected cwd.

use std::path::{Path, PathBuf};

/// The repository root (`rust/..`), resolved at compile time from the
/// crate's manifest directory and canonicalized when possible.
pub fn repo_root() -> PathBuf {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    root.canonicalize().unwrap_or(root)
}

/// Splice `entry` (one JSON object, no trailing newline needed) into
/// the JSON array at `path`, creating the file as `[entry]` when it is
/// missing, empty, or malformed.
pub fn append_entry(path: &Path, entry: &str) -> std::io::Result<()> {
    let entry = entry.trim();
    let body = match std::fs::read_to_string(path) {
        Ok(existing) => {
            let trimmed = existing.trim_end();
            match trimmed.strip_suffix(']') {
                Some(head) if head.trim_end().is_empty() || head.trim_end().ends_with('[') => {
                    format!("[\n{entry}\n]\n")
                }
                Some(head) => format!("{},\n{entry}\n]\n", head.trim_end()),
                None => format!("[\n{entry}\n]\n"), // malformed: start over
            }
        }
        Err(_) => format!("[\n{entry}\n]\n"),
    };
    std::fs::write(path, body)
}

/// [`append_entry`] into `<repo root>/<file_name>`; returns the path
/// written so the bench can print where the trajectory landed.
pub fn append_to_repo_root(file_name: &str, entry: &str) -> std::io::Result<PathBuf> {
    let path = repo_root().join(file_name);
    append_entry(&path, entry)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ftms_traj_{}_{name}", std::process::id()))
    }

    #[test]
    fn append_builds_a_growing_json_array() {
        let path = tmp("grow.json");
        let _ = std::fs::remove_file(&path);
        append_entry(&path, "{\"a\": 1}").unwrap();
        let one = std::fs::read_to_string(&path).unwrap();
        assert_eq!(one.trim(), "[\n{\"a\": 1}\n]");
        append_entry(&path, "{\"b\": 2}").unwrap();
        let two = std::fs::read_to_string(&path).unwrap();
        assert!(two.contains("{\"a\": 1},"), "{two}");
        assert!(two.contains("{\"b\": 2}"), "{two}");
        assert_eq!(two.matches('{').count(), 2);
        assert!(two.trim_end().ends_with(']'));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn append_recovers_from_empty_and_malformed_files() {
        let path = tmp("recover.json");
        for seed in ["", "[]", "[\n]", "not json at all"] {
            std::fs::write(&path, seed).unwrap();
            append_entry(&path, "{\"x\": 1}").unwrap();
            let body = std::fs::read_to_string(&path).unwrap();
            assert_eq!(body.trim(), "[\n{\"x\": 1}\n]", "seed {seed:?}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn repo_root_contains_the_rust_crate() {
        assert!(repo_root().join("rust").join("Cargo.toml").exists());
    }
}
