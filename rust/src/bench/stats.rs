//! Robust summary statistics over timing samples.

use std::time::Duration;

/// Summary of a sample of durations.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl Stats {
    /// Compute from raw samples (sorted internally).
    pub fn from_samples(samples: &[Duration]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let mut s: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| -> Duration {
            let idx = ((n - 1) as f64 * p).floor() as usize;
            Duration::from_secs_f64(s[idx])
        };
        Stats {
            n,
            mean: Duration::from_secs_f64(mean),
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(s[0]),
            p50: q(0.5),
            p95: q(0.95),
            max: Duration::from_secs_f64(s[n - 1]),
        }
    }

    /// Throughput in ops/sec given ops per iteration.
    pub fn throughput(&self, ops_per_iter: f64) -> f64 {
        ops_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3?} ±{:.3?} min={:.3?} p50={:.3?} p95={:.3?} max={:.3?}",
            self.n, self.mean, self.std_dev, self.min, self.p50, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples(&[Duration::from_millis(5); 10]);
        assert_eq!(s.n, 10);
        assert_eq!(s.mean, Duration::from_millis(5));
        assert_eq!(s.min, s.max);
        assert_eq!(s.std_dev, Duration::ZERO);
    }

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<Duration> =
            (1..=100).map(|i| Duration::from_micros(i)).collect();
        let s = Stats::from_samples(&samples);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
        assert_eq!(s.p50, Duration::from_micros(50));
        assert_eq!(s.max, Duration::from_micros(100));
    }

    #[test]
    fn throughput() {
        let s = Stats::from_samples(&[Duration::from_secs(1)]);
        assert!((s.throughput(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn empty_panics() {
        let _ = Stats::from_samples(&[]);
    }
}
