//! Benchmark harness (offline substitute for `criterion`): auto-tuned
//! iteration counts, warmup, robust statistics, CSV output and ASCII
//! plots for the paper-figure benches.

pub mod harness;
pub mod plot;
pub mod schema;
pub mod stats;
pub mod trajectory;

pub use harness::{BenchRunner, BenchSpec};
pub use plot::{ascii_loglog, Series};
pub use stats::Stats;
