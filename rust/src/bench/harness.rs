//! The measurement loop: warmup, auto-tuned batch size, per-sample
//! timing, CSV emission — criterion-style discipline without the crate.

use std::hint::black_box;
use std::io::Write;
use std::path::Path;
use std::time::{Duration, Instant};

use super::stats::Stats;

/// What to measure and how hard.
#[derive(Clone, Copy, Debug)]
pub struct BenchSpec {
    /// Wall-clock budget for warmup.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub measure: Duration,
    /// Number of recorded samples (iterations are batched to fill
    /// `measure` across exactly this many samples).
    pub samples: usize,
}

impl Default for BenchSpec {
    fn default() -> Self {
        BenchSpec {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            samples: 30,
        }
    }
}

impl BenchSpec {
    /// A faster profile for CI/smoke runs.
    pub fn quick() -> Self {
        BenchSpec {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            samples: 10,
        }
    }
}

/// One benchmark result row.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    /// Iterations per sample (after auto-tuning).
    pub iters_per_sample: u64,
}

/// Collects results and renders a report.
#[derive(Debug, Default)]
pub struct BenchRunner {
    pub results: Vec<BenchResult>,
    spec: BenchSpec,
}

impl BenchRunner {
    pub fn new(spec: BenchSpec) -> Self {
        BenchRunner { results: Vec::new(), spec }
    }

    /// Honor `FT_BENCH_QUICK=1` for smoke runs.
    pub fn from_env() -> Self {
        if std::env::var("FT_BENCH_QUICK").as_deref() == Ok("1") {
            BenchRunner::new(BenchSpec::quick())
        } else {
            BenchRunner::new(BenchSpec::default())
        }
    }

    /// Measure `f`, which performs ONE logical operation per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // Warmup + estimate cost.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.spec.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed() / warm_iters.max(1) as u32;
        // Pick batch so samples * batch * per_iter ≈ measure budget.
        let budget_per_sample = self.spec.measure / self.spec.samples as u32;
        let batch = (budget_per_sample.as_nanos() / per_iter.as_nanos().max(1))
            .clamp(1, 1_000_000) as u64;
        let mut samples = Vec::with_capacity(self.spec.samples);
        for _ in 0..self.spec.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed() / batch as u32);
        }
        let result = BenchResult {
            name: name.to_string(),
            stats: Stats::from_samples(&samples),
            iters_per_sample: batch,
        };
        println!("bench {:40} {}", result.name, result.stats);
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Measure `f` and prevent its result from being optimized away.
    pub fn bench_value<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench(name, || {
            black_box(f());
        })
    }

    /// Write the results as a JSON array (`[{"name": ..., "mean_ns":
    /// ...}, ...]`), the machine-readable companion of
    /// [`Self::write_csv`] for trajectory files tracked across PRs.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "[")?;
        for (i, r) in self.results.iter().enumerate() {
            let comma = if i + 1 == self.results.len() { "" } else { "," };
            writeln!(
                f,
                "  {{\"name\": \"{}\", \"mean_ns\": {}, \"std_ns\": {}, \"min_ns\": {}, \
                 \"p50_ns\": {}, \"p95_ns\": {}, \"max_ns\": {}, \"iters_per_sample\": {}}}{comma}",
                r.name.replace('"', "'"),
                r.stats.mean.as_nanos(),
                r.stats.std_dev.as_nanos(),
                r.stats.min.as_nanos(),
                r.stats.p50.as_nanos(),
                r.stats.p95.as_nanos(),
                r.stats.max.as_nanos(),
                r.iters_per_sample,
            )?;
        }
        writeln!(f, "]")?;
        Ok(())
    }

    /// Write `name,mean_ns,std_ns,min_ns,p50_ns,p95_ns,max_ns,iters` CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,mean_ns,std_ns,min_ns,p50_ns,p95_ns,max_ns,iters_per_sample")?;
        for r in &self.results {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{}",
                r.name,
                r.stats.mean.as_nanos(),
                r.stats.std_dev.as_nanos(),
                r.stats.min.as_nanos(),
                r.stats.p50.as_nanos(),
                r.stats.p95.as_nanos(),
                r.stats.max.as_nanos(),
                r.iters_per_sample,
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_runner() -> BenchRunner {
        BenchRunner::new(BenchSpec {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            samples: 5,
        })
    }

    #[test]
    fn bench_produces_sane_stats() {
        let mut r = quick_runner();
        let res = r.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(res.stats.mean < Duration::from_micros(100));
        assert_eq!(res.stats.n, 5);
    }

    #[test]
    fn bench_value_keeps_result_alive() {
        let mut r = quick_runner();
        let res = r.bench_value("sum", || (0..100u64).sum::<u64>());
        assert!(res.iters_per_sample >= 1);
    }

    #[test]
    fn json_output_is_wellformed_array() {
        let mut r = quick_runner();
        r.bench("a", || {});
        r.bench("b", || {});
        let path = std::env::temp_dir().join("ft_strassen_bench_test.json");
        r.write_json(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.trim_start().starts_with('['));
        assert!(content.trim_end().ends_with(']'));
        assert!(content.contains("\"name\": \"a\""));
        assert!(content.contains("\"mean_ns\""));
        assert_eq!(content.matches('{').count(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_roundtrip() {
        let mut r = quick_runner();
        r.bench("a", || {});
        let path = std::env::temp_dir().join("ft_strassen_bench_test.csv");
        r.write_csv(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.starts_with("name,mean_ns"));
        assert!(content.contains("a,"));
        let _ = std::fs::remove_file(&path);
    }
}
