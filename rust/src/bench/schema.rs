//! Trajectory-entry schemas for the `BENCH_*.json` files — builders the
//! benches render entries with, plus a minimal JSON reader that the
//! schema unit tests round-trip every entry through.
//!
//! The benches are `harness = false` binaries, so inline `format!`
//! strings there are untestable: a typo (missing quote, trailing comma)
//! would corrupt the repo-root trajectory arrays silently. Each entry
//! kind therefore lives here as a struct with a `render()` method —
//! the single source of the schema documented in README "Benchmark
//! trajectories" — and the tests parse rendered entries back and check
//! every required key, after appending through
//! [`super::trajectory::append_entry`] exactly like the benches do.

use std::fmt::Write as _;

// ---------------------------------------------------------------------
// Entry builders
// ---------------------------------------------------------------------

/// One depth point of the e2e throughput sweep.
#[derive(Clone, Copy, Debug)]
pub struct DepthPoint {
    pub depth: usize,
    pub jobs_per_s: f64,
    pub mean_ns: u128,
    pub p95_ns: u128,
}

/// One `BENCH_e2e.json` entry: a depth sweep of the multiplexed
/// scheduler under the given fault parameters.
#[derive(Clone, Debug)]
pub struct E2eEntry {
    pub unix_time: u64,
    pub scheme: String,
    pub n: usize,
    pub jobs: usize,
    pub p_fail: f64,
    pub p_straggle: f64,
    pub delay_ms: u128,
    pub quick: bool,
    pub speedup_depth4_vs_1: f64,
    pub decode_clones_per_solve: u64,
    pub depths: Vec<DepthPoint>,
}

impl E2eEntry {
    pub fn render(&self) -> String {
        let depth_objs: Vec<String> = self
            .depths
            .iter()
            .map(|d| {
                format!(
                    "{{\"depth\": {}, \"jobs_per_s\": {:.3}, \"mean_ns\": {}, \"p95_ns\": {}}}",
                    d.depth, d.jobs_per_s, d.mean_ns, d.p95_ns
                )
            })
            .collect();
        format!(
            "{{\"unix_time\": {}, \"scheme\": \"{}\", \"n\": {}, \
             \"jobs\": {}, \"p_fail\": {}, \"p_straggle\": {}, \"delay_ms\": {}, \
             \"quick\": {}, \"speedup_depth4_vs_1\": {:.3}, \
             \"decode_clones_per_solve\": {}, \"depths\": [{}]}}",
            self.unix_time,
            self.scheme,
            self.n,
            self.jobs,
            self.p_fail,
            self.p_straggle,
            self.delay_ms,
            self.quick,
            self.speedup_depth4_vs_1,
            self.decode_clones_per_solve,
            depth_objs.join(", ")
        )
    }
}

/// One size row of the kernel bench (`BENCH_kernel.json` `sizes[]`).
#[derive(Clone, Copy, Debug)]
pub struct KernelSizeRow {
    pub n: usize,
    pub naive_ns: u128,
    pub packed_ns: u128,
    pub packed_mt_ns: u128,
}

/// One `BENCH_kernel.json` entry.
#[derive(Clone, Debug)]
pub struct KernelEntry {
    pub unix_time: u64,
    pub quick: bool,
    pub threads_mt: usize,
    pub encode_clones: u64,
    pub sizes: Vec<KernelSizeRow>,
}

impl KernelEntry {
    pub fn render(&self) -> String {
        let size_objs: Vec<String> = self
            .sizes
            .iter()
            .map(|r| {
                format!(
                    "{{\"n\": {}, \"naive_ns\": {}, \"packed_ns\": {}, \"packed_mt_ns\": {}, \
                     \"speedup_packed\": {:.3}, \"speedup_packed_mt\": {:.3}}}",
                    r.n,
                    r.naive_ns,
                    r.packed_ns,
                    r.packed_mt_ns,
                    r.naive_ns as f64 / r.packed_ns.max(1) as f64,
                    r.naive_ns as f64 / r.packed_mt_ns.max(1) as f64,
                )
            })
            .collect();
        format!(
            "{{\"unix_time\": {}, \"quick\": {}, \"threads_mt\": {}, \
             \"encode_clones\": {}, \"sizes\": [{}]}}",
            self.unix_time,
            self.quick,
            self.threads_mt,
            self.encode_clones,
            size_objs.join(", ")
        )
    }
}

/// One crossover point of the recursive sweep.
#[derive(Clone, Copy, Debug)]
pub struct CrossoverPoint {
    pub crossover: usize,
    pub rec_ns: u128,
    pub speedup: f64,
}

/// One matrix-size row of the recursive sweep
/// (`BENCH_recursive.json` `sweep[]`).
#[derive(Clone, Debug)]
pub struct RecursiveSweepRow {
    pub n: usize,
    pub flat_ns: u128,
    pub best_crossover: usize,
    pub points: Vec<CrossoverPoint>,
}

/// One `BENCH_recursive.json` entry.
#[derive(Clone, Debug)]
pub struct RecursiveEntry {
    pub unix_time: u64,
    pub quick: bool,
    pub kernel: String,
    pub sweep: Vec<RecursiveSweepRow>,
}

impl RecursiveEntry {
    pub fn render(&self) -> String {
        let sweep_objs: Vec<String> = self
            .sweep
            .iter()
            .map(|row| {
                let points: Vec<String> = row
                    .points
                    .iter()
                    .map(|p| {
                        format!(
                            "{{\"crossover\": {}, \"rec_ns\": {}, \"speedup\": {:.3}}}",
                            p.crossover, p.rec_ns, p.speedup
                        )
                    })
                    .collect();
                format!(
                    "{{\"n\": {}, \"flat_ns\": {}, \"best_crossover\": {}, \
                     \"points\": [{}]}}",
                    row.n,
                    row.flat_ns,
                    row.best_crossover,
                    points.join(", ")
                )
            })
            .collect();
        format!(
            "{{\"unix_time\": {}, \"quick\": {}, \"kernel\": \"{}\", \
             \"sweep\": [{}]}}",
            self.unix_time,
            self.quick,
            self.kernel,
            sweep_objs.join(", ")
        )
    }
}

/// One cell of the multi-tenant serving sweep
/// (`BENCH_serve.json` `cells[]`): a (tenant layout, batch window,
/// cache capacity) point.
#[derive(Clone, Copy, Debug)]
pub struct ServeCell {
    pub tenants: usize,
    pub batch_window: usize,
    pub cache_cap: usize,
    pub jobs_per_s: f64,
    pub mean_ns: u128,
    pub p95_ns: u128,
    /// cache_hits / (cache_hits + cache_misses); 0 when the cache is off.
    pub cache_hit_rate: f64,
    pub fell_back: usize,
}

/// One `BENCH_serve.json` entry: the serving-tier sweep
/// (tenants × batch window × cache on/off) under stragglers.
#[derive(Clone, Debug)]
pub struct ServeEntry {
    pub unix_time: u64,
    pub scheme: String,
    pub n: usize,
    pub jobs: usize,
    pub p_straggle: f64,
    pub delay_ms: u128,
    pub quick: bool,
    /// Order-independent logical-trace digest of a traced run
    /// ([`crate::obs::logical_digest`]); omitted when the run was not
    /// traced. Rendered as a hex string: the reader's f64 numbers
    /// cannot carry 64 bits losslessly.
    pub trace_digest: Option<u64>,
    pub cells: Vec<ServeCell>,
}

impl ServeEntry {
    pub fn render(&self) -> String {
        let cell_objs: Vec<String> = self
            .cells
            .iter()
            .map(|c| {
                format!(
                    "{{\"tenants\": {}, \"batch_window\": {}, \"cache_cap\": {}, \
                     \"jobs_per_s\": {:.3}, \"mean_ns\": {}, \"p95_ns\": {}, \
                     \"cache_hit_rate\": {:.3}, \"fell_back\": {}}}",
                    c.tenants,
                    c.batch_window,
                    c.cache_cap,
                    c.jobs_per_s,
                    c.mean_ns,
                    c.p95_ns,
                    c.cache_hit_rate,
                    c.fell_back
                )
            })
            .collect();
        format!(
            "{{\"unix_time\": {}, \"scheme\": \"{}\", \"n\": {}, \"jobs\": {}, \
             \"p_straggle\": {}, \"delay_ms\": {}, \"quick\": {}, {}\"cells\": [{}]}}",
            self.unix_time,
            self.scheme,
            self.n,
            self.jobs,
            self.p_straggle,
            self.delay_ms,
            self.quick,
            render_trace_digest(self.trace_digest),
            cell_objs.join(", ")
        )
    }
}

/// The optional `trace_digest` field (with its trailing separator), or
/// nothing when the run was untraced.
fn render_trace_digest(digest: Option<u64>) -> String {
    match digest {
        Some(d) => format!("\"trace_digest\": \"0x{d:016x}\", "),
        None => String::new(),
    }
}

/// One sweep point of the fleet simulation (`BENCH_sim.json` `cells[]`):
/// a (policy, p_e) cell of the discrete-event campaign.
#[derive(Clone, Copy, Debug)]
pub struct SimCell {
    pub p_e: f64,
    pub theory_pf: f64,
    pub measured_pf: f64,
    pub std_err: f64,
    pub mean_completion_s: f64,
    pub p95_completion_s: f64,
    pub backups: u64,
    pub network_bytes: u64,
}

/// One `BENCH_sim.json` entry: one scheduling policy swept over p_e on
/// a fixed fleet by the discrete-event simulator (`sim::des`).
#[derive(Clone, Debug)]
pub struct SimEntry {
    pub unix_time: u64,
    pub plan: String,
    pub policy: String,
    pub workers: usize,
    pub jobs: usize,
    pub seed: u64,
    pub quick: bool,
    /// See [`ServeEntry::trace_digest`] — hex string, omitted when the
    /// campaign was not traced.
    pub trace_digest: Option<u64>,
    pub cells: Vec<SimCell>,
}

impl SimEntry {
    pub fn render(&self) -> String {
        let cell_objs: Vec<String> = self
            .cells
            .iter()
            .map(|c| {
                format!(
                    "{{\"p_e\": {}, \"theory_pf\": {:.6e}, \"measured_pf\": {:.6}, \
                     \"std_err\": {:.6}, \"mean_completion_s\": {:.6}, \
                     \"p95_completion_s\": {:.6}, \"backups\": {}, \"network_bytes\": {}}}",
                    c.p_e,
                    c.theory_pf,
                    c.measured_pf,
                    c.std_err,
                    c.mean_completion_s,
                    c.p95_completion_s,
                    c.backups,
                    c.network_bytes
                )
            })
            .collect();
        format!(
            "{{\"unix_time\": {}, \"plan\": \"{}\", \"policy\": \"{}\", \
             \"workers\": {}, \"jobs\": {}, \"seed\": {}, \"quick\": {}, \
             {}\"cells\": [{}]}}",
            self.unix_time,
            self.plan,
            self.policy,
            self.workers,
            self.jobs,
            self.seed,
            self.quick,
            render_trace_digest(self.trace_digest),
            cell_objs.join(", ")
        )
    }
}

// ---------------------------------------------------------------------
// Minimal JSON reader (round-trip checking; no external deps)
// ---------------------------------------------------------------------

/// A parsed JSON value — just enough structure to verify the
/// trajectory files (objects keep insertion order; numbers are f64).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing garbage is an error.
pub fn parse_json(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key must be a string, got {other:?}")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        // The trajectory entries never need more than
                        // the simple escapes.
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            other => return Err(format!("unsupported escape {other:?}")),
                        }
                        *pos += 1;
                    }
                    Some(&c) => {
                        s.push(c as char);
                        *pos += 1;
                    }
                }
            }
        }
        Some(b't') if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        Some(b'f') if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        Some(b'n') if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let tok = &text_slice(b, start, *pos);
            tok.parse::<f64>()
                .map(Json::Num)
                .map_err(|e| format!("bad number {tok:?} at byte {start}: {e}"))
        }
    }
}

fn text_slice(b: &[u8], start: usize, end: usize) -> String {
    String::from_utf8_lossy(&b[start..end]).into_owned()
}

/// Parse a trajectory file and check that every entry is an object
/// carrying all of `required` as top-level keys. Returns the parsed
/// entries.
pub fn validate_trajectory(text: &str, required: &[&str]) -> Result<Vec<Json>, String> {
    let doc = parse_json(text)?;
    let entries = doc.as_arr().ok_or("trajectory root must be a JSON array")?;
    for (i, e) in entries.iter().enumerate() {
        if !matches!(e, Json::Obj(_)) {
            return Err(format!("entry {i} is not an object"));
        }
        let mut missing = String::new();
        for k in required {
            if e.get(k).is_none() {
                let _ = write!(missing, " {k}");
            }
        }
        if !missing.is_empty() {
            return Err(format!("entry {i} missing keys:{missing}"));
        }
    }
    Ok(entries.to_vec())
}

/// Required top-level keys of each trajectory file.
pub const E2E_KEYS: &[&str] = &[
    "unix_time",
    "scheme",
    "n",
    "jobs",
    "p_fail",
    "p_straggle",
    "delay_ms",
    "quick",
    "speedup_depth4_vs_1",
    "decode_clones_per_solve",
    "depths",
];
pub const KERNEL_KEYS: &[&str] =
    &["unix_time", "quick", "threads_mt", "encode_clones", "sizes"];
pub const RECURSIVE_KEYS: &[&str] = &["unix_time", "quick", "kernel", "sweep"];
pub const SERVE_KEYS: &[&str] = &[
    "unix_time",
    "scheme",
    "n",
    "jobs",
    "p_straggle",
    "delay_ms",
    "quick",
    "cells",
];
pub const SIM_KEYS: &[&str] = &[
    "unix_time",
    "plan",
    "policy",
    "workers",
    "jobs",
    "seed",
    "quick",
    "cells",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::trajectory::append_entry;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ftms_schema_{}_{name}", std::process::id()))
    }

    fn sample_e2e() -> E2eEntry {
        E2eEntry {
            unix_time: 1,
            scheme: "sw+2psmm".into(),
            n: 64,
            jobs: 24,
            p_fail: 0.05,
            p_straggle: 0.2,
            delay_ms: 3,
            quick: true,
            speedup_depth4_vs_1: 2.131,
            decode_clones_per_solve: 0,
            depths: vec![
                DepthPoint { depth: 1, jobs_per_s: 10.0, mean_ns: 5000, p95_ns: 9000 },
                DepthPoint { depth: 4, jobs_per_s: 21.3, mean_ns: 2300, p95_ns: 4100 },
            ],
        }
    }

    fn sample_kernel() -> KernelEntry {
        KernelEntry {
            unix_time: 2,
            quick: false,
            threads_mt: 4,
            encode_clones: 0,
            sizes: vec![KernelSizeRow {
                n: 256,
                naive_ns: 1_000_000,
                packed_ns: 400_000,
                packed_mt_ns: 150_000,
            }],
        }
    }

    fn sample_recursive() -> RecursiveEntry {
        RecursiveEntry {
            unix_time: 3,
            quick: true,
            kernel: "packed".into(),
            sweep: vec![RecursiveSweepRow {
                n: 512,
                flat_ns: 9_000_000,
                best_crossover: 128,
                points: vec![
                    CrossoverPoint { crossover: 64, rec_ns: 8_000_000, speedup: 1.125 },
                    CrossoverPoint { crossover: 128, rec_ns: 7_000_000, speedup: 1.286 },
                ],
            }],
        }
    }

    fn sample_serve() -> ServeEntry {
        ServeEntry {
            unix_time: 4,
            scheme: "sw+2psmm".into(),
            n: 64,
            jobs: 32,
            p_straggle: 0.3,
            delay_ms: 25,
            quick: true,
            trace_digest: Some(0xdead_beef_0123_4567),
            cells: vec![
                ServeCell {
                    tenants: 1,
                    batch_window: 1,
                    cache_cap: 0,
                    jobs_per_s: 40.0,
                    mean_ns: 90_000,
                    p95_ns: 210_000,
                    cache_hit_rate: 0.0,
                    fell_back: 0,
                },
                ServeCell {
                    tenants: 2,
                    batch_window: 4,
                    cache_cap: 16,
                    jobs_per_s: 55.5,
                    mean_ns: 70_000,
                    p95_ns: 160_000,
                    cache_hit_rate: 0.875,
                    fell_back: 1,
                },
            ],
        }
    }

    fn sample_sim() -> SimEntry {
        SimEntry {
            unix_time: 5,
            plan: "nested(sw+2psmm^2)".into(),
            policy: "speculative".into(),
            workers: 10_000,
            jobs: 300,
            seed: 7,
            quick: true,
            trace_digest: None,
            cells: vec![
                SimCell {
                    p_e: 0.005,
                    theory_pf: 1.93e-7,
                    measured_pf: 0.0,
                    std_err: 0.0,
                    mean_completion_s: 0.0123,
                    p95_completion_s: 0.031,
                    backups: 12,
                    network_bytes: 4_915_200,
                },
                SimCell {
                    p_e: 0.5,
                    theory_pf: 0.999987,
                    measured_pf: 1.0,
                    std_err: 0.0,
                    mean_completion_s: 0.0171,
                    p95_completion_s: 0.044,
                    backups: 0,
                    network_bytes: 3_276_800,
                },
            ],
        }
    }

    #[test]
    fn every_entry_kind_round_trips_through_the_parser() {
        let cases: Vec<(String, &[&str])> = vec![
            (sample_e2e().render(), E2E_KEYS),
            (sample_kernel().render(), KERNEL_KEYS),
            (sample_recursive().render(), RECURSIVE_KEYS),
            (sample_serve().render(), SERVE_KEYS),
            (sample_sim().render(), SIM_KEYS),
        ];
        for (entry, keys) in cases {
            let doc = parse_json(&entry).unwrap_or_else(|e| panic!("{entry}: {e}"));
            for k in keys {
                assert!(doc.get(k).is_some(), "missing {k} in {entry}");
            }
        }
    }

    #[test]
    fn appended_trajectory_files_validate_and_grow() {
        // The full writer path the benches use: render → append (twice)
        // → parse the file → check keys. Append must extend, not
        // clobber.
        let cases: Vec<(&str, String, &[&str])> = vec![
            ("e2e", sample_e2e().render(), E2E_KEYS),
            ("kernel", sample_kernel().render(), KERNEL_KEYS),
            ("recursive", sample_recursive().render(), RECURSIVE_KEYS),
            ("serve", sample_serve().render(), SERVE_KEYS),
            ("sim", sample_sim().render(), SIM_KEYS),
        ];
        for (name, entry, keys) in cases {
            let path = tmp(&format!("{name}.json"));
            let _ = std::fs::remove_file(&path);
            append_entry(&path, &entry).unwrap();
            append_entry(&path, &entry).unwrap();
            let text = std::fs::read_to_string(&path).unwrap();
            let entries = validate_trajectory(&text, keys)
                .unwrap_or_else(|e| panic!("{name}: {e}\n{text}"));
            assert_eq!(entries.len(), 2, "{name}: append clobbered the array");
            let _ = std::fs::remove_file(&path);
        }
    }

    #[test]
    fn parsed_numbers_and_nesting_survive_the_round_trip() {
        let doc = parse_json(&sample_e2e().render()).unwrap();
        assert_eq!(doc.get("n").and_then(Json::as_num), Some(64.0));
        assert_eq!(doc.get("p_fail").and_then(Json::as_num), Some(0.05));
        assert_eq!(doc.get("quick"), Some(&Json::Bool(true)));
        let depths = doc.get("depths").and_then(Json::as_arr).unwrap();
        assert_eq!(depths.len(), 2);
        assert_eq!(depths[1].get("depth").and_then(Json::as_num), Some(4.0));
        assert_eq!(depths[1].get("jobs_per_s").and_then(Json::as_num), Some(21.3));
    }

    #[test]
    fn trace_digest_renders_as_hex_only_when_present() {
        // Present: round-trips losslessly through the hex string (f64
        // numbers could not carry all 64 bits).
        let doc = parse_json(&sample_serve().render()).unwrap();
        match doc.get("trace_digest") {
            Some(Json::Str(s)) => {
                let parsed = u64::from_str_radix(s.trim_start_matches("0x"), 16).unwrap();
                assert_eq!(parsed, 0xdead_beef_0123_4567);
            }
            other => panic!("expected hex string, got {other:?}"),
        }
        // Absent: the key is omitted entirely, and the entry still
        // carries every required key.
        let doc = parse_json(&sample_sim().render()).unwrap();
        assert!(doc.get("trace_digest").is_none());
        for k in SIM_KEYS {
            assert!(doc.get(k).is_some(), "missing {k}");
        }
        let mut traced_sim = sample_sim();
        traced_sim.trace_digest = Some(1);
        let doc = parse_json(&traced_sim.render()).unwrap();
        assert_eq!(doc.get("trace_digest"), Some(&Json::Str("0x0000000000000001".into())));
    }

    #[test]
    fn serve_cells_survive_the_round_trip() {
        let doc = parse_json(&sample_serve().render()).unwrap();
        assert_eq!(doc.get("p_straggle").and_then(Json::as_num), Some(0.3));
        let cells = doc.get("cells").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[1].get("tenants").and_then(Json::as_num), Some(2.0));
        assert_eq!(cells[1].get("batch_window").and_then(Json::as_num), Some(4.0));
        assert_eq!(cells[1].get("cache_hit_rate").and_then(Json::as_num), Some(0.875));
        assert_eq!(cells[1].get("fell_back").and_then(Json::as_num), Some(1.0));
    }

    #[test]
    fn sim_cells_survive_the_round_trip() {
        let doc = parse_json(&sample_sim().render()).unwrap();
        assert_eq!(doc.get("workers").and_then(Json::as_num), Some(10_000.0));
        assert_eq!(doc.get("seed").and_then(Json::as_num), Some(7.0));
        let cells = doc.get("cells").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 2);
        // The scientific-notation theory_pf must survive the parse.
        let tiny = cells[0].get("theory_pf").and_then(Json::as_num).unwrap();
        assert!((tiny - 1.93e-7).abs() < 1e-12, "{tiny}");
        assert_eq!(cells[1].get("measured_pf").and_then(Json::as_num), Some(1.0));
        assert_eq!(
            cells[0].get("network_bytes").and_then(Json::as_num),
            Some(4_915_200.0)
        );
    }

    #[test]
    fn writer_is_cwd_independent() {
        // The benches write via append_to_repo_root, which resolves the
        // path from the compile-time manifest dir — an absolute path
        // that cannot depend on the process working directory.
        let root = crate::bench::trajectory::repo_root();
        assert!(root.is_absolute(), "{root:?}");
        assert!(root.join("rust").join("Cargo.toml").exists());
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[{\"a\": 1},]",
            "[1 2]",
            "{\"a\" 1}",
            "[{\"a\": 1}] trailing",
            "{\"a\": 01x}",
        ] {
            assert!(parse_json(bad).is_err(), "accepted {bad:?}");
        }
    }
}
