//! PSMM (parity sub-matrix multiplication) selection — reproduces the
//! paper's §IV construction and generalizes it to any scheme pair.
//!
//! The paper's reasoning: with the 14 joint S+W products and no parity,
//! certain *pairs* of simultaneous failures — `(S3, W5)` and `(S7, W2)` —
//! leave C unrecoverable. A PSMM must "involve the delayed
//! subcomputation" to help; the computer search finds
//! `PSMM-1 = S3 + W4 = M21(B12 - B22)` for the first pair, while for the
//! second no non-trivial parity exists, so a replica (`W2`) is used as
//! PSMM-2. `select_psmms` re-derives this greedily from the decodability
//! oracle: at each step, add the candidate (searched parity or replica)
//! that repairs the most currently-unrecoverable failure pairs.

use crate::algebra::form::{BilinearForm, Target};
use crate::algebra::gauss::SpanBasis;
use crate::search::searchlp::{search_lp, ParityCandidate, SearchOptions};

/// Can all four C targets be decoded from the given subset of forms?
pub fn decodable(forms: &[BilinearForm], alive: impl Iterator<Item = usize> + Clone) -> bool {
    let mut basis = SpanBasis::new();
    for i in alive {
        basis.insert(&forms[i]);
    }
    Target::ALL.iter().all(|t| basis.contains(&t.form()))
}

/// All unordered pairs `{i, j}` whose simultaneous failure makes the
/// system undecodable (assuming every other product finished).
pub fn uncoverable_pairs(forms: &[BilinearForm]) -> Vec<(usize, usize)> {
    let n = forms.len();
    let mut pairs = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let alive = (0..n).filter(|&k| k != i && k != j);
            if !decodable(forms, alive) {
                pairs.push((i, j));
            }
        }
    }
    pairs
}

/// One selected PSMM.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Psmm {
    /// A searched parity: a new rank-1 multiplication equal to a signed
    /// sum of existing products.
    Parity(ParityCandidate),
    /// A replica of product `idx` (used when no parity covers a pair —
    /// the paper's PSMM-2 = copy of W2).
    Replica(usize),
}

impl Psmm {
    pub fn form(&self, forms: &[BilinearForm]) -> BilinearForm {
        match self {
            Psmm::Parity(p) => p.form(),
            Psmm::Replica(i) => forms[*i],
        }
    }

    pub fn render(&self, forms: &[BilinearForm], names: &[&str]) -> String {
        match self {
            Psmm::Parity(p) => p.render(names),
            Psmm::Replica(i) => format!("copy of {} = {}", names[*i], forms[*i]),
        }
    }
}

/// Greedily select up to `count` PSMMs that repair 2-failure patterns.
///
/// Candidates are the Algorithm-1 parity list (preferred, searched with
/// `opts`) plus replicas of each product. A candidate's score is the
/// number of currently-unrecoverable failure pairs it repairs; ties are
/// broken toward parities with fewer terms (cheaper bookkeeping), then
/// lower product index.
pub fn select_psmms(forms: &[BilinearForm], count: usize, opts: &SearchOptions) -> Vec<Psmm> {
    let parities = search_lp(forms, opts).parities;
    let mut chosen: Vec<Psmm> = Vec::new();
    let mut extended: Vec<BilinearForm> = forms.to_vec();

    for _ in 0..count {
        let pairs = open_pairs(&extended, forms.len());
        if pairs.is_empty() {
            // Nothing left to repair at pair level; replicate the product
            // participating in the most >2-failure losses — for the paper
            // configuration this branch selects the W2/S7 replica.
        }
        let mut best: Option<(usize, usize, Psmm)> = None; // (score, tiebreak, psmm)
        let mut consider = |psmm: Psmm, tiebreak: usize, extended: &Vec<BilinearForm>| {
            let f = psmm.form(forms);
            let score = pairs
                .iter()
                .filter(|&&(i, j)| {
                    let mut trial = extended.clone();
                    trial.push(f);
                    let n = trial.len();
                    decodable(&trial, (0..n).filter(|&k| k != i && k != j))
                })
                .count();
            let better = match &best {
                None => true,
                Some((s, tb, _)) => score > *s || (score == *s && tiebreak < *tb),
            };
            if better {
                best = Some((score, tiebreak, psmm));
            }
        };
        for p in &parities {
            consider(Psmm::Parity(p.clone()), p.terms.len(), &extended);
        }
        for i in 0..forms.len() {
            // Replicas get a large tiebreak so searched parities win ties.
            consider(Psmm::Replica(i), 100 + i, &extended);
        }
        let (_, _, psmm) = best.expect("candidate set never empty");
        extended.push(psmm.form(forms));
        chosen.push(psmm);
    }
    chosen
}

/// Unrecoverable pairs among the ORIGINAL products, evaluated with the
/// already-extended form set alive (parities never fail in this analysis;
/// the full FC(k) accounting in `coding::fc` treats them as fallible).
fn open_pairs(extended: &[BilinearForm], num_original: usize) -> Vec<(usize, usize)> {
    let n = extended.len();
    let mut pairs = Vec::new();
    for i in 0..num_original {
        for j in (i + 1)..num_original {
            if !decodable(extended, (0..n).filter(|&k| k != i && k != j)) {
                pairs.push((i, j));
            }
        }
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{strassen, winograd};

    fn sw_forms() -> Vec<BilinearForm> {
        let mut f = strassen().forms();
        f.extend(winograd().forms());
        f
    }

    const NAMES: [&str; 14] = [
        "S1", "S2", "S3", "S4", "S5", "S6", "S7", "W1", "W2", "W3", "W4", "W5", "W6", "W7",
    ];

    #[test]
    fn paper_uncoverable_pairs_present() {
        // §IV: "(S3, W5) or (S7, W2)" are the problematic simultaneous
        // local-computation pairs. Indices: S3=2, W5=11, S7=6, W2=8.
        let pairs = uncoverable_pairs(&sw_forms());
        assert!(pairs.contains(&(2, 11)), "(S3, W5) should be uncoverable: {pairs:?}");
        assert!(pairs.contains(&(6, 8)), "(S7, W2) should be uncoverable: {pairs:?}");
    }

    #[test]
    fn single_failures_always_recoverable() {
        let forms = sw_forms();
        for i in 0..forms.len() {
            assert!(
                decodable(&forms, (0..forms.len()).filter(|&k| k != i)),
                "single failure of {} must be recoverable",
                NAMES[i]
            );
        }
    }

    #[test]
    fn greedy_psmm1_repairs_s3_w5_like_papers_choice() {
        // The greedy search may pick any maximum-coverage parity; the
        // paper's S3 + W4 = M21(B12 - B22) is one of several equivalent
        // choices (ours lands on S2 + W5 = (M21+M22)B12). Both must
        // repair the (S3, W5) pair; and the paper's choice must be a
        // valid alternative with the same repair behaviour.
        let forms = sw_forms();
        let psmms = select_psmms(&forms, 2, &SearchOptions::default());
        assert_eq!(psmms.len(), 2);
        let n = forms.len();
        let check_repairs = |f: BilinearForm, i: usize, j: usize| {
            let mut ext = forms.clone();
            ext.push(f);
            decodable(&ext, (0..n + 1).filter(|&k| k != i && k != j))
        };
        // chosen PSMM-1 repairs (S3, W5) = (2, 11)
        assert!(check_repairs(psmms[0].form(&forms), 2, 11));
        // the paper's PSMM-1 does too
        let paper_p1 = BilinearForm::from_uv(&[0, 0, 1, 0], &[0, 1, 0, -1]);
        assert!(check_repairs(paper_p1, 2, 11));
        // PSMM-2 must repair (S7, W2) = (6, 8). The paper argues only
        // W2/S7 redundancy can do it; the greedy finds either a replica
        // or a parity PROPORTIONAL to one of them (e.g.
        // S1+S4-S5+S7-W1+W2 = 2·M12B21 = 2·W2 — same spanned line).
        let f2 = psmms[1].form(&forms);
        assert!(check_repairs(f2, 6, 8), "chosen PSMM-2 does not repair (S7, W2)");
        let proportional = |a: &BilinearForm, b: &BilinearForm| {
            (0..16).all(|i| {
                (0..16).all(|j| {
                    a.coeffs[i] as i64 * b.coeffs[j] as i64
                        == a.coeffs[j] as i64 * b.coeffs[i] as i64
                })
            })
        };
        let (w2, s7) = (forms[8], forms[6]);
        assert!(
            proportional(&f2, &w2) || proportional(&f2, &s7),
            "PSMM-2 = {f2}, expected ∝ W2 or S7; chosen: {}",
            psmms[1].render(&forms, &NAMES)
        );
    }

    #[test]
    fn two_psmms_cover_all_pairs() {
        let forms = sw_forms();
        let psmms = select_psmms(&forms, 2, &SearchOptions::default());
        let mut extended = forms.clone();
        for p in &psmms {
            extended.push(p.form(&forms));
        }
        // Any two failures among the ORIGINAL 14 are now recoverable.
        let n = extended.len();
        for i in 0..14 {
            for j in (i + 1)..14 {
                assert!(
                    decodable(&extended, (0..n).filter(|&k| k != i && k != j)),
                    "pair ({}, {}) still uncoverable",
                    NAMES[i],
                    NAMES[j]
                );
            }
        }
    }

    #[test]
    fn psmm1_alone_fixes_s3_w5_but_not_s7_w2() {
        let forms = sw_forms();
        let psmms = select_psmms(&forms, 1, &SearchOptions::default());
        let mut extended = forms.clone();
        extended.push(psmms[0].form(&forms));
        let n = extended.len();
        assert!(decodable(&extended, (0..n).filter(|&k| k != 2 && k != 11)));
        assert!(!decodable(&extended, (0..n).filter(|&k| k != 6 && k != 8)));
    }
}
