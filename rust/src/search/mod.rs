//! Computer-aided search (the paper's Algorithm 1 and §IV analysis).
//!
//! Given the bilinear forms of a set of sub-matrix multiplications (e.g.
//! the 14 products S1..S7 ∪ W1..W7), [`searchlp`] exhaustively enumerates
//! signed combinations and classifies them:
//!
//! * **local computations** — combinations equal to an output target
//!   `C_ij` (the paper's eqs. (1)-(8), Table II, and the "52 independent
//!   relations"),
//! * **parity candidates** — combinations equal to a *single* block
//!   multiplication `u(M)·v(B)` (rank-1 forms), i.e. PSMMs that one extra
//!   worker can compute (the paper's `S3 + W4 = M21(B12-B22)`).
//!
//! [`relations`] canonicalizes/deduplicates and measures the linear
//! structure; [`psmm`] reproduces the paper's 2-PSMM selection.

pub mod pair_explorer;
pub mod psmm;
pub mod relations;
pub mod searchlp;

pub use psmm::select_psmms;
pub use relations::{independent_rank, relations_for_target};
pub use searchlp::{search_lp, LocalRelation, ParityCandidate, SearchResult};
