//! Algorithm 1 (SearchLP): exhaustive enumeration of local computations
//! and local-parity calculations.
//!
//! The paper's procedure iterates over all `(M choose K)` combinations of
//! sub-matrix multiplications and all `2^K` sign patterns (the Hadamard
//! product with `(-1)^{n_1} … (-1)^{n_K}`), keeping combinations equal to
//! an output block (`L`, local computations) or to one multiplication
//! (`P`, parity calculations). We implement it as a depth-first search
//! with incremental partial sums — same enumeration order and output,
//! ~3^M visited nodes instead of re-summing every combination from
//! scratch.

use crate::algebra::form::{BilinearForm, Target};

/// A local computation: `target = Σ sign_i · forms[idx_i]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LocalRelation {
    pub target: Target,
    /// `(product index, ±1)`, sorted by index, at most one term per index.
    pub terms: Vec<(usize, i32)>,
}

impl LocalRelation {
    /// Number of participating products.
    pub fn weight(&self) -> usize {
        self.terms.len()
    }

    /// Render like `C11 = S1 + S4 - S5 + S7` given product names.
    pub fn render(&self, names: &[&str]) -> String {
        let mut s = format!("{} =", self.target.name());
        for (i, (idx, sign)) in self.terms.iter().enumerate() {
            if i == 0 {
                if *sign < 0 {
                    s.push_str(" -");
                } else {
                    s.push(' ');
                }
            } else {
                s.push_str(if *sign < 0 { " - " } else { " + " });
            }
            s.push_str(names[*idx]);
        }
        s
    }
}

/// A parity candidate: a combination equal to ONE block multiplication
/// `(Σ u_p M_p)(Σ v_q B_q)` — i.e. a PSMM one extra worker could compute.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParityCandidate {
    pub terms: Vec<(usize, i32)>,
    /// Left encoding of the equivalent single multiplication.
    pub u: [i32; 4],
    /// Right encoding of the equivalent single multiplication.
    pub v: [i32; 4],
}

impl ParityCandidate {
    pub fn form(&self) -> BilinearForm {
        BilinearForm::from_uv(&self.u, &self.v)
    }

    pub fn render(&self, names: &[&str]) -> String {
        let terms: Vec<String> = self
            .terms
            .iter()
            .enumerate()
            .map(|(i, (idx, sign))| {
                let prefix = if i == 0 {
                    if *sign < 0 { "-" } else { "" }
                } else if *sign < 0 {
                    " - "
                } else {
                    " + "
                };
                format!("{prefix}{}", names[*idx])
            })
            .collect();
        format!("{} = {}", terms.concat(), self.form())
    }
}

/// Output of [`search_lp`].
#[derive(Clone, Debug, Default)]
pub struct SearchResult {
    pub relations: Vec<LocalRelation>,
    pub parities: Vec<ParityCandidate>,
}

impl SearchResult {
    /// Relations for one target, sorted by weight (shortest first).
    pub fn for_target(&self, t: Target) -> Vec<&LocalRelation> {
        let mut v: Vec<&LocalRelation> =
            self.relations.iter().filter(|r| r.target == t).collect();
        v.sort_by_key(|r| r.weight());
        v
    }

    pub fn num_relations(&self) -> usize {
        self.relations.len()
    }
}

/// Options for the enumeration.
#[derive(Clone, Copy, Debug)]
pub struct SearchOptions {
    /// Maximum number of combined products (the paper's K).
    pub max_k: usize,
    /// Keep only *minimal* relations: no nonempty proper subset of the
    /// chosen signed terms sums to the zero form. Non-minimal relations
    /// are paddings of shorter ones with zero-sum subsets and carry no
    /// extra decoding power.
    pub minimal_only: bool,
    /// Collect parity candidates (Algorithm 1's `P` output).
    pub collect_parities: bool,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions { max_k: 8, minimal_only: true, collect_parities: true }
    }
}

/// Run Algorithm 1 over `forms` (the available sub-matrix multiplications).
///
/// Returns all local computations (combinations equal to C11/C12/C21/C22)
/// and, if enabled, all parity candidates (combinations equal to a single
/// rank-1 multiplication that is not itself ± one of `forms`).
pub fn search_lp(forms: &[BilinearForm], opts: &SearchOptions) -> SearchResult {
    let targets: Vec<(Target, BilinearForm)> =
        Target::ALL.iter().map(|t| (*t, t.form())).collect();
    let mut result = SearchResult::default();
    let mut terms: Vec<(usize, i32)> = Vec::with_capacity(opts.max_k);
    dfs(
        forms,
        &targets,
        opts,
        0,
        BilinearForm::ZERO,
        &mut terms,
        &mut result,
    );
    result
}

#[allow(clippy::too_many_arguments)]
fn dfs(
    forms: &[BilinearForm],
    targets: &[(Target, BilinearForm)],
    opts: &SearchOptions,
    start: usize,
    sum: BilinearForm,
    terms: &mut Vec<(usize, i32)>,
    out: &mut SearchResult,
) {
    if !terms.is_empty() {
        classify(forms, targets, opts, &sum, terms, out);
    }
    if terms.len() == opts.max_k {
        return;
    }
    for idx in start..forms.len() {
        for sign in [1i32, -1] {
            terms.push((idx, sign));
            let next = if sign > 0 { sum + forms[idx] } else { sum - forms[idx] };
            dfs(forms, targets, opts, idx + 1, next, terms, out);
            terms.pop();
        }
    }
}

fn classify(
    forms: &[BilinearForm],
    targets: &[(Target, BilinearForm)],
    opts: &SearchOptions,
    sum: &BilinearForm,
    terms: &[(usize, i32)],
    out: &mut SearchResult,
) {
    for (t, tf) in targets {
        if sum == tf {
            if !opts.minimal_only || is_minimal(forms, terms) {
                out.relations.push(LocalRelation { target: *t, terms: terms.to_vec() });
            }
            return; // a sum equals at most one target
        }
    }
    if opts.collect_parities && terms.len() >= 2 {
        if let Some((u, v)) = sum.rank_one_factor() {
            // Skip sums that are just ± an existing product (those are
            // replicas, not new parity computations).
            let dup = forms.iter().any(|f| f == sum || *f == -*sum);
            if !dup && (!opts.minimal_only || is_minimal(forms, terms)) {
                out.parities.push(ParityCandidate { terms: terms.to_vec(), u, v });
            }
        }
    }
}

/// No nonempty proper subset of the signed terms sums to zero.
fn is_minimal(forms: &[BilinearForm], terms: &[(usize, i32)]) -> bool {
    let k = terms.len();
    if k <= 1 {
        return true;
    }
    // Enumerate proper nonempty subsets; by symmetry it suffices to check
    // subsets not containing the last element OR containing it — we check
    // all of them (k <= max_k <= 14 and relations are short in practice).
    for mask in 1u32..((1 << k) - 1) {
        let mut sum = BilinearForm::ZERO;
        for (i, (idx, sign)) in terms.iter().enumerate() {
            if mask & (1 << i) != 0 {
                sum = if *sign > 0 { sum + forms[*idx] } else { sum - forms[*idx] };
            }
        }
        if sum.is_zero() {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{strassen, winograd};

    fn sw_forms() -> Vec<BilinearForm> {
        let mut f = strassen().forms();
        f.extend(winograd().forms());
        f
    }

    #[test]
    fn finds_paper_equations_1_to_4_within_strassen() {
        let forms = strassen().forms();
        let res = search_lp(&forms, &SearchOptions::default());
        // Paper eq. (1): C11 = S1 + S4 - S5 + S7.
        let want = LocalRelation {
            target: Target::C11,
            terms: vec![(0, 1), (3, 1), (4, -1), (6, 1)],
        };
        assert!(res.relations.contains(&want), "eq (1) not found");
        // Paper eq. (3): C21 = S2 + S4.
        let want = LocalRelation { target: Target::C21, terms: vec![(1, 1), (3, 1)] };
        assert!(res.relations.contains(&want), "eq (3) not found");
    }

    #[test]
    fn strassen_alone_has_unique_decode_per_target() {
        // Rank-7 scheme: each target has exactly ONE signed combination.
        let forms = strassen().forms();
        let res = search_lp(&forms, &SearchOptions { max_k: 7, ..Default::default() });
        for t in Target::ALL {
            assert_eq!(res.for_target(t).len(), 1, "{t}");
        }
    }

    #[test]
    fn finds_paper_equations_5_to_8_in_joint_set() {
        let forms = sw_forms();
        let res = search_lp(&forms, &SearchOptions::default());
        // Eq. (8): C22 = S3 + S5 + W4 - W6. Indices: S3=2, S5=4, W4=10, W6=12.
        let want = LocalRelation {
            target: Target::C22,
            terms: vec![(2, 1), (4, 1), (10, 1), (12, -1)],
        };
        assert!(res.relations.contains(&want), "eq (8) not found");
        // Eq. (5): C11 = S2 + S4 - S6 + S7 + W4 - W6.
        let want = LocalRelation {
            target: Target::C11,
            terms: vec![(1, 1), (3, 1), (5, -1), (6, 1), (10, 1), (12, -1)],
        };
        assert!(res.relations.contains(&want), "eq (5) not found");
        // Eq. (6): C12 = S1 + S3 + S4 + S7 - W1 - W2.
        let want = LocalRelation {
            target: Target::C12,
            terms: vec![(0, 1), (2, 1), (3, 1), (6, 1), (7, -1), (8, -1)],
        };
        assert!(res.relations.contains(&want), "eq (6) not found");
    }

    #[test]
    fn finds_paper_equation_7_without_minimality_filter() {
        // Eq. (7): C21 = S2 + S3 + S4 + S5 - W1 - W5 - W6 + W7 is NOT
        // minimal: it is eq. (3) (C21 = S2 + S4) padded with the
        // product-space identity S3 + S5 - W1 - W5 - W6 + W7 = 0 (the
        // joint form rank is 10, so four such identities exist). The
        // paper lists it anyway; the unfiltered search finds it.
        let forms = sw_forms();
        let res = search_lp(
            &forms,
            &SearchOptions { max_k: 8, minimal_only: false, collect_parities: false },
        );
        let want = LocalRelation {
            target: Target::C21,
            terms: vec![(1, 1), (2, 1), (3, 1), (4, 1), (7, -1), (11, -1), (12, -1), (13, 1)],
        };
        assert!(res.relations.contains(&want), "eq (7) not found");
    }

    #[test]
    fn finds_psmm1_as_parity_candidate() {
        // S3 + W4 = M21(B12 - B22) — the paper's 1st PSMM.
        let forms = sw_forms();
        let res = search_lp(&forms, &SearchOptions::default());
        let p1_form = BilinearForm::from_uv(&[0, 0, 1, 0], &[0, 1, 0, -1]);
        let found = res.parities.iter().any(|p| {
            (p.form() == p1_form || p.form() == -p1_form)
                && p.terms == vec![(2, 1), (10, 1)]
        });
        assert!(found, "PSMM-1 (= S3 + W4) not among parity candidates");
    }

    #[test]
    fn every_relation_verifies_symbolically() {
        let forms = sw_forms();
        let res = search_lp(&forms, &SearchOptions { max_k: 6, ..Default::default() });
        assert!(!res.relations.is_empty());
        for r in &res.relations {
            let mut sum = BilinearForm::ZERO;
            for (idx, sign) in &r.terms {
                sum = if *sign > 0 { sum + forms[*idx] } else { sum - forms[*idx] };
            }
            assert_eq!(sum, r.target.form(), "{r:?}");
        }
        for p in &res.parities {
            let mut sum = BilinearForm::ZERO;
            for (idx, sign) in &p.terms {
                sum = if *sign > 0 { sum + forms[*idx] } else { sum - forms[*idx] };
            }
            assert_eq!(sum, p.form(), "{p:?}");
        }
    }

    #[test]
    fn minimality_filter_drops_padded_relations() {
        // Non-minimal search finds strictly more relations (the shortest
        // zero-sum identity has 6 terms, so padded relations appear from
        // 8 terms on).
        let forms = sw_forms();
        let minimal = search_lp(
            &forms,
            &SearchOptions { max_k: 8, minimal_only: true, collect_parities: false },
        );
        let all = search_lp(
            &forms,
            &SearchOptions { max_k: 8, minimal_only: false, collect_parities: false },
        );
        assert!(all.num_relations() > minimal.num_relations());
        // and every minimal relation is also in the unfiltered set
        for r in &minimal.relations {
            assert!(all.relations.contains(r));
        }
    }

    #[test]
    fn render_matches_paper_style() {
        let forms = strassen().forms();
        let res = search_lp(&forms, &SearchOptions::default());
        let names = ["S1", "S2", "S3", "S4", "S5", "S6", "S7"];
        let rendered = res.for_target(Target::C11)[0].render(&names);
        assert_eq!(rendered, "C11 = S1 + S4 - S5 + S7");
    }
}
