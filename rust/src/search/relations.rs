//! Relation-set analysis: counts, weights, linear structure.
//!
//! The paper reports "52 independent relations" among the 14 joint
//! Strassen+Winograd products and lists the extra C11 relations in its
//! Table II. The functions here compute those summaries from the raw
//! [`search_lp`] output so the numbers in EXPERIMENTS.md are generated,
//! not transcribed.

use crate::algebra::form::{BilinearForm, Target};
use crate::algebra::frac::Frac;
use crate::search::searchlp::{LocalRelation, SearchResult};

/// All relations for one target, sorted by weight then lexicographically —
/// the layout of the paper's Table II.
pub fn relations_for_target(res: &SearchResult, t: Target) -> Vec<LocalRelation> {
    let mut v: Vec<LocalRelation> =
        res.relations.iter().filter(|r| r.target == t).cloned().collect();
    v.sort_by(|a, b| a.weight().cmp(&b.weight()).then_with(|| a.terms.cmp(&b.terms)));
    v
}

/// Linear rank of a relation set.
///
/// Each relation `C_t = Σ s_i P_i` is the vector `Σ s_i e_i - e_{C_t}` in
/// ℚ^(num_products + 4); the rank bounds how many relations carry
/// linearly independent information. For the 14-product S+W system this
/// is 8 (= 18 symbols - joint form rank 10): the paper's "52 independent
/// relations" are 52 *distinct* local computations spanning this
/// 8-dimensional relation space.
pub fn independent_rank(relations: &[LocalRelation], num_products: usize) -> usize {
    let dim = num_products + 4;
    let mut basis: Vec<Vec<Frac>> = Vec::new();
    let mut rank = 0;
    for r in relations {
        let mut v = vec![Frac::ZERO; dim];
        for (idx, sign) in &r.terms {
            v[*idx] = Frac::int(*sign as i128);
        }
        v[num_products + r.target.index()] = Frac::int(-1);
        // Reduce against basis (plain Gauss, small dims).
        for b in &basis {
            let pivot = b.iter().position(|c| !c.is_zero()).unwrap();
            let f = v[pivot];
            if !f.is_zero() {
                for i in 0..dim {
                    v[i] = v[i] - f * b[i];
                }
            }
        }
        if let Some(p) = v.iter().position(|c| !c.is_zero()) {
            let lead = v[p];
            for c in v.iter_mut() {
                *c = *c / lead;
            }
            basis.push(v);
            rank += 1;
        }
    }
    rank
}

/// Histogram of relation weights (index = number of terms).
pub fn weight_histogram(relations: &[LocalRelation], max_k: usize) -> Vec<usize> {
    let mut h = vec![0usize; max_k + 1];
    for r in relations {
        h[r.weight()] += 1;
    }
    h
}

/// Pretty one-line summary per target (counts by weight).
pub fn summarize(res: &SearchResult, max_k: usize) -> String {
    let mut s = String::new();
    for t in Target::ALL {
        let rels = relations_for_target(res, t);
        let h = weight_histogram(&rels, max_k);
        let per_weight: Vec<String> = h
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(w, c)| format!("{c}@k={w}"))
            .collect();
        s.push_str(&format!(
            "{}: {} relations ({})\n",
            t.name(),
            rels.len(),
            per_weight.join(", ")
        ));
    }
    s
}

/// Deduplicate relations that use the same support with globally flipped
/// signs on a zero-sum — defensive; `search_lp` with `minimal_only`
/// should already emit unique term lists.
pub fn dedup(relations: &mut Vec<LocalRelation>) {
    relations.sort_by(|a, b| {
        (a.target.index(), &a.terms).cmp(&(b.target.index(), &b.terms))
    });
    relations.dedup();
}

/// Verify every relation expands to its target (defense in depth for
/// anything that constructs relations outside `search_lp`).
pub fn verify_all(relations: &[LocalRelation], forms: &[BilinearForm]) -> Result<(), String> {
    for r in relations {
        let mut sum = BilinearForm::ZERO;
        for (idx, sign) in &r.terms {
            if *idx >= forms.len() {
                return Err(format!("relation {r:?} references product {idx}"));
            }
            sum = if *sign > 0 { sum + forms[*idx] } else { sum - forms[*idx] };
        }
        if sum != r.target.form() {
            return Err(format!("relation {r:?} expands to {sum}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{strassen, winograd};
    use crate::search::searchlp::{search_lp, SearchOptions};

    fn sw_forms() -> Vec<BilinearForm> {
        let mut f = strassen().forms();
        f.extend(winograd().forms());
        f
    }

    #[test]
    fn rank_of_joint_relation_space_is_eight() {
        let forms = sw_forms();
        let res = search_lp(&forms, &SearchOptions { max_k: 8, ..Default::default() });
        let rank = independent_rank(&res.relations, forms.len());
        // 18 symbols (14 products + 4 targets), joint form rank 10
        // -> relation space has dimension 18 - 10 = 8, and the target
        // relations found by the search span all of it.
        assert_eq!(rank, 8);
    }

    #[test]
    fn strassen_only_rank_is_four() {
        let forms = strassen().forms();
        let res = search_lp(&forms, &SearchOptions::default());
        // 11 symbols, form rank 7 -> 4 relations (eqs. (1)-(4)) exactly.
        assert_eq!(independent_rank(&res.relations, 7), 4);
        assert_eq!(res.num_relations(), 4);
    }

    #[test]
    fn weight_histogram_counts() {
        let res = search_lp(&strassen().forms(), &SearchOptions::default());
        let h = weight_histogram(&res.relations, 8);
        assert_eq!(h.iter().sum::<usize>(), 4);
        assert_eq!(h[2], 2); // C12 = S3+S5, C21 = S2+S4
        assert_eq!(h[4], 2); // C11, C22 with 4 terms
    }

    #[test]
    fn verify_all_detects_corruption() {
        let forms = sw_forms();
        let mut res = search_lp(&forms, &SearchOptions { max_k: 4, ..Default::default() });
        verify_all(&res.relations, &forms).unwrap();
        res.relations[0].terms[0].1 *= -1;
        assert!(verify_all(&res.relations, &forms).is_err());
    }

    #[test]
    fn dedup_is_stable_noop_on_clean_output() {
        let forms = sw_forms();
        let res = search_lp(&forms, &SearchOptions { max_k: 5, ..Default::default() });
        let mut rels = res.relations.clone();
        let before = rels.len();
        dedup(&mut rels);
        assert_eq!(rels.len(), before, "search_lp emitted duplicates");
    }

    #[test]
    fn live_search_reproduces_the_golden_fixture_exactly() {
        // The checked-in fixture is the one source of truth for the
        // Table-II relation set: tests that only consume relations load
        // it instead of re-running the exhaustive search, and this test
        // pins the live search against it so neither can drift.
        let res = search_lp(
            &sw_forms(),
            &SearchOptions { max_k: 8, minimal_only: true, collect_parities: false },
        );
        let mut live = res.relations;
        dedup(&mut live);
        let golden = crate::testkit::golden::sw_relations();
        assert_eq!(
            live, golden,
            "search_lp output diverged from testkit/golden_sw_relations.txt — \
             regenerate the fixture if the search changed intentionally"
        );
    }

    #[test]
    fn summary_mentions_every_target() {
        let res = search_lp(&sw_forms(), &SearchOptions { max_k: 5, ..Default::default() });
        let s = summarize(&res, 5);
        for t in ["C11", "C12", "C21", "C22"] {
            assert!(s.contains(t), "{s}");
        }
    }
}
