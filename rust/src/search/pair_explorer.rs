//! Pair exploration — executes the paper's §V research direction:
//! *"better Strassen-like pairs that can generate more independent local
//! relations may be found using the Triple Product Condition."*
//!
//! Strategy: hold Strassen fixed, sample validity-preserving variants of
//! a partner scheme ([`crate::algorithms::transform`]), and score each
//! joint 14-product configuration by the fault-tolerance metrics that
//! drive Fig. 2:
//!
//! 1. number of fatal 2-failure pairs (FC(2); fewer is better),
//! 2. FC(3) as tiebreak,
//! 3. relation-space rank (more independent checks is better).
//!
//! The explorer reports the best pair found and how the published
//! Strassen+Winograd choice ranks against the sampled population.

use crate::algebra::form::BilinearForm;
use crate::algorithms::scheme::BilinearScheme;
use crate::algorithms::transform::random_variant;
use crate::coding::fc::fc_table;
use crate::coding::scheme::TaskSet;
use crate::sim::rng::Rng;

/// Score of one candidate pair (lower is better, lexicographic).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PairScore {
    /// Fatal 2-failure combinations (FC(2)).
    pub fatal_pairs: u64,
    /// Fatal 3-failure combinations (FC(3)).
    pub fatal_triples: u64,
}

/// One explored candidate.
#[derive(Clone, Debug)]
pub struct PairCandidate {
    pub partner: BilinearScheme,
    pub score: PairScore,
    /// rank of span(S ∪ partner) — 10 for the published pair; a higher
    /// joint rank means fewer check relations, a lower one means more.
    pub joint_rank: usize,
}

/// Score the joint configuration of `base` + `partner` (no PSMMs).
pub fn score_pair(base: &BilinearScheme, partner: &BilinearScheme) -> (PairScore, usize) {
    let ts = TaskSet::pair(base, partner, 0);
    let fc = fc_table(&ts);
    let mut forms: Vec<BilinearForm> = base.forms();
    forms.extend(partner.forms());
    let rank = crate::algebra::gauss::rank(&forms);
    (
        PairScore { fatal_pairs: fc.counts[2], fatal_triples: fc.counts[3] },
        rank,
    )
}

/// Explore `samples` random partner variants; returns candidates sorted
/// best-first (published-pair score included for reference as index 0 of
/// the returned `(published, best)` tuple).
pub fn explore(
    base: &BilinearScheme,
    partner_seed: &BilinearScheme,
    samples: usize,
    rng: &mut Rng,
) -> (PairCandidate, Vec<PairCandidate>) {
    let (pub_score, pub_rank) = score_pair(base, partner_seed);
    let published = PairCandidate {
        partner: partner_seed.clone(),
        score: pub_score,
        joint_rank: pub_rank,
    };
    let mut all: Vec<PairCandidate> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let variant = random_variant(partner_seed, rng);
        let (score, joint_rank) = score_pair(base, &variant);
        all.push(PairCandidate { partner: variant, score, joint_rank });
    }
    all.sort_by(|a, b| a.score.cmp(&b.score).then(a.joint_rank.cmp(&b.joint_rank)));
    (published, all)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{naive8, strassen, winograd};

    #[test]
    fn published_pair_score() {
        let (score, rank) = score_pair(&strassen(), &winograd());
        assert_eq!(score.fatal_pairs, 2, "(S3,W5) and (S7,W2)");
        assert_eq!(rank, 10);
    }

    #[test]
    fn self_pair_scores_like_replication() {
        // strassen + strassen == 2-copy: FC(2) = 7.
        let (score, rank) = score_pair(&strassen(), &strassen());
        assert_eq!(score.fatal_pairs, 7);
        assert_eq!(rank, 7);
    }

    #[test]
    fn naive8_partner_is_scored() {
        let (score, rank) = score_pair(&strassen(), &naive8());
        // naive8 has 8 products, rank 8; joint rank must be >= 8.
        assert!(rank >= 8);
        // the score is well-defined (no panic) whatever its value
        let _ = score;
    }

    #[test]
    fn explorer_never_beats_validity() {
        // every sampled variant scores on a VALID scheme — implied by
        // transform invariants, revalidated through score_pair's TaskSet
        // construction (decodable full set).
        let mut rng = Rng::seeded(11);
        let (_published, all) = explore(&strassen(), &winograd(), 8, &mut rng);
        assert_eq!(all.len(), 8);
        for c in &all {
            c.partner.verify().unwrap();
            // a valid pair always decodes with zero failures:
            let ts = TaskSet::pair(&strassen(), &c.partner, 0);
            assert!(ts.decodable_with_failures(0));
        }
    }

    #[test]
    fn sign_and_permutation_variants_preserve_the_score() {
        // Sign flips negate a product's form and permutations relabel
        // workers — the spanned subspaces are identical, so FC tables
        // must match the published pair exactly. (The operand-swap
        // transform genuinely changes the forms and MAY change the
        // score — that is exactly the search space `explore` covers.)
        use crate::algorithms::transform::{flip_sign, permute_products, SignFlip};
        let published = score_pair(&strassen(), &winograd());
        let mut w = winograd();
        for (i, f) in [(0, SignFlip::UV), (3, SignFlip::UW), (5, SignFlip::VW)] {
            w = flip_sign(&w, i, f);
        }
        let w = permute_products(&w, &[2, 0, 1, 6, 5, 4, 3]);
        assert_eq!(score_pair(&strassen(), &w), published);
    }

    #[test]
    fn explore_reports_sorted_candidates() {
        let mut rng = Rng::seeded(23);
        let (published, all) = explore(&strassen(), &winograd(), 24, &mut rng);
        assert!(all.windows(2).all(|w| w[0].score <= w[1].score));
        // published pair tolerates all single failures; every sampled
        // candidate's score is well-defined and none decodes worse than
        // the trivially-worst bound C(14,2) = 91.
        assert_eq!(published.score.fatal_pairs, 2);
        for c in &all {
            assert!(c.score.fatal_pairs <= 91);
        }
    }
}
