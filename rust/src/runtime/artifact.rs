//! Artifact discovery: parse `artifacts/manifest.tsv` (written by
//! `python -m compile.aot`) so the runtime knows which executables and
//! block sizes exist without parsing HLO.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Worker-product slots in the decode executable (14 products + 2 PSMMs).
pub const DECODE_SLOTS: usize = 16;

/// One artifact row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
        let mut entries = BTreeMap::new();
        for (i, line) in text.lines().enumerate() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                return Err(format!(
                    "{}:{}: expected 4 tab-separated columns, got {}",
                    path.display(),
                    i + 1,
                    cols.len()
                ));
            }
            let entry = ArtifactEntry {
                name: cols[0].to_string(),
                file: dir.join(cols[1]),
                inputs: cols[2].split(';').map(str::to_string).collect(),
                outputs: cols[3].split(';').map(str::to_string).collect(),
            };
            entries.insert(entry.name.clone(), entry);
        }
        if entries.is_empty() {
            return Err(format!("{}: no artifacts listed", path.display()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Block sizes for which a `worker_task_bs{bs}` executable exists.
    pub fn worker_block_sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .entries
            .keys()
            .filter_map(|n| n.strip_prefix("worker_task_bs"))
            .filter_map(|s| s.parse().ok())
            .collect();
        sizes.sort_unstable();
        sizes
    }

    /// Does `name` exist?
    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Path for an artifact name.
    pub fn path_of(&self, name: &str) -> Option<&Path> {
        self.entries.get(name).map(|e| e.file.as_path())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.tsv")).unwrap();
        write!(f, "{body}").unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ftms_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn parses_manifest() {
        let dir = tmpdir("ok");
        write_manifest(
            &dir,
            "# name\tfile\tinputs\toutputs\n\
             worker_task_bs32\tworker_task_bs32.hlo.txt\tfloat32[4];float32[4,32,32]\tfloat32[32,32]\n\
             worker_task_bs64\tworker_task_bs64.hlo.txt\tfloat32[4];float32[4,64,64]\tfloat32[64,64]\n\
             matmul_n64\tmatmul_n64.hlo.txt\tfloat32[64,64];float32[64,64]\tfloat32[64,64]\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.worker_block_sizes(), vec![32, 64]);
        assert!(m.has("matmul_n64"));
        assert!(m.path_of("matmul_n64").unwrap().ends_with("matmul_n64.hlo.txt"));
        assert!(!m.has("nope"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent_xyz")).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn malformed_row_is_error() {
        let dir = tmpdir("bad");
        write_manifest(&dir, "only_two\tcolumns\n");
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.contains("4 tab-separated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // Integration: if `make artifacts` has run, validate its output.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(m) = Manifest::load(&dir) {
            let sizes = m.worker_block_sizes();
            assert!(!sizes.is_empty());
            for bs in sizes {
                assert!(m.has(&format!("decode_combine_bs{bs}")));
                assert!(m.has(&format!("strassen_once_bs{bs}")));
            }
        }
    }
}
