//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! The `xla` crate's PJRT handles wrap raw pointers without `Send`/`Sync`,
//! so the runtime is owned by a dedicated **compute service thread**
//! ([`service::ComputeService`]); worker threads hold a cheap clonable
//! [`service::PjrtHandle`] and exchange requests/replies over channels.
//! Requests are tagged with the originating `job_id` so the service's
//! errors and logs stay attributable under job multiplexing. The CPU
//! PJRT executor parallelizes internally, so a single service thread
//! does not serialize the actual math.
//!
//! Interchange is HLO *text* (jax >= 0.5 protos use 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! The `xla` dependency sits behind the **`pjrt` cargo feature** so the
//! crate builds and tests on machines without `libxla_extension`.
//! Without the feature, [`client`] is a stub whose `Runtime::new`
//! always fails (after validating the artifact manifest, so error
//! messages stay helpful) and the coordinator degrades to the native
//! backend exactly as if artifacts were missing.

pub mod artifact;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub mod service;

pub use artifact::{Manifest, DECODE_SLOTS};
pub use client::Runtime;
pub use service::{ComputeService, PjrtHandle};
