//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! The `xla` crate's PJRT handles wrap raw pointers without `Send`/`Sync`,
//! so the runtime is owned by a dedicated **compute service thread**
//! ([`service::ComputeService`]); worker threads hold a cheap clonable
//! [`service::PjrtHandle`] and exchange requests/replies over channels.
//! The CPU PJRT executor parallelizes internally, so a single service
//! thread does not serialize the actual math.
//!
//! Interchange is HLO *text* (jax >= 0.5 protos use 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids — see
//! /opt/xla-example/README.md and DESIGN.md).

pub mod artifact;
pub mod client;
pub mod service;

pub use artifact::{Manifest, DECODE_SLOTS};
pub use client::Runtime;
pub use service::{ComputeService, PjrtHandle};
