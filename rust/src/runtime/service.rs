//! The compute service: a dedicated thread that owns the (non-`Send`)
//! PJRT [`Runtime`] and serves block-multiply requests from the worker
//! pool over channels. Cloning a [`PjrtHandle`] is cheap; dropping the
//! last handle shuts the service down.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::linalg::matrix::Matrix;
use crate::runtime::artifact::DECODE_SLOTS;
use crate::runtime::client::Runtime;

enum Request {
    WorkerTask {
        /// Originating job id (0 = untagged), for attributable errors
        /// and logs under job multiplexing.
        tag: u64,
        ca: [f32; 4],
        /// Shared with the dispatching work item — crossing the channel
        /// bumps a refcount, not four matrix copies.
        a4: Arc<[Matrix; 4]>,
        cb: [f32; 4],
        b4: Arc<[Matrix; 4]>,
        reply: Sender<Result<Matrix, String>>,
    },
    DecodeCombine {
        weights: Vec<f32>,
        products: Vec<Option<Matrix>>,
        bs: usize,
        reply: Sender<Result<Matrix, String>>,
    },
    /// Pre-serialized product stack (`DECODE_SLOTS·bs·bs` floats, zero
    /// padding for missing slots): the zero-clone decode wire format —
    /// the only multi-target request shape (un-stacked multi decode was
    /// removed when the decode path went zero-copy).
    DecodeCombineMultiStacked {
        weight_sets: Vec<Vec<f32>>,
        stacked: Vec<f32>,
        num_products: usize,
        bs: usize,
        reply: Sender<Result<Vec<Matrix>, String>>,
    },
    Matmul {
        a: Matrix,
        b: Matrix,
        reply: Sender<Result<Matrix, String>>,
    },
    Platform {
        reply: Sender<Result<String, String>>,
    },
}

/// Clonable, `Send + Sync` front-end to the service thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Request>,
}

// `Sender<T>` is `Send` but not `Sync`; the handle is cloned per thread,
// which is how the worker pool uses it.

impl PjrtHandle {
    fn call<T>(&self, build: impl FnOnce(Sender<Result<T, String>>) -> Request) -> Result<T, String> {
        let (tx, rx) = channel();
        self.tx
            .send(build(tx))
            .map_err(|_| "compute service is down".to_string())?;
        rx.recv().map_err(|_| "compute service dropped request".to_string())?
    }

    /// `(Σ ca A_i)(Σ cb B_j)` on the PJRT backend.
    pub fn worker_task(
        &self,
        ca: [f32; 4],
        a4: [Matrix; 4],
        cb: [f32; 4],
        b4: [Matrix; 4],
    ) -> Result<Matrix, String> {
        self.worker_task_tagged(0, ca, Arc::new(a4), cb, Arc::new(b4))
    }

    /// [`Self::worker_task`] tagged with the originating `job_id`, so
    /// multiplexed requests stay attributable in errors and logs. Takes
    /// the operand blocks by `Arc` so the worker pool's shared blocks
    /// cross into the service without being cloned.
    pub fn worker_task_tagged(
        &self,
        tag: u64,
        ca: [f32; 4],
        a4: Arc<[Matrix; 4]>,
        cb: [f32; 4],
        b4: Arc<[Matrix; 4]>,
    ) -> Result<Matrix, String> {
        self.call(|reply| Request::WorkerTask { tag, ca, a4, cb, b4, reply })
    }

    /// `Σ w[t] products[t]` on the PJRT backend.
    pub fn decode_combine(
        &self,
        weights: Vec<f32>,
        products: Vec<Option<Matrix>>,
        bs: usize,
    ) -> Result<Matrix, String> {
        self.call(|reply| Request::DecodeCombine { weights, products, bs, reply })
    }

    /// All four C blocks in one round-trip: borrows the products,
    /// copies each finished one ONCE into the pre-padded wire stack
    /// (missing slots stay zero — their weights must be zero), and
    /// ships the stack; no `Matrix` is cloned to cross the channel.
    pub fn decode_combine_multi(
        &self,
        weight_sets: Vec<Vec<f32>>,
        products: &[Option<Matrix>],
        bs: usize,
    ) -> Result<Vec<Matrix>, String> {
        if products.len() > DECODE_SLOTS {
            return Err(format!(
                "{} products exceed the {DECODE_SLOTS} decode slots",
                products.len()
            ));
        }
        let mut stacked = vec![0.0f32; DECODE_SLOTS * bs * bs];
        for (t, p) in products.iter().enumerate() {
            if let Some(m) = p {
                stacked[t * bs * bs..(t + 1) * bs * bs].copy_from_slice(m.as_slice());
            }
        }
        self.decode_combine_multi_stacked(weight_sets, stacked, products.len(), bs)
    }

    /// [`Self::decode_combine_multi`] over an already-serialized
    /// product stack (`DECODE_SLOTS·bs·bs` floats, missing slots zero).
    pub fn decode_combine_multi_stacked(
        &self,
        weight_sets: Vec<Vec<f32>>,
        stacked: Vec<f32>,
        num_products: usize,
        bs: usize,
    ) -> Result<Vec<Matrix>, String> {
        self.call(|reply| Request::DecodeCombineMultiStacked {
            weight_sets,
            stacked,
            num_products,
            bs,
            reply,
        })
    }

    /// Plain matmul baseline.
    pub fn matmul(&self, a: Matrix, b: Matrix) -> Result<Matrix, String> {
        self.call(|reply| Request::Matmul { a, b, reply })
    }

    /// Platform description (also a liveness probe).
    pub fn platform(&self) -> Result<String, String> {
        self.call(|reply| Request::Platform { reply })
    }
}

/// The service thread owner.
#[allow(missing_debug_implementations)]
pub struct ComputeService {
    handle: PjrtHandle,
    join: Option<JoinHandle<()>>,
}

impl ComputeService {
    /// Spawn the service; fails fast if the artifacts/manifest are
    /// missing or the PJRT client cannot start.
    pub fn spawn(artifacts_dir: &Path, warmup_sizes: &[usize]) -> Result<ComputeService, String> {
        let (tx, rx) = channel::<Request>();
        let dir = artifacts_dir.to_path_buf();
        let sizes = warmup_sizes.to_vec();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("pjrt-compute".into())
            .spawn(move || serve(dir, sizes, rx, ready_tx))
            .map_err(|e| format!("spawn compute service: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| "compute service died during init".to_string())??;
        Ok(ComputeService { handle: PjrtHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> PjrtHandle {
        self.handle.clone()
    }
}

impl std::fmt::Debug for ComputeService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ComputeService")
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        // Closing our handle clone isn't enough if callers hold clones;
        // the thread exits when ALL senders drop. We only join if the
        // channel is already closed to avoid blocking teardown.
        let _ = self.join.take(); // detach
    }
}

fn serve(
    dir: std::path::PathBuf,
    warmup_sizes: Vec<usize>,
    rx: Receiver<Request>,
    ready: Sender<Result<(), String>>,
) {
    let mut rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    for bs in &warmup_sizes {
        if let Err(e) = rt.warmup(*bs) {
            let _ = ready.send(Err(e));
            return;
        }
    }
    let _ = ready.send(Ok(()));
    while let Ok(req) = rx.recv() {
        match req {
            Request::WorkerTask { tag, ca, a4, cb, b4, reply } => {
                let _ = reply.send(
                    rt.worker_task(&ca, &a4, &cb, &b4)
                        .map_err(|e| format!("job {tag}: {e}")),
                );
            }
            Request::DecodeCombine { weights, products, bs, reply } => {
                let refs: Vec<Option<&Matrix>> = products.iter().map(|p| p.as_ref()).collect();
                let _ = reply.send(rt.decode_combine(&weights, &refs, bs));
            }
            Request::DecodeCombineMultiStacked {
                weight_sets,
                stacked,
                num_products,
                bs,
                reply,
            } => {
                let _ = reply.send(rt.decode_combine_multi_stacked(
                    &weight_sets,
                    &stacked,
                    num_products,
                    bs,
                ));
            }
            Request::Matmul { a, b, reply } => {
                let _ = reply.send(rt.matmul(&a, &b));
            }
            Request::Platform { reply } => {
                let _ = reply.send(Ok(rt.platform()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blocked::split_blocks;
    use crate::sim::rng::Rng;

    fn service() -> Option<ComputeService> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ComputeService::spawn(&dir, &[32]).ok()
    }

    #[test]
    fn spawn_fails_cleanly_without_artifacts() {
        let err = ComputeService::spawn(Path::new("/no/such/dir"), &[]).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn concurrent_worker_tasks_from_many_threads() {
        let Some(svc) = service() else { return };
        let mut rng = Rng::seeded(4);
        let a = Matrix::random(64, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        let want = a4[0].matmul(&b4[0]);
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = svc.handle();
                let (a4, b4, want) = (a4.clone(), b4.clone(), want.clone());
                s.spawn(move || {
                    let got = h
                        .worker_task([1.0, 0.0, 0.0, 0.0], a4, [1.0, 0.0, 0.0, 0.0], b4)
                        .unwrap();
                    assert!(got.approx_eq(&want, 1e-4));
                });
            }
        });
    }

    #[test]
    fn platform_probe() {
        let Some(svc) = service() else { return };
        let p = svc.handle().platform().unwrap();
        assert!(p.to_lowercase().contains("cpu") || !p.is_empty());
    }
}
