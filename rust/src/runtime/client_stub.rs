//! Stub PJRT client, compiled when the `pjrt` cargo feature is OFF.
//!
//! Mirrors the API surface of the real [`Runtime`] (`client.rs`) so the
//! compute service, benches and examples compile unchanged on machines
//! without `libxla_extension`. `Runtime::new` still loads and validates
//! the artifact manifest — a missing `artifacts/` directory reports the
//! usual "run `make artifacts`" error — but then always fails with a
//! feature-gate message, so a `Runtime` value is never constructed and
//! the coordinator falls back to the native backend.
//!
//! Why a stub rather than `#[cfg]`-ing out the call sites: the PJRT
//! runtime is threaded through the worker pool ([`Backend::Pjrt`] in
//! `coordinator::worker`), the decode path and the launcher, and
//! scattering feature gates across all of them would let native-only
//! builds rot. The stub keeps exactly one `#[cfg]` switch (in
//! `runtime::mod`) and makes every call site compile both ways; its
//! methods return the same `RtResult` error so callers exercise their
//! real error paths in tests.
//!
//! [`Backend::Pjrt`]: crate::coordinator::worker::Backend

use std::path::Path;

use crate::linalg::matrix::Matrix;
use crate::runtime::artifact::Manifest;

/// Errors from the runtime, stringly-typed at this boundary.
pub type RtResult<T> = Result<T, String>;

const DISABLED: &str = "ft_strassen was built without the `pjrt` feature; \
wire the vendored `xla` crate into rust/Cargo.toml (see the header comment \
there for the exact lines) and rebuild with `--features pjrt`";

/// Feature-gated stand-in for the PJRT runtime. Never constructible.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    /// Validate the artifact manifest, then fail with the feature-gate
    /// message (artifacts exist but this build cannot execute them).
    pub fn new(artifacts_dir: &Path) -> RtResult<Runtime> {
        let _ = Manifest::load(artifacts_dir)?;
        Err(DISABLED.to_string())
    }

    pub fn platform(&self) -> String {
        DISABLED.to_string()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn warmup(&mut self, _bs: usize) -> RtResult<()> {
        Err(DISABLED.to_string())
    }

    pub fn cached(&self) -> usize {
        0
    }

    pub fn worker_task(
        &mut self,
        _ca: &[f32; 4],
        _a4: &[Matrix; 4],
        _cb: &[f32; 4],
        _b4: &[Matrix; 4],
    ) -> RtResult<Matrix> {
        Err(DISABLED.to_string())
    }

    pub fn decode_combine(
        &mut self,
        _weights: &[f32],
        _products: &[Option<&Matrix>],
        _bs: usize,
    ) -> RtResult<Matrix> {
        Err(DISABLED.to_string())
    }

    pub fn decode_combine_multi(
        &mut self,
        _weight_sets: &[Vec<f32>],
        _products: &[Option<&Matrix>],
        _bs: usize,
    ) -> RtResult<Vec<Matrix>> {
        Err(DISABLED.to_string())
    }

    pub fn decode_combine_multi_stacked(
        &mut self,
        _weight_sets: &[Vec<f32>],
        _stacked: &[f32],
        _num_products: usize,
        _bs: usize,
    ) -> RtResult<Vec<Matrix>> {
        Err(DISABLED.to_string())
    }

    pub fn matmul(&mut self, _a: &Matrix, _b: &Matrix) -> RtResult<Matrix> {
        Err(DISABLED.to_string())
    }

    pub fn strassen_once(
        &mut self,
        _a4: &[Matrix; 4],
        _b4: &[Matrix; 4],
    ) -> RtResult<[Matrix; 4]> {
        Err(DISABLED.to_string())
    }

    pub fn winograd_once(
        &mut self,
        _a4: &[Matrix; 4],
        _b4: &[Matrix; 4],
    ) -> RtResult<[Matrix; 4]> {
        Err(DISABLED.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_artifacts_first() {
        let err = Runtime::new(Path::new("/no/such/dir")).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn stub_reports_feature_gate_when_artifacts_exist() {
        use std::io::Write;
        let dir = std::env::temp_dir().join(format!("ftms_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.tsv")).unwrap();
        write!(
            f,
            "worker_task_bs32\tworker_task_bs32.hlo.txt\tfloat32[4]\tfloat32[32,32]\n"
        )
        .unwrap();
        let err = Runtime::new(&dir).unwrap_err();
        assert!(err.contains("pjrt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
