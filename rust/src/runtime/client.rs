//! The PJRT client wrapper: compile HLO-text artifacts once, cache the
//! loaded executables, execute with `Matrix` inputs/outputs.
//!
//! NOT `Send`: must live on one thread (see [`crate::runtime::service`]
//! for the multi-threaded front-end).

use std::collections::HashMap;
use std::path::Path;

use crate::linalg::matrix::Matrix;
use crate::runtime::artifact::{Manifest, DECODE_SLOTS};

/// Errors from the runtime, stringly-typed at this boundary (the `xla`
/// crate error is not `Send`, and the service layer ships errors across
/// threads).
pub type RtResult<T> = Result<T, String>;

fn xerr<E: std::fmt::Display>(ctx: &str) -> impl FnOnce(E) -> String + '_ {
    move |e| format!("{ctx}: {e}")
}

/// One-thread PJRT runtime with an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifacts_dir: &Path) -> RtResult<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr("PjRtClient::cpu"))?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact by name.
    fn executable(&mut self, name: &str) -> RtResult<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self
                .manifest
                .path_of(name)
                .ok_or_else(|| format!("artifact `{name}` not in manifest"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(xerr("parse HLO text"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xerr("compile"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Pre-compile every artifact for the given block size (avoids
    /// first-request latency spikes).
    pub fn warmup(&mut self, bs: usize) -> RtResult<()> {
        for name in [
            format!("worker_task_bs{bs}"),
            format!("decode_combine_bs{bs}"),
            format!("strassen_once_bs{bs}"),
            format!("winograd_once_bs{bs}"),
            format!("matmul_n{}", 2 * bs),
        ] {
            if self.manifest.has(&name) {
                self.executable(&name)?;
            }
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> RtResult<xla::Literal> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(xerr("execute"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(xerr("to_literal_sync"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        lit.to_tuple1().map_err(xerr("to_tuple1"))
    }

    /// The generic worker product:
    /// `(Σ ca[i] A_i) @ (Σ cb[j] B_j)` at block size `bs`.
    pub fn worker_task(
        &mut self,
        ca: &[f32; 4],
        a4: &[Matrix; 4],
        cb: &[f32; 4],
        b4: &[Matrix; 4],
    ) -> RtResult<Matrix> {
        let bs = a4[0].rows();
        let name = format!("worker_task_bs{bs}");
        let inputs = [
            vec_literal(ca),
            stack_literal(a4)?,
            vec_literal(cb),
            stack_literal(b4)?,
        ];
        let out = self.run(&name, &inputs)?;
        literal_to_matrix(&out, bs, bs)
    }

    /// Decode combine: `Σ w[t] products[t]` with `DECODE_SLOTS` slots;
    /// missing products may be `None` (their weight must be 0).
    pub fn decode_combine(
        &mut self,
        weights: &[f32],
        products: &[Option<&Matrix>],
        bs: usize,
    ) -> RtResult<Matrix> {
        assert_eq!(weights.len(), products.len());
        assert!(weights.len() <= DECODE_SLOTS, "too many tasks for decode slots");
        let name = format!("decode_combine_bs{bs}");
        let mut w = vec![0.0f32; DECODE_SLOTS];
        w[..weights.len()].copy_from_slice(weights);
        let mut stacked = vec![0.0f32; DECODE_SLOTS * bs * bs];
        for (t, p) in products.iter().enumerate() {
            match p {
                Some(m) => {
                    assert_eq!(m.shape(), (bs, bs));
                    stacked[t * bs * bs..(t + 1) * bs * bs].copy_from_slice(m.as_slice());
                }
                None => assert_eq!(weights[t], 0.0, "missing product with nonzero weight"),
            }
        }
        let inputs = [
            xla::Literal::vec1(&w),
            xla::Literal::vec1(&stacked)
                .reshape(&[DECODE_SLOTS as i64, bs as i64, bs as i64])
                .map_err(xerr("reshape stack"))?,
        ];
        let out = self.run(&name, &inputs)?;
        literal_to_matrix(&out, bs, bs)
    }

    /// Multi-target decode: same product stack, several weight vectors
    /// (the master decodes all four C blocks per job). Serializes the
    /// borrowed products into the wire stack once, then delegates to
    /// [`Self::decode_combine_multi_stacked`].
    pub fn decode_combine_multi(
        &mut self,
        weight_sets: &[Vec<f32>],
        products: &[Option<&Matrix>],
        bs: usize,
    ) -> RtResult<Vec<Matrix>> {
        assert!(products.len() <= DECODE_SLOTS);
        let mut stacked = vec![0.0f32; DECODE_SLOTS * bs * bs];
        for (t, p) in products.iter().enumerate() {
            if let Some(m) = p {
                assert_eq!(m.shape(), (bs, bs));
                stacked[t * bs * bs..(t + 1) * bs * bs].copy_from_slice(m.as_slice());
            }
        }
        for weights in weight_sets {
            assert_eq!(weights.len(), products.len(), "weights/products length mismatch");
            for (t, p) in products.iter().enumerate() {
                if p.is_none() {
                    assert_eq!(weights[t], 0.0, "missing product with nonzero weight");
                }
            }
        }
        self.decode_combine_multi_stacked(weight_sets, &stacked, products.len(), bs)
    }

    /// Batched decode submission over a pre-serialized product stack
    /// (`DECODE_SLOTS·bs·bs` floats, zero padding for missing slots).
    /// The stacked literal is built ONCE and reused across the weight
    /// vectors — the dominant cost at bs >= 64 (§Perf) — and the caller
    /// never clones a `Matrix` to get its products on the wire.
    pub fn decode_combine_multi_stacked(
        &mut self,
        weight_sets: &[Vec<f32>],
        stacked: &[f32],
        num_products: usize,
        bs: usize,
    ) -> RtResult<Vec<Matrix>> {
        assert!(num_products <= DECODE_SLOTS, "too many tasks for decode slots");
        assert_eq!(stacked.len(), DECODE_SLOTS * bs * bs, "wire stack size");
        let name = format!("decode_combine_bs{bs}");
        let stack_lit = xla::Literal::vec1(stacked)
            .reshape(&[DECODE_SLOTS as i64, bs as i64, bs as i64])
            .map_err(xerr("reshape stack"))?;
        let mut out = Vec::with_capacity(weight_sets.len());
        for weights in weight_sets {
            assert_eq!(weights.len(), num_products);
            let mut w = vec![0.0f32; DECODE_SLOTS];
            w[..weights.len()].copy_from_slice(weights);
            let lit = self.run(&name, &[xla::Literal::vec1(&w), stack_lit.clone()])?;
            out.push(literal_to_matrix(&lit, bs, bs)?);
        }
        Ok(out)
    }

    /// Plain matmul baseline (`matmul_n{n}` artifact).
    pub fn matmul(&mut self, a: &Matrix, b: &Matrix) -> RtResult<Matrix> {
        let n = a.rows();
        let name = format!("matmul_n{n}");
        let inputs = [matrix_literal(a)?, matrix_literal(b)?];
        let out = self.run(&name, &inputs)?;
        literal_to_matrix(&out, n, n)
    }

    /// Single-node one-level Strassen through the L2 graph.
    pub fn strassen_once(&mut self, a4: &[Matrix; 4], b4: &[Matrix; 4]) -> RtResult<[Matrix; 4]> {
        self.once("strassen_once", a4, b4)
    }

    /// Single-node one-level Winograd through the L2 graph.
    pub fn winograd_once(&mut self, a4: &[Matrix; 4], b4: &[Matrix; 4]) -> RtResult<[Matrix; 4]> {
        self.once("winograd_once", a4, b4)
    }

    fn once(&mut self, which: &str, a4: &[Matrix; 4], b4: &[Matrix; 4]) -> RtResult<[Matrix; 4]> {
        let bs = a4[0].rows();
        let name = format!("{which}_bs{bs}");
        let inputs = [stack_literal(a4)?, stack_literal(b4)?];
        let out = self.run(&name, &inputs)?;
        let data: Vec<f32> = out.to_vec().map_err(xerr("to_vec"))?;
        if data.len() != 4 * bs * bs {
            return Err(format!("{name}: expected {} floats, got {}", 4 * bs * bs, data.len()));
        }
        let block = |i: usize| Matrix::from_slice(bs, bs, &data[i * bs * bs..(i + 1) * bs * bs]);
        Ok([block(0), block(1), block(2), block(3)])
    }
}

fn vec_literal(v: &[f32; 4]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn matrix_literal(m: &Matrix) -> RtResult<xla::Literal> {
    xla::Literal::vec1(m.as_slice())
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .map_err(xerr("reshape matrix"))
}

fn stack_literal(blocks: &[Matrix; 4]) -> RtResult<xla::Literal> {
    let (r, c) = blocks[0].shape();
    let mut data = Vec::with_capacity(4 * r * c);
    for b in blocks {
        assert_eq!(b.shape(), (r, c), "ragged block stack");
        data.extend_from_slice(b.as_slice());
    }
    xla::Literal::vec1(&data)
        .reshape(&[4, r as i64, c as i64])
        .map_err(xerr("reshape stack"))
}

fn literal_to_matrix(lit: &xla::Literal, r: usize, c: usize) -> RtResult<Matrix> {
    let data: Vec<f32> = lit.to_vec().map_err(xerr("to_vec"))?;
    if data.len() != r * c {
        return Err(format!("expected {}x{} = {} floats, got {}", r, c, r * c, data.len()));
    }
    Ok(Matrix::from_slice(r, c, &data))
}

#[cfg(test)]
mod tests {
    //! These tests need `artifacts/` (run `make artifacts` first); they
    //! self-skip when the manifest is missing so `cargo test` stays green
    //! on a fresh checkout.
    use super::*;
    use crate::linalg::blocked::split_blocks;
    use crate::sim::rng::Rng;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::new(&dir).ok()
    }

    #[test]
    fn worker_task_matches_native() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::seeded(1);
        let bs = 32;
        let a = Matrix::random(2 * bs, 2 * bs, &mut rng);
        let b = Matrix::random(2 * bs, 2 * bs, &mut rng);
        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        // S6 = (M21 - M11)(B11 + B12)
        let got = rt
            .worker_task(&[-1.0, 0.0, 1.0, 0.0], &a4, &[1.0, 1.0, 0.0, 0.0], &b4)
            .unwrap();
        let left = &a4[2] - &a4[0];
        let right = &b4[0] + &b4[1];
        let want = left.matmul(&right);
        assert!(got.approx_eq(&want, 1e-4), "rel err {}", got.rel_error(&want));
    }

    #[test]
    fn decode_combine_matches_native() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::seeded(2);
        let bs = 32;
        let mats: Vec<Matrix> = (0..16).map(|_| Matrix::random(bs, bs, &mut rng)).collect();
        let mut weights = vec![0.0f32; 16];
        weights[0] = 1.0;
        weights[3] = -1.0;
        weights[7] = 0.5;
        let products: Vec<Option<&Matrix>> = mats.iter().map(Some).collect();
        let got = rt.decode_combine(&weights, &products, bs).unwrap();
        let mut want = Matrix::zeros(bs, bs);
        let refs: Vec<&Matrix> = mats.iter().collect();
        Matrix::weighted_sum_into(&mut want, &weights, &refs);
        assert!(got.approx_eq(&want, 1e-5));
    }

    #[test]
    fn decode_combine_multi_matches_singles() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::seeded(21);
        let bs = 32;
        let mats: Vec<Matrix> = (0..16).map(|_| Matrix::random(bs, bs, &mut rng)).collect();
        let mut products: Vec<Option<&Matrix>> = mats.iter().map(Some).collect();
        products[5] = None; // a failed worker slot
        let mut w1 = vec![0.5f32; 16];
        w1[5] = 0.0;
        let mut w2 = vec![0.0f32; 16];
        w2[0] = 1.0;
        w2[15] = -1.0;
        let multi = rt
            .decode_combine_multi(&[w1.clone(), w2.clone()], &products, bs)
            .unwrap();
        // compare against the zero-filled single-shot path
        let zero = Matrix::zeros(bs, bs);
        let filled: Vec<Option<&Matrix>> = products
            .iter()
            .map(|p| Some(p.unwrap_or(&zero)))
            .collect();
        for (w, got) in [(w1, &multi[0]), (w2, &multi[1])] {
            let want = rt.decode_combine(&w, &filled, bs).unwrap();
            assert!(got.approx_eq(&want, 1e-5));
        }
    }

    #[test]
    fn matmul_and_once_paths() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Rng::seeded(3);
        let n = 64;
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let want = a.matmul(&b);
        let got = rt.matmul(&a, &b).unwrap();
        assert!(got.approx_eq(&want, 1e-4), "matmul rel {}", got.rel_error(&want));

        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        let cs = rt.strassen_once(&a4, &b4).unwrap();
        let cw = rt.winograd_once(&a4, &b4).unwrap();
        let want4 = split_blocks(&want);
        for i in 0..4 {
            assert!(cs[i].approx_eq(&want4[i], 1e-4), "strassen block {i}");
            assert!(cw[i].approx_eq(&want4[i], 1e-4), "winograd block {i}");
        }
    }

    #[test]
    fn warmup_caches_executables() {
        let Some(mut rt) = runtime() else { return };
        rt.warmup(32).unwrap();
        assert!(rt.cached() >= 4, "cached {}", rt.cached());
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(mut rt) = runtime() else { return };
        match rt.run("does_not_exist", &[]) {
            Ok(_) => panic!("expected error"),
            Err(err) => assert!(err.contains("not in manifest"), "{err}"),
        }
    }
}
