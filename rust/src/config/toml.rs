//! A pragmatic TOML-subset parser: `[section.sub]` tables, `key = value`
//! with string / integer / float / bool / homogeneous-array values, `#`
//! comments. Covers everything the launcher's config files use; rejects
//! anything outside the subset loudly rather than guessing.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key -> value (section names joined
/// with '.').
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, Value>,
}

impl TomlDoc {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn int_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Like [`TomlDoc::int_or`] for unsigned config fields: rejects a
    /// negative value with the offending key in the message, instead of
    /// letting a later `as usize` cast silently wrap it to a huge
    /// number.
    pub fn uint_or(&self, path: &str, default: usize) -> Result<usize, String> {
        let v = self.int_or(path, default as i64);
        usize::try_from(v).map_err(|_| format!("{path} must be >= 0, got {v}"))
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, message: impl Into<String>) -> TomlError {
    TomlError { line, message: message.into() }
}

/// Parse a document.
pub fn parse_toml(input: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, format!("expected `key = value`, got `{line}`")))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let parsed = parse_value(value.trim(), lineno)?;
        if doc.entries.insert(path.clone(), parsed).is_some() {
            return Err(err(lineno, format!("duplicate key `{path}`")));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, TomlError> {
    if s.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quote in string (subset: no escapes)"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, TomlError> = inner
            .split(',')
            .map(|p| parse_value(p.trim(), lineno))
            .collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, format!("cannot parse value `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse_toml(
            r#"
# top comment
name = "ft"            # trailing comment
[run]
block_size = 128
p_e = 0.05
verbose = true
[run.worker]
count = 16
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "ft");
        assert_eq!(doc.int_or("run.block_size", 0), 128);
        assert!((doc.float_or("run.p_e", 0.0) - 0.05).abs() < 1e-12);
        assert!(doc.bool_or("run.verbose", false));
        assert_eq!(doc.int_or("run.worker.count", 0), 16);
    }

    #[test]
    fn arrays() {
        let doc = parse_toml("sizes = [32, 64, 128]\nnames = [\"a\", \"b\"]").unwrap();
        let sizes: Vec<i64> = doc
            .get("sizes")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_int().unwrap())
            .collect();
        assert_eq!(sizes, vec![32, 64, 128]);
        assert_eq!(
            doc.get("names").unwrap().as_array().unwrap()[1].as_str(),
            Some("b")
        );
    }

    #[test]
    fn int_vs_float() {
        let doc = parse_toml("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc.get("a"), Some(&Value::Int(3)));
        assert_eq!(doc.get("b"), Some(&Value::Float(3.5)));
        // int coerces to float on demand
        assert_eq!(doc.float_or("a", 0.0), 3.0);
        // but not the reverse
        assert_eq!(doc.int_or("b", -1), -1);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_toml("x = ").unwrap_err();
        assert!(e.message.contains("missing value"));
        let e = parse_toml("[oops").unwrap_err();
        assert!(e.message.contains("unterminated section"));
        let e = parse_toml("a = 1\na = 2").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn defaults_on_missing() {
        let doc = parse_toml("").unwrap();
        assert_eq!(doc.int_or("nope", 9), 9);
        assert_eq!(doc.str_or("nope", "d"), "d");
    }

    #[test]
    fn uint_rejects_negatives_with_the_key_name() {
        let doc = parse_toml("a = 12\nb = -3").unwrap();
        assert_eq!(doc.uint_or("a", 0).unwrap(), 12);
        assert_eq!(doc.uint_or("nope", 7).unwrap(), 7);
        let e = doc.uint_or("b", 0).unwrap_err();
        assert!(e.contains('b') && e.contains("-3"), "{e}");
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_toml("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }
}
