//! Typed run configuration: what scheme to run, on which backend, with
//! which failure model — loadable from a TOML file and overridable from
//! the CLI (the launcher merges both).

use std::path::PathBuf;

use super::toml::{parse_toml, TomlDoc, TomlError};
use crate::algorithms::{strassen, winograd};
use crate::coding::nested::NestedTaskSet;
use crate::coding::scheme::TaskSet;
use crate::coordinator::tier::TenantSpec;
use crate::linalg::kernel::KernelKind;
use crate::sim::latency::LatencyModel;

/// Which task-set family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// c-copy replication of Strassen.
    StrassenReplicated { copies: usize },
    /// c-copy replication of Winograd.
    WinogradReplicated { copies: usize },
    /// The paper's joint configuration with 0..=2 PSMMs.
    StrassenWinograd { psmms: usize },
}

impl SchemeKind {
    /// Parse names like `strassen-x2`, `winograd-x1`, `sw+2psmm`.
    pub fn parse(s: &str) -> Result<SchemeKind, String> {
        let s = s.trim().to_lowercase();
        if let Some(rest) = s.strip_prefix("strassen-x") {
            let c: usize = rest.parse().map_err(|_| format!("bad copies in `{s}`"))?;
            return Ok(SchemeKind::StrassenReplicated { copies: c });
        }
        if let Some(rest) = s.strip_prefix("winograd-x") {
            let c: usize = rest.parse().map_err(|_| format!("bad copies in `{s}`"))?;
            return Ok(SchemeKind::WinogradReplicated { copies: c });
        }
        if let Some(rest) = s.strip_prefix("sw+") {
            let p: usize = rest
                .strip_suffix("psmm")
                .ok_or_else(|| format!("expected sw+<n>psmm, got `{s}`"))?
                .parse()
                .map_err(|_| format!("bad psmm count in `{s}`"))?;
            if p > 2 {
                return Err("at most 2 PSMMs supported".into());
            }
            return Ok(SchemeKind::StrassenWinograd { psmms: p });
        }
        Err(format!(
            "unknown scheme `{s}` (try strassen-x1/2/3, winograd-x1, sw+0psmm, sw+1psmm, sw+2psmm)"
        ))
    }

    /// Materialize the task set.
    pub fn task_set(&self) -> TaskSet {
        match *self {
            SchemeKind::StrassenReplicated { copies } => {
                TaskSet::replication(&strassen(), copies)
            }
            SchemeKind::WinogradReplicated { copies } => {
                TaskSet::replication(&winograd(), copies)
            }
            SchemeKind::StrassenWinograd { psmms } => TaskSet::strassen_winograd(psmms),
        }
    }

    pub fn display_name(&self) -> String {
        match *self {
            SchemeKind::StrassenReplicated { copies } => format!("strassen-x{copies}"),
            SchemeKind::WinogradReplicated { copies } => format!("winograd-x{copies}"),
            SchemeKind::StrassenWinograd { psmms } => format!("sw+{psmms}psmm"),
        }
    }
}

/// A nested two-level scheme spec: `outer:inner` (each side any
/// [`SchemeKind`] name), e.g. `sw+2psmm:sw+2psmm` for the 256-leaf
/// composition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NestSpec {
    pub outer: SchemeKind,
    pub inner: SchemeKind,
}

impl NestSpec {
    pub fn parse(s: &str) -> Result<NestSpec, String> {
        let (o, i) = s
            .split_once(':')
            .ok_or_else(|| format!("expected outer:inner (e.g. sw+2psmm:sw+2psmm), got `{s}`"))?;
        Ok(NestSpec { outer: SchemeKind::parse(o)?, inner: SchemeKind::parse(i)? })
    }

    /// Materialize the composed task set.
    pub fn task_set(&self) -> NestedTaskSet {
        NestedTaskSet::compose(self.outer.task_set(), self.inner.task_set())
    }

    pub fn display_name(&self) -> String {
        format!("{}:{}", self.outer.display_name(), self.inner.display_name())
    }
}

/// Which compute backend executes block multiplications.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust blocked matmul (always available; test hermetic).
    Native,
    /// AOT Pallas artifacts through PJRT (the production hot path).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind, String> {
        match s.trim().to_lowercase().as_str() {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend `{other}` (native|pjrt)")),
        }
    }
}

/// Full launcher configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub scheme: SchemeKind,
    /// When set, dispatch nested (two-level) instead of `scheme`:
    /// `outer:inner` composition, n must be divisible by 4.
    pub nest: Option<NestSpec>,
    pub backend: BackendKind,
    /// Matrix dimension n (the multiply is n x n).
    pub n: usize,
    /// Worker threads in the pool.
    pub workers: usize,
    /// Node failure probability (Bernoulli model).
    pub p_e: f64,
    /// Straggler injection: probability a worker sleeps `straggle_ms`.
    pub p_straggle: f64,
    pub straggle_ms: u64,
    /// Master-side deadline before declaring nodes failed (ms).
    pub deadline_ms: u64,
    pub seed: u64,
    /// Directory with AOT artifacts (for the PJRT backend).
    pub artifacts_dir: PathBuf,
    /// Native matmul kernel family (`--kernel {naive,packed,simd}`);
    /// packed/simd still route sub-break-even products to the naive
    /// kernel via the global dispatch, and `simd` degrades to `packed`
    /// on CPUs without the features.
    pub kernel: KernelKind,
    /// Worker threads for the packed kernel's row-panel loop (>= 1;
    /// 1 = serial, the safe default under the multi-threaded pool).
    pub kernel_threads: usize,
    /// Recursive split/leaf crossover for the single-node recursive
    /// path (`localmm`): at or below this dimension leaves go straight
    /// to the kernel (TOML `run.cutoff`, CLI `--cutoff`; >= 1).
    pub crossover: usize,
    /// Maximum recursion depth for the single-node recursive path;
    /// 0 = unlimited (TOML `run.max_depth`, CLI `--max-depth`).
    pub max_depth: usize,
    /// Serving tier: maximum concurrently in-flight jobs (TOML
    /// `serve.depth`, CLI `--depth`; >= 1).
    pub depth: usize,
    /// Serving tier: outstanding-job cap before `submit` reports
    /// backpressure (TOML `serve.queue_cap`, CLI `--queue-cap`; >= 1).
    pub queue_cap: usize,
    /// Serving tier: jobs coalesced into one dispatch round (TOML
    /// `serve.batch_window`, CLI `--batch-window`; >= 1, 1 = no
    /// batching).
    pub batch_window: usize,
    /// Serving tier: encoded-operand cache capacity in operands (TOML
    /// `cache.cap`, CLI `--cache-cap`; 0 disables the cache).
    pub cache_cap: usize,
    /// Serving tier: tenant specs `name:weight:quota` (TOML
    /// `tenants.specs` string array, CLI `--tenants` comma-separated).
    /// Empty = one unbounded `default` tenant.
    pub tenants: Vec<TenantSpec>,
    /// Fleet simulator: workers per rack — the correlated failure
    /// domain (TOML `fleet.rack_size`, CLI `--rack-size`; >= 1).
    pub rack_size: usize,
    /// Fleet simulator: per-(job, rack) outage probability (TOML
    /// `fleet.p_rack`, CLI `--p-rack`; 0 disables rack faults).
    pub p_rack: f64,
    /// Fleet simulator: one-way link latency in ms (TOML
    /// `fleet.link_latency_ms`, CLI `--link-latency-ms`).
    pub link_latency_ms: f64,
    /// Fleet simulator: link bandwidth in Gbit/s (TOML
    /// `fleet.link_gbps`, CLI `--link-gbps`; 0 = infinite).
    pub link_gbps: f64,
    /// Fleet simulator: per-worker slowness-multiplier distribution
    /// (TOML `fleet.speed`, CLI `--speed`; spellings of
    /// [`LatencyModel::parse`], default `det:1` = homogeneous).
    pub fleet_speed: LatencyModel,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            scheme: SchemeKind::StrassenWinograd { psmms: 2 },
            nest: None,
            backend: BackendKind::Native,
            n: 256,
            workers: 16,
            p_e: 0.0,
            p_straggle: 0.0,
            straggle_ms: 50,
            deadline_ms: 1_000,
            seed: 0,
            artifacts_dir: PathBuf::from("artifacts"),
            kernel: KernelKind::Packed,
            kernel_threads: 1,
            crossover: 64,
            max_depth: 0,
            depth: 4,
            queue_cap: 4096,
            batch_window: 1,
            cache_cap: 0,
            tenants: Vec::new(),
            rack_size: 32,
            p_rack: 0.0,
            link_latency_ms: 0.0,
            link_gbps: 0.0,
            fleet_speed: LatencyModel::Deterministic { t: 1.0 },
        }
    }
}

impl RunConfig {
    /// Load from a TOML document (all keys optional; defaults above).
    pub fn from_toml(doc: &TomlDoc) -> Result<RunConfig, String> {
        let d = RunConfig::default();
        let scheme = match doc.get("run.scheme") {
            Some(v) => SchemeKind::parse(
                v.as_str().ok_or("run.scheme must be a string")?,
            )?,
            None => d.scheme,
        };
        let backend = match doc.get("run.backend") {
            Some(v) => BackendKind::parse(
                v.as_str().ok_or("run.backend must be a string")?,
            )?,
            None => d.backend,
        };
        let nest = match doc.get("run.nest") {
            Some(v) => Some(NestSpec::parse(
                v.as_str().ok_or("run.nest must be a string")?,
            )?),
            None => d.nest,
        };
        let kernel = match doc.get("run.kernel") {
            Some(v) => KernelKind::parse(
                v.as_str().ok_or("run.kernel must be a string")?,
            )?,
            None => d.kernel,
        };
        // Validate in i64 BEFORE the usize cast: a negative TOML value
        // would otherwise wrap to a huge thread count and sail past
        // validate()'s `== 0` check.
        let kernel_threads = doc.int_or("run.kernel_threads", d.kernel_threads as i64);
        if kernel_threads < 1 {
            return Err(format!("run.kernel_threads must be >= 1, got {kernel_threads}"));
        }
        let cfg = RunConfig {
            scheme,
            nest,
            backend,
            n: doc.int_or("run.n", d.n as i64) as usize,
            workers: doc.int_or("run.workers", d.workers as i64) as usize,
            p_e: doc.float_or("fault.p_e", d.p_e),
            p_straggle: doc.float_or("fault.p_straggle", d.p_straggle),
            straggle_ms: doc.int_or("fault.straggle_ms", d.straggle_ms as i64) as u64,
            deadline_ms: doc.int_or("run.deadline_ms", d.deadline_ms as i64) as u64,
            seed: doc.int_or("run.seed", d.seed as i64) as u64,
            artifacts_dir: PathBuf::from(
                doc.str_or("run.artifacts_dir", d.artifacts_dir.to_str().unwrap()),
            ),
            kernel,
            kernel_threads: kernel_threads as usize,
            crossover: doc.uint_or("run.cutoff", d.crossover)?,
            max_depth: doc.uint_or("run.max_depth", d.max_depth)?,
            depth: doc.uint_or("serve.depth", d.depth)?,
            queue_cap: doc.uint_or("serve.queue_cap", d.queue_cap)?,
            batch_window: doc.uint_or("serve.batch_window", d.batch_window)?,
            cache_cap: doc.uint_or("cache.cap", d.cache_cap)?,
            tenants: match doc.get("tenants.specs") {
                Some(v) => {
                    let arr = v
                        .as_array()
                        .ok_or("tenants.specs must be an array of strings")?;
                    arr.iter()
                        .map(|it| {
                            let s = it
                                .as_str()
                                .ok_or("tenants.specs entries must be strings")?;
                            TenantSpec::parse(s)
                        })
                        .collect::<Result<Vec<_>, _>>()?
                }
                None => d.tenants,
            },
            rack_size: doc.uint_or("fleet.rack_size", d.rack_size)?,
            p_rack: doc.float_or("fleet.p_rack", d.p_rack),
            link_latency_ms: doc.float_or("fleet.link_latency_ms", d.link_latency_ms),
            link_gbps: doc.float_or("fleet.link_gbps", d.link_gbps),
            fleet_speed: match doc.get("fleet.speed") {
                Some(v) => {
                    LatencyModel::parse(v.as_str().ok_or("fleet.speed must be a string")?)?
                }
                None => d.fleet_speed,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The recursion parameters for the single-node recursive path,
    /// with the `max_depth == 0` sentinel mapped to unlimited and the
    /// configured kernel routed explicitly to the leaves.
    pub fn recursive_config(&self) -> crate::linalg::recursive::RecursiveConfig {
        crate::linalg::recursive::RecursiveConfig {
            crossover: self.crossover,
            max_depth: if self.max_depth == 0 { usize::MAX } else { self.max_depth },
            leaf: self.kernel,
        }
    }

    /// The simulated-fleet spec for the `simfleet` subcommand: the
    /// `[fleet]` knobs plus an explicit worker count and per-leaf
    /// service-time model (those two are sweep parameters, not config).
    pub fn fleet_spec(
        &self,
        workers: usize,
        leaf_latency: LatencyModel,
    ) -> crate::sim::des::FleetSpec {
        crate::sim::des::FleetSpec {
            workers,
            rack_size: self.rack_size,
            p_rack: self.p_rack,
            speed: self.fleet_speed,
            leaf_latency,
            link: crate::sim::des::LinkModel {
                latency_s: self.link_latency_ms / 1e3,
                // Gbit/s -> bytes/s.
                bytes_per_s: self.link_gbps * 1.25e8,
            },
        }
    }

    /// Load from a file path. Inert-key warnings (present keys that
    /// cannot take effect under the rest of the config) go to stderr —
    /// they are advisory, never errors.
    pub fn from_file(path: &std::path::Path) -> Result<RunConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let doc = parse_toml(&text).map_err(|e: TomlError| format!("{}: {e}", path.display()))?;
        for w in inert_key_warnings(&doc) {
            eprintln!("warning: {}: {w}", path.display());
        }
        RunConfig::from_toml(&doc)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 || self.n % 2 != 0 {
            return Err(format!("n must be even and positive, got {}", self.n));
        }
        if self.nest.is_some() && self.n % 4 != 0 {
            return Err(format!(
                "nested schemes split twice: n must be divisible by 4, got {}",
                self.n
            ));
        }
        if self.workers == 0 {
            return Err("workers must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.p_e) {
            return Err(format!("p_e out of [0,1]: {}", self.p_e));
        }
        if !(0.0..=1.0).contains(&self.p_straggle) {
            return Err(format!("p_straggle out of [0,1]: {}", self.p_straggle));
        }
        if self.p_e + self.p_straggle > 1.0 {
            return Err(format!(
                "fail/straggle are exclusive marginals: p_e + p_straggle must be <= 1, \
                 got {} + {}",
                self.p_e, self.p_straggle
            ));
        }
        if self.kernel_threads == 0 {
            return Err("kernel_threads must be >= 1".into());
        }
        if self.crossover == 0 {
            return Err("cutoff (recursive crossover) must be >= 1".into());
        }
        if self.depth == 0 {
            return Err("serve.depth must be >= 1".into());
        }
        if self.queue_cap == 0 {
            return Err("serve.queue_cap must be >= 1".into());
        }
        if self.batch_window == 0 {
            return Err("serve.batch_window must be >= 1".into());
        }
        if self.rack_size == 0 {
            return Err("fleet.rack_size must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.p_rack) {
            return Err(format!("fleet.p_rack out of [0,1]: {}", self.p_rack));
        }
        if self.link_latency_ms < 0.0 || !self.link_latency_ms.is_finite() {
            return Err(format!("fleet.link_latency_ms must be >= 0, got {}", self.link_latency_ms));
        }
        if self.link_gbps < 0.0 || !self.link_gbps.is_finite() {
            return Err(format!("fleet.link_gbps must be >= 0, got {}", self.link_gbps));
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if t.quota != usize::MAX && t.quota > self.queue_cap {
                return Err(format!(
                    "tenant `{}` quota {} exceeds queue_cap {} — the quota could never \
                     bind",
                    t.name, t.quota, self.queue_cap
                ));
            }
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(format!("duplicate tenant name `{}`", t.name));
            }
        }
        Ok(())
    }

    /// Build the serving-tier configuration from the serve/tenants/cache
    /// fields plus a per-job policy.
    pub fn tier_config(
        &self,
        master: crate::coordinator::master::MasterConfig,
    ) -> crate::coordinator::tier::TierConfig {
        crate::coordinator::tier::TierConfig {
            master,
            depth: self.depth,
            queue_cap: self.queue_cap,
            tenants: self.tenants.clone(),
            batch_window: self.batch_window,
            cache_cap: self.cache_cap,
        }
    }
}

/// Keys that are *present* in the document but cannot take effect
/// under the rest of the configuration. Each warning names the key,
/// why it is dead, and what to change. Advisory only: an inert key is
/// never an error (profiles legitimately share a base file), but a
/// silent one cost us a debugging session — `configs/sim_fig2.toml`
/// shipped `straggle_ms = 50` next to `p_straggle = 0.0` for five PRs.
pub fn inert_key_warnings(doc: &TomlDoc) -> Vec<String> {
    let mut out = Vec::new();
    let p_straggle = doc.float_or("fault.p_straggle", 0.0);
    if doc.get("fault.straggle_ms").is_some() && p_straggle <= 0.0 {
        out.push(
            "fault.straggle_ms is inert: fault.p_straggle is 0, so no dispatch ever \
             straggles (set p_straggle > 0, or drop the key — see \
             configs/sim_fig2_straggle.toml)"
                .to_string(),
        );
    }
    let d = RunConfig::default();
    if doc.int_or("serve.batch_window", 1) > 1 && doc.int_or("serve.depth", d.depth as i64) == 1
    {
        out.push(
            "serve.batch_window > 1 is inert: serve.depth = 1 admits one job at a time, \
             so no batch ever forms"
                .to_string(),
        );
    }
    if doc.int_or("cache.cap", 0) > 0 && doc.str_or("run.backend", "native") == "pjrt" {
        out.push(
            "cache.cap is inert: the pjrt backend ships raw blocks, so cached encoded \
             operands are never routed to it (use run.backend = \"native\")"
                .to_string(),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        assert_eq!(
            SchemeKind::parse("strassen-x3").unwrap(),
            SchemeKind::StrassenReplicated { copies: 3 }
        );
        assert_eq!(
            SchemeKind::parse("SW+2PSMM").unwrap(),
            SchemeKind::StrassenWinograd { psmms: 2 }
        );
        assert_eq!(
            SchemeKind::parse("winograd-x1").unwrap(),
            SchemeKind::WinogradReplicated { copies: 1 }
        );
        assert!(SchemeKind::parse("sw+3psmm").is_err());
        assert!(SchemeKind::parse("bogus").is_err());
    }

    #[test]
    fn scheme_materializes() {
        assert_eq!(
            SchemeKind::parse("sw+2psmm").unwrap().task_set().num_tasks(),
            16
        );
        assert_eq!(
            SchemeKind::parse("strassen-x2").unwrap().task_set().num_tasks(),
            14
        );
    }

    #[test]
    fn nest_spec_parsing() {
        let n = NestSpec::parse("sw+2psmm:strassen-x2").unwrap();
        assert_eq!(n.outer, SchemeKind::StrassenWinograd { psmms: 2 });
        assert_eq!(n.inner, SchemeKind::StrassenReplicated { copies: 2 });
        assert_eq!(n.display_name(), "sw+2psmm:strassen-x2");
        assert_eq!(n.task_set().num_leaves(), 16 * 14);
        assert!(NestSpec::parse("sw+2psmm").is_err(), "missing inner");
        assert!(NestSpec::parse("bogus:sw+2psmm").is_err());
    }

    #[test]
    fn nest_in_toml_and_validation() {
        let doc = parse_toml("[run]\nnest = \"sw+0psmm:sw+0psmm\"\nn = 64").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(
            cfg.nest,
            Some(NestSpec {
                outer: SchemeKind::StrassenWinograd { psmms: 0 },
                inner: SchemeKind::StrassenWinograd { psmms: 0 },
            })
        );
        // Nested requires n % 4 == 0.
        let doc = parse_toml("[run]\nnest = \"sw+0psmm:sw+0psmm\"\nn = 6").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn config_from_toml_with_defaults() {
        let doc = parse_toml(
            r#"
[run]
scheme = "sw+1psmm"
n = 128
[fault]
p_e = 0.2
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.scheme, SchemeKind::StrassenWinograd { psmms: 1 });
        assert_eq!(cfg.n, 128);
        assert!((cfg.p_e - 0.2).abs() < 1e-12);
        // untouched fields keep defaults
        assert_eq!(cfg.workers, RunConfig::default().workers);
    }

    #[test]
    fn config_validation() {
        let mut cfg = RunConfig::default();
        cfg.n = 7;
        assert!(cfg.validate().is_err());
        cfg.n = 64;
        cfg.p_e = 1.5;
        assert!(cfg.validate().is_err());
        cfg.p_e = 0.1;
        cfg.workers = 0;
        assert!(cfg.validate().is_err());
        cfg.workers = 4;
        cfg.p_e = 0.7;
        cfg.p_straggle = 0.6;
        assert!(cfg.validate().is_err(), "marginals must sum to <= 1");
        cfg.p_straggle = 0.2;
        assert!(cfg.validate().is_ok());
        cfg.kernel_threads = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn kernel_in_toml() {
        let doc = parse_toml("[run]\nkernel = \"naive\"\nkernel_threads = 4").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.kernel, KernelKind::Naive);
        assert_eq!(cfg.kernel_threads, 4);
        assert_eq!(RunConfig::default().kernel, KernelKind::Packed);
        let doc = parse_toml("[run]\nkernel = \"blas\"").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // Negative thread counts must not wrap through the usize cast.
        let doc = parse_toml("[run]\nkernel_threads = -2").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("kernel_threads"), "{err}");
    }

    #[test]
    fn cutoff_and_depth_in_toml() {
        let doc = parse_toml("[run]\ncutoff = 32\nmax_depth = 3\nkernel = \"simd\"").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.crossover, 32);
        assert_eq!(cfg.max_depth, 3);
        assert_eq!(cfg.kernel, KernelKind::Simd);
        let rc = cfg.recursive_config();
        assert_eq!(rc.crossover, 32);
        assert_eq!(rc.max_depth, 3);
        assert_eq!(rc.leaf, KernelKind::Simd);
        // Defaults: crossover 64, depth sentinel 0 -> unlimited.
        let d = RunConfig::default();
        assert_eq!(d.crossover, 64);
        assert_eq!(d.recursive_config().max_depth, usize::MAX);
        // Negative values must not wrap through the usize cast.
        let doc = parse_toml("[run]\ncutoff = -1").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("cutoff"), "{err}");
        let doc = parse_toml("[run]\nmax_depth = -4").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("max_depth"), "{err}");
        // cutoff = 0 is rejected by validation.
        let doc = parse_toml("[run]\ncutoff = 0").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn bad_scheme_in_toml_is_error() {
        let doc = parse_toml("[run]\nscheme = \"nope\"").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ftms_cfg_{}.toml", std::process::id()));
        std::fs::write(
            &path,
            "[run]\nscheme = \"winograd-x1\"\nbackend = \"native\"\nn = 64\n\
             deadline_ms = 250\nseed = 9\n[fault]\np_straggle = 0.25\nstraggle_ms = 10\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.scheme, SchemeKind::WinogradReplicated { copies: 1 });
        assert_eq!(cfg.backend, BackendKind::Native);
        assert_eq!(cfg.n, 64);
        assert_eq!(cfg.deadline_ms, 250);
        assert_eq!(cfg.seed, 9);
        assert!((cfg.p_straggle - 0.25).abs() < 1e-12);
        assert_eq!(cfg.straggle_ms, 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn from_file_missing_is_descriptive() {
        let err = RunConfig::from_file(std::path::Path::new("/no/such.toml")).unwrap_err();
        assert!(err.contains("/no/such.toml"), "{err}");
    }

    #[test]
    fn serve_sections_in_toml() {
        let doc = parse_toml(
            r#"
[serve]
depth = 8
queue_cap = 64
batch_window = 4
[cache]
cap = 32
[tenants]
specs = ["heavy:3:16", "light:1:4"]
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.depth, 8);
        assert_eq!(cfg.queue_cap, 64);
        assert_eq!(cfg.batch_window, 4);
        assert_eq!(cfg.cache_cap, 32);
        assert_eq!(cfg.tenants.len(), 2);
        assert_eq!(cfg.tenants[0].name, "heavy");
        assert_eq!(cfg.tenants[0].weight, 3);
        assert_eq!(cfg.tenants[0].quota, 16);
        assert_eq!(cfg.tenants[1].name, "light");
        // Defaults when the sections are absent.
        let d = RunConfig::default();
        assert_eq!(d.depth, 4);
        assert_eq!(d.queue_cap, 4096);
        assert_eq!(d.batch_window, 1);
        assert_eq!(d.cache_cap, 0);
        assert!(d.tenants.is_empty());
        let tc = cfg.tier_config(crate::coordinator::master::MasterConfig::default());
        assert_eq!(tc.depth, 8);
        assert_eq!(tc.tenants.len(), 2);
    }

    #[test]
    fn serve_sections_reject_bad_values() {
        // A malformed tenant spec is a parse error, not a silent skip.
        let doc = parse_toml("[tenants]\nspecs = [\"heavy:0:4\"]").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err(), "weight 0 rejected");
        let doc = parse_toml("[tenants]\nspecs = [\"oops\"]").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err(), "missing fields rejected");
        let doc = parse_toml("[tenants]\nspecs = [3]").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err(), "non-string entry rejected");
        // A quota no queue could ever satisfy is a config error.
        let doc = parse_toml(
            "[serve]\nqueue_cap = 4\n[tenants]\nspecs = [\"big:1:100\"]",
        )
        .unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("exceeds queue_cap"), "{err}");
        // Duplicate tenant names are rejected.
        let doc = parse_toml("[tenants]\nspecs = [\"a:1:1\", \"a:2:2\"]").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("duplicate tenant"), "{err}");
        // Zero knobs are rejected.
        let doc = parse_toml("[serve]\ndepth = 0").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = parse_toml("[serve]\nbatch_window = 0").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = parse_toml("[serve]\nqueue_cap = 0").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        // Negative values must not wrap through the usize cast.
        let doc = parse_toml("[cache]\ncap = -1").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("cache.cap"), "{err}");
    }

    #[test]
    fn fleet_section_in_toml() {
        let doc = parse_toml(
            r#"
[fleet]
rack_size = 16
p_rack = 0.05
link_latency_ms = 0.5
link_gbps = 10
speed = "bimodal:1:0.1:4"
"#,
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.rack_size, 16);
        assert!((cfg.p_rack - 0.05).abs() < 1e-12);
        assert_eq!(
            cfg.fleet_speed,
            LatencyModel::Bimodal { base: 1.0, p_slow: 0.1, factor: 4.0 }
        );
        let spec = cfg.fleet_spec(1000, LatencyModel::Deterministic { t: 0.01 });
        assert_eq!(spec.workers, 1000);
        assert_eq!(spec.rack_size, 16);
        assert!((spec.link.latency_s - 5e-4).abs() < 1e-15);
        assert!((spec.link.bytes_per_s - 1.25e9).abs() < 1.0);
        // Defaults: free link, homogeneous speeds, no rack faults.
        let d = RunConfig::default();
        assert_eq!(d.rack_size, 32);
        assert_eq!(d.p_rack, 0.0);
        assert_eq!(d.fleet_speed, LatencyModel::Deterministic { t: 1.0 });
        // Bad values are rejected.
        let doc = parse_toml("[fleet]\nrack_size = 0").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = parse_toml("[fleet]\np_rack = 1.5").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
        let doc = parse_toml("[fleet]\nspeed = \"warp:9\"").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn inert_keys_are_flagged_with_reasons() {
        // The sim_fig2 regression: straggle_ms next to p_straggle = 0.
        let doc = parse_toml("[fault]\np_straggle = 0.0\nstraggle_ms = 50").unwrap();
        let w = inert_key_warnings(&doc);
        assert_eq!(w.len(), 1, "{w:?}");
        assert!(w[0].contains("straggle_ms"), "{w:?}");
        // straggle_ms with p_straggle unset (defaults to 0) also warns.
        let doc = parse_toml("[fault]\nstraggle_ms = 50").unwrap();
        assert_eq!(inert_key_warnings(&doc).len(), 1);
        // ... but a live straggle probability silences it.
        let doc = parse_toml("[fault]\np_straggle = 0.2\nstraggle_ms = 50").unwrap();
        assert!(inert_key_warnings(&doc).is_empty());
        // batch_window > 1 under depth = 1 can never form a batch.
        let doc = parse_toml("[serve]\ndepth = 1\nbatch_window = 8").unwrap();
        let w = inert_key_warnings(&doc);
        assert_eq!(w.len(), 1);
        assert!(w[0].contains("batch_window"), "{w:?}");
        let doc = parse_toml("[serve]\ndepth = 4\nbatch_window = 8").unwrap();
        assert!(inert_key_warnings(&doc).is_empty());
        // Encoded-operand cache never reaches pjrt workers.
        let doc = parse_toml("[run]\nbackend = \"pjrt\"\n[cache]\ncap = 64").unwrap();
        let w = inert_key_warnings(&doc);
        assert_eq!(w.len(), 1);
        assert!(w[0].contains("cache.cap"), "{w:?}");
        // A clean config warns about nothing.
        let doc = parse_toml("[run]\nn = 64\n[fault]\np_e = 0.1").unwrap();
        assert!(inert_key_warnings(&doc).is_empty());
    }

    #[test]
    fn example_configs_in_repo_parse() {
        for f in [
            "configs/serve_pjrt.toml",
            "configs/sim_fig2.toml",
            "configs/sim_fig2_straggle.toml",
            "configs/serve_tenants.toml",
        ] {
            let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
            let cfg = RunConfig::from_file(&p).unwrap_or_else(|e| panic!("{f}: {e}"));
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn shipped_configs_have_no_inert_keys() {
        for f in [
            "configs/serve_pjrt.toml",
            "configs/sim_fig2.toml",
            "configs/sim_fig2_straggle.toml",
            "configs/serve_tenants.toml",
        ] {
            let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(f);
            let text = std::fs::read_to_string(&p).unwrap();
            let doc = parse_toml(&text).unwrap();
            assert!(
                inert_key_warnings(&doc).is_empty(),
                "{f} ships an inert key: {:?}",
                inert_key_warnings(&doc)
            );
        }
    }
}
