//! Configuration: a TOML-subset parser (offline substitute for
//! `toml`/`serde`) plus the typed configs the launcher consumes.

pub mod toml;
pub mod types;

pub use toml::{parse_toml, TomlDoc, Value};
pub use types::{BackendKind, NestSpec, RunConfig, SchemeKind};
