//! Unified observability: tracing, exporters and profiling hooks.
//!
//! This layer sits on top of `metrics::Registry` and gives every
//! execution surface — the live [`crate::coordinator::ServingTier`],
//! its worker pool, and the `sim::des` fleet simulator — one shared
//! trace schema:
//!
//! - [`trace`] — the [`TraceEvent`] schema, [`TraceSink`] trait, the
//!   lock-free [`RingRecorder`], the cheap [`Tracer`] handle, the
//!   order-independent [`logical_digest`], and the span-tree checker
//!   that turns traces into assertable test artifacts.
//! - [`chrome`] — `chrome://tracing`-loadable trace-event JSON.
//! - [`prom`] — Prometheus text exposition of a `Registry`.
//! - [`prof`] — global kernel/arena profiling hooks for the linalg
//!   hot paths, off by default.
//!
//! Design rule: instrumented code never pays for disabled tracing. A
//! [`Tracer::off`] handle is one branch per emission site — no clock
//! read, no allocation, no virtual call.

pub mod chrome;
pub mod prof;
pub mod prom;
pub mod trace;

pub use chrome::chrome_trace_json;
pub use prom::prometheus_text;
pub use trace::{
    check_span_tree, logical_digest, EventKind, NoopSink, RingRecorder, SpanSummary, TraceEvent,
    TraceSink, Tracer, NO_LEAF,
};
