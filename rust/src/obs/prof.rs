//! Kernel and arena profiling hooks.
//!
//! `linalg/kernel.rs` and `linalg/recursive.rs` sit on the innermost
//! hot paths, so they record into process-global atomics here instead
//! of carrying a `Registry` handle: per-call flops, packed bytes and
//! effective kernel kind, plus recursion-arena depth bounds and arena
//! growth. Everything is gated on one relaxed [`AtomicBool`] load
//! (default **off**) so un-profiled runs pay a single predictable
//! branch per kernel call.
//!
//! Values (flops, bytes, depth) are not durations, so they land in a
//! dedicated log₂ [`ValueHist`] rather than the µs-based
//! `metrics::Histogram`; [`prometheus_text`] exposes them with the same
//! `_bucket{le="…"}` shape the registry exporter uses.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn the hooks on or off (off by default).
pub fn set_profiling(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The single hot-path gate.
#[inline]
pub fn profiling_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Lock-free log₂-bucketed histogram over raw `u64` values: bucket i
/// counts samples in `[2^i, 2^(i+1))` (0 counts as 1).
pub struct ValueHist {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
}

impl ValueHist {
    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);

    pub const fn new() -> ValueHist {
        ValueHist { buckets: [Self::ZERO; 64], count: AtomicU64::new(0), sum: AtomicU64::new(0) }
    }

    pub fn record(&self, v: u64) {
        let bucket = 63 - v.max(1).leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Cumulative `(upper, count ≤ upper)` pairs up to the last
    /// non-empty bucket (same shape as `metrics::Histogram`).
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        let mut last_nonzero = 0usize;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                last_nonzero = i + 1;
            }
            cum += n;
            out.push((1u64.checked_shl(i as u32 + 1).unwrap_or(u64::MAX), cum));
        }
        out.truncate(last_nonzero);
        out
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }
}

impl Default for ValueHist {
    fn default() -> Self {
        ValueHist::new()
    }
}

/// Flops (`2·m·k·n` style multiply-add counts) per kernel call.
pub static KERNEL_FLOPS: ValueHist = ValueHist::new();
/// Bytes packed into panel buffers per packed-kernel call.
pub static KERNEL_BYTES_PACKED: ValueHist = ValueHist::new();
/// Recursion-arena depth bound per recursive solve.
pub static ARENA_DEPTH: ValueHist = ValueHist::new();
/// Calls per *effective* kernel kind, indexed by [`kind_index`].
pub static KERNEL_CALLS_BY_KIND: [AtomicU64; KIND_NAMES.len()] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
/// Arena levels newly allocated (growth events, not reuses).
pub static ARENA_GROWS: AtomicU64 = AtomicU64::new(0);

/// Display names for the effective-kind counters.
pub const KIND_NAMES: [&str; 3] = ["naive", "packed", "simd"];

/// Clamp an arbitrary kind discriminant into the counter range.
pub fn kind_index(kind: u8) -> usize {
    (kind as usize).min(KIND_NAMES.len() - 1)
}

/// Record one kernel call (call only when [`profiling_enabled`]).
pub fn record_kernel(kind: u8, flops: u64, bytes_packed: u64) {
    KERNEL_CALLS_BY_KIND[kind_index(kind)].fetch_add(1, Ordering::Relaxed);
    KERNEL_FLOPS.record(flops);
    if bytes_packed > 0 {
        KERNEL_BYTES_PACKED.record(bytes_packed);
    }
}

/// Record one recursive solve's arena usage (call only when
/// [`profiling_enabled`]).
pub fn record_arena(depth_bound: u64, grew_levels: u64) {
    ARENA_DEPTH.record(depth_bound);
    if grew_levels > 0 {
        ARENA_GROWS.fetch_add(grew_levels, Ordering::Relaxed);
    }
}

/// Zero every profiling accumulator (tests and repeated CLI runs).
pub fn reset() {
    KERNEL_FLOPS.reset();
    KERNEL_BYTES_PACKED.reset();
    ARENA_DEPTH.reset();
    for c in &KERNEL_CALLS_BY_KIND {
        c.store(0, Ordering::Relaxed);
    }
    ARENA_GROWS.store(0, Ordering::Relaxed);
}

fn render_hist(out: &mut String, name: &str, h: &ValueHist) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut total = 0;
    for (upper, cum) in h.cumulative_buckets() {
        let _ = writeln!(out, "{name}_bucket{{le=\"{upper}\"}} {cum}");
        total = cum;
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count().max(total));
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Prometheus text exposition of the profiling state. Unlike the
/// registry exporter these buckets are raw values, not seconds.
pub fn prometheus_text() -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "# TYPE ftms_kernel_calls counter");
    for (i, name) in KIND_NAMES.iter().enumerate() {
        let _ = writeln!(
            out,
            "ftms_kernel_calls{{kind=\"{name}\"}} {}",
            KERNEL_CALLS_BY_KIND[i].load(Ordering::Relaxed)
        );
    }
    let _ = writeln!(out, "# TYPE ftms_arena_grows counter");
    let _ = writeln!(out, "ftms_arena_grows {}", ARENA_GROWS.load(Ordering::Relaxed));
    render_hist(&mut out, "ftms_kernel_flops", &KERNEL_FLOPS);
    render_hist(&mut out, "ftms_kernel_bytes_packed", &KERNEL_BYTES_PACKED);
    render_hist(&mut out, "ftms_arena_depth", &ARENA_DEPTH);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_hist_buckets_and_sum() {
        let h = ValueHist::new();
        for v in [0u64, 1, 5, 1024] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1030);
        let buckets = h.cumulative_buckets();
        assert_eq!(buckets.last().unwrap().1, 4);
        // 0 and 1 both land in [1,2).
        assert_eq!(buckets[0], (2, 2));
    }

    #[test]
    fn gate_defaults_off_and_toggles() {
        // Other tests in the binary may flip the gate; just verify the
        // toggle round-trips and restore the default.
        set_profiling(true);
        assert!(profiling_enabled());
        set_profiling(false);
        assert!(!profiling_enabled());
    }

    #[test]
    fn exposition_contains_every_family() {
        reset();
        record_kernel(1, 1 << 20, 4096);
        record_kernel(0, 100, 0);
        record_arena(12, 3);
        let text = prometheus_text();
        assert!(text.contains("ftms_kernel_calls{kind=\"packed\"} 1"));
        assert!(text.contains("ftms_kernel_calls{kind=\"naive\"} 1"));
        assert!(text.contains("ftms_arena_grows 3"));
        assert!(text.contains("ftms_kernel_flops_count 2"));
        assert!(text.contains("ftms_kernel_bytes_packed_count 1"));
        assert!(text.contains("ftms_arena_depth_count 1"));
        assert!(text.contains("_bucket{le=\"+Inf\"}"));
        reset();
        assert_eq!(KERNEL_FLOPS.count(), 0);
    }
}
