//! Prometheus text-exposition exporter for [`crate::metrics::Registry`].
//!
//! Renders the standard format: `# TYPE` headers, plain samples for
//! counters and gauges, and `_bucket{le="…"}` cumulative counts plus
//! `_sum`/`_count` for histograms. Histogram bounds and sums are in
//! **seconds** (the Prometheus convention); metric names get an `ftms_`
//! namespace prefix and are sanitized to the legal charset (tenant
//! names may contain `-`).

use crate::metrics::Registry;
use std::fmt::Write as _;

const NAMESPACE: &str = "ftms_";

/// A metric name is `[a-zA-Z_:][a-zA-Z0-9_:]*`; map anything else to `_`.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(NAMESPACE.len() + name.len());
    out.push_str(NAMESPACE);
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic()
            || c == '_'
            || c == ':'
            || (i > 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    out
}

fn secs(us: u64) -> f64 {
    us as f64 / 1e6
}

/// Render the whole registry as Prometheus text exposition.
pub fn prometheus_text(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, v) in reg.counters() {
        let n = sanitize(&name);
        let _ = writeln!(out, "# TYPE {n} counter");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, v) in reg.gauges() {
        let n = sanitize(&name);
        let _ = writeln!(out, "# TYPE {n} gauge");
        let _ = writeln!(out, "{n} {v}");
    }
    for (name, h) in reg.histograms() {
        let n = sanitize(&name);
        let _ = writeln!(out, "# TYPE {n} histogram");
        let mut total = 0;
        for (upper_us, cum) in h.cumulative_buckets() {
            let _ = writeln!(out, "{n}_bucket{{le=\"{}\"}} {cum}", secs(upper_us));
            total = cum;
        }
        let _ = writeln!(out, "{n}_bucket{{le=\"+Inf\"}} {}", h.count().max(total));
        let _ = writeln!(out, "{n}_sum {}", h.sum().as_secs_f64());
        let _ = writeln!(out, "{n}_count {}", h.count());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn sanitizes_names_into_the_legal_charset() {
        assert_eq!(sanitize("jobs_completed"), "ftms_jobs_completed");
        assert_eq!(sanitize("tenant_jobs_team-a"), "ftms_tenant_jobs_team_a");
        assert_eq!(sanitize("9lives"), "ftms__lives");
    }

    #[test]
    fn renders_all_metric_families() {
        let r = Registry::new();
        r.counter("jobs_completed").add(3);
        r.gauge("inflight_jobs").set(2);
        let h = r.histogram("job_latency");
        h.observe(Duration::from_micros(3));
        h.observe(Duration::from_micros(100));
        let text = prometheus_text(&r);
        assert!(text.contains("# TYPE ftms_jobs_completed counter\nftms_jobs_completed 3\n"));
        assert!(text.contains("# TYPE ftms_inflight_jobs gauge\nftms_inflight_jobs 2\n"));
        assert!(text.contains("# TYPE ftms_job_latency histogram"));
        // 3 µs falls in [2,4) µs -> le="0.000004" carries 1 sample.
        assert!(text.contains("ftms_job_latency_bucket{le=\"0.000004\"} 1"), "{text}");
        assert!(text.contains("ftms_job_latency_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("ftms_job_latency_count 2"));
        let sum: f64 = text
            .lines()
            .find(|l| l.starts_with("ftms_job_latency_sum "))
            .unwrap()
            .split_whitespace()
            .nth(1)
            .unwrap()
            .parse()
            .unwrap();
        assert!((sum - 103e-6).abs() < 1e-9, "{sum}");
    }

    #[test]
    fn empty_histogram_still_exposes_inf_bucket() {
        let r = Registry::new();
        r.histogram("empty");
        let text = prometheus_text(&r);
        assert!(text.contains("ftms_empty_bucket{le=\"+Inf\"} 0"));
        assert!(text.contains("ftms_empty_count 0"));
    }
}
