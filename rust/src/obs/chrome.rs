//! Chrome trace-event JSON exporter.
//!
//! Renders a drained trace as a `chrome://tracing` / Perfetto-loadable
//! JSON object (`{"traceEvents": [...]}`). Each job becomes a complete
//! (`"X"`) span on its own track (`tid` = job id, `pid` = 0) running
//! from `job-admit` to its terminal event; each dispatched leaf becomes
//! a complete span on the *same* track from `leaf-dispatch` to its
//! leaf-terminal, so Chrome's containment rule nests every leaf span
//! under its job span. All remaining events (encode, cache-hit,
//! compute, group-recover, …) render as instant (`"i"`) events on the
//! job's track.

use super::trace::{EventKind, TraceEvent, NO_LEAF};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Per-job span bookkeeping gathered in one pass over the events.
#[derive(Default)]
struct JobSpan {
    admit: Option<u64>,
    end: Option<u64>,
    max_wall: u64,
    // leaf -> (dispatch wall, terminal wall)
    leaves: BTreeMap<u32, (Option<u64>, Option<u64>)>,
    instants: Vec<(EventKind, u32, u64, u64)>, // kind, leaf, detail, wall
}

/// Render events as Chrome trace-event JSON. `process_name` labels the
/// single process track (e.g. `"serve"` or `"simfleet"`).
pub fn chrome_trace_json(events: &[TraceEvent], process_name: &str) -> String {
    let mut jobs: BTreeMap<u64, JobSpan> = BTreeMap::new();
    for e in events {
        let j = jobs.entry(e.job).or_default();
        j.max_wall = j.max_wall.max(e.wall_us);
        match e.kind {
            EventKind::JobAdmit => j.admit = Some(e.wall_us),
            k if k.is_job_terminal() => {
                j.end = Some(j.end.unwrap_or(0).max(e.wall_us));
                j.instants.push((k, e.leaf, e.detail, e.wall_us));
            }
            EventKind::LeafDispatch => {
                let slot = j.leaves.entry(e.leaf).or_default();
                slot.0 = Some(slot.0.unwrap_or(u64::MAX).min(e.wall_us));
            }
            k if k.is_leaf_terminal() => {
                let slot = j.leaves.entry(e.leaf).or_default();
                slot.1 = Some(slot.1.unwrap_or(0).max(e.wall_us));
            }
            k => j.instants.push((k, e.leaf, e.detail, e.wall_us)),
        }
    }

    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut push = |s: &str, out: &mut String| {
        if !std::mem::take(&mut first) {
            out.push(',');
        }
        out.push_str(s);
    };

    let meta = format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"tid\":0,\
         \"args\":{{\"name\":\"{}\"}}}}",
        escape(process_name)
    );
    push(&meta, &mut out);

    let mut buf = String::new();
    for (&job, span) in &jobs {
        let start = span.admit.unwrap_or(0);
        let end = span.end.unwrap_or(span.max_wall).max(start);
        buf.clear();
        let _ = write!(
            buf,
            "{{\"name\":\"job {job}\",\"cat\":\"job\",\"ph\":\"X\",\
             \"ts\":{start},\"dur\":{},\"pid\":0,\"tid\":{job}}}",
            end - start
        );
        push(&buf, &mut out);
        for (&leaf, &(dispatch, terminal)) in &span.leaves {
            let Some(d) = dispatch else {
                continue; // revoked-in-queue leaves have no span to draw
            };
            let t = terminal.unwrap_or(end).max(d);
            buf.clear();
            let _ = write!(
                buf,
                "{{\"name\":\"leaf {leaf}\",\"cat\":\"leaf\",\"ph\":\"X\",\
                 \"ts\":{d},\"dur\":{},\"pid\":0,\"tid\":{job},\
                 \"args\":{{\"job\":{job},\"leaf\":{leaf}}}}}",
                t - d
            );
            push(&buf, &mut out);
        }
        for &(kind, leaf, detail, wall) in &span.instants {
            buf.clear();
            let _ = write!(
                buf,
                "{{\"name\":\"{}\",\"cat\":\"event\",\"ph\":\"i\",\"s\":\"t\",\
                 \"ts\":{wall},\"pid\":0,\"tid\":{job},\
                 \"args\":{{\"leaf\":{},\"detail\":{detail}}}}}",
                kind.name(),
                if leaf == NO_LEAF { -1i64 } else { leaf as i64 },
            );
            push(&buf, &mut out);
        }
    }
    out.push_str("]}");
    out
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, job: u64, leaf: u32, detail: u64, wall_us: u64) -> TraceEvent {
        TraceEvent { kind, job, leaf, detail, wall_us }
    }

    #[test]
    fn leaf_spans_sit_inside_their_job_span() {
        let events = vec![
            ev(EventKind::JobAdmit, 1, NO_LEAF, 0, 10),
            ev(EventKind::LeafDispatch, 1, 0, 0, 20),
            ev(EventKind::Compute, 1, 0, 0, 30),
            ev(EventKind::Reply, 1, 0, 0, 40),
            ev(EventKind::JobDecode, 1, NO_LEAF, 0, 50),
        ];
        let json = chrome_trace_json(&events, "test");
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"name\":\"job 1\""));
        assert!(json.contains("\"name\":\"leaf 0\""));
        assert!(json.contains("\"name\":\"job-decode\""));
        // Leaf span [20, 40] inside job span [10, 50], same tid.
        assert!(json.contains("\"ts\":10,\"dur\":40,\"pid\":0,\"tid\":1"));
        assert!(json.contains("\"ts\":20,\"dur\":20,\"pid\":0,\"tid\":1"));
    }

    #[test]
    fn queue_revoked_leaves_draw_no_span() {
        let events = vec![
            ev(EventKind::JobAdmit, 3, NO_LEAF, 0, 0),
            ev(EventKind::Revoke, 3, 7, 0, 5),
            ev(EventKind::JobFail, 3, NO_LEAF, 1, 9),
        ];
        let json = chrome_trace_json(&events, "test");
        assert!(!json.contains("\"name\":\"leaf 7\""));
        assert!(json.contains("\"name\":\"job 3\""));
    }

    #[test]
    fn escapes_process_name() {
        let json = chrome_trace_json(&[], "a\"b\\c");
        assert!(json.contains("a\\\"b\\\\c"));
    }
}
