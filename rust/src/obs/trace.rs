//! The span/event tracer: one flat [`TraceEvent`] schema shared by the
//! live serving tier and the discrete-event fleet simulator, a
//! [`TraceSink`] trait with a lock-free [`RingRecorder`], and the
//! cheap-to-clone [`Tracer`] handle the coordinator threads through the
//! leaf lifecycle.
//!
//! ## Schema
//!
//! Every event is four logical fields plus one wall-clock field:
//!
//! | field     | meaning                                                  |
//! |-----------|----------------------------------------------------------|
//! | `kind`    | lifecycle stage ([`EventKind`])                          |
//! | `job`     | job id (the span id of the enclosing job span)           |
//! | `leaf`    | work-item id within the job; [`NO_LEAF`] for job-level   |
//! | `detail`  | kind-specific payload (worker id, encode count, group id)|
//! | `wall_us` | µs since the tracer's epoch (sim time for DES traces)    |
//!
//! ## Determinism discipline
//!
//! `wall_us` and `detail` are **auxiliary**: timing and placement
//! (which worker computed a leaf) race under the threaded tier, so the
//! [`logical_digest`] covers only the canonically sorted
//! `(job, leaf, kind)` tuples. For a seeded run whose event *multiset*
//! is a pure function of `(seed, config)` — no stragglers, no revokes,
//! `collect_all` decode — the digest is byte-stable across runs,
//! thread interleavings, and the `serve`-vs-`trace` replay pair (the
//! same discipline `sim::des` uses for its trace digests).
//!
//! ## Zero cost when disabled
//!
//! [`Tracer::off`] holds no sink: `emit` is one branch, takes no
//! timestamp, and allocates nothing — pinned by the alloc-regression
//! test in `tests/obs_trace.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Sentinel `leaf` id for job-level events (admit, decode, …).
pub const NO_LEAF: u32 = u32::MAX;

/// Lifecycle stage of a trace event. The leaf lifecycle is
/// `LeafDispatch → Compute → {Reply, StaleDrop}` (or `Revoke` /
/// `LeafDead` for items that never compute or never report); the job
/// lifecycle is `JobAdmit → … → {JobDecode, JobFallback, JobFail}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// A job entered a tenant queue (span open for the job).
    JobAdmit = 0,
    /// Operand(s) encoded; `detail` = number of operands encoded
    /// (coordinator-side bulk encodes use `leaf == NO_LEAF`).
    Encode = 1,
    /// The encoded-operand cache served this leaf's left operand.
    CacheHit = 2,
    /// A leaf item was handed to a worker; `detail` = worker id.
    LeafDispatch = 3,
    /// A worker finished computing a leaf product; `detail` = worker id.
    Compute = 4,
    /// The coordinator accepted a leaf reply; `detail` = 1 for an
    /// error reply, 0 for a product.
    Reply = 5,
    /// A reply arrived for a job no longer in flight and was dropped.
    StaleDrop = 6,
    /// A still-queued leaf item was purged (job finished/cancelled or
    /// its nested group recovered before the item ran).
    Revoke = 7,
    /// The leaf's node failed / its reply was lost (DES fleet model).
    LeafDead = 8,
    /// A nested inner group's product was recovered; `detail` = group.
    GroupRecover = 9,
    /// A nested inner group can no longer span (DES); `detail` = group.
    GroupHopeless = 10,
    /// The job decoded from its reply span (span close, success).
    JobDecode = 11,
    /// The job fell back to the local product (span close).
    JobFallback = 12,
    /// The job failed or was cancelled; `detail` = 1 for cancellation.
    JobFail = 13,
}

impl EventKind {
    /// Every kind, in tag order.
    pub const ALL: [EventKind; 14] = [
        EventKind::JobAdmit,
        EventKind::Encode,
        EventKind::CacheHit,
        EventKind::LeafDispatch,
        EventKind::Compute,
        EventKind::Reply,
        EventKind::StaleDrop,
        EventKind::Revoke,
        EventKind::LeafDead,
        EventKind::GroupRecover,
        EventKind::GroupHopeless,
        EventKind::JobDecode,
        EventKind::JobFallback,
        EventKind::JobFail,
    ];

    /// Stable display name (the span taxonomy in `docs/ARCHITECTURE.md`).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::JobAdmit => "job-admit",
            EventKind::Encode => "encode",
            EventKind::CacheHit => "cache-hit",
            EventKind::LeafDispatch => "leaf-dispatch",
            EventKind::Compute => "compute",
            EventKind::Reply => "reply",
            EventKind::StaleDrop => "stale-drop",
            EventKind::Revoke => "revoke",
            EventKind::LeafDead => "leaf-dead",
            EventKind::GroupRecover => "group-recover",
            EventKind::GroupHopeless => "group-hopeless",
            EventKind::JobDecode => "job-decode",
            EventKind::JobFallback => "job-fallback",
            EventKind::JobFail => "job-fail",
        }
    }

    /// Inverse of the `repr(u8)` tag (recorder slots store the tag).
    pub fn from_u8(v: u8) -> Option<EventKind> {
        EventKind::ALL.get(v as usize).copied()
    }

    /// Terminal stages of a leaf span.
    pub fn is_leaf_terminal(self) -> bool {
        matches!(
            self,
            EventKind::Reply | EventKind::StaleDrop | EventKind::Revoke | EventKind::LeafDead
        )
    }

    /// Terminal stages of a job span.
    pub fn is_job_terminal(self) -> bool {
        matches!(self, EventKind::JobDecode | EventKind::JobFallback | EventKind::JobFail)
    }
}

/// One trace event (see module docs for the field semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub job: u64,
    pub leaf: u32,
    pub detail: u64,
    pub wall_us: u64,
}

/// Where emitted events go. Implementations must be thread-safe: the
/// tier, every worker event loop, and the DES engine all share one sink.
pub trait TraceSink: Send + Sync {
    fn emit(&self, ev: TraceEvent);
}

/// A sink that drops everything (useful as an explicit trait object;
/// [`Tracer::off`] is cheaper — it skips the virtual call entirely).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn emit(&self, _ev: TraceEvent) {}
}

/// One recorder slot: a per-slot seqlock. `stamp == seq + 1` publishes
/// the fields written for sequence `seq`; `stamp == 0` marks a write in
/// progress. `meta` packs `kind << 32 | leaf`.
struct Slot {
    stamp: AtomicU64,
    job: AtomicU64,
    meta: AtomicU64,
    detail: AtomicU64,
    wall: AtomicU64,
}

/// Lock-free ring-buffer recorder: emitters claim a sequence number
/// with one `fetch_add` and publish their slot with a release store —
/// no locks, no allocation per event. When the ring wraps, the oldest
/// events are overwritten (and counted in [`RingRecorder::dropped`]).
///
/// [`RingRecorder::drain`] is designed to run after the traced
/// workload quiesces; a drain concurrent with emitters simply skips
/// slots whose seqlock check fails rather than returning torn events.
pub struct RingRecorder {
    slots: Box<[Slot]>,
    head: AtomicU64,
    mask: u64,
}

impl RingRecorder {
    /// Default capacity: 2^16 events (≈ 2.6 MB).
    pub fn new() -> RingRecorder {
        RingRecorder::with_capacity(1 << 16)
    }

    /// Capacity is rounded up to a power of two (min 8).
    pub fn with_capacity(cap: usize) -> RingRecorder {
        let cap = cap.next_power_of_two().max(8);
        let slots = (0..cap)
            .map(|_| Slot {
                stamp: AtomicU64::new(0),
                job: AtomicU64::new(0),
                meta: AtomicU64::new(0),
                detail: AtomicU64::new(0),
                wall: AtomicU64::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        RingRecorder { slots, head: AtomicU64::new(0), mask: (cap - 1) as u64 }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events emitted since construction (including overwritten).
    pub fn emitted(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Events lost to ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.emitted().saturating_sub(self.slots.len() as u64)
    }

    /// Snapshot the retained events in emission order. Slots that fail
    /// their seqlock check (mid-write or overwritten during the drain)
    /// are skipped.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let head = self.head.load(Ordering::Acquire);
        let start = head.saturating_sub(self.slots.len() as u64);
        let mut out = Vec::with_capacity((head - start) as usize);
        for seq in start..head {
            let slot = &self.slots[(seq & self.mask) as usize];
            let s1 = slot.stamp.load(Ordering::Acquire);
            if s1 != seq + 1 {
                continue;
            }
            let job = slot.job.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            let detail = slot.detail.load(Ordering::Relaxed);
            let wall_us = slot.wall.load(Ordering::Relaxed);
            if slot.stamp.load(Ordering::Acquire) != s1 {
                continue; // overwritten mid-read
            }
            let Some(kind) = EventKind::from_u8((meta >> 32) as u8) else { continue };
            out.push(TraceEvent { kind, job, leaf: meta as u32, detail, wall_us });
        }
        out
    }
}

impl Default for RingRecorder {
    fn default() -> Self {
        RingRecorder::new()
    }
}

impl TraceSink for RingRecorder {
    fn emit(&self, ev: TraceEvent) {
        let seq = self.head.fetch_add(1, Ordering::AcqRel);
        let slot = &self.slots[(seq & self.mask) as usize];
        slot.stamp.store(0, Ordering::Release);
        slot.job.store(ev.job, Ordering::Relaxed);
        slot.meta.store(((ev.kind as u64) << 32) | ev.leaf as u64, Ordering::Relaxed);
        slot.detail.store(ev.detail, Ordering::Relaxed);
        slot.wall.store(ev.wall_us, Ordering::Relaxed);
        slot.stamp.store(seq + 1, Ordering::Release);
    }
}

/// The handle instrumented code holds: an optional shared sink plus the
/// wall-clock epoch. Cloning is two pointer copies; a disabled tracer
/// ([`Tracer::off`]) makes `emit` a single branch with no timestamp
/// read and no allocation.
#[derive(Clone)]
pub struct Tracer {
    sink: Option<Arc<dyn TraceSink>>,
    t0: Instant,
}

impl Tracer {
    /// A tracer writing into `sink`; `wall_us` counts from now.
    pub fn new(sink: Arc<dyn TraceSink>) -> Tracer {
        Tracer { sink: Some(sink), t0: Instant::now() }
    }

    /// The disabled tracer — the zero-cost default everywhere.
    pub fn off() -> Tracer {
        Tracer { sink: None, t0: Instant::now() }
    }

    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Emit one event stamped with the elapsed wall clock.
    #[inline]
    pub fn emit(&self, kind: EventKind, job: u64, leaf: u32, detail: u64) {
        if let Some(sink) = &self.sink {
            let wall_us = self.t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
            sink.emit(TraceEvent { kind, job, leaf, detail, wall_us });
        }
    }

    /// Emit with an explicit clock — the DES engine passes simulated
    /// time here so live and simulated traces share one schema.
    #[inline]
    pub fn emit_at(&self, kind: EventKind, job: u64, leaf: u32, detail: u64, wall_us: u64) {
        if let Some(sink) = &self.sink {
            sink.emit(TraceEvent { kind, job, leaf, detail, wall_us });
        }
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::off()
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tracer({})", if self.enabled() { "on" } else { "off" })
    }
}

// ---------------------------------------------------------------------
// Logical digest
// ---------------------------------------------------------------------

const FNV_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a digest over the **logical** trace content: the canonically
/// sorted `(job, leaf, kind)` tuples. Wall clock and `detail` (worker
/// placement, counts) are excluded, so the digest is invariant to
/// thread interleaving and byte-stable for seeded runs whose event
/// multiset is a pure function of `(seed, config)`.
pub fn logical_digest(events: &[TraceEvent]) -> u64 {
    let mut keys: Vec<(u64, u32, u8)> =
        events.iter().map(|e| (e.job, e.leaf, e.kind as u8)).collect();
    keys.sort_unstable();
    let mut h = FNV_BASIS;
    for (job, leaf, kind) in keys {
        h = fnv_bytes(h, &job.to_le_bytes());
        h = fnv_bytes(h, &leaf.to_le_bytes());
        h = fnv_bytes(h, &[kind]);
    }
    h
}

// ---------------------------------------------------------------------
// Span-tree checker
// ---------------------------------------------------------------------

/// Aggregate counts returned by a successful [`check_span_tree`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanSummary {
    pub jobs: usize,
    pub decoded: usize,
    pub fell_back: usize,
    pub failed: usize,
    pub dispatched_leaves: usize,
    pub replies: usize,
    pub revokes: usize,
    pub stale_drops: usize,
    pub cache_hits: usize,
}

/// Verify the span-tree invariants of a trace and summarize it.
///
/// Always enforced:
/// 1. every job that has any event has exactly one `JobAdmit`;
/// 2. every admitted job reaches exactly one job-terminal state;
/// 3. no leaf has both `Reply` and `Revoke` (a revoked leaf never
///    contributes to decode);
/// 4. a leaf with `Reply` was dispatched;
/// 5. a leaf with `CacheHit` never carries a full 2-operand worker
///    encode (`Encode.detail < 2` — the cache hit skipped the left).
///
/// With `strict` (seeded runs with no faults, no cancellation, no
/// speculative re-dispatch): every dispatched leaf is dispatched
/// exactly once and reaches exactly one leaf-terminal state.
pub fn check_span_tree(events: &[TraceEvent], strict: bool) -> Result<SpanSummary, String> {
    use std::collections::{BTreeMap, BTreeSet};
    let mut admits: BTreeMap<u64, usize> = BTreeMap::new();
    let mut terminals: BTreeMap<u64, usize> = BTreeMap::new();
    let mut jobs_seen: BTreeSet<u64> = BTreeSet::new();
    let mut dispatches: BTreeMap<(u64, u32), usize> = BTreeMap::new();
    let mut leaf_terminals: BTreeMap<(u64, u32), Vec<EventKind>> = BTreeMap::new();
    let mut cache_hit_leaves: BTreeSet<(u64, u32)> = BTreeSet::new();
    let mut sum = SpanSummary::default();

    for e in events {
        jobs_seen.insert(e.job);
        match e.kind {
            EventKind::JobAdmit => *admits.entry(e.job).or_default() += 1,
            EventKind::JobDecode => {
                sum.decoded += 1;
                *terminals.entry(e.job).or_default() += 1;
            }
            EventKind::JobFallback => {
                sum.fell_back += 1;
                *terminals.entry(e.job).or_default() += 1;
            }
            EventKind::JobFail => {
                sum.failed += 1;
                *terminals.entry(e.job).or_default() += 1;
            }
            EventKind::LeafDispatch => {
                *dispatches.entry((e.job, e.leaf)).or_default() += 1;
            }
            EventKind::CacheHit => {
                if e.leaf != NO_LEAF {
                    cache_hit_leaves.insert((e.job, e.leaf));
                }
                sum.cache_hits += 1;
            }
            k if k.is_leaf_terminal() => {
                leaf_terminals.entry((e.job, e.leaf)).or_default().push(k);
                match k {
                    EventKind::Reply => sum.replies += 1,
                    EventKind::Revoke => sum.revokes += 1,
                    EventKind::StaleDrop => sum.stale_drops += 1,
                    _ => {}
                }
            }
            _ => {}
        }
    }
    sum.jobs = admits.len();
    sum.dispatched_leaves = dispatches.len();

    for &job in &jobs_seen {
        match admits.get(&job).copied().unwrap_or(0) {
            1 => {}
            n => return Err(format!("job {job}: {n} admit events (want exactly 1)")),
        }
        match terminals.get(&job).copied().unwrap_or(0) {
            1 => {}
            n => return Err(format!("job {job}: {n} terminal events (want exactly 1)")),
        }
    }
    for (&(job, leaf), kinds) in &leaf_terminals {
        let replied = kinds.contains(&EventKind::Reply);
        if replied && kinds.contains(&EventKind::Revoke) {
            return Err(format!("job {job} leaf {leaf}: both reply and revoke"));
        }
        if replied && !dispatches.contains_key(&(job, leaf)) {
            return Err(format!("job {job} leaf {leaf}: reply without dispatch"));
        }
        if strict && kinds.len() != 1 {
            return Err(format!(
                "job {job} leaf {leaf}: {} terminal events under strict mode",
                kinds.len()
            ));
        }
    }
    for e in events {
        if e.kind == EventKind::Encode
            && e.leaf != NO_LEAF
            && e.detail >= 2
            && cache_hit_leaves.contains(&(e.job, e.leaf))
        {
            return Err(format!(
                "job {} leaf {}: cache hit but a full 2-operand encode ran",
                e.job, e.leaf
            ));
        }
    }
    if strict {
        for (&(job, leaf), &n) in &dispatches {
            if n != 1 {
                return Err(format!("job {job} leaf {leaf}: dispatched {n} times"));
            }
            match leaf_terminals.get(&(job, leaf)).map(Vec::len).unwrap_or(0) {
                1 => {}
                n => {
                    return Err(format!(
                        "job {job} leaf {leaf}: {n} terminal events (want exactly 1)"
                    ))
                }
            }
        }
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, job: u64, leaf: u32, detail: u64, wall_us: u64) -> TraceEvent {
        TraceEvent { kind, job, leaf, detail, wall_us }
    }

    #[test]
    fn kinds_round_trip_their_tags() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_u8(k as u8), Some(k), "{k:?}");
        }
        assert_eq!(EventKind::from_u8(200), None);
    }

    #[test]
    fn ring_records_in_emission_order() {
        let r = RingRecorder::with_capacity(64);
        let t = Tracer::new(Arc::new(RingRecorder::with_capacity(8)));
        assert!(t.enabled());
        for i in 0..10u64 {
            r.emit(ev(EventKind::Reply, i, i as u32, 7, 100 + i));
        }
        let got = r.drain();
        assert_eq!(got.len(), 10);
        assert_eq!(r.emitted(), 10);
        assert_eq!(r.dropped(), 0);
        for (i, e) in got.iter().enumerate() {
            assert_eq!(e.job, i as u64);
            assert_eq!(e.leaf, i as u32);
            assert_eq!(e.detail, 7);
            assert_eq!(e.wall_us, 100 + i as u64);
        }
    }

    #[test]
    fn ring_wraps_keeping_the_newest_events() {
        let r = RingRecorder::with_capacity(8);
        for i in 0..20u64 {
            r.emit(ev(EventKind::Compute, i, 0, 0, i));
        }
        let got = r.drain();
        assert_eq!(got.len(), 8);
        assert_eq!(r.dropped(), 12);
        let jobs: Vec<u64> = got.iter().map(|e| e.job).collect();
        assert_eq!(jobs, (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn ring_capacity_rounds_up() {
        assert_eq!(RingRecorder::with_capacity(100).capacity(), 128);
        assert_eq!(RingRecorder::with_capacity(0).capacity(), 8);
    }

    #[test]
    fn concurrent_emitters_lose_nothing_when_capacity_suffices() {
        let r = Arc::new(RingRecorder::with_capacity(8192));
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let r = Arc::clone(&r);
                s.spawn(move || {
                    let tracer = Tracer::new(r);
                    for i in 0..500u64 {
                        tracer.emit(EventKind::Compute, t, i as u32, t, 0);
                    }
                });
            }
        });
        let got = r.drain();
        assert_eq!(got.len(), 4000);
        for t in 0..8u64 {
            assert_eq!(got.iter().filter(|e| e.job == t).count(), 500);
        }
    }

    #[test]
    fn disabled_tracer_emits_nothing() {
        let t = Tracer::off();
        assert!(!t.enabled());
        t.emit(EventKind::Reply, 1, 2, 3);
        t.emit_at(EventKind::Reply, 1, 2, 3, 4);
        // Nothing to observe — the point is that the calls are inert
        // (the alloc-regression test in tests/obs_trace.rs pins cost).
        assert_eq!(format!("{t:?}"), "Tracer(off)");
    }

    #[test]
    fn logical_digest_ignores_wall_detail_and_order() {
        let a = vec![
            ev(EventKind::JobAdmit, 1, NO_LEAF, 0, 5),
            ev(EventKind::Reply, 1, 3, 7, 50),
            ev(EventKind::Reply, 1, 2, 1, 60),
        ];
        let mut b = vec![
            ev(EventKind::Reply, 1, 2, 9, 999),
            ev(EventKind::JobAdmit, 1, NO_LEAF, 4, 0),
            ev(EventKind::Reply, 1, 3, 0, 1),
        ];
        assert_eq!(logical_digest(&a), logical_digest(&b));
        // ... but not the logical content itself.
        b.push(ev(EventKind::Reply, 1, 4, 0, 1));
        assert_ne!(logical_digest(&a), logical_digest(&b));
        assert_ne!(logical_digest(&a), logical_digest(&a[..2]));
    }

    #[test]
    fn span_tree_checker_accepts_a_clean_run_and_rejects_violations() {
        let clean = vec![
            ev(EventKind::JobAdmit, 1, NO_LEAF, 0, 0),
            ev(EventKind::LeafDispatch, 1, 0, 2, 1),
            ev(EventKind::Encode, 1, 0, 2, 2),
            ev(EventKind::Compute, 1, 0, 2, 3),
            ev(EventKind::Reply, 1, 0, 0, 4),
            ev(EventKind::JobDecode, 1, NO_LEAF, 0, 5),
        ];
        let sum = check_span_tree(&clean, true).unwrap();
        assert_eq!(sum.jobs, 1);
        assert_eq!(sum.decoded, 1);
        assert_eq!(sum.replies, 1);

        // No terminal.
        let e = check_span_tree(&clean[..5], false).unwrap_err();
        assert!(e.contains("terminal"), "{e}");
        // Reply + revoke on the same leaf.
        let mut bad = clean.clone();
        bad.insert(5, ev(EventKind::Revoke, 1, 0, 0, 4));
        let e = check_span_tree(&bad, false).unwrap_err();
        assert!(e.contains("reply and revoke"), "{e}");
        // Reply without dispatch.
        let mut bad = clean.clone();
        bad.remove(1);
        let e = check_span_tree(&bad, false).unwrap_err();
        assert!(e.contains("without dispatch"), "{e}");
        // Cache hit followed by a full 2-operand encode.
        let mut bad = clean.clone();
        bad.insert(2, ev(EventKind::CacheHit, 1, 0, 0, 1));
        let e = check_span_tree(&bad, false).unwrap_err();
        assert!(e.contains("cache hit"), "{e}");
        // A revoked leaf with no reply is fine in non-strict mode.
        let ok = vec![
            ev(EventKind::JobAdmit, 2, NO_LEAF, 0, 0),
            ev(EventKind::Revoke, 2, 5, 0, 1),
            ev(EventKind::JobFail, 2, NO_LEAF, 1, 2),
        ];
        let sum = check_span_tree(&ok, false).unwrap();
        assert_eq!(sum.revokes, 1);
        // ... but strict mode requires dispatch-terminal pairing.
        let mut dup = clean;
        dup.insert(1, ev(EventKind::LeafDispatch, 1, 0, 3, 1));
        assert!(check_span_tree(&dup, true).is_err());
        assert!(check_span_tree(&dup, false).is_ok());
    }
}
