//! Tiny command-line parser (offline substitute for `clap`):
//! `binary <subcommand> [--flag] [--key value] ...` with typed accessors
//! and generated usage text.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed arguments: a subcommand, `--key value` options, `--flag`
/// booleans and bare positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

/// Parse error with a message suitable for printing next to usage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError(pub String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    ///
    /// `known_flags` lists options that take NO value; everything else
    /// starting with `--` consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_flags: &[&str],
    ) -> Result<Args, ParseError> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(ParseError("empty option name '--'".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        ParseError(format!("option --{name} expects a value"))
                    })?;
                    out.options.insert(name.to_string(), v);
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env(known_flags: &[&str]) -> Result<Args, ParseError> {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed accessor with a default; errors mention the offending value.
    pub fn get_parsed_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, ParseError>
    where
        T::Err: fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|e| {
                ParseError(format!("--{name} {s}: {e}"))
            }),
        }
    }

    /// Comma-separated list accessor.
    pub fn get_list_parsed<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, ParseError>
    where
        T: Clone,
        T::Err: fmt::Display,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.parse::<T>()
                        .map_err(|e| ParseError(format!("--{name} {p}: {e}")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn subcommand_options_flags_positionals() {
        let a = parse(
            &["sim", "--p-e", "0.1", "--verbose", "extra1", "extra2"],
            &["verbose"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("sim"));
        assert_eq!(a.get("p-e"), Some("0.1"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["run", "--n=256"], &[]);
        assert_eq!(a.get("n"), Some("256"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--trials", "5000", "--sizes", "32,64,128"], &[]);
        assert_eq!(a.get_parsed_or("trials", 0u64).unwrap(), 5000);
        assert_eq!(a.get_parsed_or("missing", 7i32).unwrap(), 7);
        assert_eq!(
            a.get_list_parsed::<usize>("sizes", &[]).unwrap(),
            vec![32, 64, 128]
        );
        assert_eq!(
            a.get_list_parsed::<usize>("absent", &[1, 2]).unwrap(),
            vec![1, 2]
        );
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(["--p".to_string()], &[]).unwrap_err();
        assert!(e.0.contains("expects a value"));
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["x", "--n", "abc"], &[]);
        assert!(a.get_parsed_or("n", 0u32).is_err());
    }
}
