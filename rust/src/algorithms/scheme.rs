//! The ⟨2,2,2;t⟩ bilinear-scheme type shared by Strassen, Winograd and
//! the naive algorithm.

use crate::algebra::form::{BilinearForm, Target};
use crate::linalg::blocked::{encode_operand, split_blocks};
use crate::linalg::matrix::Dense;
use crate::linalg::scalar::Scalar;

/// One rank-1 bilinear product `(Σ u[p] M_p)(Σ v[q] B_q)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Product {
    /// Coefficients over the M blocks [M11, M12, M21, M22].
    pub u: [i32; 4],
    /// Coefficients over the B blocks [B11, B12, B21, B22].
    pub v: [i32; 4],
}

impl Product {
    pub const fn new(u: [i32; 4], v: [i32; 4]) -> Self {
        Product { u, v }
    }

    /// The product's bilinear form (its expansion over Table I).
    pub fn form(&self) -> BilinearForm {
        BilinearForm::from_uv(&self.u, &self.v)
    }

    /// Number of block additions the encoder performs for this product
    /// (|supp(u)| - 1) + (|supp(v)| - 1).
    pub fn encode_adds(&self) -> usize {
        let nz = |c: &[i32; 4]| c.iter().filter(|&&x| x != 0).count();
        (nz(&self.u) - 1) + (nz(&self.v) - 1)
    }
}

/// A complete Strassen-like algorithm: `t` products and an output table
/// with `output[j][i]` the coefficient of product `i` in target `j`
/// (targets ordered C11, C12, C21, C22).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BilinearScheme {
    pub name: &'static str,
    pub products: Vec<Product>,
    pub output: [Vec<i32>; 4],
}

impl BilinearScheme {
    /// Number of block multiplications (the scheme's rank).
    pub fn num_products(&self) -> usize {
        self.products.len()
    }

    /// The bilinear forms of all products, in order.
    pub fn forms(&self) -> Vec<BilinearForm> {
        self.products.iter().map(|p| p.form()).collect()
    }

    /// Symbolic validity: for each target, the output combination of the
    /// product forms expands to exactly the target's form.
    pub fn verify(&self) -> Result<(), String> {
        for t in Target::ALL {
            let row = &self.output[t.index()];
            if row.len() != self.products.len() {
                return Err(format!(
                    "{}: output row {} has {} coeffs for {} products",
                    self.name,
                    t,
                    row.len(),
                    self.products.len()
                ));
            }
            let mut acc = BilinearForm::ZERO;
            for (c, p) in row.iter().zip(self.products.iter()) {
                acc = acc + p.form() * *c;
            }
            if acc != t.form() {
                return Err(format!(
                    "{}: {} expands to {} (expected {})",
                    self.name,
                    t,
                    acc,
                    t.form()
                ));
            }
        }
        Ok(())
    }

    /// Apply the scheme at one level of 2×2 blocking over any scalar
    /// backend: encode both operands per product, multiply, and combine
    /// into the targets via the output table. Every coefficient is an
    /// integer, so over exact backends this equals the naive product
    /// with `==` — the single-level ground-truth route of the
    /// cross-backend conformance suite (the distributed coordinator
    /// performs the same computation with one worker per product).
    pub fn apply_once<S: Scalar>(&self, a: &Dense<S>, b: &Dense<S>) -> Dense<S> {
        assert_eq!(a.cols(), b.rows(), "matmul dims: {:?} x {:?}", a.shape(), b.shape());
        let ablocks = split_blocks(a);
        let bblocks = split_blocks(b);
        let (hr, hc) = (a.rows() / 2, b.cols() / 2);
        let mut out = Dense::zeros(a.rows(), b.cols());
        for (i, p) in self.products.iter().enumerate() {
            let prod = encode_operand(&p.u, &ablocks).matmul(&encode_operand(&p.v, &bblocks));
            for (t, coeffs) in self.output.iter().enumerate() {
                let coef = coeffs[i];
                if coef != 0 {
                    out.add_scaled_region((t / 2) * hr, (t % 2) * hc, S::from_i64(coef as i64), &prod);
                }
            }
        }
        out
    }

    /// Total block additions/subtractions: encoder adds for every product
    /// plus output-combination adds (|supp| - 1 per target). Winograd's
    /// claim to fame is 15 here vs Strassen's 18 (Probert's lower bound).
    pub fn total_adds(&self) -> usize {
        let encode: usize = self.products.iter().map(|p| p.encode_adds()).sum();
        let decode: usize = self
            .output
            .iter()
            .map(|row| row.iter().filter(|&&c| c != 0).count() - 1)
            .sum();
        encode + decode
    }
}

#[cfg(test)]
mod tests {
    use crate::algorithms::{naive8, strassen, winograd};

    #[test]
    fn all_builtin_schemes_verify() {
        for s in [strassen(), winograd(), naive8()] {
            s.verify().unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn product_counts() {
        assert_eq!(strassen().num_products(), 7);
        assert_eq!(winograd().num_products(), 7);
        assert_eq!(naive8().num_products(), 8);
    }

    #[test]
    fn addition_counts_match_literature() {
        // Without common-subexpression reuse: Strassen 18, Winograd 24,
        // naive 4 (output sums only). Winograd's celebrated 15 (Probert's
        // bound, quoted in the paper) is reached only after sharing the
        // repeated sums (M11-M21, B22-B12, ...) — the distributed setting
        // here cannot share them across workers, so the naive count is
        // the operative one (each worker encodes its own operands).
        assert_eq!(strassen().total_adds(), 18);
        assert_eq!(winograd().total_adds(), 24);
        assert_eq!(naive8().total_adds(), 4);
    }

    #[test]
    fn verify_catches_broken_output_row() {
        let mut s = strassen();
        s.output[0][0] = -1; // corrupt C11's S1 coefficient
        assert!(s.verify().is_err());
    }

    #[test]
    fn verify_catches_wrong_row_length() {
        let mut s = strassen();
        s.output[2].pop();
        assert!(s.verify().is_err());
    }

    #[test]
    fn apply_once_is_exact_over_integer_backends() {
        use crate::linalg::matrix::Dense;
        let a: Dense<i64> = Dense::from_i64_fn(4, 4, |i, j| (i * 4 + j) as i64 - 8);
        let b: Dense<i64> = Dense::from_i64_fn(4, 4, |i, j| 3 - (i + 2 * j) as i64);
        let want = a.matmul_naive(&b);
        for s in [strassen(), winograd(), naive8()] {
            assert_eq!(s.apply_once(&a, &b), want, "{}", s.name);
        }
    }

    #[test]
    fn encode_adds() {
        // S1 = (M11+M22)(B11+B22): one add each side.
        assert_eq!(strassen().products[0].encode_adds(), 2);
        // W1 = M11 B11: no adds.
        assert_eq!(winograd().products[0].encode_adds(), 0);
    }
}
