//! Strassen's original ⟨2,2,2;7⟩ algorithm (paper's S1..S7).

use super::scheme::{BilinearScheme, Product};

/// Strassen's algorithm exactly as printed in the paper:
///
/// ```text
/// S1 = (M11 + M22)(B11 + B22)      S5 = (M11 + M12) B22
/// S2 = (M21 + M22) B11             S6 = (M21 - M11)(B11 + B12)
/// S3 = M11 (B12 - B22)             S7 = (M12 - M22)(B21 + B22)
/// S4 = M22 (B21 - B11)
///
/// C11 = S1 + S4 - S5 + S7          C21 = S2 + S4
/// C12 = S3 + S5                    C22 = S1 - S2 + S3 + S6
/// ```
pub fn strassen() -> BilinearScheme {
    BilinearScheme {
        name: "strassen",
        products: vec![
            Product::new([1, 0, 0, 1], [1, 0, 0, 1]),   // S1
            Product::new([0, 0, 1, 1], [1, 0, 0, 0]),   // S2
            Product::new([1, 0, 0, 0], [0, 1, 0, -1]),  // S3
            Product::new([0, 0, 0, 1], [-1, 0, 1, 0]),  // S4
            Product::new([1, 1, 0, 0], [0, 0, 0, 1]),   // S5
            Product::new([-1, 0, 1, 0], [1, 1, 0, 0]),  // S6
            Product::new([0, 1, 0, -1], [0, 0, 1, 1]),  // S7
        ],
        output: [
            vec![1, 0, 0, 1, -1, 0, 1], // C11 (paper eq. (1))
            vec![0, 0, 1, 0, 1, 0, 0],  // C12 (paper eq. (2))
            vec![0, 1, 0, 1, 0, 0, 0],  // C21 (paper eq. (3))
            vec![1, -1, 1, 0, 0, 1, 0], // C22 (paper eq. (4))
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::form::Target;
    use crate::algebra::gauss::{rank, solve_in_span};

    #[test]
    fn is_valid() {
        strassen().verify().unwrap();
    }

    #[test]
    fn has_full_rank_seven() {
        assert_eq!(rank(&strassen().forms()), 7);
    }

    #[test]
    fn output_rows_are_the_unique_solution() {
        // Rank 7 => the decode weights over the 7 products are unique, so
        // eqs. (1)-(4) are THE decode combination for a complete set.
        let forms = strassen().forms();
        for t in Target::ALL {
            let w = solve_in_span(&forms, &t.form()).unwrap();
            for (i, wi) in w.iter().enumerate() {
                assert_eq!(
                    wi.numerator() as i32,
                    strassen().output[t.index()][i] * wi.denominator() as i32
                );
            }
        }
    }
}
