//! Brent's triple-product condition for ⟨2,2,2;t⟩ schemes.
//!
//! A set of products `P_r = (Σ u_r[ij] M_ij)(Σ v_r[kl] B_kl)` with output
//! coefficients `w_r[mn]` computes `C = M · B` iff for every index tuple
//!
//! ```text
//! Σ_r u_r[i,j] · v_r[k,l] · w_r[m,n]  =  δ_{j,k} · δ_{m,i} · δ_{n,l}
//! ```
//!
//! (the Brent equations). The paper points to this condition — via
//! Karstadt–Schwartz — as the efficient way to enumerate alternative
//! Strassen-like algorithms to pair; we use it both as an independent
//! validator of the scheme tables and as the acceptance test for
//! externally supplied schemes in the config layer.

use super::scheme::BilinearScheme;

/// Block index (0..4, row-major) -> (row, col) in the 2×2 block grid.
#[inline]
fn rc(idx: usize) -> (usize, usize) {
    (idx / 2, idx % 2)
}

/// Check the Brent equations for a scheme. Returns the list of violated
/// index tuples `(i, j, k, l, m, n)` (empty = valid).
pub fn brent_violations(s: &BilinearScheme) -> Vec<(usize, usize, usize, usize, usize, usize)> {
    let mut bad = Vec::new();
    let t = s.num_products();
    for mj in 0..4 {
        let (i, j) = rc(mj);
        for bk in 0..4 {
            let (k, l) = rc(bk);
            for cm in 0..4 {
                let (m, n) = rc(cm);
                let mut sum: i64 = 0;
                for r in 0..t {
                    sum += s.products[r].u[mj] as i64
                        * s.products[r].v[bk] as i64
                        * s.output[cm][r] as i64;
                }
                let want = if j == k && m == i && n == l { 1 } else { 0 };
                if sum != want {
                    bad.push((i, j, k, l, m, n));
                }
            }
        }
    }
    bad
}

/// True iff the scheme satisfies all 64 Brent equations.
pub fn satisfies_triple_product(s: &BilinearScheme) -> bool {
    brent_violations(s).is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{naive8, strassen, winograd};

    #[test]
    fn builtin_schemes_satisfy_brent() {
        for s in [strassen(), winograd(), naive8()] {
            let v = brent_violations(&s);
            assert!(v.is_empty(), "{}: {} violations, first {:?}", s.name, v.len(), v.first());
        }
    }

    #[test]
    fn corrupted_scheme_fails_brent() {
        let mut s = strassen();
        s.products[3].v = [1, 0, 1, 0]; // break S4
        assert!(!satisfies_triple_product(&s));
    }

    #[test]
    fn brent_agrees_with_symbolic_verify() {
        // Property: for a batch of random corruptions, the two validators
        // agree (both accept or both reject).
        let mut seed = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for _ in 0..200 {
            let mut s = winograd();
            // Randomly perturb one coefficient by ±1.
            let r = (next() % 7) as usize;
            let p = (next() % 4) as usize;
            let delta = if next() % 2 == 0 { 1 } else { -1 };
            if next() % 2 == 0 {
                s.products[r].u[p] += delta;
            } else {
                s.products[r].v[p] += delta;
            }
            assert_eq!(
                satisfies_triple_product(&s),
                s.verify().is_ok(),
                "validators disagree"
            );
        }
    }
}
