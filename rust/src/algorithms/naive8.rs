//! The standard 8-multiplication block algorithm (classical baseline).

use super::scheme::{BilinearScheme, Product};

/// Naive ⟨2,2,2;8⟩: `P_{ikj} = M_ik · B_kj`, `C_ij = Σ_k P_{ikj}`.
/// Product order: (C11,k=1), (C11,k=2), (C12,k=1), (C12,k=2),
/// (C21,k=1), (C21,k=2), (C22,k=1), (C22,k=2).
pub fn naive8() -> BilinearScheme {
    let e = |p: usize, q: usize| {
        let mut u = [0; 4];
        let mut v = [0; 4];
        u[p] = 1;
        v[q] = 1;
        Product::new(u, v)
    };
    BilinearScheme {
        name: "naive8",
        products: vec![
            e(0, 0), // M11 B11
            e(1, 2), // M12 B21
            e(0, 1), // M11 B12
            e(1, 3), // M12 B22
            e(2, 0), // M21 B11
            e(3, 2), // M22 B21
            e(2, 1), // M21 B12
            e(3, 3), // M22 B22
        ],
        output: [
            vec![1, 1, 0, 0, 0, 0, 0, 0],
            vec![0, 0, 1, 1, 0, 0, 0, 0],
            vec![0, 0, 0, 0, 1, 1, 0, 0],
            vec![0, 0, 0, 0, 0, 0, 1, 1],
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::gauss::rank;

    #[test]
    fn is_valid() {
        naive8().verify().unwrap();
    }

    #[test]
    fn rank_eight() {
        assert_eq!(rank(&naive8().forms()), 8);
    }
}
