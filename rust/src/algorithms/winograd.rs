//! Winograd's ⟨2,2,2;7⟩ variant (paper's W1..W7) — optimal at 15
//! additions (Probert's lower bound).

use super::scheme::{BilinearScheme, Product};

/// Winograd's algorithm exactly as printed in the paper:
///
/// ```text
/// W1 = M11 B11                         W5 = (M21 + M22)(B12 - B11)
/// W2 = M12 B21                         W6 = (M11 + M12 - M21 - M22) B22
/// W3 = M22 (B11 - B12 - B21 + B22)     W7 = (M11 - M21 - M22)(B11 - B12 + B22)
/// W4 = (M11 - M21)(B22 - B12)
///
/// C11 = W1 + W2                        C21 = W1 - W3 + W4 - W7
/// C12 = W1 + W5 + W6 - W7              C22 = W1 + W4 + W5 - W7
/// ```
pub fn winograd() -> BilinearScheme {
    BilinearScheme {
        name: "winograd",
        products: vec![
            Product::new([1, 0, 0, 0], [1, 0, 0, 0]),             // W1
            Product::new([0, 1, 0, 0], [0, 0, 1, 0]),             // W2
            Product::new([0, 0, 0, 1], [1, -1, -1, 1]),           // W3
            Product::new([1, 0, -1, 0], [0, -1, 0, 1]),           // W4
            Product::new([0, 0, 1, 1], [-1, 1, 0, 0]),            // W5
            Product::new([1, 1, -1, -1], [0, 0, 0, 1]),           // W6
            Product::new([1, 0, -1, -1], [1, -1, 0, 1]),          // W7
        ],
        output: [
            vec![1, 1, 0, 0, 0, 0, 0],    // C11
            vec![1, 0, 0, 0, 1, 1, -1],   // C12
            vec![1, 0, -1, 1, 0, 0, -1],  // C21
            vec![1, 0, 0, 1, 1, 0, -1],   // C22
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::gauss::rank;
    use crate::algorithms::strassen::strassen;

    #[test]
    fn is_valid() {
        winograd().verify().unwrap();
    }

    #[test]
    fn has_full_rank_seven() {
        assert_eq!(rank(&winograd().forms()), 7);
    }

    #[test]
    fn distinct_from_strassen_as_forms() {
        // The fault-tolerance of the paper comes precisely from the two
        // algorithms having different product forms: only W1/W2-style
        // overlaps are allowed to coincide. Check no S_i duplicates any
        // W_j up to sign.
        let s = strassen().forms();
        let w = winograd().forms();
        let mut overlaps = 0;
        for sf in &s {
            for wf in &w {
                if sf == wf || *sf == -*wf {
                    overlaps += 1;
                }
            }
        }
        assert_eq!(overlaps, 0, "paper's S and W sets share no product");
    }

    #[test]
    fn joint_rank_is_ten() {
        // dim span(S1..S7, W1..W7) = 10: the 14 joint products carry
        // 14 - 10 = 4 independent product-space dependencies, and with
        // the 4 output targets adjoined the relation space has dimension
        // 18 - 10 = 8 (see search::relations). These check relations are
        // exactly where the paper's fault tolerance comes from.
        let mut forms = strassen().forms();
        forms.extend(winograd().forms());
        assert_eq!(rank(&forms), 10);
    }
}
