//! Strassen-like bilinear algorithms (⟨2,2,2;t⟩ schemes).
//!
//! A *Strassen-like algorithm* computes the 2×2 block product with `t`
//! block multiplications: `t` rank-1 bilinear products
//! `P_i = u_i(M) · v_i(B)` plus an integer output table expressing each
//! `C_jk` as a combination of the `P_i`. The paper uses Strassen's and
//! Winograd's `t = 7` schemes; the naive `t = 8` scheme is included as
//! the classical baseline substrate.
//!
//! Validity is checked two independent ways: symbolically (the output
//! combinations expand to exactly `C_jk = Σ M·B` — see
//! [`scheme::BilinearScheme::verify`]) and via Brent's triple-product
//! equations ([`triple_product`]).

pub mod naive8;
pub mod scheme;
pub mod strassen;
pub mod transform;
pub mod triple_product;
pub mod winograd;

pub use naive8::naive8;
pub use scheme::BilinearScheme;
pub use strassen::strassen;
pub use winograd::winograd;
