//! The paper's failure model: each compute node independently fails to
//! deliver its result on time with probability `p_e` (Fig. 2 x-axis).

use crate::sim::rng::Rng;

/// i.i.d. Bernoulli failure model over `m` nodes.
#[derive(Clone, Copy, Debug)]
pub struct BernoulliFailures {
    /// Per-node failure probability.
    pub p_e: f64,
    /// Number of compute nodes.
    pub m: usize,
}

impl BernoulliFailures {
    pub fn new(p_e: f64, m: usize) -> Self {
        assert!((0.0..=1.0).contains(&p_e), "p_e out of range: {p_e}");
        assert!(m <= 64, "bitmask model supports up to 64 nodes");
        BernoulliFailures { p_e, m }
    }

    /// Sample a failure pattern as a bitmask (bit i set = node i FAILED).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let mut mask = 0u64;
        for i in 0..self.m {
            if rng.bernoulli(self.p_e) {
                mask |= 1 << i;
            }
        }
        mask
    }

    /// Probability of a specific pattern with `k` failures.
    pub fn pattern_probability(&self, k: u32) -> f64 {
        self.p_e.powi(k as i32) * (1.0 - self.p_e).powi((self.m as u32 - k) as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_respects_m() {
        let model = BernoulliFailures::new(0.5, 10);
        let mut rng = Rng::seeded(1);
        for _ in 0..100 {
            assert_eq!(model.sample(&mut rng) >> 10, 0);
        }
    }

    #[test]
    fn empirical_failure_rate() {
        let model = BernoulliFailures::new(0.2, 16);
        let mut rng = Rng::seeded(2);
        let trials = 50_000;
        let total: u32 = (0..trials).map(|_| model.sample(&mut rng).count_ones()).sum();
        let rate = total as f64 / (trials as f64 * 16.0);
        assert!((rate - 0.2).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn pattern_probabilities_sum_to_one() {
        let model = BernoulliFailures::new(0.3, 8);
        let total: f64 = (0u64..256)
            .map(|mask| model.pattern_probability(mask.count_ones()))
            .sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_probability() {
        let _ = BernoulliFailures::new(1.5, 4);
    }
}
