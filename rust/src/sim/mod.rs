//! Stochastic simulation substrate: RNG, failure models, Monte Carlo.
//!
//! The paper evaluates with i.i.d. Bernoulli node failures (Fig. 2) and
//! leaves latency-distribution models to future work; we implement both
//! (`bernoulli` for the paper's model, `latency` for shifted-exponential
//! stragglers) plus the Monte-Carlo estimator that cross-validates the
//! analytical P_f of `coding::theory` — including per-leaf failure and
//! latency sampling for nested two-level schemes at fan-outs of 196–256
//! leaves, where the flat 2^M enumeration is impossible.

pub mod bernoulli;
pub mod des;
pub mod latency;
pub mod montecarlo;
pub mod rng;

pub use montecarlo::MonteCarlo;
pub use rng::Rng;
