//! Monte-Carlo estimation of reconstruction-failure probability and
//! completion-time statistics (cross-validates `coding::theory` and
//! generates the simulation series of Fig. 2), including the nested
//! two-level variants at fan-outs (196–256 leaves) where the flat
//! 2^M bitmask enumeration is impossible.

use crate::coding::nested::NestedOracle;
use crate::sim::bernoulli::BernoulliFailures;
use crate::sim::latency::{completion_time, sample_completion_times, LatencyModel};
use crate::sim::rng::Rng;

/// Monte-Carlo engine with an explicit trial budget and seed.
#[derive(Clone, Copy, Debug)]
pub struct MonteCarlo {
    pub trials: u64,
    pub seed: u64,
}

/// Estimate with its standard error.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Estimate {
    pub mean: f64,
    pub std_err: f64,
    pub trials: u64,
}

impl Estimate {
    /// Does this estimate agree with a `reference` value within `z`
    /// standard errors plus an absolute `slack`?
    ///
    /// The slack term covers the regime where the estimate cannot
    /// resolve the reference at all — e.g. a binomial proportion of 0
    /// successes has `std_err == 0`, yet by the rule of three the true
    /// value may be as large as ≈ 3/n; passing `slack = 3.0 / n`
    /// makes such points pass exactly when they are statistically
    /// uninformative rather than wrong.
    pub fn agrees_with(&self, reference: f64, z: f64, slack: f64) -> bool {
        (self.mean - reference).abs() <= z * self.std_err + slack
    }
}

impl MonteCarlo {
    pub fn new(trials: u64, seed: u64) -> Self {
        MonteCarlo { trials, seed }
    }

    /// P(reconstruction fails) under i.i.d. Bernoulli node failures, for a
    /// decodability oracle over *failed*-node masks.
    ///
    /// The oracle receives the FAILED mask (bit i set = node i failed) and
    /// must return `true` iff the output is still decodable.
    pub fn failure_probability(
        &self,
        p_e: f64,
        m: usize,
        decodable_with_failures: impl Fn(u64) -> bool,
    ) -> Estimate {
        let model = BernoulliFailures::new(p_e, m);
        let mut rng = Rng::seeded(self.seed);
        let mut failures = 0u64;
        for _ in 0..self.trials {
            let mask = model.sample(&mut rng);
            if !decodable_with_failures(mask) {
                failures += 1;
            }
        }
        let mean = failures as f64 / self.trials as f64;
        let std_err = (mean * (1.0 - mean) / self.trials as f64).sqrt();
        Estimate { mean, std_err, trials: self.trials }
    }

    /// P(reconstruction fails) for a nested two-level scheme under
    /// i.i.d. Bernoulli **leaf** failures: each trial samples one
    /// failed-leaf mask per outer group and asks the two-stage oracle.
    /// Cross-validates `coding::theory::nested_failure_probability`
    /// (the Fig.-2-style curves at M = 196–256).
    pub fn nested_failure_probability(&self, p_e: f64, oracle: &NestedOracle) -> Estimate {
        let model = BernoulliFailures::new(p_e, oracle.group_size());
        let mut rng = Rng::seeded(self.seed);
        let mut masks = vec![0u64; oracle.num_groups()];
        let mut failures = 0u64;
        for _ in 0..self.trials {
            for m in masks.iter_mut() {
                *m = model.sample(&mut rng);
            }
            if !oracle.is_decodable(&masks) {
                failures += 1;
            }
        }
        let mean = failures as f64 / self.trials as f64;
        let std_err = (mean * (1.0 - mean) / self.trials as f64).sqrt();
        Estimate { mean, std_err, trials: self.trials }
    }

    /// Mean time-to-decode of a nested scheme under a per-leaf latency
    /// model: a group's product is available at the earliest time its
    /// finished leaves span the inner targets; the job decodes at the
    /// earliest time the available groups span the outer targets.
    pub fn nested_mean_completion_time(
        &self,
        model: &LatencyModel,
        oracle: &NestedOracle,
    ) -> Estimate {
        let (m1, m2) = (oracle.num_groups(), oracle.group_size());
        let full2 = (1u64 << m2) - 1;
        let full1 = (1u64 << m1) - 1;
        let mut rng = Rng::seeded(self.seed);
        let mut sum = 0.0;
        let mut sum_sq = 0.0;
        for _ in 0..self.trials {
            let group_times: Vec<f64> = (0..m1)
                .map(|_| {
                    let times = sample_completion_times(model, m2, &mut rng);
                    completion_time(&times, |fin| oracle.group_decodable(!fin & full2))
                        .expect("full inner set always decodes")
                })
                .collect();
            let t = completion_time(&group_times, |fin| oracle.outer_decodable(!fin & full1))
                .expect("full outer set always decodes");
            sum += t;
            sum_sq += t * t;
        }
        let n = self.trials;
        let mean = sum / n as f64;
        let var = (sum_sq / n as f64 - mean * mean).max(0.0);
        Estimate { mean, std_err: (var / n as f64).sqrt(), trials: n }
    }

    /// Mean time-to-decode under a latency model: nodes finish at sampled
    /// times; the oracle receives the FINISHED mask.
    pub fn mean_completion_time(
        &self,
        model: &LatencyModel,
        m: usize,
        decodable_with_finished: impl Fn(u64) -> bool,
    ) -> Estimate {
        let mut rng = Rng::seeded(self.seed);
        let mut sum = 0.0;
        let mut sum_sq = 0.0;
        let mut n = 0u64;
        for _ in 0..self.trials {
            let times = sample_completion_times(model, m, &mut rng);
            if let Some(t) = completion_time(&times, &decodable_with_finished) {
                sum += t;
                sum_sq += t * t;
                n += 1;
            }
        }
        assert!(n > 0, "never decodable");
        let mean = sum / n as f64;
        let var = (sum_sq / n as f64 - mean * mean).max(0.0);
        Estimate { mean, std_err: (var / n as f64).sqrt(), trials: n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_probability_of_trivial_oracles() {
        let mc = MonteCarlo::new(20_000, 1);
        // Never decodable -> P_f = 1.
        let e = mc.failure_probability(0.1, 8, |_| false);
        assert_eq!(e.mean, 1.0);
        // Always decodable -> P_f = 0.
        let e = mc.failure_probability(0.1, 8, |_| true);
        assert_eq!(e.mean, 0.0);
    }

    #[test]
    fn matches_binomial_for_single_node_oracle() {
        // Oracle: decodable iff node 0 did not fail -> P_f = p_e.
        let mc = MonteCarlo::new(100_000, 2);
        let p_e = 0.23;
        let e = mc.failure_probability(p_e, 8, |mask| mask & 1 == 0);
        assert!((e.mean - p_e).abs() < 4.0 * e.std_err + 1e-3, "{e:?}");
    }

    #[test]
    fn replication_all_nodes_needed() {
        // 7 nodes all required: P_f = 1 - (1-p)^7.
        let mc = MonteCarlo::new(100_000, 3);
        let p_e = 0.1;
        let e = mc.failure_probability(p_e, 7, |mask| mask == 0);
        let want = 1.0 - (1.0f64 - p_e).powi(7);
        assert!((e.mean - want).abs() < 5.0 * e.std_err, "{e:?} want {want}");
    }

    #[test]
    fn nested_mc_matches_compositional_theory() {
        use crate::coding::fc::fc_table;
        use crate::coding::nested::{NestedOracle, NestedTaskSet};
        use crate::coding::scheme::TaskSet;
        use crate::coding::theory::nested_failure_probability;
        use crate::algorithms::strassen;

        // strassen-x2 nested in strassen-x2 (196 leaves): both the
        // theory and the oracle take the replication fast paths, and
        // the failure probability is large enough to resolve by MC.
        let outer = TaskSet::replication(&strassen(), 2);
        let inner = TaskSet::replication(&strassen(), 2);
        let want = nested_failure_probability(&fc_table(&outer), &fc_table(&inner), 0.2);
        let nested = NestedTaskSet::compose(outer, inner);
        let oracle = NestedOracle::build(&nested);
        let mc = MonteCarlo::new(40_000, 7).nested_failure_probability(0.2, &oracle);
        assert!(
            (mc.mean - want).abs() < 5.0 * mc.std_err + 1e-3,
            "mc {} vs theory {want} (stderr {})",
            mc.mean,
            mc.std_err
        );
    }

    #[test]
    fn nested_completion_time_single_copy_is_max_of_all_leaves() {
        use crate::coding::nested::{NestedOracle, NestedTaskSet};
        use crate::coding::scheme::TaskSet;
        use crate::algorithms::strassen;

        // strassen-x1 : strassen-x1 needs every one of the 49 leaves,
        // so time-to-decode is the max of 49 exponentials: E = H_49.
        let nested = NestedTaskSet::compose(
            TaskSet::replication(&strassen(), 1),
            TaskSet::replication(&strassen(), 1),
        );
        let oracle = NestedOracle::build(&nested);
        let model = LatencyModel::ShiftedExp { shift: 0.0, rate: 1.0 };
        let e = MonteCarlo::new(20_000, 11).nested_mean_completion_time(&model, &oracle);
        let h49: f64 = (1..=49).map(|k| 1.0 / k as f64).sum();
        assert!((e.mean - h49).abs() < 0.1, "{e:?} want {h49}");
    }

    #[test]
    fn agrees_with_uses_z_times_std_err_plus_slack() {
        let e = Estimate { mean: 0.10, std_err: 0.01, trials: 1000 };
        assert!(e.agrees_with(0.12, 2.0, 0.0));
        assert!(!e.agrees_with(0.15, 2.0, 0.0));
        // Zero-failure estimate: only the slack term can admit it.
        let zero = Estimate { mean: 0.0, std_err: 0.0, trials: 1000 };
        assert!(!zero.agrees_with(0.002, 4.0, 0.0));
        assert!(zero.agrees_with(0.002, 4.0, 3.0 / 1000.0));
    }

    #[test]
    fn completion_time_order_statistic_mean() {
        // m exponential(1) nodes, need all m: E[max] = H_m.
        let mc = MonteCarlo::new(50_000, 4);
        let model = LatencyModel::ShiftedExp { shift: 0.0, rate: 1.0 };
        let e = mc.mean_completion_time(&model, 5, |mask| mask == 0b11111);
        let h5 = 1.0 + 0.5 + 1.0 / 3.0 + 0.25 + 0.2;
        assert!((e.mean - h5).abs() < 0.05, "{e:?} want {h5}");
    }
}
