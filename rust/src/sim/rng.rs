//! xoshiro256++ PRNG (offline substitute for the `rand` crate).
//!
//! Deterministic, seedable, splittable; every stochastic component of the
//! library threads one of these explicitly so simulations are exactly
//! reproducible from the seed logged in benchmark/experiment output.

/// xoshiro256++ with splitmix64 seeding (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a 64-bit value.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.uniform(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal (Box–Muller; one value per call for simplicity).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::seeded(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = Rng::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_frequency() {
        let mut rng = Rng::seeded(11);
        let p = 0.3;
        let n = 200_000;
        let hits = (0..n).filter(|_| rng.bernoulli(p)).count();
        let freq = hits as f64 / n as f64;
        assert!((freq - p).abs() < 0.01, "freq {freq}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seeded(13);
        let lambda = 2.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seeded(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seeded(19);
        for _ in 0..100 {
            let s = rng.sample_indices(16, 5);
            assert_eq!(s.len(), 5);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "duplicates in {s:?}");
        }
    }

    #[test]
    fn split_streams_are_independent_enough() {
        let mut root = Rng::seeded(23);
        let mut a = root.split();
        let mut b = root.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
