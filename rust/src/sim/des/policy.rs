//! Scheduling policies for the fleet simulator.
//!
//! A policy owns the representation of the idle-worker set — at 10k
//! workers a per-dispatch linear scan would dominate the run, so each
//! policy keeps a structure matched to its decision rule (swap-remove
//! vector, speed-ordered heap, per-rack free lists). The engine calls
//! `acquire` once per dispatch and `release` once per completion; both
//! must be deterministic given the call sequence and the engine RNG.

use crate::sim::des::fleet::Fleet;
use crate::sim::rng::Rng;

/// What a policy may observe about the job whose work item is at the
/// head of the dispatch queue.
pub struct JobView<'a> {
    pub job_id: u64,
    /// `touched_racks[r]` — has this job already shipped operands to
    /// rack `r`? (Length = `fleet.num_racks()`.)
    pub touched_racks: &'a [bool],
    /// Leaf attempts currently in flight for this job.
    pub outstanding: usize,
    /// Work items of this job still queued.
    pub pending: usize,
    /// Outer groups still needed (neither recovered nor hopeless).
    pub groups_needed: usize,
}

/// A worker-selection policy. The default implementations in this
/// module are compared head-to-head by `benches/fleet_sim.rs`.
pub trait SchedPolicy {
    fn name(&self) -> &'static str;
    /// Reset to "all workers idle" for the given fleet.
    fn init(&mut self, fleet: &Fleet);
    /// Worker `w` finished (or was freed) and is idle again.
    fn release(&mut self, worker: u32, fleet: &Fleet);
    /// Pick an idle worker for the job at the queue head, or `None` to
    /// leave the item queued (no idle worker the policy will spend).
    fn acquire(&mut self, job: &JobView, fleet: &Fleet, rng: &mut Rng) -> Option<u32>;
    /// Should the engine duplicate one of this job's in-flight leaves
    /// when capacity is idle? (Speculative execution; the engine caps
    /// attempts per leaf.)
    fn wants_backup(&self, _job: &JobView) -> bool {
        false
    }
}

/// Uniformly random idle worker (the baseline).
#[derive(Default)]
pub struct RandomPolicy {
    idle: Vec<u32>,
}

impl SchedPolicy for RandomPolicy {
    fn name(&self) -> &'static str {
        "random"
    }

    fn init(&mut self, fleet: &Fleet) {
        self.idle = (0..fleet.len() as u32).collect();
    }

    fn release(&mut self, worker: u32, _fleet: &Fleet) {
        self.idle.push(worker);
    }

    fn acquire(&mut self, _job: &JobView, _fleet: &Fleet, rng: &mut Rng) -> Option<u32> {
        if self.idle.is_empty() {
            return None;
        }
        let i = rng.below(self.idle.len() as u64) as usize;
        Some(self.idle.swap_remove(i))
    }
}

/// Heap entry ordered fastest-first (smallest slowness multiplier),
/// worker id as the deterministic tie-break.
struct FastEntry {
    speed: f64,
    worker: u32,
}

impl PartialEq for FastEntry {
    fn eq(&self, other: &Self) -> bool {
        self.speed.total_cmp(&other.speed).is_eq() && self.worker == other.worker
    }
}

impl Eq for FastEntry {}

impl PartialOrd for FastEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FastEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap pops the max, we want the smallest
        // multiplier (fastest worker), lowest id among equals.
        other
            .speed
            .total_cmp(&self.speed)
            .then_with(|| other.worker.cmp(&self.worker))
    }
}

/// Always dispatch to the fastest idle worker.
#[derive(Default)]
pub struct FastestFirst {
    idle: std::collections::BinaryHeap<FastEntry>,
}

impl SchedPolicy for FastestFirst {
    fn name(&self) -> &'static str {
        "fastest"
    }

    fn init(&mut self, fleet: &Fleet) {
        self.idle.clear();
        for w in 0..fleet.len() as u32 {
            self.idle.push(FastEntry { speed: fleet.speed(w), worker: w });
        }
    }

    fn release(&mut self, worker: u32, fleet: &Fleet) {
        self.idle.push(FastEntry { speed: fleet.speed(worker), worker });
    }

    fn acquire(&mut self, _job: &JobView, _fleet: &Fleet, _rng: &mut Rng) -> Option<u32> {
        self.idle.pop().map(|e| e.worker)
    }
}

/// Prefer racks the job has already shipped operands to (warm racks
/// skip the operand transfer), falling back to a rotating cursor over
/// all racks so cold dispatches spread instead of piling onto rack 0.
#[derive(Default)]
pub struct LocalityAware {
    idle_by_rack: Vec<Vec<u32>>,
    cursor: usize,
}

impl SchedPolicy for LocalityAware {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn init(&mut self, fleet: &Fleet) {
        self.idle_by_rack = vec![Vec::new(); fleet.num_racks()];
        for w in 0..fleet.len() as u32 {
            self.idle_by_rack[fleet.rack_of(w) as usize].push(w);
        }
        self.cursor = 0;
    }

    fn release(&mut self, worker: u32, fleet: &Fleet) {
        self.idle_by_rack[fleet.rack_of(worker) as usize].push(worker);
    }

    fn acquire(&mut self, job: &JobView, _fleet: &Fleet, _rng: &mut Rng) -> Option<u32> {
        // Warm racks first, lowest rack id as the deterministic order.
        for (r, touched) in job.touched_racks.iter().enumerate() {
            if *touched {
                if let Some(w) = self.idle_by_rack[r].pop() {
                    return Some(w);
                }
            }
        }
        // Cold fallback: rotating cursor so successive cold dispatches
        // land on different racks.
        let n = self.idle_by_rack.len();
        for step in 0..n {
            let r = (self.cursor + step) % n;
            if let Some(w) = self.idle_by_rack[r].pop() {
                self.cursor = (r + 1) % n;
                return Some(w);
            }
        }
        None
    }
}

/// Fastest-first dispatch plus speculative backups: when a job has no
/// queued work left but attempts still in flight, ask the engine to
/// duplicate an outstanding leaf on the next idle worker. Backups beat
/// stragglers (a delayed first attempt is overtaken by a clean rerun);
/// they cannot beat the paper's fail-stop faults, which are pure
/// per-(job, leaf) and re-roll identically on every attempt.
#[derive(Default)]
pub struct Speculative {
    inner: FastestFirst,
}

impl SchedPolicy for Speculative {
    fn name(&self) -> &'static str {
        "speculative"
    }

    fn init(&mut self, fleet: &Fleet) {
        self.inner.init(fleet);
    }

    fn release(&mut self, worker: u32, fleet: &Fleet) {
        self.inner.release(worker, fleet);
    }

    fn acquire(&mut self, job: &JobView, fleet: &Fleet, rng: &mut Rng) -> Option<u32> {
        self.inner.acquire(job, fleet, rng)
    }

    fn wants_backup(&self, job: &JobView) -> bool {
        job.pending == 0 && job.outstanding > 0
    }
}

/// Construct a policy by CLI name.
pub fn policy_by_name(name: &str) -> Result<Box<dyn SchedPolicy>, String> {
    match name.trim().to_lowercase().as_str() {
        "random" => Ok(Box::<RandomPolicy>::default()),
        "fastest" => Ok(Box::<FastestFirst>::default()),
        "locality" => Ok(Box::<LocalityAware>::default()),
        "speculative" => Ok(Box::<Speculative>::default()),
        other => Err(format!(
            "unknown policy `{other}` (random|fastest|locality|speculative)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::des::fleet::FleetSpec;
    use crate::sim::latency::LatencyModel;

    fn small_fleet() -> Fleet {
        Fleet::build(
            &FleetSpec {
                workers: 8,
                rack_size: 4,
                speed: LatencyModel::Bimodal { base: 1.0, p_slow: 0.5, factor: 10.0 },
                ..FleetSpec::default()
            },
            42,
        )
    }

    fn view<'a>(touched: &'a [bool]) -> JobView<'a> {
        JobView { job_id: 0, touched_racks: touched, outstanding: 0, pending: 1, groups_needed: 4 }
    }

    #[test]
    fn random_draws_every_worker_once() {
        let fleet = small_fleet();
        let touched = vec![false; fleet.num_racks()];
        let mut p = RandomPolicy::default();
        p.init(&fleet);
        let mut rng = Rng::seeded(1);
        let mut got: Vec<u32> =
            (0..8).map(|_| p.acquire(&view(&touched), &fleet, &mut rng).unwrap()).collect();
        assert!(p.acquire(&view(&touched), &fleet, &mut rng).is_none());
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
        p.release(3, &fleet);
        assert_eq!(p.acquire(&view(&touched), &fleet, &mut rng), Some(3));
    }

    #[test]
    fn fastest_first_pops_in_speed_order() {
        let fleet = small_fleet();
        let touched = vec![false; fleet.num_racks()];
        let mut p = FastestFirst::default();
        p.init(&fleet);
        let mut rng = Rng::seeded(1);
        let order: Vec<u32> =
            (0..8).map(|_| p.acquire(&view(&touched), &fleet, &mut rng).unwrap()).collect();
        let speeds: Vec<f64> = order.iter().map(|&w| fleet.speed(w)).collect();
        assert!(speeds.windows(2).all(|w| w[0] <= w[1]), "not speed-sorted: {speeds:?}");
        // Equal-speed workers pop lowest id first.
        for w in order.windows(2) {
            if fleet.speed(w[0]) == fleet.speed(w[1]) {
                assert!(w[0] < w[1], "tie-break broken: {order:?}");
            }
        }
    }

    #[test]
    fn locality_prefers_touched_racks() {
        let fleet = small_fleet(); // racks: {0..3}, {4..7}
        let mut p = LocalityAware::default();
        p.init(&fleet);
        let mut rng = Rng::seeded(1);
        let touched = vec![false, true];
        let w = p.acquire(&view(&touched), &fleet, &mut rng).unwrap();
        assert_eq!(fleet.rack_of(w), 1, "warm rack ignored");
        // Exhaust rack 1, then it must fall back to rack 0.
        for _ in 0..3 {
            let w = p.acquire(&view(&touched), &fleet, &mut rng).unwrap();
            assert_eq!(fleet.rack_of(w), 1);
        }
        let w = p.acquire(&view(&touched), &fleet, &mut rng).unwrap();
        assert_eq!(fleet.rack_of(w), 0);
    }

    #[test]
    fn speculative_wants_backup_only_when_drained() {
        let p = Speculative::default();
        let touched = [false];
        let mut v = JobView {
            job_id: 1,
            touched_racks: &touched,
            outstanding: 3,
            pending: 0,
            groups_needed: 1,
        };
        assert!(p.wants_backup(&v));
        v.pending = 2;
        assert!(!p.wants_backup(&v));
        v.pending = 0;
        v.outstanding = 0;
        assert!(!p.wants_backup(&v));
    }

    #[test]
    fn policy_by_name_round_trip() {
        for name in ["random", "fastest", "locality", "speculative"] {
            assert_eq!(policy_by_name(name).unwrap().name(), name);
        }
        assert!(policy_by_name("bogus").is_err());
    }
}
