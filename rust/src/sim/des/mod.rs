//! Fleet-scale discrete-event simulator for coded-matmul campaigns.
//!
//! Where [`crate::sim::montecarlo`] samples the *static* question
//! ("given i.i.d. node faults, does this failure pattern decode?"),
//! this subsystem simulates the *dynamics*: jobs arriving at a shared
//! 10k-worker fleet, scheduling policies racing leaf tasks onto
//! heterogeneous nodes, rack-correlated outages, network transfer
//! costs, and speculative re-execution — while keeping the decode
//! semantics bit-identical to the live coordinator (the same span
//! oracles, the same pure per-`(seed, job, leaf)` fault hash).
//!
//! Layout:
//! * [`calendar`] — the event queue: binary heap over simulated time
//!   with a pinned `(time, insertion-seq)` tie-break.
//! * [`fleet`] — worker speeds, rack topology, link-cost model.
//! * [`arrival`] — uniform / Poisson / diurnal / trace-driven job
//!   arrival processes.
//! * [`policy`] — the [`SchedPolicy`] trait and four reference
//!   policies (random, fastest-first, locality-aware, speculative).
//! * [`engine`] — the campaign loop tying it all together.
//!
//! The headline experiment (`ft_strassen simfleet`, pinned by
//! `tests/fleet_sim.rs`) sweeps p_e over a 10k-node fleet running
//! nested fan-out-256 jobs and checks the simulated failure rate
//! against [`crate::coding::theory::nested_failure_probability`]
//! within Monte-Carlo confidence bounds.

pub mod arrival;
pub mod calendar;
pub mod engine;
pub mod fleet;
pub mod policy;

pub use arrival::ArrivalProcess;
pub use calendar::Calendar;
pub use engine::{Campaign, CampaignResult, CampaignSummary, SimPlan};
pub use fleet::{Fleet, FleetSpec, LinkModel};
pub use policy::{
    policy_by_name, FastestFirst, JobView, LocalityAware, RandomPolicy, SchedPolicy, Speculative,
};
