//! Event calendar: a binary-heap priority queue over simulated time
//! with a **pinned, total** tie-break rule.
//!
//! `BinaryHeap` alone is not deterministic enough for a regression-
//! testable simulator: equal-time events pop in an order that depends
//! on the heap's internal layout, which in turn depends on insertion
//! history *and* capacity-driven sift paths. The calendar therefore
//! orders entries by `(time, seq)` where `seq` is the global insertion
//! number — FIFO among equal-time events — making the pop sequence a
//! pure function of the schedule calls, independent of heap capacity,
//! platform, or allocator. `tests` pin this rule; the determinism
//! regression suite (`tests/fleet_sim.rs`) pins it end to end.

use std::collections::BinaryHeap;

struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest time
        // (and among equal times the earliest insertion) on top.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event calendar ordered by `(time, insertion seq)`.
pub struct Calendar<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Calendar<T> {
    pub fn new() -> Self {
        Calendar { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Pre-sized heap. The pop order is identical for every capacity —
    /// the determinism suite runs the same campaign at capacities 0 and
    /// 4096 and compares event traces byte for byte.
    pub fn with_capacity(cap: usize) -> Self {
        Calendar { heap: BinaryHeap::with_capacity(cap), seq: 0 }
    }

    /// Schedule `payload` at absolute simulated time `time` (seconds).
    pub fn schedule(&mut self, time: f64, payload: T) {
        assert!(time.is_finite(), "non-finite event time {time}");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, payload });
    }

    /// Pop the earliest event: `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T> Default for Calendar<T> {
    fn default() -> Self {
        Calendar::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut cal = Calendar::new();
        cal.schedule(3.0, "c");
        cal.schedule(1.0, "a");
        cal.schedule(2.0, "b");
        assert_eq!(cal.peek_time(), Some(1.0));
        assert_eq!(cal.pop(), Some((1.0, "a")));
        assert_eq!(cal.pop(), Some((2.0, "b")));
        assert_eq!(cal.pop(), Some((3.0, "c")));
        assert_eq!(cal.pop(), None);
    }

    #[test]
    fn equal_times_pop_fifo() {
        // The pinned tie-break rule: equal-time events pop in insertion
        // order, regardless of how many other events interleave.
        let mut cal = Calendar::new();
        for i in 0..32u32 {
            cal.schedule(1.0, i);
            cal.schedule(0.5, 1000 + i);
        }
        let mut early = Vec::new();
        let mut late = Vec::new();
        while let Some((t, v)) = cal.pop() {
            if t == 0.5 {
                early.push(v);
            } else {
                late.push(v);
            }
        }
        assert_eq!(early, (0..32).map(|i| 1000 + i).collect::<Vec<_>>());
        assert_eq!(late, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn capacity_does_not_change_pop_order() {
        let schedule = |cal: &mut Calendar<u32>| {
            let mut x = 0x12345u64;
            for i in 0..200u32 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                // Coarse times force plenty of ties.
                let t = (x >> 60) as f64;
                cal.schedule(t, i);
            }
        };
        let drain = |mut cal: Calendar<u32>| {
            let mut out = Vec::new();
            while let Some(e) = cal.pop() {
                out.push(e);
            }
            out
        };
        let mut a = Calendar::new();
        let mut b = Calendar::with_capacity(4096);
        let mut c = Calendar::with_capacity(1);
        schedule(&mut a);
        schedule(&mut b);
        schedule(&mut c);
        let ra = drain(a);
        assert_eq!(ra, drain(b));
        assert_eq!(ra, drain(c));
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_times() {
        let mut cal = Calendar::new();
        cal.schedule(f64::NAN, ());
    }
}
