//! Job arrival processes: trace-driven and synthetic (uniform, Poisson,
//! diurnal). Every process materializes into a sorted vector of
//! absolute arrival times — a pure function of `(process, seed)` so
//! campaigns replay identically.

use crate::sim::rng::Rng;

/// How multiply jobs arrive at the simulated serving tier.
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// `count` jobs, one every `interarrival` seconds starting at 0
    /// (interarrival 0 = a single burst at t = 0).
    Uniform { count: usize, interarrival: f64 },
    /// Homogeneous Poisson process with `rate` jobs/second.
    Poisson { count: usize, rate: f64 },
    /// Inhomogeneous Poisson with a sinusoidal day cycle: the rate
    /// swings between `base_rate` and `peak_rate` over `period`
    /// seconds (thinning of a `peak_rate` homogeneous process).
    Diurnal { count: usize, base_rate: f64, peak_rate: f64, period: f64 },
    /// Trace-driven: explicit arrival times (sorted on materialize).
    Trace { times: Vec<f64> },
}

impl ArrivalProcess {
    pub fn count(&self) -> usize {
        match self {
            ArrivalProcess::Uniform { count, .. }
            | ArrivalProcess::Poisson { count, .. }
            | ArrivalProcess::Diurnal { count, .. } => *count,
            ArrivalProcess::Trace { times } => times.len(),
        }
    }

    /// Materialize the sorted arrival times. Deterministic in
    /// `(self, seed)`; the seed is ignored by `Uniform` and `Trace`.
    pub fn times(&self, seed: u64) -> Vec<f64> {
        match self {
            ArrivalProcess::Uniform { count, interarrival } => {
                assert!(*interarrival >= 0.0, "negative interarrival");
                (0..*count).map(|i| i as f64 * interarrival).collect()
            }
            ArrivalProcess::Poisson { count, rate } => {
                assert!(*rate > 0.0, "poisson rate must be positive");
                let mut rng = Rng::seeded(seed ^ 0xa881_07a1);
                let mut t = 0.0;
                (0..*count)
                    .map(|_| {
                        t += rng.exponential(*rate);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Diurnal { count, base_rate, peak_rate, period } => {
                assert!(*peak_rate > 0.0 && *base_rate >= 0.0, "bad diurnal rates");
                assert!(*peak_rate >= *base_rate, "peak_rate below base_rate");
                assert!(*period > 0.0, "period must be positive");
                let mut rng = Rng::seeded(seed ^ 0xd1a2_4a15);
                let mut t = 0.0;
                let mut out = Vec::with_capacity(*count);
                while out.len() < *count {
                    // Thinning: candidates at the peak rate, accepted
                    // with probability rate(t) / peak_rate where
                    // rate(t) dips to base_rate at the cycle trough.
                    t += rng.exponential(*peak_rate);
                    let phase = (2.0 * std::f64::consts::PI * t / period).cos();
                    let rate = base_rate + (peak_rate - base_rate) * 0.5 * (1.0 - phase);
                    if rng.uniform() < rate / peak_rate {
                        out.push(t);
                    }
                }
                out
            }
            ArrivalProcess::Trace { times } => {
                let mut out = times.clone();
                assert!(
                    out.iter().all(|t| t.is_finite() && *t >= 0.0),
                    "trace times must be finite and non-negative"
                );
                out.sort_by(f64::total_cmp);
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_sorted(ts: &[f64]) -> bool {
        ts.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn uniform_spacing_and_burst() {
        let ts = ArrivalProcess::Uniform { count: 4, interarrival: 0.5 }.times(0);
        assert_eq!(ts, vec![0.0, 0.5, 1.0, 1.5]);
        let burst = ArrivalProcess::Uniform { count: 3, interarrival: 0.0 }.times(0);
        assert_eq!(burst, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn poisson_mean_rate_and_determinism() {
        let p = ArrivalProcess::Poisson { count: 20_000, rate: 4.0 };
        let ts = p.times(11);
        assert!(is_sorted(&ts));
        assert_eq!(ts, p.times(11), "same seed, same trace");
        assert_ne!(ts, p.times(12), "different seed, different trace");
        // 20k arrivals at 4/s should take about 5000 s.
        let span = *ts.last().unwrap();
        assert!((span - 5000.0).abs() < 200.0, "span {span}");
    }

    #[test]
    fn diurnal_is_sorted_deterministic_and_modulated() {
        let p = ArrivalProcess::Diurnal {
            count: 20_000,
            base_rate: 1.0,
            peak_rate: 9.0,
            period: 100.0,
        };
        let ts = p.times(3);
        assert_eq!(ts.len(), 20_000);
        assert!(is_sorted(&ts));
        assert_eq!(ts, p.times(3));
        // The first half of each cycle (rising toward the peak at
        // period/2) must carry more arrivals than a flat process would:
        // count arrivals in the middle vs the edges of the cycle.
        let period = 100.0;
        let (mut mid, mut edge) = (0usize, 0usize);
        for t in &ts {
            let phase = t % period / period;
            if (0.25..0.75).contains(&phase) {
                mid += 1;
            } else {
                edge += 1;
            }
        }
        assert!(
            mid as f64 > 1.5 * edge as f64,
            "diurnal modulation missing: mid {mid} edge {edge}"
        );
    }

    #[test]
    fn trace_sorts_and_validates() {
        let p = ArrivalProcess::Trace { times: vec![3.0, 1.0, 2.0] };
        assert_eq!(p.times(99), vec![1.0, 2.0, 3.0]);
        assert_eq!(p.count(), 3);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn trace_rejects_nan() {
        ArrivalProcess::Trace { times: vec![f64::NAN] }.times(0);
    }
}
