//! The discrete-event campaign engine: jobs arrive, their coded leaf
//! tasks are dispatched onto a simulated fleet by a [`SchedPolicy`],
//! and each job decodes (or fails) under exactly the live
//! coordinator's semantics — decodability via the real
//! [`DecodeOracle`]/[`NestedOracle`] span decoders, fail-stop faults
//! via the pure [`FaultSampler`] keyed by `(seed, job_id, leaf)`.
//!
//! ## Determinism
//!
//! Every run is a pure function of `(plan, campaign, policy)`:
//! * the [`Calendar`] pops events in `(time, insertion-seq)` order,
//!   independent of heap capacity;
//! * leaf faults and per-attempt latency draws are hashed from their
//!   coordinates, never taken from a shared stream;
//! * the one shared RNG (policy randomness) is consumed inside the
//!   deterministic event loop.
//!
//! Because fail-stop faults are keyed by `(seed, job_id, leaf)` alone
//! — the same purity contract as the live
//! [`crate::coordinator::worker::FaultPlan::sample_at`] — the set of
//! dead leaves, and therefore each job's decode outcome, is **exactly
//! invariant** across fleet sizes, policies, and arrival processes
//! (given `p_rack = 0`). Measured P_f can be compared against
//! [`crate::coding::theory`] directly; the determinism suite pins the
//! invariance bit for bit.
//!
//! ## Decode-state machine (mirrors `coordinator/job.rs`)
//!
//! Per group: `good` (arrived results) and `dead` (fail-stop leaves)
//! masks. After every leaf resolution the engine asks the span oracle
//! twice: *recovered* when the not-yet-good set is already a decodable
//! failure pattern (early exit — remaining leaves are revoked), and
//! *hopeless* when the dead set alone defeats the inner decoder. The
//! two are mutually exclusive (decodability is monotone in the failure
//! mask), and at a group's last event exactly one fires. The outer
//! level runs the same pair over recovered/hopeless group masks.

use std::collections::VecDeque;

use crate::coding::fc::DecodeOracle;
use crate::coding::nested::{NestedOracle, NestedTaskSet};
use crate::coding::scheme::TaskSet;
use crate::coordinator::worker::{FaultAction, FaultPlan, FaultSampler};
use crate::obs::{EventKind, Tracer, NO_LEAF};
use crate::sim::des::arrival::ArrivalProcess;
use crate::sim::des::calendar::Calendar;
use crate::sim::des::fleet::{Fleet, FleetSpec};
use crate::sim::des::policy::{JobView, SchedPolicy};
use crate::sim::montecarlo::Estimate;
use crate::sim::rng::Rng;

/// What one simulated job computes: a flat coded task set (one worker
/// per task, the paper's Fig. 2 shape) or a nested two-level
/// composition (fan-out 196–256).
#[derive(Clone, Debug)]
pub enum SimPlan {
    Flat(TaskSet),
    Nested(NestedTaskSet),
}

impl SimPlan {
    pub fn name(&self) -> &str {
        match self {
            SimPlan::Flat(ts) => &ts.name,
            SimPlan::Nested(ns) => &ns.name,
        }
    }

    pub fn num_leaves(&self) -> usize {
        match self {
            SimPlan::Flat(ts) => ts.num_tasks(),
            SimPlan::Nested(ns) => ns.num_leaves(),
        }
    }

    fn oracle(&self) -> PlanOracle {
        match self {
            SimPlan::Flat(ts) => {
                PlanOracle::Flat { oracle: DecodeOracle::build(ts), m: ts.num_tasks() }
            }
            SimPlan::Nested(ns) => PlanOracle::Nested { oracle: NestedOracle::build(ns) },
        }
    }
}

/// Decodability questions, uniform over flat and nested plans: a flat
/// plan is one group whose recovery decodes the job.
enum PlanOracle {
    Flat { oracle: DecodeOracle, m: usize },
    Nested { oracle: NestedOracle },
}

impl PlanOracle {
    fn num_groups(&self) -> usize {
        match self {
            PlanOracle::Flat { .. } => 1,
            PlanOracle::Nested { oracle } => oracle.num_groups(),
        }
    }

    fn group_size(&self) -> usize {
        match self {
            PlanOracle::Flat { m, .. } => *m,
            PlanOracle::Nested { oracle } => oracle.group_size(),
        }
    }

    /// Can the group still decode despite this failed-leaf mask?
    fn group_decodable(&self, failed: u64) -> bool {
        match self {
            PlanOracle::Flat { oracle, .. } => oracle.is_decodable(failed),
            PlanOracle::Nested { oracle } => oracle.group_decodable(failed),
        }
    }

    /// Is the job decodable given this failed/unrecovered-GROUP mask?
    fn outer_decodable(&self, failed_groups: u64) -> bool {
        match self {
            PlanOracle::Flat { .. } => failed_groups == 0,
            PlanOracle::Nested { oracle } => oracle.outer_decodable(failed_groups),
        }
    }
}

/// A fleet campaign: arrivals, fault model, link economics, seed.
#[derive(Clone, Debug)]
pub struct Campaign {
    pub fleet: FleetSpec,
    pub arrivals: ArrivalProcess,
    /// Fail/straggle plan (`p_fail` is the paper's p_e). Faults are
    /// sampled through [`FaultSampler`] purely per `(seed, job, leaf)`.
    pub fault: FaultPlan,
    /// Bytes of ONE encoded operand block; a cold dispatch ships two
    /// (A and B) into the rack, every result ships one back.
    pub block_bytes: u64,
    pub seed: u64,
    /// Attempt cap per leaf (re-dispatch after rack loss, speculative
    /// backups). ≥ 1.
    pub max_attempts: u16,
    /// Initial calendar capacity — pop order is capacity-invariant;
    /// the determinism suite varies this knob to prove it.
    pub heap_capacity: usize,
    /// Keep the full formatted event trace in the result (the FNV
    /// digest over the same lines is always computed).
    pub record_trace: bool,
}

impl Default for Campaign {
    fn default() -> Self {
        Campaign {
            fleet: FleetSpec::default(),
            arrivals: ArrivalProcess::Uniform { count: 100, interarrival: 0.05 },
            fault: FaultPlan::NONE,
            block_bytes: 64 * 64 * 8,
            seed: 0,
            max_attempts: 4,
            heap_capacity: 0,
            record_trace: false,
        }
    }
}

/// Aggregate results of one campaign run.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSummary {
    pub jobs: usize,
    pub decoded: usize,
    pub failed: usize,
    /// failed / jobs with its binomial standard error — comparable to
    /// [`crate::coding::theory`] P_f via [`Estimate::agrees_with`].
    pub measured_pf: Estimate,
    /// Mean arrival→decode latency over decoded jobs (0 if none).
    pub mean_completion_s: f64,
    pub p95_completion_s: f64,
    /// Time of the last event.
    pub makespan_s: f64,
    pub events: u64,
    pub dispatches: u64,
    pub backups: u64,
    /// Re-dispatches after rack-outage losses.
    pub requeues: u64,
    pub network_bytes: u64,
    /// FNV-1a digest of the formatted event trace.
    pub trace_digest: u64,
    /// FNV-1a digest of per-job outcomes in job order — equal across
    /// policies/fleet sizes when `p_rack = 0` (fault purity).
    pub outcome_digest: u64,
}

pub struct CampaignResult {
    pub summary: CampaignSummary,
    /// Formatted event lines (empty unless `record_trace`).
    pub trace: Vec<String>,
}

#[derive(Clone, Copy)]
enum Event {
    Arrival { job: u32 },
    Complete { job: u32, leaf: u32, worker: u32, status: Status },
}

#[derive(Clone, Copy, PartialEq)]
enum Status {
    /// The leaf's product arrives.
    Result,
    /// Fail-stop fault: the node never answers; the leaf is dead.
    LeafDead,
    /// The dispatch was lost (rack outage); the leaf may retry.
    AttemptLost,
}

#[derive(Clone, Copy)]
struct Item {
    job: u32,
    leaf: u32,
}

struct GroupState {
    good: u64,
    dead: u64,
    recovered: bool,
    hopeless: bool,
}

struct JobState {
    arrival: f64,
    groups: Vec<GroupState>,
    recovered_mask: u64,
    hopeless_mask: u64,
    attempts: Vec<u16>,
    inflight: Vec<u16>,
    outstanding: usize,
    pending: usize,
    touched: Vec<bool>,
    /// `Some(true)` decoded, `Some(false)` reconstruction failed.
    outcome: Option<bool>,
    finish: f64,
}

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
}

/// Event-trace sink: FNV digest always, full lines on request, plus
/// an optional [`Tracer`] mirroring every line as a [`crate::obs`]
/// event so simulated and live runs share one trace schema.
struct Trace {
    digest: Fnv,
    record: bool,
    lines: Vec<String>,
    tracer: Tracer,
}

impl Trace {
    fn new(record: bool, tracer: Tracer) -> Trace {
        Trace { digest: Fnv::new(), record, lines: Vec::new(), tracer }
    }

    fn note(&mut self, line: String) {
        self.digest.update(line.as_bytes());
        self.digest.update(b"\n");
        if self.record {
            self.lines.push(line);
        }
    }

    /// Mirror one calendar event into the shared trace schema, with
    /// simulated seconds carried as the µs wall-clock field.
    fn event(&self, t: f64, kind: EventKind, job: u64, leaf: u32, detail: u64) {
        self.tracer.emit_at(kind, job, leaf, detail, (t * 1e6).round() as u64);
    }
}

fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Pure per-attempt latency stream: independent of dispatch order.
fn latency_rng(seed: u64, job: u64, leaf: u32, attempt: u16) -> Rng {
    let coord = (leaf as u64) | ((attempt as u64) << 32);
    Rng::seeded(mix64(seed ^ mix64(job ^ mix64(coord ^ 0x1ea4_f11f_eed0))))
}

struct Counters {
    events: u64,
    dispatches: u64,
    backups: u64,
    requeues: u64,
    network_bytes: u64,
    decoded: usize,
    failed: usize,
}

impl Campaign {
    /// Run the campaign with the built-in [`FaultPlan`].
    pub fn run(&self, plan: &SimPlan, policy: &mut dyn SchedPolicy) -> CampaignResult {
        self.run_with_sampler(plan, policy, &self.fault)
    }

    /// [`Self::run`] plus a trace sink: every calendar event is
    /// mirrored as a [`crate::obs::TraceEvent`] (`emit_at` with
    /// simulated time), so a 10k-node campaign exports through the
    /// same Chrome/digest pipeline as a live `serve` run.
    pub fn run_traced(
        &self,
        plan: &SimPlan,
        policy: &mut dyn SchedPolicy,
        tracer: &Tracer,
    ) -> CampaignResult {
        self.run_with_sampler_traced(plan, policy, &self.fault, tracer)
    }

    /// Run with an explicit fault source — anything implementing the
    /// coordinator's policy-facing [`FaultSampler`] trait.
    pub fn run_with_sampler(
        &self,
        plan: &SimPlan,
        policy: &mut dyn SchedPolicy,
        sampler: &dyn FaultSampler,
    ) -> CampaignResult {
        self.run_with_sampler_traced(plan, policy, sampler, &Tracer::off())
    }

    /// The full engine: explicit fault source and trace sink.
    pub fn run_with_sampler_traced(
        &self,
        plan: &SimPlan,
        policy: &mut dyn SchedPolicy,
        sampler: &dyn FaultSampler,
        tracer: &Tracer,
    ) -> CampaignResult {
        assert!(self.max_attempts >= 1, "max_attempts must be >= 1");
        let oracle = plan.oracle();
        let (m1, m2) = (oracle.num_groups(), oracle.group_size());
        let leaves = m1 * m2;
        let full2: u64 = if m2 == 64 { u64::MAX } else { (1u64 << m2) - 1 };
        let full1: u64 = (1u64 << m1) - 1;

        let fleet = Fleet::build(&self.fleet, self.seed);
        policy.init(&fleet);
        let arrival_times = self.arrivals.times(self.seed);
        let num_jobs = arrival_times.len();

        let mut jobs: Vec<JobState> = arrival_times
            .iter()
            .map(|&t| JobState {
                arrival: t,
                groups: (0..m1)
                    .map(|_| GroupState { good: 0, dead: 0, recovered: false, hopeless: false })
                    .collect(),
                recovered_mask: 0,
                hopeless_mask: 0,
                attempts: vec![0; leaves],
                inflight: vec![0; leaves],
                outstanding: 0,
                pending: 0,
                touched: vec![false; fleet.num_racks()],
                outcome: None,
                finish: 0.0,
            })
            .collect();

        let mut cal: Calendar<Event> = Calendar::with_capacity(self.heap_capacity);
        for (i, &t) in arrival_times.iter().enumerate() {
            cal.schedule(t, Event::Arrival { job: i as u32 });
        }

        let mut queue: VecDeque<Item> = VecDeque::new();
        let mut rng = Rng::seeded(self.seed ^ 0x9049_5cde_71cf);
        let mut trace = Trace::new(self.record_trace, tracer.clone());
        let mut counters = Counters {
            events: 0,
            dispatches: 0,
            backups: 0,
            requeues: 0,
            network_bytes: 0,
            decoded: 0,
            failed: 0,
        };
        let mut makespan = 0.0f64;

        while let Some((t, ev)) = cal.pop() {
            counters.events += 1;
            makespan = t;
            match ev {
                Event::Arrival { job } => {
                    trace.note(format!("{t:.9} arrive job={job}"));
                    trace.event(t, EventKind::JobAdmit, job as u64, NO_LEAF, 0);
                    for leaf in 0..leaves as u32 {
                        queue.push_back(Item { job, leaf });
                    }
                    jobs[job as usize].pending += leaves;
                }
                Event::Complete { job, leaf, worker, status } => {
                    policy.release(worker, &fleet);
                    let js = &mut jobs[job as usize];
                    js.outstanding -= 1;
                    js.inflight[leaf as usize] -= 1;
                    let (g, j) = ((leaf as usize) / m2, (leaf as usize) % m2);
                    if js.outcome.is_some() || js.groups[g].recovered || js.groups[g].hopeless {
                        trace.note(format!(
                            "{t:.9} stale job={job} leaf={g}/{j} worker={worker}"
                        ));
                        trace.event(t, EventKind::StaleDrop, job as u64, leaf, worker as u64);
                    } else {
                        let tag = match status {
                            Status::Result => "result",
                            Status::LeafDead => "dead",
                            Status::AttemptLost => "lost",
                        };
                        trace.note(format!(
                            "{t:.9} {tag} job={job} leaf={g}/{j} worker={worker}"
                        ));
                        // Shared-schema mirror: a result is a Reply;
                        // both fail-stop deaths and exhausted losses
                        // surface as LeafDead (detail 1 marks a lost
                        // attempt that may still retry).
                        match status {
                            Status::Result => {
                                trace.event(t, EventKind::Reply, job as u64, leaf, 0)
                            }
                            Status::LeafDead => {
                                trace.event(t, EventKind::LeafDead, job as u64, leaf, 0)
                            }
                            Status::AttemptLost => {
                                trace.event(t, EventKind::LeafDead, job as u64, leaf, 1)
                            }
                        }
                        let bit = 1u64 << j;
                        match status {
                            Status::Result => {
                                if js.groups[g].good & bit == 0 {
                                    js.groups[g].good |= bit;
                                    Self::resolve(
                                        t, js, g, job, &oracle, full1, full2, &mut counters,
                                        &mut trace,
                                    );
                                }
                            }
                            Status::LeafDead => {
                                js.groups[g].dead |= bit;
                                Self::resolve(
                                    t, js, g, job, &oracle, full1, full2, &mut counters,
                                    &mut trace,
                                );
                            }
                            Status::AttemptLost => {
                                if js.attempts[leaf as usize] < self.max_attempts {
                                    queue.push_back(Item { job, leaf });
                                    js.pending += 1;
                                    counters.requeues += 1;
                                } else if js.inflight[leaf as usize] == 0
                                    && js.groups[g].good & bit == 0
                                {
                                    // Out of retries with nothing in
                                    // flight: the leaf is effectively
                                    // dead.
                                    js.groups[g].dead |= bit;
                                    Self::resolve(
                                        t, js, g, job, &oracle, full1, full2, &mut counters,
                                        &mut trace,
                                    );
                                }
                            }
                        }
                    }
                }
            }
            self.drain(
                t, &fleet, policy, &mut rng, &mut queue, &mut jobs, &mut cal, sampler, &oracle,
                &mut counters, &mut trace,
            );
        }

        debug_assert!(jobs.iter().all(|j| j.outcome.is_some()), "unresolved job at drain-out");
        let mut completions: Vec<f64> = jobs
            .iter()
            .filter(|j| j.outcome == Some(true))
            .map(|j| j.finish - j.arrival)
            .collect();
        completions.sort_by(f64::total_cmp);
        let mean_completion_s = if completions.is_empty() {
            0.0
        } else {
            completions.iter().sum::<f64>() / completions.len() as f64
        };
        let p95_completion_s = if completions.is_empty() {
            0.0
        } else {
            completions[((completions.len() as f64 * 0.95).ceil() as usize)
                .clamp(1, completions.len())
                - 1]
        };
        let mut outcome_digest = Fnv::new();
        for j in &jobs {
            outcome_digest.update(if j.outcome == Some(true) { b"1" } else { b"0" });
        }
        let pf = if num_jobs > 0 { counters.failed as f64 / num_jobs as f64 } else { 0.0 };
        let summary = CampaignSummary {
            jobs: num_jobs,
            decoded: counters.decoded,
            failed: counters.failed,
            measured_pf: Estimate {
                mean: pf,
                std_err: (pf * (1.0 - pf) / (num_jobs.max(1) as f64)).sqrt(),
                trials: num_jobs as u64,
            },
            mean_completion_s,
            p95_completion_s,
            makespan_s: makespan,
            events: counters.events,
            dispatches: counters.dispatches,
            backups: counters.backups,
            requeues: counters.requeues,
            network_bytes: counters.network_bytes,
            trace_digest: trace.digest.0,
            outcome_digest: outcome_digest.0,
        };
        CampaignResult { summary, trace: trace.lines }
    }

    /// Re-evaluate group `g` (and, if it resolves, the job) after a
    /// leaf outcome. Runs after EVERY leaf resolution so the final
    /// group event always classifies the group: *recovered* when the
    /// not-yet-good mask is decodable, *hopeless* when the dead mask
    /// alone is not — mutually exclusive by monotonicity of the span
    /// decoder in the failure mask.
    #[allow(clippy::too_many_arguments)]
    fn resolve(
        t: f64,
        js: &mut JobState,
        g: usize,
        job: u32,
        oracle: &PlanOracle,
        full1: u64,
        full2: u64,
        counters: &mut Counters,
        trace: &mut Trace,
    ) {
        let grp = &mut js.groups[g];
        if oracle.group_decodable(full2 & !grp.good) {
            grp.recovered = true;
            js.recovered_mask |= 1 << g;
            trace.note(format!("{t:.9} group-recovered job={job} group={g}"));
            trace.event(t, EventKind::GroupRecover, job as u64, NO_LEAF, g as u64);
        } else if !oracle.group_decodable(grp.dead) {
            grp.hopeless = true;
            js.hopeless_mask |= 1 << g;
            trace.note(format!("{t:.9} group-hopeless job={job} group={g}"));
            trace.event(t, EventKind::GroupHopeless, job as u64, NO_LEAF, g as u64);
        } else {
            return; // group still in flight
        }
        if oracle.outer_decodable(full1 & !js.recovered_mask) {
            js.outcome = Some(true);
            js.finish = t;
            counters.decoded += 1;
            trace.note(format!("{t:.9} decoded job={job}"));
            trace.event(t, EventKind::JobDecode, job as u64, NO_LEAF, 0);
        } else if !oracle.outer_decodable(js.hopeless_mask) {
            js.outcome = Some(false);
            js.finish = t;
            counters.failed += 1;
            trace.note(format!("{t:.9} failed job={job}"));
            trace.event(t, EventKind::JobFail, job as u64, NO_LEAF, 0);
        }
    }

    /// Dispatch work while the policy yields idle workers: drop stale
    /// queue heads, dispatch live ones, and when the queue runs dry ask
    /// the policy for speculative backups.
    #[allow(clippy::too_many_arguments)]
    fn drain(
        &self,
        t: f64,
        fleet: &Fleet,
        policy: &mut dyn SchedPolicy,
        rng: &mut Rng,
        queue: &mut VecDeque<Item>,
        jobs: &mut [JobState],
        cal: &mut Calendar<Event>,
        sampler: &dyn FaultSampler,
        oracle: &PlanOracle,
        counters: &mut Counters,
        trace: &mut Trace,
    ) {
        let m2 = oracle.group_size();
        loop {
            // Drop stale items at the head (job resolved, or the item's
            // group already recovered/hopeless — the revocation path).
            while let Some(item) = queue.front().copied() {
                let js = &jobs[item.job as usize];
                let g = (item.leaf as usize) / m2;
                let stale =
                    js.outcome.is_some() || js.groups[g].recovered || js.groups[g].hopeless;
                if !stale {
                    break;
                }
                jobs[item.job as usize].pending -= 1;
                queue.pop_front();
            }
            let item = match queue.front().copied() {
                Some(item) => item,
                None => {
                    // Speculative backups: first job (id order) whose
                    // policy wants one, first backup-able leaf.
                    match Self::pick_backup(jobs, policy, oracle, self.max_attempts) {
                        Some(item) => {
                            queue.push_back(item);
                            jobs[item.job as usize].pending += 1;
                            counters.backups += 1;
                            continue;
                        }
                        None => break,
                    }
                }
            };
            let view = Self::view(&jobs[item.job as usize], item.job, oracle);
            let worker = match policy.acquire(&view, fleet, rng) {
                Some(w) => w,
                None => break,
            };
            queue.pop_front();
            let js = &mut jobs[item.job as usize];
            js.pending -= 1;
            js.attempts[item.leaf as usize] += 1;
            let attempt = js.attempts[item.leaf as usize];
            js.inflight[item.leaf as usize] += 1;
            js.outstanding += 1;
            counters.dispatches += 1;

            let rack = fleet.rack_of(worker);
            let cold = !js.touched[rack as usize];
            js.touched[rack as usize] = true;
            let mut service = 0.0;
            if cold {
                // Ship both encoded operand blocks into the rack.
                service += fleet.spec.link.transfer_time(2 * self.block_bytes);
                counters.network_bytes += 2 * self.block_bytes;
            }
            let base = fleet.spec.leaf_latency.sample(&mut latency_rng(
                self.seed,
                item.job as u64,
                item.leaf,
                attempt,
            ));
            service += base * fleet.speed(worker);
            let status = if fleet.rack_down(self.seed, item.job as u64, rack) {
                Status::AttemptLost
            } else {
                match sampler.action_at(self.seed, item.job as u64, item.leaf as u64) {
                    FaultAction::Fail => Status::LeafDead,
                    FaultAction::Delay(d) if attempt == 1 => {
                        // Stragglers delay the first attempt only: a
                        // backup runs on a fresh node. Fail-stop stays
                        // leaf-pure (same verdict on every attempt).
                        service += d.as_secs_f64();
                        Status::Result
                    }
                    _ => Status::Result,
                }
            };
            if status == Status::Result {
                // The result block travels back.
                service += fleet.spec.link.transfer_time(self.block_bytes);
                counters.network_bytes += self.block_bytes;
            }
            trace.note(format!(
                "{t:.9} dispatch job={} leaf={}/{} attempt={attempt} worker={worker}",
                item.job,
                (item.leaf as usize) / m2,
                (item.leaf as usize) % m2,
            ));
            trace.event(t, EventKind::LeafDispatch, item.job as u64, item.leaf, worker as u64);
            cal.schedule(
                t + service,
                Event::Complete { job: item.job, leaf: item.leaf, worker, status },
            );
        }
    }

    fn view<'a>(js: &'a JobState, job: u32, oracle: &PlanOracle) -> JobView<'a> {
        let resolved = (js.recovered_mask | js.hopeless_mask).count_ones() as usize;
        JobView {
            job_id: job as u64,
            touched_racks: &js.touched,
            outstanding: js.outstanding,
            pending: js.pending,
            groups_needed: oracle.num_groups() - resolved,
        }
    }

    /// Find a leaf worth duplicating: lowest job id whose policy wants
    /// a backup, lowest in-flight unresolved leaf under the attempt
    /// cap.
    fn pick_backup(
        jobs: &[JobState],
        policy: &dyn SchedPolicy,
        oracle: &PlanOracle,
        max_attempts: u16,
    ) -> Option<Item> {
        let m2 = oracle.group_size();
        for (id, js) in jobs.iter().enumerate() {
            if js.outcome.is_some() || js.outstanding == 0 {
                continue;
            }
            let view = Self::view(js, id as u32, oracle);
            if !policy.wants_backup(&view) {
                continue;
            }
            for leaf in 0..js.attempts.len() {
                let g = leaf / m2;
                if js.inflight[leaf] > 0
                    && js.attempts[leaf] < max_attempts
                    && !js.groups[g].recovered
                    && !js.groups[g].hopeless
                {
                    return Some(Item { job: id as u32, leaf: leaf as u32 });
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::des::policy::{policy_by_name, FastestFirst, RandomPolicy, Speculative};
    use std::time::Duration;

    fn flat_plan() -> SimPlan {
        SimPlan::Flat(TaskSet::strassen_winograd(2))
    }

    fn small_campaign(jobs: usize) -> Campaign {
        Campaign {
            fleet: FleetSpec { workers: 64, rack_size: 16, ..FleetSpec::default() },
            arrivals: ArrivalProcess::Uniform { count: jobs, interarrival: 0.05 },
            ..Campaign::default()
        }
    }

    #[test]
    fn fault_free_campaign_decodes_everything() {
        let mut policy = RandomPolicy::default();
        let r = small_campaign(10).run(&flat_plan(), &mut policy);
        assert_eq!(r.summary.decoded, 10);
        assert_eq!(r.summary.failed, 0);
        assert_eq!(r.summary.measured_pf.mean, 0.0);
        // 16 leaves per job, no retries, no backups.
        assert_eq!(r.summary.dispatches, 160);
        assert!(r.summary.mean_completion_s > 0.0);
    }

    #[test]
    fn certain_failure_kills_every_job() {
        let mut policy = RandomPolicy::default();
        let mut c = small_campaign(10);
        c.fault = FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO };
        let r = c.run(&flat_plan(), &mut policy);
        assert_eq!(r.summary.failed, 10);
        assert_eq!(r.summary.measured_pf.mean, 1.0);
        assert_eq!(r.summary.decoded, 0);
    }

    #[test]
    fn homogeneous_completion_time_is_the_leaf_latency() {
        // 64 idle workers, 16 leaves, deterministic 10 ms service, free
        // network: the job decodes when its leaves land, at ~10 ms.
        let mut policy = RandomPolicy::default();
        let mut c = small_campaign(1);
        c.arrivals = ArrivalProcess::Uniform { count: 1, interarrival: 0.0 };
        let r = c.run(&flat_plan(), &mut policy);
        assert!(
            (r.summary.mean_completion_s - 0.01).abs() < 1e-9,
            "{}",
            r.summary.mean_completion_s
        );
    }

    #[test]
    fn run_is_deterministic_and_trace_matches_digest() {
        let mut c = small_campaign(6);
        c.fault = FaultPlan { p_fail: 0.3, p_straggle: 0.0, delay: Duration::ZERO };
        c.record_trace = true;
        let mut p1 = RandomPolicy::default();
        let mut p2 = RandomPolicy::default();
        let a = c.run(&flat_plan(), &mut p1);
        let b = c.run(&flat_plan(), &mut p2);
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.trace, b.trace);
        assert!(!a.trace.is_empty());
        // Digest is over the trace lines: recomputing it must agree.
        let mut f = Fnv::new();
        for line in &a.trace {
            f.update(line.as_bytes());
            f.update(b"\n");
        }
        assert_eq!(f.0, a.summary.trace_digest);
    }

    #[test]
    fn outcomes_are_policy_invariant_under_pure_faults() {
        let mut c = small_campaign(20);
        c.fault = FaultPlan { p_fail: 0.25, p_straggle: 0.0, delay: Duration::ZERO };
        let base = c.run(&flat_plan(), &mut RandomPolicy::default()).summary;
        assert_eq!(base.decoded + base.failed, 20);
        for name in ["fastest", "locality", "speculative"] {
            let mut p = policy_by_name(name).unwrap();
            let r = c.run(&flat_plan(), p.as_mut()).summary;
            assert_eq!(r.outcome_digest, base.outcome_digest, "policy {name}");
            assert_eq!(r.failed, base.failed, "policy {name}");
        }
        // ... and fleet-size invariant.
        let mut big = c.clone();
        big.fleet.workers = 500;
        let r = big.run(&flat_plan(), &mut RandomPolicy::default()).summary;
        assert_eq!(r.outcome_digest, base.outcome_digest);
        assert_eq!(r.failed, base.failed);
    }

    #[test]
    fn speculative_backups_cut_straggler_tails() {
        // Heavy stragglers, light base latency: the speculative policy
        // must fire backups and finish far sooner than fastest-first.
        let mut c = small_campaign(10);
        c.fleet.workers = 128;
        c.fault =
            FaultPlan { p_fail: 0.0, p_straggle: 0.3, delay: Duration::from_secs(2) };
        let slow = c.run(&flat_plan(), &mut FastestFirst::default()).summary;
        let spec = c.run(&flat_plan(), &mut Speculative::default()).summary;
        assert!(spec.backups > 0, "no backups fired");
        assert!(
            spec.mean_completion_s < slow.mean_completion_s * 0.5,
            "speculation did not help: {} vs {}",
            spec.mean_completion_s,
            slow.mean_completion_s
        );
        assert_eq!(spec.failed, 0);
        assert_eq!(spec.outcome_digest, slow.outcome_digest);
    }

    #[test]
    fn traced_run_mirrors_the_calendar_into_the_shared_schema() {
        use crate::obs::{logical_digest, RingRecorder, Tracer};
        use std::sync::Arc;
        let mut c = small_campaign(4);
        c.fault = FaultPlan { p_fail: 0.2, p_straggle: 0.0, delay: Duration::ZERO };
        let run = |c: &Campaign| {
            let ring = Arc::new(RingRecorder::with_capacity(1 << 14));
            let tracer = Tracer::new(ring.clone());
            let r = c.run_traced(&flat_plan(), &mut RandomPolicy::default(), &tracer);
            (r.summary, ring.drain())
        };
        let (s1, ev1) = run(&c);
        let (s2, ev2) = run(&c);
        assert_eq!(s1, s2);
        assert!(!ev1.is_empty());
        // Every job arrives and terminates in the shared schema too.
        let admits = ev1.iter().filter(|e| e.kind == EventKind::JobAdmit).count();
        assert_eq!(admits, 4);
        let terminal = ev1.iter().filter(|e| e.kind.is_job_terminal()).count();
        assert_eq!(terminal, 4);
        assert_eq!(
            ev1.iter().filter(|e| e.kind == EventKind::LeafDispatch).count() as u64,
            s1.dispatches
        );
        // The logical digest is reproducible run-to-run.
        assert_eq!(logical_digest(&ev1), logical_digest(&ev2));
        // An untraced run is unchanged by the mirroring.
        let plain = c.run(&flat_plan(), &mut RandomPolicy::default()).summary;
        assert_eq!(plain, s1);
    }

    #[test]
    fn nested_plan_runs_and_decodes() {
        let plan = SimPlan::Nested(NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(0),
        ));
        assert_eq!(plan.num_leaves(), 196);
        let mut c = small_campaign(3);
        c.fleet.workers = 256;
        let r = c.run(&plan, &mut RandomPolicy::default());
        assert_eq!(r.summary.decoded, 3);
        assert_eq!(r.summary.dispatches, 3 * 196);
    }

    #[test]
    fn rack_outages_trigger_requeues_but_most_jobs_still_decode() {
        let mut c = small_campaign(8);
        c.fleet.workers = 64;
        c.fleet.rack_size = 8;
        c.fleet.p_rack = 0.3;
        let r = c.run(&flat_plan(), &mut RandomPolicy::default()).summary;
        assert!(r.requeues > 0, "no rack losses at p_rack=0.3");
        assert_eq!(r.decoded + r.failed, 8);
        // Retries spread across racks, so most jobs still decode.
        assert!(r.decoded >= 4, "decoded {}", r.decoded);
    }

    #[test]
    fn link_costs_show_up_as_network_bytes_and_latency() {
        let mut c = small_campaign(2);
        c.fleet.link =
            crate::sim::des::fleet::LinkModel { latency_s: 0.005, bytes_per_s: 0.0 };
        let r = c.run(&flat_plan(), &mut RandomPolicy::default()).summary;
        assert!(r.network_bytes > 0);
        // Every result pays the 5 ms return latency on top of the
        // 10 ms compute, so no job can finish before ~15 ms (cold
        // dispatches pay a further 5 ms operand transfer; the decoder
        // may not need those leaves, so 15 ms is the hard floor).
        assert!(r.mean_completion_s > 0.0149, "{}", r.mean_completion_s);
        let free = small_campaign(2).run(&flat_plan(), &mut RandomPolicy::default()).summary;
        assert!(free.mean_completion_s < r.mean_completion_s);
    }
}
