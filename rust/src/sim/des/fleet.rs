//! Fleet model for the discrete-event simulator: per-worker speed
//! heterogeneity drawn from a [`LatencyModel`], rack topology with
//! correlated per-job outage domains, and a link-cost model charging
//! transfer time proportional to encoded-block bytes.

use crate::sim::latency::LatencyModel;
use crate::sim::rng::Rng;

/// Network link cost: a transfer of `b` bytes takes
/// `latency_s + b / bytes_per_s` seconds (`bytes_per_s == 0` means
/// infinite bandwidth — only the latency term is charged).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    pub latency_s: f64,
    pub bytes_per_s: f64,
}

impl LinkModel {
    /// Free network: transfers cost nothing.
    pub const FREE: LinkModel = LinkModel { latency_s: 0.0, bytes_per_s: 0.0 };

    pub fn transfer_time(&self, bytes: u64) -> f64 {
        let bw = if self.bytes_per_s > 0.0 { bytes as f64 / self.bytes_per_s } else { 0.0 };
        self.latency_s + bw
    }
}

/// Static description of a simulated fleet.
#[derive(Clone, Copy, Debug)]
pub struct FleetSpec {
    /// Number of workers (10k-scale campaigns are the design point).
    pub workers: usize,
    /// Workers per rack (the correlated failure domain).
    pub rack_size: usize,
    /// Per-(job, rack) probability that the rack is unreachable for the
    /// job — a correlated outage: every dispatch it receives is lost.
    /// 0.0 disables rack faults (required for exact theory agreement).
    pub p_rack: f64,
    /// Per-worker slowness multiplier distribution, sampled once at
    /// fleet build: a worker's service time is the leaf latency draw
    /// times its multiplier. `Deterministic { t: 1.0 }` = homogeneous.
    pub speed: LatencyModel,
    /// Base per-leaf service-time distribution (compute only; network
    /// is charged separately through `link`).
    pub leaf_latency: LatencyModel,
    pub link: LinkModel,
}

impl Default for FleetSpec {
    fn default() -> Self {
        FleetSpec {
            workers: 10_000,
            rack_size: 32,
            p_rack: 0.0,
            speed: LatencyModel::Deterministic { t: 1.0 },
            leaf_latency: LatencyModel::Deterministic { t: 0.01 },
            link: LinkModel::FREE,
        }
    }
}

/// A materialized fleet: per-worker speeds and rack assignment.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub spec: FleetSpec,
    /// Slowness multiplier per worker (≥ `MIN_SPEED`).
    speed: Vec<f64>,
    num_racks: usize,
}

const MIN_SPEED: f64 = 1e-6;

/// splitmix64 finalizer — the same mixing the coordinator's
/// `FaultPlan::sample_at` uses, so per-(job, rack) outage draws are
/// pure functions of their coordinates.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Fleet {
    /// Materialize a fleet: draw every worker's slowness multiplier
    /// from `spec.speed` with an RNG derived from `seed` (one stream,
    /// worker-order — deterministic for a given `(spec, seed)`).
    pub fn build(spec: &FleetSpec, seed: u64) -> Fleet {
        assert!(spec.workers > 0, "fleet needs at least one worker");
        assert!(spec.rack_size > 0, "rack_size must be >= 1");
        assert!((0.0..=1.0).contains(&spec.p_rack), "p_rack out of [0,1]");
        let mut rng = Rng::seeded(seed ^ 0x5f1e_e7a1_c0de_f1ee);
        let speed: Vec<f64> =
            (0..spec.workers).map(|_| spec.speed.sample(&mut rng).max(MIN_SPEED)).collect();
        let num_racks = spec.workers.div_ceil(spec.rack_size);
        Fleet { spec: *spec, speed, num_racks }
    }

    pub fn len(&self) -> usize {
        self.speed.len()
    }

    pub fn is_empty(&self) -> bool {
        self.speed.is_empty()
    }

    pub fn num_racks(&self) -> usize {
        self.num_racks
    }

    /// Slowness multiplier of worker `w`.
    #[inline]
    pub fn speed(&self, w: u32) -> f64 {
        self.speed[w as usize]
    }

    #[inline]
    pub fn rack_of(&self, w: u32) -> u32 {
        (w as usize / self.spec.rack_size) as u32
    }

    /// Is `rack` down for `job_id`? A pure function of
    /// `(seed, job_id, rack)` — the correlated failure domain: when a
    /// rack is down for a job, every dispatch the job sends there is
    /// lost (and retried elsewhere, up to the attempt cap).
    pub fn rack_down(&self, seed: u64, job_id: u64, rack: u32) -> bool {
        if self.spec.p_rack <= 0.0 {
            return false;
        }
        let h = mix64(seed ^ mix64(job_id ^ mix64(0x7ac4_0000_0000_0000 ^ rack as u64)));
        Rng::seeded(h).uniform() < self.spec.p_rack
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_transfer_time() {
        let l = LinkModel { latency_s: 0.001, bytes_per_s: 1e6 };
        assert!((l.transfer_time(0) - 0.001).abs() < 1e-12);
        assert!((l.transfer_time(500_000) - 0.501).abs() < 1e-12);
        assert_eq!(LinkModel::FREE.transfer_time(1 << 30), 0.0);
    }

    #[test]
    fn build_is_deterministic_and_racked() {
        let spec = FleetSpec {
            workers: 100,
            rack_size: 16,
            speed: LatencyModel::Bimodal { base: 1.0, p_slow: 0.2, factor: 4.0 },
            ..FleetSpec::default()
        };
        let a = Fleet::build(&spec, 7);
        let b = Fleet::build(&spec, 7);
        for w in 0..100u32 {
            assert_eq!(a.speed(w).to_bits(), b.speed(w).to_bits());
        }
        assert_eq!(a.num_racks(), 7); // ceil(100 / 16)
        assert_eq!(a.rack_of(0), 0);
        assert_eq!(a.rack_of(15), 0);
        assert_eq!(a.rack_of(16), 1);
        assert_eq!(a.rack_of(99), 6);
        // A different seed redraws speeds.
        let c = Fleet::build(&spec, 8);
        assert!((0..100u32).any(|w| a.speed(w) != c.speed(w)));
    }

    #[test]
    fn homogeneous_speed_is_exactly_one() {
        let fleet = Fleet::build(&FleetSpec { workers: 8, ..FleetSpec::default() }, 1);
        for w in 0..8u32 {
            assert_eq!(fleet.speed(w), 1.0);
        }
    }

    #[test]
    fn rack_outage_is_pure_and_respects_probability() {
        let spec = FleetSpec { workers: 640, rack_size: 32, p_rack: 0.25, ..Default::default() };
        let fleet = Fleet::build(&spec, 3);
        // Purity: same coordinates, same answer, every time.
        for job in 0..20u64 {
            for rack in 0..fleet.num_racks() as u32 {
                assert_eq!(fleet.rack_down(9, job, rack), fleet.rack_down(9, job, rack));
            }
        }
        // Frequency over many (job, rack) coordinates ≈ p_rack.
        let mut down = 0u32;
        let total = 4000u32;
        for i in 0..total {
            if fleet.rack_down(9, i as u64 / 20, i % 20) {
                down += 1;
            }
        }
        let freq = down as f64 / total as f64;
        assert!((freq - 0.25).abs() < 0.03, "outage freq {freq}");
        // p_rack = 0 short-circuits.
        let clean = Fleet::build(&FleetSpec { p_rack: 0.0, ..spec }, 3);
        assert!(!clean.rack_down(9, 1, 1));
    }
}
