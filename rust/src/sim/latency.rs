//! Straggler latency models — the paper's §V future-work extension
//! ("more sophisticated methods such as exponential work completion
//! time"), implemented here so the coordinator and the e2e benches can
//! inject realistic delays rather than hard failures.

use crate::sim::rng::Rng;

/// Work-completion-time model for a single node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyModel {
    /// Every node finishes after exactly `t` seconds (no stragglers).
    Deterministic { t: f64 },
    /// Shifted exponential: `shift + Exp(rate)` — the standard coded
    /// computation model (Lee et al. 2016, ref. [9] of the paper).
    ShiftedExp { shift: f64, rate: f64 },
    /// With probability `p_slow`, multiply the base time by `factor`
    /// (bimodal straggler model).
    Bimodal { base: f64, p_slow: f64, factor: f64 },
}

impl LatencyModel {
    /// Sample one node's completion time (seconds).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            LatencyModel::Deterministic { t } => t,
            LatencyModel::ShiftedExp { shift, rate } => shift + rng.exponential(rate),
            LatencyModel::Bimodal { base, p_slow, factor } => {
                if rng.bernoulli(p_slow) {
                    base * factor
                } else {
                    base
                }
            }
        }
    }

    /// Parse a CLI/config spelling:
    /// `det:T`, `sexp:SHIFT:RATE`, or `bimodal:BASE:P_SLOW:FACTOR`.
    pub fn parse(s: &str) -> Result<LatencyModel, String> {
        let parts: Vec<&str> = s.trim().split(':').collect();
        let num = |x: &str| -> Result<f64, String> {
            x.parse::<f64>().map_err(|_| format!("bad number `{x}` in latency model `{s}`"))
        };
        match parts.as_slice() {
            ["det", t] => Ok(LatencyModel::Deterministic { t: num(t)? }),
            ["sexp", shift, rate] => {
                Ok(LatencyModel::ShiftedExp { shift: num(shift)?, rate: num(rate)? })
            }
            ["bimodal", base, p, factor] => Ok(LatencyModel::Bimodal {
                base: num(base)?,
                p_slow: num(p)?,
                factor: num(factor)?,
            }),
            _ => Err(format!(
                "unknown latency model `{s}` (det:T | sexp:SHIFT:RATE | bimodal:BASE:P:FACTOR)"
            )),
        }
    }

    /// Mean completion time.
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::Deterministic { t } => t,
            LatencyModel::ShiftedExp { shift, rate } => shift + 1.0 / rate,
            LatencyModel::Bimodal { base, p_slow, factor } => {
                base * (1.0 - p_slow) + base * factor * p_slow
            }
        }
    }
}

/// Sample completion times for `m` nodes.
pub fn sample_completion_times(model: &LatencyModel, m: usize, rng: &mut Rng) -> Vec<f64> {
    (0..m).map(|_| model.sample(rng)).collect()
}

/// Given per-node completion times and a decodability oracle over
/// finished-node masks, return the earliest time at which the output is
/// decodable (`None` if it never becomes decodable, which cannot happen
/// when the full set decodes).
pub fn completion_time(times: &[f64], decodable: impl Fn(u64) -> bool) -> Option<f64> {
    assert!(times.len() <= 64);
    let mut order: Vec<usize> = (0..times.len()).collect();
    order.sort_by(|&a, &b| times[a].total_cmp(&times[b]));
    let mut finished = 0u64;
    for &i in &order {
        finished |= 1 << i;
        if decodable(finished) {
            return Some(times[i]);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifted_exp_mean() {
        let m = LatencyModel::ShiftedExp { shift: 1.0, rate: 2.0 };
        let mut rng = Rng::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| m.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - m.mean()).abs() < 0.02, "mean {mean} want {}", m.mean());
        // shift is a hard lower bound
        let mn = (0..1000).map(|_| m.sample(&mut rng)).fold(f64::MAX, f64::min);
        assert!(mn >= 1.0);
    }

    #[test]
    fn bimodal_mean() {
        let m = LatencyModel::Bimodal { base: 1.0, p_slow: 0.1, factor: 10.0 };
        assert!((m.mean() - 1.9).abs() < 1e-12);
    }

    #[test]
    fn parse_round_trips_all_three_models() {
        assert_eq!(
            LatencyModel::parse("det:0.25").unwrap(),
            LatencyModel::Deterministic { t: 0.25 }
        );
        assert_eq!(
            LatencyModel::parse("sexp:0.01:5").unwrap(),
            LatencyModel::ShiftedExp { shift: 0.01, rate: 5.0 }
        );
        assert_eq!(
            LatencyModel::parse("bimodal:1:0.1:8").unwrap(),
            LatencyModel::Bimodal { base: 1.0, p_slow: 0.1, factor: 8.0 }
        );
        assert!(LatencyModel::parse("uniform:1:2").is_err());
        assert!(LatencyModel::parse("det:abc").is_err());
        assert!(LatencyModel::parse("sexp:1").is_err());
    }

    #[test]
    fn completion_time_kth_order_statistic() {
        // Oracle: decodable when any 3 of 5 have finished -> 3rd order stat.
        let times = [5.0, 1.0, 4.0, 2.0, 3.0];
        let t = completion_time(&times, |mask| mask.count_ones() >= 3).unwrap();
        assert_eq!(t, 3.0);
    }

    #[test]
    fn completion_time_never() {
        let times = [1.0, 2.0];
        assert_eq!(completion_time(&times, |_| false), None);
    }

    #[test]
    fn completion_time_all_needed() {
        let times = [1.0, 9.0, 4.0];
        let t = completion_time(&times, |mask| mask == 0b111).unwrap();
        assert_eq!(t, 9.0);
    }
}
