//! Lightweight runtime metrics: counters, gauges and log-bucketed
//! latency histograms, aggregated in a [`Registry`] the server exposes.
//!
//! All types are lock-free (atomics) so workers can record from their
//! threads without contending with the master's hot path.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-value gauge.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Increment (for occupancy-style gauges, e.g. busy workers).
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement (never wraps below zero).
    pub fn dec(&self) {
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }
}

/// Histogram with base-2 log buckets over microseconds: bucket i counts
/// samples in [2^i, 2^(i+1)) µs. 64 buckets cover > 500 years.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let bucket = 63 - us.max(1).leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Upper bound of the bucket containing quantile `q` (0..1) — a
    /// conservative estimate good to a factor of 2.
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let rank = ((n as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(u64::MAX)
    }
}

/// Named metric registry.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<RegistryInner>>,
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .lock()
            .unwrap()
            .counters
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::new()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .lock()
            .unwrap()
            .gauges
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::new()))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Text snapshot (stable order) for logs / the `serve` endpoint.
    pub fn snapshot(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, c) in &inner.counters {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in &inner.gauges {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, h) in &inner.histograms {
            out.push_str(&format!(
                "histogram {name} count={} mean={:?} p50={:?} p95={:?} p99={:?}\n",
                h.count(),
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99),
            ));
        }
        out
    }
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        let c = r.counter("jobs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name -> same counter
        assert_eq!(r.counter("jobs").get(), 5);
        let g = r.gauge("queue_depth");
        g.set(17);
        assert_eq!(r.gauge("queue_depth").get(), 17);
        g.inc();
        assert_eq!(g.get(), 18);
        g.dec();
        g.dec();
        assert_eq!(g.get(), 16);
        g.set(0);
        g.dec();
        assert_eq!(g.get(), 0, "dec saturates at zero");
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::new();
        for us in [10u64, 20, 40, 80, 100, 1000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        let p50 = h.quantile(0.5);
        assert!(p50 >= Duration::from_micros(20) && p50 <= Duration::from_micros(128), "{p50:?}");
        let p100 = h.quantile(1.0);
        assert!(p100 >= Duration::from_micros(1000), "{p100:?}");
        assert!(h.mean() >= Duration::from_micros(100));
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn snapshot_contains_all() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("b").set(2);
        r.histogram("c").observe(Duration::from_micros(50));
        let s = r.snapshot();
        assert!(s.contains("counter a 1"));
        assert!(s.contains("gauge b 2"));
        assert!(s.contains("histogram c count=1"));
    }

    #[test]
    fn concurrent_increments() {
        let r = Registry::new();
        let c = r.counter("x");
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}
