//! Lightweight runtime metrics: counters, gauges and log-bucketed
//! latency histograms, aggregated in a [`Registry`] the server exposes.
//!
//! All types are lock-free (atomics) so workers can record from their
//! threads without contending with the master's hot path.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotone counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-value gauge.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Increment (for occupancy-style gauges, e.g. busy workers).
    pub fn inc(&self) {
        self.value.fetch_add(1, Ordering::Relaxed);
    }

    /// Saturating decrement (never wraps below zero).
    pub fn dec(&self) {
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }
}

/// Histogram with base-2 log buckets over microseconds: bucket i counts
/// samples in [2^i, 2^(i+1)) µs. 64 buckets cover > 500 years.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    pub fn observe(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let bucket = 63 - us.max(1).leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    /// Sum of all observed samples.
    pub fn sum(&self) -> Duration {
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed))
    }

    /// Cumulative (`le`-style) bucket counts: `(upper_us, count ≤ upper)`
    /// pairs for every bucket up to the last non-empty one. The final
    /// pair's count equals [`Histogram::count`], so exporters only need
    /// to append a `+Inf` bucket. Empty histogram → empty vec.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        let mut last_nonzero = 0usize;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                last_nonzero = i + 1;
            }
            cum += n;
            // bucket i covers [2^i, 2^(i+1)) µs -> upper bound 2^(i+1)
            out.push((1u64.checked_shl(i as u32 + 1).unwrap_or(u64::MAX), cum));
        }
        out.truncate(last_nonzero);
        out
    }

    /// Upper bound of the bucket containing quantile `q` (0..1) — a
    /// conservative estimate good to a factor of 2.
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let rank = ((n as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(u64::MAX)
    }
}

/// Named metric registry.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Mutex<RegistryInner>>,
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .lock()
            .unwrap()
            .counters
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::new()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .lock()
            .unwrap()
            .gauges
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::new()))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .lock()
            .unwrap()
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Sorted `(name, value)` snapshot of every counter.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let inner = self.inner.lock().unwrap();
        inner.counters.iter().map(|(n, c)| (n.clone(), c.get())).collect()
    }

    /// Sorted `(name, value)` snapshot of every gauge.
    pub fn gauges(&self) -> Vec<(String, u64)> {
        let inner = self.inner.lock().unwrap();
        inner.gauges.iter().map(|(n, g)| (n.clone(), g.get())).collect()
    }

    /// Sorted `(name, handle)` snapshot of every histogram.
    pub fn histograms(&self) -> Vec<(String, Arc<Histogram>)> {
        let inner = self.inner.lock().unwrap();
        inner.histograms.iter().map(|(n, h)| (n.clone(), h.clone())).collect()
    }

    /// Text snapshot (stable order) for logs / the `serve` endpoint.
    /// Histogram lines carry cumulative `le`-bucket counts so the
    /// log-bucket boundaries are interpretable from the export alone.
    pub fn snapshot(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, c) in &inner.counters {
            out.push_str(&format!("counter {name} {}\n", c.get()));
        }
        for (name, g) in &inner.gauges {
            out.push_str(&format!("gauge {name} {}\n", g.get()));
        }
        for (name, h) in &inner.histograms {
            out.push_str(&format!(
                "histogram {name} count={} mean={:?} p50={:?} p95={:?} p99={:?} buckets=",
                h.count(),
                h.mean(),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99),
            ));
            for (i, (upper_us, cum)) in h.cumulative_buckets().into_iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("le{upper_us}:{cum}"));
            }
            out.push('\n');
        }
        out
    }
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        let c = r.counter("jobs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name -> same counter
        assert_eq!(r.counter("jobs").get(), 5);
        let g = r.gauge("queue_depth");
        g.set(17);
        assert_eq!(r.gauge("queue_depth").get(), 17);
        g.inc();
        assert_eq!(g.get(), 18);
        g.dec();
        g.dec();
        assert_eq!(g.get(), 16);
        g.set(0);
        g.dec();
        assert_eq!(g.get(), 0, "dec saturates at zero");
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::new();
        for us in [10u64, 20, 40, 80, 100, 1000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        let p50 = h.quantile(0.5);
        assert!(p50 >= Duration::from_micros(20) && p50 <= Duration::from_micros(128), "{p50:?}");
        let p100 = h.quantile(1.0);
        assert!(p100 >= Duration::from_micros(1000), "{p100:?}");
        assert!(h.mean() >= Duration::from_micros(100));
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn snapshot_contains_all() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("b").set(2);
        r.histogram("c").observe(Duration::from_micros(50));
        let s = r.snapshot();
        assert!(s.contains("counter a 1"));
        assert!(s.contains("gauge b 2"));
        assert!(s.contains("histogram c count=1"));
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_complete() {
        let h = Histogram::new();
        assert!(h.cumulative_buckets().is_empty());
        let samples = [1u64, 3, 3, 7, 100, 5000];
        for us in samples {
            h.observe(Duration::from_micros(us));
        }
        let buckets = h.cumulative_buckets();
        assert!(!buckets.is_empty());
        // Monotone uppers and counts; final count == total count.
        for w in buckets.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert_eq!(buckets.last().unwrap().1, h.count());
        // Each cumulative count matches the samples ≤ that bound
        // (bucket upper bounds are exclusive: [2^i, 2^(i+1))).
        for &(upper, cum) in &buckets {
            let expect = samples.iter().filter(|&&s| s < upper).count() as u64;
            assert_eq!(cum, expect, "le{upper}");
        }
    }

    #[test]
    fn snapshot_buckets_round_trip() {
        let r = Registry::new();
        let h = r.histogram("lat");
        for us in [2u64, 9, 9, 33] {
            h.observe(Duration::from_micros(us));
        }
        let snap = r.snapshot();
        let line = snap.lines().find(|l| l.starts_with("histogram lat ")).unwrap();
        let rendered = line.split("buckets=").nth(1).unwrap();
        // Parse the `leUPPER:CUM` pairs back out of the text export.
        let parsed: Vec<(u64, u64)> = rendered
            .split(',')
            .map(|p| {
                let (le, cum) = p.split_once(':').unwrap();
                (le.strip_prefix("le").unwrap().parse().unwrap(), cum.parse().unwrap())
            })
            .collect();
        assert_eq!(parsed, h.cumulative_buckets());
        assert_eq!(parsed.last().unwrap().1, 4);
    }

    #[test]
    fn concurrent_increments() {
        let r = Registry::new();
        let c = r.counter("x");
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}
