//! The typed coordinator↔worker message protocol.
//!
//! Everything the serving tier says to a worker, and everything a worker
//! says back, is one of the enums below — there is no shared queue, no
//! shared decode state, no shared anything except the transported
//! messages themselves. The types are transport-agnostic: the in-process
//! [`crate::coordinator::transport::ChannelTransport`] moves them over
//! mpsc channels by value, and the [`wire`] codec (de)serializes the
//! same types to length-prefixed byte frames so a socket transport can
//! carry them unchanged.
//!
//! Message flow (one leaf item, the happy path):
//!
//! ```text
//! worker                          coordinator
//!   │ ── Register{worker_id} ──────► │   worker joins the roster, idle
//!   │ ◄── AssignLeaf(Assignment) ─── │   one leaf product to compute
//!   │ ── LeafResult{reply} ────────► │   product (or error), timed
//!   │ ── Ready{worker_id} ─────────► │   slot free → next assignment
//! ```
//!
//! `Revoke` cancels a job's (or nested group's) still-queued tasks —
//! workers purge their local backlog and answer `RevokeAck` with exact
//! purge accounting; `Heartbeat`/`HeartbeatAck` prove liveness;
//! `Shutdown` drains and stops the event loop. [`JobDone`] is the
//! coordinator→client completion event.
//!
//! A straggler is a *delayed* `LeafResult` (slow link): the worker
//! computes, hands the message to the transport's delay line, and sends
//! `Ready` immediately — the slot is never blocked. A failed node sends
//! no `LeafResult` at all (the paper's model) but still sends `Ready`:
//! liveness signalling and result delivery are decoupled.

use std::ops::Range;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::job::MultiplyReport;
use crate::coordinator::worker::{FaultAction, WorkerReply};
use crate::linalg::matrix::Matrix;

/// One operand of a leaf product, as shipped to a worker.
///
/// `Blocks` is the paper's protocol: the master sends the four 2×2
/// blocks and the worker applies its ±1 coefficient row itself.
/// `Encoded` is the encoded-operand-cache fast path: the coordinator
/// already holds this task's encoded operand (content-hash hit), so the
/// worker skips its own encode entirely. Both forms produce bit-identical
/// products — [`crate::linalg::blocked::encode_operand_into`] is
/// deterministic, so pre-encoding at the coordinator and encoding at the
/// worker write the exact same floats.
#[derive(Clone, Debug)]
pub enum OperandPayload {
    /// The four 2×2-split blocks; the worker encodes with its coefficients.
    Blocks(Arc<[Matrix; 4]>),
    /// The already-encoded operand for this task; coefficients are ignored.
    Encoded(Arc<Matrix>),
}

impl OperandPayload {
    pub fn is_encoded(&self) -> bool {
        matches!(self, OperandPayload::Encoded(_))
    }
}

/// One leaf product assignment (the body of [`ToWorker::AssignLeaf`]).
#[derive(Clone, Debug)]
pub struct Assignment {
    pub job_id: u64,
    /// Task id within the job's dispatch plan (for nested plans the
    /// group-major leaf id `g·M₂ + j`).
    pub task_id: usize,
    /// Left/right coefficient rows (±1 and 0 entries of the scheme).
    pub ca: [f32; 4],
    pub cb: [f32; 4],
    pub left: OperandPayload,
    pub right: OperandPayload,
    /// Injected fault, stamped by the coordinator at admission as a pure
    /// function of (seed, job, item) — the worker only acts it out.
    pub fault: FaultAction,
}

/// Coordinator → worker messages.
#[derive(Debug)]
pub enum ToWorker {
    /// Compute one leaf product.
    AssignLeaf(Assignment),
    /// Purge still-queued tasks of `job_id` with ids in `tasks` from the
    /// worker's local backlog; answer with [`ToCoord::RevokeAck`].
    Revoke { job_id: u64, tasks: Range<usize> },
    /// Liveness probe; answer with [`ToCoord::HeartbeatAck`].
    Heartbeat { seq: u64 },
    /// Drain the local backlog, then exit the event loop.
    Shutdown,
}

/// Worker → coordinator messages.
#[derive(Debug)]
pub enum ToCoord {
    /// First message a worker sends: joins the roster, implies idle.
    Register { worker_id: usize },
    /// The worker finished processing an assignment (whatever its fault
    /// outcome) and can take the next one.
    Ready { worker_id: usize },
    /// One computed leaf product (possibly delivered late by the
    /// transport's delay line — the straggler model).
    LeafResult { worker_id: usize, reply: WorkerReply },
    /// Exact accounting for a [`ToWorker::Revoke`]: `purged` backlog
    /// items were dropped, of which `replying` would have produced a
    /// `LeafResult` (i.e. were not injected failures).
    RevokeAck { worker_id: usize, job_id: u64, purged: usize, replying: usize },
    /// Liveness answer echoing the probe's sequence number.
    HeartbeatAck { worker_id: usize, seq: u64 },
}

/// Coordinator → client completion event for one multiply job.
#[derive(Debug)]
pub struct JobDone {
    pub job_id: u64,
    /// The tenant the job was admitted under.
    pub tenant: String,
    /// The product and its report, or the job-level error (only when
    /// local fallback is disabled).
    pub result: Result<(Matrix, MultiplyReport), String>,
    /// Submit → completion (queue wait included).
    pub total_latency: Duration,
}

// ---------------------------------------------------------------------
// Wire codec: length-prefixed frames, no external dependencies.
// ---------------------------------------------------------------------

/// Byte-level codec for the protocol types — the proof that they are
/// socket-ready. Frames are `u32 LE length ‖ tag byte ‖ payload`;
/// matrices travel as `rows u32 ‖ cols u32 ‖ f32 LE data` (bit pattern
/// preserved exactly — encode/decode round-trips are bit-identical, the
/// same guarantee the in-process transport gives for free).
pub mod wire {
    use super::*;

    // --- writers -----------------------------------------------------

    fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_f32(out: &mut Vec<u8>, v: f32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
        put_u64(out, b.len() as u64);
        out.extend_from_slice(b);
    }

    fn put_matrix(out: &mut Vec<u8>, m: &Matrix) {
        put_u32(out, m.rows() as u32);
        put_u32(out, m.cols() as u32);
        for &x in m.as_slice() {
            put_f32(out, x);
        }
    }

    fn put_fault(out: &mut Vec<u8>, f: &FaultAction) {
        match f {
            FaultAction::None => out.push(0),
            FaultAction::Delay(d) => {
                out.push(1);
                put_u64(out, d.as_nanos().min(u64::MAX as u128) as u64);
            }
            FaultAction::Fail => out.push(2),
        }
    }

    fn put_payload(out: &mut Vec<u8>, p: &OperandPayload) {
        match p {
            OperandPayload::Blocks(b4) => {
                out.push(0);
                for m in b4.iter() {
                    put_matrix(out, m);
                }
            }
            OperandPayload::Encoded(m) => {
                out.push(1);
                put_matrix(out, m);
            }
        }
    }

    /// Serialize one coordinator→worker message (unframed body).
    pub fn encode_to_worker(msg: &ToWorker) -> Vec<u8> {
        let mut out = Vec::new();
        match msg {
            ToWorker::AssignLeaf(a) => {
                out.push(0);
                put_u64(&mut out, a.job_id);
                put_u64(&mut out, a.task_id as u64);
                for &c in &a.ca {
                    put_f32(&mut out, c);
                }
                for &c in &a.cb {
                    put_f32(&mut out, c);
                }
                put_fault(&mut out, &a.fault);
                put_payload(&mut out, &a.left);
                put_payload(&mut out, &a.right);
            }
            ToWorker::Revoke { job_id, tasks } => {
                out.push(1);
                put_u64(&mut out, *job_id);
                put_u64(&mut out, tasks.start as u64);
                put_u64(&mut out, tasks.end as u64);
            }
            ToWorker::Heartbeat { seq } => {
                out.push(2);
                put_u64(&mut out, *seq);
            }
            ToWorker::Shutdown => out.push(3),
        }
        out
    }

    /// Serialize one worker→coordinator message (unframed body).
    pub fn encode_to_coord(msg: &ToCoord) -> Vec<u8> {
        let mut out = Vec::new();
        match msg {
            ToCoord::Register { worker_id } => {
                out.push(0);
                put_u64(&mut out, *worker_id as u64);
            }
            ToCoord::Ready { worker_id } => {
                out.push(1);
                put_u64(&mut out, *worker_id as u64);
            }
            ToCoord::LeafResult { worker_id, reply } => {
                out.push(2);
                put_u64(&mut out, *worker_id as u64);
                put_u64(&mut out, reply.job_id);
                put_u64(&mut out, reply.task_id as u64);
                put_u64(&mut out, reply.compute_time.as_nanos().min(u64::MAX as u128) as u64);
                match &reply.product {
                    Ok(m) => {
                        out.push(0);
                        put_matrix(&mut out, m);
                    }
                    Err(e) => {
                        out.push(1);
                        put_bytes(&mut out, e.as_bytes());
                    }
                }
            }
            ToCoord::RevokeAck { worker_id, job_id, purged, replying } => {
                out.push(3);
                put_u64(&mut out, *worker_id as u64);
                put_u64(&mut out, *job_id);
                put_u64(&mut out, *purged as u64);
                put_u64(&mut out, *replying as u64);
            }
            ToCoord::HeartbeatAck { worker_id, seq } => {
                out.push(4);
                put_u64(&mut out, *worker_id as u64);
                put_u64(&mut out, *seq);
            }
        }
        out
    }

    /// Prefix a message body with its `u32 LE` length — the frame a
    /// stream socket would carry.
    pub fn frame(body: Vec<u8>) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + body.len());
        put_u32(&mut out, body.len() as u32);
        out.extend_from_slice(&body);
        out
    }

    /// Split one frame off the front of `buf`: returns the message body
    /// and the unconsumed rest, or `None` if the frame is incomplete.
    pub fn unframe(buf: &[u8]) -> Option<(&[u8], &[u8])> {
        if buf.len() < 4 {
            return None;
        }
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        if buf.len() < 4 + len {
            return None;
        }
        Some((&buf[4..4 + len], &buf[4 + len..]))
    }

    // --- readers -----------------------------------------------------

    struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        fn new(buf: &'a [u8]) -> Self {
            Reader { buf, pos: 0 }
        }

        fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
            if self.pos + n > self.buf.len() {
                return Err(format!(
                    "truncated message: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len() - self.pos
                ));
            }
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        fn u8(&mut self) -> Result<u8, String> {
            Ok(self.take(1)?[0])
        }

        fn u32(&mut self) -> Result<u32, String> {
            let b = self.take(4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        }

        fn u64(&mut self) -> Result<u64, String> {
            let b = self.take(8)?;
            Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
        }

        fn f32(&mut self) -> Result<f32, String> {
            let b = self.take(4)?;
            Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        }

        fn matrix(&mut self) -> Result<Matrix, String> {
            let rows = self.u32()? as usize;
            let cols = self.u32()? as usize;
            let mut data = Vec::with_capacity(rows * cols);
            for _ in 0..rows * cols {
                data.push(self.f32()?);
            }
            Ok(Matrix::from_slice(rows, cols, &data))
        }

        fn fault(&mut self) -> Result<FaultAction, String> {
            match self.u8()? {
                0 => Ok(FaultAction::None),
                1 => Ok(FaultAction::Delay(Duration::from_nanos(self.u64()?))),
                2 => Ok(FaultAction::Fail),
                t => Err(format!("unknown fault tag {t}")),
            }
        }

        fn payload(&mut self) -> Result<OperandPayload, String> {
            match self.u8()? {
                0 => {
                    let b4 =
                        [self.matrix()?, self.matrix()?, self.matrix()?, self.matrix()?];
                    Ok(OperandPayload::Blocks(Arc::new(b4)))
                }
                1 => Ok(OperandPayload::Encoded(Arc::new(self.matrix()?))),
                t => Err(format!("unknown payload tag {t}")),
            }
        }

        fn done(&self) -> Result<(), String> {
            if self.pos != self.buf.len() {
                return Err(format!("{} trailing bytes after message", self.buf.len() - self.pos));
            }
            Ok(())
        }
    }

    /// Deserialize one coordinator→worker message body.
    pub fn decode_to_worker(buf: &[u8]) -> Result<ToWorker, String> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            0 => {
                let job_id = r.u64()?;
                let task_id = r.u64()? as usize;
                let mut ca = [0f32; 4];
                for c in &mut ca {
                    *c = r.f32()?;
                }
                let mut cb = [0f32; 4];
                for c in &mut cb {
                    *c = r.f32()?;
                }
                let fault = r.fault()?;
                let left = r.payload()?;
                let right = r.payload()?;
                ToWorker::AssignLeaf(Assignment { job_id, task_id, ca, cb, left, right, fault })
            }
            1 => {
                let job_id = r.u64()?;
                let start = r.u64()? as usize;
                let end = r.u64()? as usize;
                ToWorker::Revoke { job_id, tasks: start..end }
            }
            2 => ToWorker::Heartbeat { seq: r.u64()? },
            3 => ToWorker::Shutdown,
            t => return Err(format!("unknown ToWorker tag {t}")),
        };
        r.done()?;
        Ok(msg)
    }

    /// Deserialize one worker→coordinator message body.
    pub fn decode_to_coord(buf: &[u8]) -> Result<ToCoord, String> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            0 => ToCoord::Register { worker_id: r.u64()? as usize },
            1 => ToCoord::Ready { worker_id: r.u64()? as usize },
            2 => {
                let worker_id = r.u64()? as usize;
                let job_id = r.u64()?;
                let task_id = r.u64()? as usize;
                let compute_time = Duration::from_nanos(r.u64()?);
                let product = match r.u8()? {
                    0 => Ok(r.matrix()?),
                    1 => {
                        let len = r.u64()? as usize;
                        let bytes = r.take(len)?;
                        Err(String::from_utf8_lossy(bytes).into_owned())
                    }
                    t => return Err(format!("unknown result tag {t}")),
                };
                ToCoord::LeafResult {
                    worker_id,
                    reply: WorkerReply { job_id, task_id, product, compute_time },
                }
            }
            3 => ToCoord::RevokeAck {
                worker_id: r.u64()? as usize,
                job_id: r.u64()?,
                purged: r.u64()? as usize,
                replying: r.u64()? as usize,
            },
            4 => ToCoord::HeartbeatAck { worker_id: r.u64()? as usize, seq: r.u64()? },
            t => return Err(format!("unknown ToCoord tag {t}")),
        };
        r.done()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::wire::*;
    use super::*;
    use crate::linalg::blocked::split_blocks;
    use crate::sim::rng::Rng;

    fn blocks(seed: u64, n: usize) -> Arc<[Matrix; 4]> {
        let mut rng = Rng::seeded(seed);
        Arc::new(split_blocks(&Matrix::random(n, n, &mut rng)))
    }

    fn assert_matrix_eq(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        // Bit-exact: the wire codec must not perturb a single float.
        let bits = |m: &Matrix| m.as_slice().iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(a), bits(b));
    }

    #[test]
    fn assignments_round_trip_bit_exactly() {
        let a4 = blocks(1, 8);
        let enc = Arc::new(a4[0].matmul(&a4[1]));
        let msg = ToWorker::AssignLeaf(Assignment {
            job_id: 42,
            task_id: 7,
            ca: [1.0, -1.0, 0.0, 1.0],
            cb: [-1.0, 0.0, 1.0, 1.0],
            left: OperandPayload::Encoded(enc.clone()),
            right: OperandPayload::Blocks(a4.clone()),
            fault: FaultAction::Delay(Duration::from_millis(25)),
        });
        let decoded = decode_to_worker(&encode_to_worker(&msg)).unwrap();
        let ToWorker::AssignLeaf(d) = decoded else { panic!("wrong variant") };
        assert_eq!(d.job_id, 42);
        assert_eq!(d.task_id, 7);
        assert_eq!(d.ca, [1.0, -1.0, 0.0, 1.0]);
        assert_eq!(d.cb, [-1.0, 0.0, 1.0, 1.0]);
        assert_eq!(d.fault, FaultAction::Delay(Duration::from_millis(25)));
        assert!(d.left.is_encoded());
        let OperandPayload::Encoded(m) = &d.left else { panic!() };
        assert_matrix_eq(m, &enc);
        let OperandPayload::Blocks(b) = &d.right else { panic!() };
        for (x, y) in b.iter().zip(a4.iter()) {
            assert_matrix_eq(x, y);
        }
    }

    #[test]
    fn control_messages_round_trip() {
        for msg in [
            ToWorker::Revoke { job_id: 9, tasks: 32..48 },
            ToWorker::Heartbeat { seq: 17 },
            ToWorker::Shutdown,
        ] {
            let d = decode_to_worker(&encode_to_worker(&msg)).unwrap();
            match (&msg, &d) {
                (
                    ToWorker::Revoke { job_id: a, tasks: ta },
                    ToWorker::Revoke { job_id: b, tasks: tb },
                ) => {
                    assert_eq!(a, b);
                    assert_eq!(ta, tb);
                }
                (ToWorker::Heartbeat { seq: a }, ToWorker::Heartbeat { seq: b }) => {
                    assert_eq!(a, b)
                }
                (ToWorker::Shutdown, ToWorker::Shutdown) => {}
                other => panic!("variant mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn worker_messages_round_trip() {
        let product = blocks(2, 8)[0].clone();
        let msgs = [
            ToCoord::Register { worker_id: 3 },
            ToCoord::Ready { worker_id: 11 },
            ToCoord::LeafResult {
                worker_id: 1,
                reply: WorkerReply {
                    job_id: 5,
                    task_id: 12,
                    product: Ok(product.clone()),
                    compute_time: Duration::from_micros(321),
                },
            },
            ToCoord::LeafResult {
                worker_id: 2,
                reply: WorkerReply {
                    job_id: 6,
                    task_id: 0,
                    product: Err("device lost".into()),
                    compute_time: Duration::ZERO,
                },
            },
            ToCoord::RevokeAck { worker_id: 0, job_id: 5, purged: 3, replying: 2 },
            ToCoord::HeartbeatAck { worker_id: 7, seq: 17 },
        ];
        for msg in msgs {
            let d = decode_to_coord(&encode_to_coord(&msg)).unwrap();
            match (&msg, &d) {
                (ToCoord::Register { worker_id: a }, ToCoord::Register { worker_id: b }) => {
                    assert_eq!(a, b)
                }
                (ToCoord::Ready { worker_id: a }, ToCoord::Ready { worker_id: b }) => {
                    assert_eq!(a, b)
                }
                (
                    ToCoord::LeafResult { worker_id: wa, reply: ra },
                    ToCoord::LeafResult { worker_id: wb, reply: rb },
                ) => {
                    assert_eq!(wa, wb);
                    assert_eq!(ra.job_id, rb.job_id);
                    assert_eq!(ra.task_id, rb.task_id);
                    assert_eq!(ra.compute_time, rb.compute_time);
                    match (&ra.product, &rb.product) {
                        (Ok(x), Ok(y)) => assert_matrix_eq(x, y),
                        (Err(x), Err(y)) => assert_eq!(x, y),
                        other => panic!("result mismatch: {other:?}"),
                    }
                }
                (
                    ToCoord::RevokeAck { job_id: a, purged: pa, replying: ra, .. },
                    ToCoord::RevokeAck { job_id: b, purged: pb, replying: rb, .. },
                ) => {
                    assert_eq!((a, pa, ra), (b, pb, rb));
                }
                (
                    ToCoord::HeartbeatAck { worker_id: wa, seq: sa },
                    ToCoord::HeartbeatAck { worker_id: wb, seq: sb },
                ) => {
                    assert_eq!((wa, sa), (wb, sb));
                }
                other => panic!("variant mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn frames_split_cleanly_from_a_stream() {
        let m1 = frame(encode_to_worker(&ToWorker::Heartbeat { seq: 1 }));
        let m2 = frame(encode_to_worker(&ToWorker::Shutdown));
        let mut stream = m1.clone();
        stream.extend_from_slice(&m2);
        let (body1, rest) = unframe(&stream).unwrap();
        assert!(matches!(decode_to_worker(body1).unwrap(), ToWorker::Heartbeat { seq: 1 }));
        let (body2, rest2) = unframe(rest).unwrap();
        assert!(matches!(decode_to_worker(body2).unwrap(), ToWorker::Shutdown));
        assert!(rest2.is_empty());
        // Incomplete frames are not consumed.
        assert!(unframe(&m1[..3]).is_none());
        assert!(unframe(&m1[..m1.len() - 1]).is_none());
    }

    #[test]
    fn decoder_rejects_malformed_bodies() {
        assert!(decode_to_worker(&[]).is_err());
        assert!(decode_to_worker(&[99]).is_err());
        assert!(decode_to_coord(&[2, 1, 0]).is_err(), "truncated LeafResult");
        // Trailing garbage after a complete message is an error.
        let mut body = encode_to_coord(&ToCoord::Ready { worker_id: 1 });
        body.push(0);
        assert!(decode_to_coord(&body).is_err());
    }
}
