//! The job-multiplexed scheduler: many in-flight multiply jobs share one
//! [`WorkerPool`], with admission up to a configurable depth, per-job
//! decode state machines keyed by `job_id`, early cancellation of
//! spanned jobs' outstanding items, and a `job_id` guard that drops
//! (and counts) late replies from closed jobs.
//!
//! A job is dispatched according to its [`DispatchPlan`]:
//!
//! * **Flat** — one work item per task of the scheme (the paper's
//!   model: the master encodes each operand pair and sends one product
//!   to each node).
//! * **Nested** — the two-level fan-out: for every outer group `g` the
//!   scheduler computes the outer-encoded operands `L_g = Σ u_g[p] A_p`
//!   and `R_g = Σ v_g[q] B_q`, splits them 2×2 again, and dispatches
//!   one leaf item per inner task — `M₁·M₂` items with contiguous ids
//!   per group. The moment a group's inner span closes, its remaining
//!   queued leaf items are **revoked as a group**
//!   ([`WorkerPool::revoke_range`]) and the job's expected-reply count
//!   is debited, so a 256-leaf job stops occupying the fleet long
//!   before every leaf has run.
//!
//! Determinism: each work item's fault is a **pure function** of
//! `(master seed, job_id, item index)` —
//! [`FaultPlan::sample_at`](crate::coordinator::worker::FaultPlan::sample_at)
//! hashes the coordinates, no shared RNG stream exists — so a seeded
//! job stream sees the exact same fault pattern at every in-flight
//! depth, pool size, backend, and thread count (the invariance the
//! property tests pin down; combine with [`MasterConfig::collect_all`]
//! for bit-identical outputs). Jobs submitted with an explicit fault
//! script ([`Scheduler::submit_with_faults`]) sample nothing.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::scheme::TaskSet;
use crate::coordinator::job::{JobState, MultiplyReport};
use crate::coordinator::master::MasterConfig;
use crate::coordinator::task::DispatchPlan;
use crate::coordinator::worker::{Backend, FaultAction, WorkItem, WorkerPool, WorkerReply};
use crate::linalg::blocked::{encode_operand_into, split_blocks};
use crate::linalg::matrix::Matrix;
use crate::metrics::Registry;

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Per-job policy (deadline, fault plan, seed, fallback, decode mode).
    pub master: MasterConfig,
    /// Maximum concurrently in-flight jobs (≥ 1).
    pub depth: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { master: MasterConfig::default(), depth: 1 }
    }
}

/// A completed job, in completion order.
pub struct FinishedJob {
    pub job_id: u64,
    /// The product and its report, or the job-level error (only when
    /// local fallback is disabled).
    pub result: Result<(Matrix, MultiplyReport), String>,
    /// Submit → completion (queue wait included).
    pub total_latency: Duration,
}

struct Pending {
    job_id: u64,
    a: Matrix,
    b: Matrix,
    enqueued: Instant,
    /// Explicit per-item fault script (tests / replay); `None` samples
    /// from the scheduler RNG at admission.
    faults: Option<Vec<FaultAction>>,
}

/// The multiplexed scheduler.
pub struct Scheduler {
    plan: DispatchPlan,
    pool: WorkerPool,
    backend: Backend,
    cfg: SchedulerConfig,
    next_job: u64,
    pending: VecDeque<Pending>,
    inflight: HashMap<u64, JobState>,
    reply_tx: Sender<WorkerReply>,
    reply_rx: Receiver<WorkerReply>,
    pub metrics: Registry,
}

impl Scheduler {
    /// Build a scheduler with one worker thread per task in the set.
    pub fn new(set: TaskSet, backend: Backend, cfg: SchedulerConfig) -> Scheduler {
        Scheduler::with_plan(DispatchPlan::flat(set), backend, cfg, None)
    }

    /// Build a scheduler for an arbitrary dispatch plan. `workers`
    /// overrides the pool size (defaults to one node per task for flat
    /// plans, a capped fleet for nested fan-outs — leaf items multiplex
    /// onto whatever fleet exists, they do not each own a thread).
    pub fn with_plan(
        plan: DispatchPlan,
        backend: Backend,
        cfg: SchedulerConfig,
        workers: Option<usize>,
    ) -> Scheduler {
        let metrics = Registry::new();
        let pool_size = workers.unwrap_or_else(|| plan.default_pool_size());
        let pool = WorkerPool::spawn(pool_size, backend.clone(), metrics.clone());
        let (reply_tx, reply_rx) = channel();
        Scheduler {
            plan,
            pool,
            backend,
            cfg,
            next_job: 0,
            pending: VecDeque::new(),
            inflight: HashMap::new(),
            reply_tx,
            reply_rx,
            metrics,
        }
    }

    pub fn scheme_name(&self) -> &str {
        self.plan.name()
    }

    pub fn num_workers(&self) -> usize {
        self.pool.size()
    }

    /// Work items dispatched per job (tasks, or leaves for nested plans).
    pub fn items_per_job(&self) -> usize {
        self.plan.num_work_items()
    }

    /// Configured in-flight depth (≥ 1).
    pub fn depth(&self) -> usize {
        self.cfg.depth.max(1)
    }

    /// Jobs not yet completed (queued + in flight).
    pub fn outstanding(&self) -> usize {
        self.pending.len() + self.inflight.len()
    }

    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Submit a multiply job `C = A · B` (square, dimension divisible by
    /// 2 per split level: 2 for flat plans, 4 for nested). Admits
    /// immediately if an in-flight slot is free.
    pub fn submit(&mut self, a: Matrix, b: Matrix) -> Result<u64, String> {
        self.submit_job(a, b, None)
    }

    /// Submit with an explicit per-item fault script (length must equal
    /// [`Self::items_per_job`]), bypassing the fault plan's sampling —
    /// deterministic replay for tests and fault-pattern experiments.
    pub fn submit_with_faults(
        &mut self,
        a: Matrix,
        b: Matrix,
        faults: Vec<FaultAction>,
    ) -> Result<u64, String> {
        if faults.len() != self.plan.num_work_items() {
            return Err(format!(
                "fault script length {} != work items per job {}",
                faults.len(),
                self.plan.num_work_items()
            ));
        }
        self.submit_job(a, b, Some(faults))
    }

    fn submit_job(
        &mut self,
        a: Matrix,
        b: Matrix,
        faults: Option<Vec<FaultAction>>,
    ) -> Result<u64, String> {
        let n = a.rows();
        if a.shape() != (n, n) || b.shape() != (n, n) {
            return Err(format!(
                "square matrices required, got {:?} x {:?}",
                a.shape(),
                b.shape()
            ));
        }
        let div = self.plan.block_divisor();
        if n == 0 || n % div != 0 {
            return Err(format!(
                "dimension must be a positive multiple of {div} for {}, got {n}",
                self.plan.name()
            ));
        }
        self.next_job += 1;
        let job_id = self.next_job;
        self.pending
            .push_back(Pending { job_id, a, b, enqueued: Instant::now(), faults });
        self.admit_ready();
        self.update_gauges();
        Ok(job_id)
    }

    /// Drive the scheduler until `max_jobs` complete (or nothing is
    /// outstanding). Completions are returned in completion order, which
    /// at depth > 1 may differ from submission order.
    pub fn drive(&mut self, max_jobs: usize) -> Vec<FinishedJob> {
        let mut out = Vec::new();
        while out.len() < max_jobs && self.outstanding() > 0 {
            let want = max_jobs - out.len();
            let mut got = self.poll(Duration::from_millis(200), want);
            out.append(&mut got);
        }
        out
    }

    /// Process events for up to `timeout`, returning at most
    /// `max_completions` finished jobs (early-exits once reached).
    pub fn poll(&mut self, timeout: Duration, max_completions: usize) -> Vec<FinishedJob> {
        let mut done = Vec::new();
        let until = Instant::now() + timeout;
        loop {
            self.admit_ready();
            self.reap(&mut done, max_completions);
            if done.len() >= max_completions || self.inflight.is_empty() {
                break;
            }
            let now = Instant::now();
            if now >= until {
                break;
            }
            let mut wait = until - now;
            if let Some(d) = self.inflight.values().map(|j| j.deadline).min() {
                wait = wait.min(d.saturating_duration_since(now));
            }
            match self.reply_rx.recv_timeout(wait) {
                Ok(reply) => self.on_reply(reply, &mut done),
                Err(RecvTimeoutError::Timeout) => {} // re-check deadlines
                Err(RecvTimeoutError::Disconnected) => break, // unreachable: we hold reply_tx
            }
        }
        self.update_gauges();
        done
    }

    /// Admit pending jobs while in-flight slots are free, in submission
    /// order (completion order stays reproducible; fault sampling is
    /// admission-order independent by construction).
    fn admit_ready(&mut self) {
        while self.inflight.len() < self.cfg.depth.max(1) {
            let Some(p) = self.pending.pop_front() else { break };
            self.admit(p);
        }
    }

    fn admit(&mut self, p: Pending) {
        let started = Instant::now();
        let a4 = Arc::new(split_blocks(&p.a));
        let b4 = Arc::new(split_blocks(&p.b));
        // Sample faults per item as a pure function of (master seed,
        // job_id, item index) — no shared stream, so the pattern cannot
        // shift with backend, pool size, depth, or admission history
        // (scripted jobs sample nothing).
        let faults: Vec<FaultAction> = match p.faults {
            Some(f) => f,
            None => (0..self.plan.num_work_items())
                .map(|i| self.cfg.master.fault.sample_at(self.cfg.master.seed, p.job_id, i as u64))
                .collect(),
        };
        let mut injected_failures = 0;
        let mut injected_stragglers = 0;
        for fault in &faults {
            match fault {
                FaultAction::Fail => injected_failures += 1,
                FaultAction::Delay(_) => injected_stragglers += 1,
                FaultAction::None => {}
            }
        }
        match &self.plan {
            DispatchPlan::Flat(graph) => {
                for (spec, fault) in graph.specs.iter().zip(&faults) {
                    self.pool.submit(WorkItem {
                        job_id: p.job_id,
                        task_id: spec.id,
                        ca: spec.ca,
                        cb: spec.cb,
                        a4: a4.clone(),
                        b4: b4.clone(),
                        fault: *fault,
                        reply: self.reply_tx.clone(),
                    });
                }
            }
            DispatchPlan::Nested(graph) => {
                let m2 = graph.group_size();
                // One encode scratch pair for the whole dispatch: the
                // level-1 encodes write into it in place, and only the
                // level-2 split blocks (shared by the group's leaf
                // items) are allocated per group.
                let mut enc_l = Matrix::zeros(0, 0);
                let mut enc_r = Matrix::zeros(0, 0);
                for (g, ospec) in graph.outer.specs.iter().enumerate() {
                    // Level-1 encode at the master, level-2 split: the
                    // group's operands are shared by its leaf items.
                    encode_operand_into(&mut enc_l, &ospec.int_ca(), &a4);
                    encode_operand_into(&mut enc_r, &ospec.int_cb(), &b4);
                    let ga4 = Arc::new(split_blocks(&enc_l));
                    let gb4 = Arc::new(split_blocks(&enc_r));
                    for (j, ispec) in graph.inner.specs.iter().enumerate() {
                        let task_id = g * m2 + j;
                        self.pool.submit(WorkItem {
                            job_id: p.job_id,
                            task_id,
                            ca: ispec.ca,
                            cb: ispec.cb,
                            a4: ga4.clone(),
                            b4: gb4.clone(),
                            fault: faults[task_id],
                            reply: self.reply_tx.clone(),
                        });
                    }
                }
            }
        }
        let job = JobState::new(
            &self.plan,
            p.job_id,
            a4,
            b4,
            p.enqueued,
            started,
            started + self.cfg.master.deadline,
            injected_failures,
            injected_stragglers,
            !self.cfg.master.collect_all,
        );
        self.metrics.counter("jobs_dispatched").inc();
        self.inflight.insert(p.job_id, job);
    }

    /// Route one reply to its job; replies for jobs that are no longer
    /// open (completed, cancelled, or never existed) are dropped and
    /// counted — the cross-job leakage guard. A reply that closes a
    /// nested group triggers the group's queue revocation.
    fn on_reply(&mut self, reply: WorkerReply, done: &mut Vec<FinishedJob>) {
        let job_id = reply.job_id;
        let revoke = {
            let Some(job) = self.inflight.get_mut(&job_id) else {
                self.metrics.counter("replies_stale_dropped").inc();
                return;
            };
            match &reply.product {
                Ok(_) => {
                    self.metrics.histogram("worker_compute").observe(reply.compute_time);
                }
                Err(_) => {
                    self.metrics.counter("worker_errors").inc();
                }
            }
            job.on_reply(reply)
        };
        if let Some(range) = revoke {
            let (removed, replying) = self.pool.revoke_range(job_id, range);
            if removed > 0 {
                self.metrics.counter("group_items_cancelled").add(removed as u64);
            }
            if let Some(job) = self.inflight.get_mut(&job_id) {
                job.note_revoked(replying);
            }
            self.metrics.counter("groups_recovered").inc();
        }
        let Some(job) = self.inflight.get(&job_id) else { return };
        let decodable = job.is_decodable();
        let collect_all = self.cfg.master.collect_all;
        let complete = if decodable {
            !collect_all || job.all_replies_in()
        } else {
            // Every possible reply is in and the span is still short:
            // no point waiting for the deadline.
            job.all_replies_in()
        };
        if complete {
            let job = self.inflight.remove(&job_id).unwrap();
            self.finish(job, decodable, done);
        }
    }

    /// Complete jobs that hit their deadline or exhausted their replies,
    /// at most up to the caller's completion budget (the rest stay in
    /// flight and are reaped by the next poll, so `poll`'s "at most
    /// `max_completions`" contract holds even when several deadlines
    /// expire in the same window).
    fn reap(&mut self, done: &mut Vec<FinishedJob>, max_completions: usize) {
        let now = Instant::now();
        let mut ready: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, j)| now >= j.deadline || j.all_replies_in())
            .map(|(id, _)| *id)
            .collect();
        ready.sort_unstable(); // oldest job first
        for id in ready {
            if done.len() >= max_completions {
                break;
            }
            let job = self.inflight.remove(&id).unwrap();
            // collect_all promises a decode set that depends only on the
            // injected faults: if the deadline fires before every live
            // reply arrived, fall back (or error) rather than silently
            // decoding from a timing-dependent partial set.
            let decodable = job.is_decodable()
                && (!self.cfg.master.collect_all || job.all_replies_in());
            self.finish(job, decodable, done);
        }
    }

    /// Finalize one job: cancel its outstanding items, assemble or fall
    /// back, record metrics, free the slot (admitting the next job).
    fn finish(&mut self, mut job: JobState, decodable: bool, done: &mut Vec<FinishedJob>) {
        self.pool.revoke(job.job_id);
        let scheme = self.plan.name().to_string();
        let result = if decodable {
            match job.assemble(&self.backend) {
                Ok(c) => Ok((c, job.report(&scheme, false))),
                Err(e) => Err(format!("job {}: {e}", job.job_id)),
            }
        } else if self.cfg.master.fallback_local {
            self.metrics.counter("jobs_fell_back").inc();
            let c = job.fallback_product();
            Ok((c, job.report(&scheme, true)))
        } else {
            Err(format!(
                "job {}: not decodable within deadline ({} of {} replies)",
                job.job_id, job.finished, job.dispatched
            ))
        };
        if let Ok((_, report)) = &result {
            self.metrics.histogram("job_latency").observe(report.elapsed);
        }
        self.metrics
            .histogram("queue_wait")
            .observe(job.started.duration_since(job.enqueued));
        self.metrics.counter("jobs_completed").inc();
        done.push(FinishedJob {
            job_id: job.job_id,
            result,
            total_latency: job.enqueued.elapsed(),
        });
        self.admit_ready();
    }

    fn update_gauges(&self) {
        self.metrics.gauge("inflight_jobs").set(self.inflight.len() as u64);
        self.metrics.gauge("pending_jobs").set(self.pending.len() as u64);
    }

    /// Shut the shared pool down.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::nested::NestedTaskSet;
    use crate::coordinator::worker::FaultPlan;
    use crate::sim::rng::Rng;

    fn cfg(depth: usize, fault: FaultPlan, seed: u64) -> SchedulerConfig {
        SchedulerConfig {
            master: MasterConfig {
                deadline: Duration::from_secs(10),
                fault,
                seed,
                fallback_local: true,
                collect_all: false,
            },
            depth,
        }
    }

    fn rand_pair(n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        (Matrix::random(n, n, &mut rng), Matrix::random(n, n, &mut rng))
    }

    #[test]
    fn multiple_inflight_jobs_all_correct() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            cfg(4, FaultPlan::NONE, 1),
        );
        let mut want = Vec::new();
        for seed in 0..6 {
            let (a, b) = rand_pair(16, seed);
            want.push(a.matmul(&b));
            s.submit(a, b).unwrap();
        }
        assert!(s.in_flight() <= 4);
        let mut done = s.drive(6);
        assert_eq!(done.len(), 6);
        done.sort_by_key(|f| f.job_id);
        for (f, w) in done.iter().zip(&want) {
            let (c, report) = f.result.as_ref().unwrap();
            assert!(!report.fell_back);
            assert!(c.approx_eq(w, 1e-4));
        }
        assert_eq!(s.outstanding(), 0);
        s.shutdown();
    }

    #[test]
    fn depth_is_respected_and_pending_queueing_works() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(2, FaultPlan::NONE, 1),
        );
        for seed in 0..5 {
            let (a, b) = rand_pair(8, seed);
            s.submit(a, b).unwrap();
        }
        assert_eq!(s.in_flight(), 2);
        assert_eq!(s.outstanding(), 5);
        let done = s.drive(5);
        assert_eq!(done.len(), 5);
        s.shutdown();
    }

    #[test]
    fn drive_returns_at_most_requested() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(4, FaultPlan::NONE, 1),
        );
        for seed in 0..4 {
            let (a, b) = rand_pair(8, seed);
            s.submit(a, b).unwrap();
        }
        let done = s.drive(2);
        assert_eq!(done.len(), 2);
        assert_eq!(s.outstanding(), 2);
        let rest = s.drive(usize::MAX);
        assert_eq!(rest.len(), 2);
        s.shutdown();
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(1, FaultPlan::NONE, 1),
        );
        assert!(s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 6)).is_err());
        assert!(s.submit(Matrix::zeros(7, 7), Matrix::zeros(7, 7)).is_err());
        assert!(s.submit(Matrix::zeros(6, 6), Matrix::zeros(6, 6)).is_ok());
        assert_eq!(s.drive(1).len(), 1);
        s.shutdown();
    }

    #[test]
    fn all_failed_job_completes_quickly_via_fallback() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(
                1,
                FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                3,
            ),
        );
        let (a, b) = rand_pair(8, 3);
        let want = a.matmul(&b);
        let t0 = Instant::now();
        s.submit(a, b).unwrap();
        let done = s.drive(1);
        let (c, report) = done[0].result.as_ref().unwrap();
        assert!(report.fell_back);
        assert_eq!(report.finished, 0);
        assert!(c.approx_eq(&want, 1e-5));
        // Exhaustion (0 expected replies) completes well before the 10 s
        // deadline.
        assert!(t0.elapsed() < Duration::from_secs(5));
        s.shutdown();
    }

    #[test]
    fn fault_pattern_is_invariant_across_depth_and_pool_size() {
        // Regression for the shared-stream sampler: the injected fault
        // pattern of every job in a seeded stream must be identical no
        // matter the in-flight depth or worker-pool size (it is a pure
        // function of (seed, job_id, item) now — nothing about admission
        // history, thread count, or backend can shift it).
        let run = |depth: usize, workers: usize| -> Vec<(u64, usize, usize)> {
            let mut s = Scheduler::with_plan(
                DispatchPlan::flat(TaskSet::strassen_winograd(2)),
                Backend::Native,
                cfg(
                    depth,
                    FaultPlan {
                        p_fail: 0.2,
                        p_straggle: 0.2,
                        delay: Duration::from_millis(1),
                    },
                    42,
                ),
                Some(workers),
            );
            for seed in 0..6 {
                let (a, b) = rand_pair(8, seed);
                s.submit(a, b).unwrap();
            }
            let mut done = s.drive(6);
            s.shutdown();
            done.sort_by_key(|f| f.job_id);
            done.iter()
                .map(|f| {
                    let (_, r) = f.result.as_ref().unwrap();
                    (f.job_id, r.injected_failures, r.injected_stragglers)
                })
                .collect()
        };
        let baseline = run(1, 16);
        assert!(
            baseline.iter().any(|&(_, f, s)| f + s > 0),
            "no fault injected — the regression test exercises nothing"
        );
        assert_eq!(run(4, 16), baseline, "depth must not shift fault patterns");
        assert_eq!(run(2, 4), baseline, "pool size must not shift fault patterns");
    }

    fn nested_plan() -> DispatchPlan {
        DispatchPlan::nested(NestedTaskSet::compose(
            TaskSet::strassen_winograd(2),
            TaskSet::strassen_winograd(2),
        ))
    }

    #[test]
    fn nested_plan_runs_end_to_end_without_faults() {
        let mut s = Scheduler::with_plan(
            nested_plan(),
            Backend::Native,
            cfg(2, FaultPlan::NONE, 1),
            Some(16),
        );
        assert_eq!(s.items_per_job(), 256);
        assert_eq!(s.num_workers(), 16);
        let (a, b) = rand_pair(16, 4);
        let want = a.matmul(&b);
        s.submit(a, b).unwrap();
        let done = s.drive(1);
        let (c, report) = done[0].result.as_ref().unwrap();
        assert!(!report.fell_back);
        assert_eq!(report.dispatched, 256);
        assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
        // Eager group recovery cancels queued leaf work.
        assert!(s.metrics.counter("groups_recovered").get() >= 16);
        s.shutdown();
    }

    #[test]
    fn nested_plan_rejects_non_divisible_dimension() {
        let mut s = Scheduler::with_plan(
            nested_plan(),
            Backend::Native,
            cfg(1, FaultPlan::NONE, 1),
            Some(4),
        );
        let err = s.submit(Matrix::zeros(6, 6), Matrix::zeros(6, 6)).unwrap_err();
        assert!(err.contains("multiple of 4"), "{err}");
        s.shutdown();
    }

    #[test]
    fn fault_script_length_is_validated() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(1, FaultPlan::NONE, 1),
        );
        let err = s
            .submit_with_faults(Matrix::zeros(8, 8), Matrix::zeros(8, 8), vec![])
            .unwrap_err();
        assert!(err.contains("fault script"), "{err}");
        let ok = s.submit_with_faults(
            Matrix::zeros(8, 8),
            Matrix::zeros(8, 8),
            vec![FaultAction::None; 14],
        );
        assert!(ok.is_ok());
        assert_eq!(s.drive(1).len(), 1);
        s.shutdown();
    }
}
