//! The job-multiplexed scheduler — now a thin single-tenant adapter
//! over the message-driven [`ServingTier`].
//!
//! Historically this module owned all multiplexing state (admission
//! queue, per-job decode machines, reply routing, revocation). The
//! protocol split moved that state behind the serving tier, which talks
//! to its workers exclusively through
//! [`crate::coordinator::proto`] messages; `Scheduler` keeps the old
//! call surface — `submit`/`drive`/`poll` over one anonymous tenant at a
//! fixed in-flight depth, no batching, no operand cache — so `Master`
//! and long-standing callers are unaffected.
//!
//! The semantics pinned by this module's tests are unchanged:
//!
//! * jobs admit in submission order up to `depth`, and complete in
//!   completion order;
//! * each work item's fault is a **pure function** of `(master seed,
//!   job_id, item index)` — seeded job streams see the exact same fault
//!   pattern at every in-flight depth, pool size, backend, and thread
//!   count (combine with [`MasterConfig::collect_all`] for bit-identical
//!   outputs);
//! * nested jobs revoke a recovered group's queued leaves eagerly, and
//!   late replies for closed jobs are dropped and counted.

use std::time::Duration;

use crate::coding::scheme::TaskSet;
use crate::coordinator::job::MultiplyReport;
use crate::coordinator::master::MasterConfig;
use crate::coordinator::task::DispatchPlan;
use crate::coordinator::tier::{ServingTier, TenantSpec, TierConfig};
use crate::coordinator::worker::{Backend, FaultAction};
use crate::linalg::matrix::Matrix;
use crate::metrics::Registry;

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Per-job policy (deadline, fault plan, seed, fallback, decode mode).
    pub master: MasterConfig,
    /// Maximum concurrently in-flight jobs (≥ 1).
    pub depth: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { master: MasterConfig::default(), depth: 1 }
    }
}

/// A completed job, in completion order.
pub struct FinishedJob {
    pub job_id: u64,
    /// The product and its report, or the job-level error (only when
    /// local fallback is disabled).
    pub result: Result<(Matrix, MultiplyReport), String>,
    /// Submit → completion (queue wait included).
    pub total_latency: Duration,
}

/// The single-tenant tenant name the adapter submits under.
const TENANT: &str = "default";

/// The multiplexed scheduler (single-tenant serving-tier adapter).
pub struct Scheduler {
    tier: ServingTier,
    pub metrics: Registry,
}

impl Scheduler {
    /// Build a scheduler with one worker thread per task in the set.
    pub fn new(set: TaskSet, backend: Backend, cfg: SchedulerConfig) -> Scheduler {
        Scheduler::with_plan(DispatchPlan::flat(set), backend, cfg, None)
    }

    /// Build a scheduler for an arbitrary dispatch plan. `workers`
    /// overrides the fleet size (defaults to one node per task for flat
    /// plans, a capped fleet for nested fan-outs — leaf items multiplex
    /// onto whatever fleet exists, they do not each own a thread).
    pub fn with_plan(
        plan: DispatchPlan,
        backend: Backend,
        cfg: SchedulerConfig,
        workers: Option<usize>,
    ) -> Scheduler {
        let tier = ServingTier::with_plan(
            plan,
            backend,
            TierConfig {
                master: cfg.master,
                depth: cfg.depth,
                queue_cap: usize::MAX,
                tenants: vec![TenantSpec::unbounded(TENANT)],
                batch_window: 1,
                cache_cap: 0,
            },
            workers,
        );
        let metrics = tier.metrics.clone();
        Scheduler { tier, metrics }
    }

    pub fn scheme_name(&self) -> &str {
        self.tier.scheme_name()
    }

    pub fn num_workers(&self) -> usize {
        self.tier.num_workers()
    }

    /// Work items dispatched per job (tasks, or leaves for nested plans).
    pub fn items_per_job(&self) -> usize {
        self.tier.items_per_job()
    }

    /// Configured in-flight depth (≥ 1).
    pub fn depth(&self) -> usize {
        self.tier.depth()
    }

    /// Jobs not yet completed (queued + in flight).
    pub fn outstanding(&self) -> usize {
        self.tier.outstanding()
    }

    pub fn in_flight(&self) -> usize {
        self.tier.in_flight()
    }

    /// Submit a multiply job `C = A · B` (square, dimension divisible by
    /// 2 per split level: 2 for flat plans, 4 for nested). Admits
    /// immediately if an in-flight slot is free.
    pub fn submit(&mut self, a: Matrix, b: Matrix) -> Result<u64, String> {
        self.tier.submit(TENANT, a, b)
    }

    /// Submit with an explicit per-item fault script (length must equal
    /// [`Self::items_per_job`]), bypassing the fault plan's sampling —
    /// deterministic replay for tests and fault-pattern experiments.
    pub fn submit_with_faults(
        &mut self,
        a: Matrix,
        b: Matrix,
        faults: Vec<FaultAction>,
    ) -> Result<u64, String> {
        self.tier.submit_with_faults(TENANT, a, b, faults)
    }

    /// Drive the scheduler until `max_jobs` complete (or nothing is
    /// outstanding). Completions are returned in completion order, which
    /// at depth > 1 may differ from submission order.
    pub fn drive(&mut self, max_jobs: usize) -> Vec<FinishedJob> {
        self.tier.drive(max_jobs).into_iter().map(finished).collect()
    }

    /// Process events for up to `timeout`, returning at most
    /// `max_completions` finished jobs (early-exits once reached).
    pub fn poll(&mut self, timeout: Duration, max_completions: usize) -> Vec<FinishedJob> {
        self.tier.poll(timeout, max_completions).into_iter().map(finished).collect()
    }

    /// Shut the worker fleet down.
    pub fn shutdown(self) {
        self.tier.shutdown();
    }
}

fn finished(d: crate::coordinator::proto::JobDone) -> FinishedJob {
    FinishedJob { job_id: d.job_id, result: d.result, total_latency: d.total_latency }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::nested::NestedTaskSet;
    use crate::coordinator::worker::FaultPlan;
    use crate::sim::rng::Rng;
    use std::time::Instant;

    fn cfg(depth: usize, fault: FaultPlan, seed: u64) -> SchedulerConfig {
        SchedulerConfig {
            master: MasterConfig {
                deadline: Duration::from_secs(10),
                fault,
                seed,
                fallback_local: true,
                collect_all: false,
            },
            depth,
        }
    }

    fn rand_pair(n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        (Matrix::random(n, n, &mut rng), Matrix::random(n, n, &mut rng))
    }

    #[test]
    fn multiple_inflight_jobs_all_correct() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            cfg(4, FaultPlan::NONE, 1),
        );
        let mut want = Vec::new();
        for seed in 0..6 {
            let (a, b) = rand_pair(16, seed);
            want.push(a.matmul(&b));
            s.submit(a, b).unwrap();
        }
        assert!(s.in_flight() <= 4);
        let mut done = s.drive(6);
        assert_eq!(done.len(), 6);
        done.sort_by_key(|f| f.job_id);
        for (f, w) in done.iter().zip(&want) {
            let (c, report) = f.result.as_ref().unwrap();
            assert!(!report.fell_back);
            assert!(c.approx_eq(w, 1e-4));
        }
        assert_eq!(s.outstanding(), 0);
        s.shutdown();
    }

    #[test]
    fn depth_is_respected_and_pending_queueing_works() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(2, FaultPlan::NONE, 1),
        );
        for seed in 0..5 {
            let (a, b) = rand_pair(8, seed);
            s.submit(a, b).unwrap();
        }
        assert_eq!(s.in_flight(), 2);
        assert_eq!(s.outstanding(), 5);
        let done = s.drive(5);
        assert_eq!(done.len(), 5);
        s.shutdown();
    }

    #[test]
    fn drive_returns_at_most_requested() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(4, FaultPlan::NONE, 1),
        );
        for seed in 0..4 {
            let (a, b) = rand_pair(8, seed);
            s.submit(a, b).unwrap();
        }
        let done = s.drive(2);
        assert_eq!(done.len(), 2);
        assert_eq!(s.outstanding(), 2);
        let rest = s.drive(usize::MAX);
        assert_eq!(rest.len(), 2);
        s.shutdown();
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(1, FaultPlan::NONE, 1),
        );
        assert!(s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 6)).is_err());
        assert!(s.submit(Matrix::zeros(7, 7), Matrix::zeros(7, 7)).is_err());
        assert!(s.submit(Matrix::zeros(6, 6), Matrix::zeros(6, 6)).is_ok());
        assert_eq!(s.drive(1).len(), 1);
        s.shutdown();
    }

    #[test]
    fn all_failed_job_completes_quickly_via_fallback() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(
                1,
                FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                3,
            ),
        );
        let (a, b) = rand_pair(8, 3);
        let want = a.matmul(&b);
        let t0 = Instant::now();
        s.submit(a, b).unwrap();
        let done = s.drive(1);
        let (c, report) = done[0].result.as_ref().unwrap();
        assert!(report.fell_back);
        assert_eq!(report.finished, 0);
        assert!(c.approx_eq(&want, 1e-5));
        // Exhaustion (0 expected replies) completes well before the 10 s
        // deadline.
        assert!(t0.elapsed() < Duration::from_secs(5));
        s.shutdown();
    }

    #[test]
    fn fault_pattern_is_invariant_across_depth_and_pool_size() {
        // Regression for the shared-stream sampler: the injected fault
        // pattern of every job in a seeded stream must be identical no
        // matter the in-flight depth or worker-pool size (it is a pure
        // function of (seed, job_id, item) now — nothing about admission
        // history, thread count, or backend can shift it).
        let run = |depth: usize, workers: usize| -> Vec<(u64, usize, usize)> {
            let mut s = Scheduler::with_plan(
                DispatchPlan::flat(TaskSet::strassen_winograd(2)),
                Backend::Native,
                cfg(
                    depth,
                    FaultPlan {
                        p_fail: 0.2,
                        p_straggle: 0.2,
                        delay: Duration::from_millis(1),
                    },
                    42,
                ),
                Some(workers),
            );
            for seed in 0..6 {
                let (a, b) = rand_pair(8, seed);
                s.submit(a, b).unwrap();
            }
            let mut done = s.drive(6);
            s.shutdown();
            done.sort_by_key(|f| f.job_id);
            done.iter()
                .map(|f| {
                    let (_, r) = f.result.as_ref().unwrap();
                    (f.job_id, r.injected_failures, r.injected_stragglers)
                })
                .collect()
        };
        let baseline = run(1, 16);
        assert!(
            baseline.iter().any(|&(_, f, s)| f + s > 0),
            "no fault injected — the regression test exercises nothing"
        );
        assert_eq!(run(4, 16), baseline, "depth must not shift fault patterns");
        assert_eq!(run(2, 4), baseline, "pool size must not shift fault patterns");
    }

    fn nested_plan() -> DispatchPlan {
        DispatchPlan::nested(NestedTaskSet::compose(
            TaskSet::strassen_winograd(2),
            TaskSet::strassen_winograd(2),
        ))
    }

    #[test]
    fn nested_plan_runs_end_to_end_without_faults() {
        let mut s = Scheduler::with_plan(
            nested_plan(),
            Backend::Native,
            cfg(2, FaultPlan::NONE, 1),
            Some(16),
        );
        assert_eq!(s.items_per_job(), 256);
        assert_eq!(s.num_workers(), 16);
        let (a, b) = rand_pair(16, 4);
        let want = a.matmul(&b);
        s.submit(a, b).unwrap();
        let done = s.drive(1);
        let (c, report) = done[0].result.as_ref().unwrap();
        assert!(!report.fell_back);
        assert_eq!(report.dispatched, 256);
        assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
        // Eager group recovery cancels queued leaf work.
        assert!(s.metrics.counter("groups_recovered").get() >= 16);
        s.shutdown();
    }

    #[test]
    fn nested_plan_rejects_non_divisible_dimension() {
        let mut s = Scheduler::with_plan(
            nested_plan(),
            Backend::Native,
            cfg(1, FaultPlan::NONE, 1),
            Some(4),
        );
        let err = s.submit(Matrix::zeros(6, 6), Matrix::zeros(6, 6)).unwrap_err();
        assert!(err.contains("multiple of 4"), "{err}");
        s.shutdown();
    }

    #[test]
    fn fault_script_length_is_validated() {
        let mut s = Scheduler::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(1, FaultPlan::NONE, 1),
        );
        let err = s
            .submit_with_faults(Matrix::zeros(8, 8), Matrix::zeros(8, 8), vec![])
            .unwrap_err();
        assert!(err.contains("fault script"), "{err}");
        let ok = s.submit_with_faults(
            Matrix::zeros(8, 8),
            Matrix::zeros(8, 8),
            vec![FaultAction::None; 14],
        );
        assert!(ok.is_ok());
        assert_eq!(s.drive(1).len(), 1);
        s.shutdown();
    }
}
