//! The worker pool: one OS thread per simulated compute node.
//!
//! Each node receives `WorkItem`s (the encoded coefficients plus shared
//! handles to the operand blocks), computes its single block product on
//! the configured backend, and reports back. Fault injection happens at
//! the node, exactly like the paper's model: a failed node simply never
//! answers; a straggler answers late.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::linalg::blocked::encode_operand;
use crate::linalg::matrix::Matrix;
use crate::runtime::service::PjrtHandle;
use crate::sim::rng::Rng;

/// Compute backend for a worker's block product.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust encode + blocked matmul in the worker thread.
    Native,
    /// The AOT Pallas artifact through the PJRT compute service.
    Pjrt(PjrtHandle),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

/// Per-dispatch fault decision (sampled by the master's fault plan).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    None,
    /// Delay the response by this much (straggler).
    Delay(Duration),
    /// Never respond (the paper's node failure).
    Fail,
}

/// Job-level fault plan: how to sample per-node actions.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// P(node fails) — the paper's p_e.
    pub p_fail: f64,
    /// P(node straggles by `delay`).
    pub p_straggle: f64,
    pub delay: Duration,
}

impl FaultPlan {
    pub const NONE: FaultPlan =
        FaultPlan { p_fail: 0.0, p_straggle: 0.0, delay: Duration::ZERO };

    pub fn sample(&self, rng: &mut Rng) -> FaultAction {
        if self.p_fail > 0.0 && rng.bernoulli(self.p_fail) {
            FaultAction::Fail
        } else if self.p_straggle > 0.0 && rng.bernoulli(self.p_straggle) {
            FaultAction::Delay(self.delay)
        } else {
            FaultAction::None
        }
    }
}

/// One unit of work for a node.
pub struct WorkItem {
    pub job_id: u64,
    pub task_id: usize,
    pub ca: [f32; 4],
    pub cb: [f32; 4],
    pub a4: Arc<[Matrix; 4]>,
    pub b4: Arc<[Matrix; 4]>,
    pub fault: FaultAction,
    pub reply: Sender<WorkerReply>,
}

/// A node's answer.
#[derive(Debug)]
pub struct WorkerReply {
    pub job_id: u64,
    pub task_id: usize,
    pub product: Result<Matrix, String>,
    pub compute_time: Duration,
}

/// Fixed pool of worker nodes.
pub struct WorkerPool {
    senders: Vec<Sender<WorkItem>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` nodes on the given backend.
    pub fn spawn(n: usize, backend: Backend) -> WorkerPool {
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for node in 0..n {
            let (tx, rx) = channel::<WorkItem>();
            let backend = backend.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{node}"))
                .spawn(move || node_loop(rx, backend))
                .expect("spawn worker");
            senders.push(tx);
            handles.push(handle);
        }
        WorkerPool { senders, handles }
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Send one item to node `i % size`.
    pub fn dispatch(&self, i: usize, item: WorkItem) {
        // A dead node's channel is gone; the master treats missing
        // replies as failures anyway, so ignore send errors.
        let _ = self.senders[i % self.senders.len()].send(item);
    }

    /// Graceful shutdown: close all queues and join.
    pub fn shutdown(self) {
        drop(self.senders);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn node_loop(rx: Receiver<WorkItem>, backend: Backend) {
    while let Ok(item) = rx.recv() {
        match item.fault {
            FaultAction::Fail => continue, // silently drop (paper's model)
            FaultAction::Delay(d) => std::thread::sleep(d),
            FaultAction::None => {}
        }
        let t0 = Instant::now();
        let product = compute(&backend, &item);
        let reply = WorkerReply {
            job_id: item.job_id,
            task_id: item.task_id,
            product,
            compute_time: t0.elapsed(),
        };
        let _ = item.reply.send(reply);
    }
}

fn compute(backend: &Backend, item: &WorkItem) -> Result<Matrix, String> {
    match backend {
        Backend::Native => {
            let ica = to_int(&item.ca);
            let icb = to_int(&item.cb);
            let left = encode_operand(&ica, &item.a4);
            let right = encode_operand(&icb, &item.b4);
            Ok(left.matmul(&right))
        }
        Backend::Pjrt(h) => h.worker_task(
            item.ca,
            (*item.a4).clone(),
            item.cb,
            (*item.b4).clone(),
        ),
    }
}

fn to_int(c: &[f32; 4]) -> [i32; 4] {
    let mut out = [0i32; 4];
    for (o, &x) in out.iter_mut().zip(c.iter()) {
        *o = x as i32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blocked::split_blocks;

    fn blocks(seed: u64, n: usize) -> (Arc<[Matrix; 4]>, Arc<[Matrix; 4]>) {
        let mut rng = Rng::seeded(seed);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        (Arc::new(split_blocks(&a)), Arc::new(split_blocks(&b)))
    }

    #[test]
    fn pool_computes_products() {
        let pool = WorkerPool::spawn(4, Backend::Native);
        let (a4, b4) = blocks(1, 16);
        let (tx, rx) = channel();
        for task_id in 0..4 {
            pool.dispatch(
                task_id,
                WorkItem {
                    job_id: 7,
                    task_id,
                    ca: [1.0, 0.0, 0.0, 0.0],
                    cb: [1.0, 0.0, 0.0, 0.0],
                    a4: a4.clone(),
                    b4: b4.clone(),
                    fault: FaultAction::None,
                    reply: tx.clone(),
                },
            );
        }
        drop(tx);
        let want = a4[0].matmul(&b4[0]);
        let mut got = 0;
        while let Ok(reply) = rx.recv() {
            assert_eq!(reply.job_id, 7);
            assert!(reply.product.unwrap().approx_eq(&want, 1e-5));
            got += 1;
        }
        assert_eq!(got, 4);
        pool.shutdown();
    }

    #[test]
    fn failed_nodes_never_reply() {
        let pool = WorkerPool::spawn(2, Backend::Native);
        let (a4, b4) = blocks(2, 8);
        let (tx, rx) = channel();
        pool.dispatch(
            0,
            WorkItem {
                job_id: 1,
                task_id: 0,
                ca: [1.0; 4],
                cb: [1.0; 4],
                a4: a4.clone(),
                b4: b4.clone(),
                fault: FaultAction::Fail,
                reply: tx.clone(),
            },
        );
        pool.dispatch(
            1,
            WorkItem {
                job_id: 1,
                task_id: 1,
                ca: [1.0; 4],
                cb: [1.0; 4],
                a4,
                b4,
                fault: FaultAction::None,
                reply: tx.clone(),
            },
        );
        drop(tx);
        let replies: Vec<WorkerReply> = rx.iter().collect();
        assert_eq!(replies.len(), 1);
        assert_eq!(replies[0].task_id, 1);
        pool.shutdown();
    }

    #[test]
    fn stragglers_reply_late() {
        let pool = WorkerPool::spawn(1, Backend::Native);
        let (a4, b4) = blocks(3, 8);
        let (tx, rx) = channel();
        let t0 = Instant::now();
        pool.dispatch(
            0,
            WorkItem {
                job_id: 1,
                task_id: 0,
                ca: [1.0, 0.0, 0.0, 0.0],
                cb: [1.0, 0.0, 0.0, 0.0],
                a4,
                b4,
                fault: FaultAction::Delay(Duration::from_millis(30)),
                reply: tx,
            },
        );
        let reply = rx.recv().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert!(reply.product.is_ok());
        pool.shutdown();
    }

    #[test]
    fn fault_plan_sampling_frequencies() {
        let plan = FaultPlan {
            p_fail: 0.25,
            p_straggle: 0.25,
            delay: Duration::from_millis(1),
        };
        let mut rng = Rng::seeded(5);
        let n = 40_000;
        let mut fails = 0;
        let mut delays = 0;
        for _ in 0..n {
            match plan.sample(&mut rng) {
                FaultAction::Fail => fails += 1,
                FaultAction::Delay(_) => delays += 1,
                FaultAction::None => {}
            }
        }
        let pf = fails as f64 / n as f64;
        // delay is sampled only among non-failures: P = 0.75 * 0.25
        let pd = delays as f64 / n as f64;
        assert!((pf - 0.25).abs() < 0.01, "{pf}");
        assert!((pd - 0.1875).abs() < 0.01, "{pd}");
    }
}
