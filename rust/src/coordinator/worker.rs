//! Workers as independent message-driven event loops.
//!
//! Each worker owns a [`WorkerEndpoint`] and nothing else: it announces
//! itself with `Register`, computes each `AssignLeaf` it is handed, and
//! reports `Ready` when its slot is free — the pull-based dispatch that
//! lets the serving tier keep exact, coordinator-side revocation
//! accounting (an undispatched task is purged from the tier's central
//! queue; at most one task is ever at a worker). `Revoke` purges the
//! local backlog with exact `RevokeAck` accounting, `Heartbeat` is
//! answered with `HeartbeatAck`, and `Shutdown` drains then exits.
//!
//! Fault injection happens at the node, exactly like the paper's model:
//! a failed node simply never answers; a straggler answers late. A
//! straggler is modeled as a *delayed response* (slow link / slow
//! node-to-master path): the product is computed, handed to the
//! transport's delay line for deferred delivery, and the worker
//! immediately reports `Ready` — the slot is never blocked.
//!
//! [`WorkerFleet`] spawns the event loops over an in-process
//! [`ChannelTransport`] and gives the serving tier its coordinator-side
//! handle; any other [`Transport`] implementation can be substituted
//! without touching the loop.

use std::collections::VecDeque;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::proto::{Assignment, OperandPayload, ToCoord, ToWorker};
use crate::coordinator::tier::names;
use crate::coordinator::transport::{ChannelTransport, Transport, WorkerEndpoint};
use crate::linalg::blocked::encode_operand_into;
use crate::linalg::matrix::Matrix;
use crate::metrics::{Counter, Gauge, Registry};
use crate::obs::{EventKind, Tracer};
use crate::runtime::service::PjrtHandle;
use crate::sim::rng::Rng;

/// Compute backend for a worker's block product.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust encode + blocked matmul in the worker thread.
    Native,
    /// The AOT Pallas artifact through the PJRT compute service.
    Pjrt(PjrtHandle),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

/// Per-dispatch fault decision (sampled by the scheduler's fault plan).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    None,
    /// Deliver the response this much later (straggler).
    Delay(Duration),
    /// Never respond (the paper's node failure).
    Fail,
}

/// Job-level fault plan: how to sample per-node actions. Failure and
/// straggling are mutually exclusive events with the exact marginal
/// probabilities the paper's model specifies: `P(Fail) = p_fail` and
/// `P(Delay) = p_straggle` (requires `p_fail + p_straggle <= 1`, which
/// [`crate::config::RunConfig::validate`] enforces for CLI runs).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// P(node fails) — the paper's p_e.
    pub p_fail: f64,
    /// P(node straggles by `delay`).
    pub p_straggle: f64,
    pub delay: Duration,
}

impl FaultPlan {
    pub const NONE: FaultPlan =
        FaultPlan { p_fail: 0.0, p_straggle: 0.0, delay: Duration::ZERO };

    /// Sample one node's fault action. A single uniform draw partitions
    /// `[0, 1)` into `[0, p_fail)` → fail, `[p_fail, p_fail +
    /// p_straggle)` → straggle, rest → healthy, so both marginals are
    /// exact. (An earlier version sampled straggling *conditionally
    /// after* non-failure, deflating the effective straggle probability
    /// to `p_straggle·(1 − p_fail)` and skewing every sim-vs-theory
    /// comparison that swept both parameters.)
    pub fn sample(&self, rng: &mut Rng) -> FaultAction {
        debug_assert!(
            self.p_fail + self.p_straggle <= 1.0,
            "fail/straggle are exclusive marginals: p_fail {} + p_straggle {} > 1 \
             silently truncates P(Delay)",
            self.p_fail,
            self.p_straggle
        );
        if self.p_fail <= 0.0 && self.p_straggle <= 0.0 {
            return FaultAction::None;
        }
        let u = rng.uniform();
        self.partition(u)
    }

    /// Sample the fault action of work item `item` of job `job_id` under
    /// master seed `seed` — a **pure function** of its three arguments.
    ///
    /// [`Self::sample`] draws from a shared stream, so a job's fault
    /// pattern depends on how many draws every earlier job made: any
    /// change in backend, pool size, or admission history shifts the
    /// stream and silently re-rolls every later job's faults. Here the
    /// coordinates are hashed (two rounds of the splitmix64 finalizer)
    /// into a private RNG seed and exactly one uniform is drawn, so the
    /// same `(seed, job_id, item)` yields the same action on every
    /// backend, thread count, and in-flight depth — the invariance
    /// `tests/multiplex.rs` and the scheduler regression tests pin.
    pub fn sample_at(&self, seed: u64, job_id: u64, item: u64) -> FaultAction {
        debug_assert!(
            self.p_fail + self.p_straggle <= 1.0,
            "fail/straggle are exclusive marginals: p_fail {} + p_straggle {} > 1 \
             silently truncates P(Delay)",
            self.p_fail,
            self.p_straggle
        );
        if self.p_fail <= 0.0 && self.p_straggle <= 0.0 {
            return FaultAction::None;
        }
        fn mix64(mut z: u64) -> u64 {
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        }
        let mixed = mix64(seed ^ mix64(job_id ^ mix64(item)));
        self.partition(Rng::seeded(mixed).uniform())
    }

    fn partition(&self, u: f64) -> FaultAction {
        if u < self.p_fail {
            FaultAction::Fail
        } else if u < self.p_fail + self.p_straggle {
            FaultAction::Delay(self.delay)
        } else {
            FaultAction::None
        }
    }
}

/// Policy-facing fault source: anything that can answer "what happens
/// to work item `item` of job `job_id` under master seed `seed`?" as a
/// pure function of those coordinates. [`FaultPlan`] is the canonical
/// implementation; the discrete-event simulator
/// ([`crate::sim::des::engine`]) consumes the trait so campaigns can be
/// driven by the exact fault process the live coordinator uses — or by
/// a custom one — without touching the engine.
pub trait FaultSampler {
    fn action_at(&self, seed: u64, job_id: u64, item: u64) -> FaultAction;
}

impl FaultSampler for FaultPlan {
    fn action_at(&self, seed: u64, job_id: u64, item: u64) -> FaultAction {
        self.sample_at(seed, job_id, item)
    }
}

/// A node's answer (the body of
/// [`ToCoord::LeafResult`](crate::coordinator::proto::ToCoord::LeafResult)).
#[derive(Debug)]
pub struct WorkerReply {
    pub job_id: u64,
    pub task_id: usize,
    pub product: Result<Matrix, String>,
    pub compute_time: Duration,
}

/// Fleet-level worker metrics, shared by every event loop.
#[derive(Clone)]
pub struct WorkerCounters {
    executed: Arc<Counter>,
    faulted: Arc<Counter>,
    revoked: Arc<Counter>,
    busy: Arc<Gauge>,
}

impl WorkerCounters {
    pub fn from_registry(metrics: &Registry) -> WorkerCounters {
        WorkerCounters {
            executed: metrics.counter(names::POOL_ITEMS_EXECUTED),
            faulted: metrics.counter(names::POOL_ITEMS_FAULTED),
            revoked: metrics.counter(names::POOL_ITEMS_REVOKED),
            busy: metrics.gauge(names::POOL_BUSY_WORKERS),
        }
    }
}

/// The worker fleet, from the coordinator's side: `n` independent event
/// loops reachable only through a [`Transport`].
pub struct WorkerFleet {
    transport: Box<dyn Transport>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerFleet {
    /// Spawn `n` event-loop workers on the given backend over an
    /// in-process [`ChannelTransport`], recording fleet metrics
    /// (`pool_*` counters/gauges) into `metrics`.
    pub fn spawn(n: usize, backend: Backend, metrics: Registry) -> WorkerFleet {
        WorkerFleet::spawn_traced(n, backend, metrics, Tracer::off())
    }

    /// [`WorkerFleet::spawn`] with a trace sink: every event loop emits
    /// `encode`/`compute`/`revoke` events through its own clone of
    /// `tracer` (a disabled tracer costs one branch per site).
    pub fn spawn_traced(
        n: usize,
        backend: Backend,
        metrics: Registry,
        tracer: Tracer,
    ) -> WorkerFleet {
        let (transport, endpoints) = ChannelTransport::new(n);
        let counters = WorkerCounters::from_registry(&metrics);
        let mut handles = Vec::with_capacity(n);
        for ep in endpoints {
            let backend = backend.clone();
            let counters = counters.clone();
            let tracer = tracer.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{}", ep.worker_id()))
                .spawn(move || event_loop_traced(ep, backend, counters, tracer))
                .expect("spawn worker");
            handles.push(handle);
        }
        WorkerFleet { transport: Box::new(transport), handles }
    }

    /// Adopt an externally built transport whose worker tasks are
    /// already running (`handles` may be empty for remote workers).
    pub fn over(transport: Box<dyn Transport>, handles: Vec<JoinHandle<()>>) -> WorkerFleet {
        WorkerFleet { transport, handles }
    }

    pub fn size(&self) -> usize {
        self.transport.num_workers()
    }

    /// Deliver `msg` to one worker; the message is handed back if the
    /// endpoint is gone.
    pub fn send(&self, worker: usize, msg: ToWorker) -> Result<(), ToWorker> {
        self.transport.send(worker, msg)
    }

    /// Receive the next worker message, waiting at most `timeout`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<ToCoord, RecvTimeoutError> {
        self.transport.recv_timeout(timeout)
    }

    /// Graceful shutdown: ask every worker to drain and exit, join the
    /// event loops, then release the transport (delay-line flush).
    pub fn shutdown(mut self) {
        for w in 0..self.transport.num_workers() {
            let _ = self.transport.send(w, ToWorker::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.transport.shutdown();
    }
}

/// Per-worker reusable encode scratch: the two encoded operands are
/// written into these buffers ([`encode_operand_into`]) instead of
/// allocating two fresh matrices per task — after the first item of a
/// given block size the native encode path allocates nothing but the
/// product it ships back.
struct EncodeScratch {
    left: Matrix,
    right: Matrix,
}

impl EncodeScratch {
    fn new() -> EncodeScratch {
        EncodeScratch { left: Matrix::zeros(0, 0), right: Matrix::zeros(0, 0) }
    }
}

/// One worker's event loop: drain the mailbox, act out control
/// messages, compute assignments one at a time, report `Ready` after
/// each. Public so alternative transports can host the identical loop.
pub fn event_loop(ep: WorkerEndpoint, backend: Backend, counters: WorkerCounters) {
    event_loop_traced(ep, backend, counters, Tracer::off())
}

/// [`event_loop`] with a trace sink (the traced fleet spawns this).
pub fn event_loop_traced(
    ep: WorkerEndpoint,
    backend: Backend,
    counters: WorkerCounters,
    tracer: Tracer,
) {
    let mut scratch = EncodeScratch::new();
    let mut backlog: VecDeque<Assignment> = VecDeque::new();
    let mut shutting_down = false;
    ep.send(ToCoord::Register { worker_id: ep.worker_id() });
    loop {
        // Block only when there is nothing to compute and no shutdown
        // pending; otherwise just drain what has already arrived so
        // control messages (Revoke, Shutdown) are seen before the next
        // compute.
        if backlog.is_empty() && !shutting_down {
            match ep.recv() {
                Ok(msg) => handle(msg, &mut backlog, &ep, &counters, &tracer, &mut shutting_down),
                Err(_) => break, // coordinator gone
            }
        }
        while let Some(msg) = ep.try_recv() {
            handle(msg, &mut backlog, &ep, &counters, &tracer, &mut shutting_down);
        }
        match backlog.pop_front() {
            Some(item) => {
                counters.busy.inc();
                process(item, &backend, &counters, &ep, &tracer, &mut scratch);
                counters.busy.dec();
                ep.send(ToCoord::Ready { worker_id: ep.worker_id() });
            }
            None => {
                if shutting_down {
                    break;
                }
            }
        }
    }
}

fn handle(
    msg: ToWorker,
    backlog: &mut VecDeque<Assignment>,
    ep: &WorkerEndpoint,
    counters: &WorkerCounters,
    tracer: &Tracer,
    shutting_down: &mut bool,
) {
    match msg {
        ToWorker::AssignLeaf(a) => backlog.push_back(a),
        ToWorker::Revoke { job_id, tasks } => {
            let before = backlog.len();
            let mut replying = 0usize;
            backlog.retain(|item| {
                let hit = item.job_id == job_id && tasks.contains(&item.task_id);
                if hit {
                    // Backlog purges count into `pool_items_revoked`
                    // exactly like the tier's central-queue purges, so
                    // they emit the same `revoke` event (the
                    // counter-vs-events equality in tests/obs_trace.rs
                    // covers both sites).
                    tracer.emit(EventKind::Revoke, job_id, item.task_id as u32, 0);
                    if item.fault != FaultAction::Fail {
                        replying += 1;
                    }
                }
                !hit
            });
            let purged = before - backlog.len();
            if purged > 0 {
                counters.revoked.add(purged as u64);
            }
            ep.send(ToCoord::RevokeAck { worker_id: ep.worker_id(), job_id, purged, replying });
        }
        ToWorker::Heartbeat { seq } => {
            ep.send(ToCoord::HeartbeatAck { worker_id: ep.worker_id(), seq });
        }
        ToWorker::Shutdown => *shutting_down = true,
    }
}

fn process(
    item: Assignment,
    backend: &Backend,
    counters: &WorkerCounters,
    ep: &WorkerEndpoint,
    tracer: &Tracer,
    scratch: &mut EncodeScratch,
) {
    let delay = match item.fault {
        FaultAction::Fail => {
            // Silently drop (the paper's model: a dead node never answers).
            counters.faulted.inc();
            return;
        }
        FaultAction::Delay(d) => Some(d),
        FaultAction::None => None,
    };
    // Worker-side encode span: detail = how many operands this worker
    // encodes itself (a cache-hit left arrives pre-encoded, so a leaf
    // whose job hit the cache records detail ≤ 1 — the invariant the
    // span-tree checker enforces).
    let encodes =
        u64::from(!item.left.is_encoded()) + u64::from(!item.right.is_encoded());
    tracer.emit(EventKind::Encode, item.job_id, item.task_id as u32, encodes);
    let t0 = Instant::now();
    let product = compute(backend, &item, scratch);
    tracer.emit(EventKind::Compute, item.job_id, item.task_id as u32, ep.worker_id() as u64);
    let reply = WorkerReply {
        job_id: item.job_id,
        task_id: item.task_id,
        product,
        compute_time: t0.elapsed(),
    };
    counters.executed.inc();
    let msg = ToCoord::LeafResult { worker_id: ep.worker_id(), reply };
    match delay {
        None => ep.send(msg),
        // Hand off to the delay line; this slot is free again now.
        Some(d) => ep.send_after(msg, d),
    }
}

fn compute(
    backend: &Backend,
    item: &Assignment,
    scratch: &mut EncodeScratch,
) -> Result<Matrix, String> {
    match backend {
        Backend::Native => {
            let EncodeScratch { left: sl, right: sr } = scratch;
            // A pre-encoded payload (coordinator cache hit) is used as
            // is; encode_operand_into is deterministic, so both routes
            // write bit-identical operands.
            let left: &Matrix = match &item.left {
                OperandPayload::Encoded(m) => m,
                OperandPayload::Blocks(a4) => {
                    encode_operand_into(sl, &to_int(&item.ca), a4);
                    sl
                }
            };
            let right: &Matrix = match &item.right {
                OperandPayload::Encoded(m) => m,
                OperandPayload::Blocks(b4) => {
                    encode_operand_into(sr, &to_int(&item.cb), b4);
                    sr
                }
            };
            Ok(left.matmul(right))
        }
        Backend::Pjrt(h) => {
            // The PJRT task protocol ships blocks; the tier never routes
            // cached encodes to this backend.
            let (OperandPayload::Blocks(a4), OperandPayload::Blocks(b4)) =
                (&item.left, &item.right)
            else {
                return Err("pre-encoded operands require the native backend".into());
            };
            // The Arc clones here bump refcounts; the blocks themselves
            // are shared with the tier's assignments, never copied.
            h.worker_task_tagged(item.job_id, item.ca, a4.clone(), item.cb, b4.clone())
        }
    }
}

fn to_int(c: &[f32; 4]) -> [i32; 4] {
    let mut out = [0i32; 4];
    for (o, &x) in out.iter_mut().zip(c.iter()) {
        *o = x as i32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blocked::split_blocks;

    fn blocks(seed: u64, n: usize) -> (Arc<[Matrix; 4]>, Arc<[Matrix; 4]>) {
        let mut rng = Rng::seeded(seed);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        (Arc::new(split_blocks(&a)), Arc::new(split_blocks(&b)))
    }

    fn assignment(
        job_id: u64,
        task_id: usize,
        a4: &Arc<[Matrix; 4]>,
        b4: &Arc<[Matrix; 4]>,
        fault: FaultAction,
    ) -> Assignment {
        Assignment {
            job_id,
            task_id,
            ca: [1.0, 0.0, 0.0, 0.0],
            cb: [1.0, 0.0, 0.0, 0.0],
            left: OperandPayload::Blocks(a4.clone()),
            right: OperandPayload::Blocks(b4.clone()),
            fault,
        }
    }

    /// Pump the fleet: deliver one assignment per Ready/Register until
    /// `n_results` LeafResults arrived or the queue runs dry.
    fn run_until(
        fleet: &WorkerFleet,
        queue: &mut VecDeque<Assignment>,
        n_results: usize,
        window: Duration,
    ) -> Vec<WorkerReply> {
        let mut out = Vec::new();
        let deadline = Instant::now() + window;
        while out.len() < n_results && Instant::now() < deadline {
            let msg = match fleet.recv_timeout(Duration::from_millis(50)) {
                Ok(m) => m,
                Err(_) => continue,
            };
            match msg {
                ToCoord::Register { worker_id } | ToCoord::Ready { worker_id } => {
                    if let Some(item) = queue.pop_front() {
                        fleet.send(worker_id, ToWorker::AssignLeaf(item)).unwrap();
                    }
                }
                ToCoord::LeafResult { reply, .. } => out.push(reply),
                _ => {}
            }
        }
        out
    }

    #[test]
    fn fleet_computes_products() {
        let fleet = WorkerFleet::spawn(4, Backend::Native, Registry::new());
        let (a4, b4) = blocks(1, 16);
        let mut queue: VecDeque<Assignment> =
            (0..4).map(|t| assignment(7, t, &a4, &b4, FaultAction::None)).collect();
        let replies = run_until(&fleet, &mut queue, 4, Duration::from_secs(10));
        assert_eq!(replies.len(), 4);
        let want = a4[0].matmul(&b4[0]);
        for r in replies {
            assert_eq!(r.job_id, 7);
            assert!(r.product.unwrap().approx_eq(&want, 1e-5));
        }
        fleet.shutdown();
    }

    #[test]
    fn encoded_payloads_skip_the_worker_encode_bit_exactly() {
        use crate::linalg::blocked::encode_operand;
        let fleet = WorkerFleet::spawn(1, Backend::Native, Registry::new());
        let (a4, b4) = blocks(8, 16);
        let ca = [1.0f32, -1.0, 0.0, 1.0];
        let cb = [1.0f32, 1.0, -1.0, 0.0];
        let pre = Arc::new(encode_operand(&to_int(&ca), &a4));
        let mut queue: VecDeque<Assignment> = VecDeque::new();
        // Task 0 ships blocks; task 1 ships the pre-encoded left operand.
        queue.push_back(Assignment {
            job_id: 1,
            task_id: 0,
            ca,
            cb,
            left: OperandPayload::Blocks(a4.clone()),
            right: OperandPayload::Blocks(b4.clone()),
            fault: FaultAction::None,
        });
        queue.push_back(Assignment {
            job_id: 1,
            task_id: 1,
            ca,
            cb,
            left: OperandPayload::Encoded(pre),
            right: OperandPayload::Blocks(b4.clone()),
            fault: FaultAction::None,
        });
        let mut replies = run_until(&fleet, &mut queue, 2, Duration::from_secs(10));
        assert_eq!(replies.len(), 2);
        replies.sort_by_key(|r| r.task_id);
        let x = replies[0].product.as_ref().unwrap();
        let y = replies[1].product.as_ref().unwrap();
        let bits = |m: &Matrix| m.as_slice().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(x), bits(y), "cached encode must be bit-identical");
        fleet.shutdown();
    }

    #[test]
    fn failed_nodes_never_send_results_but_still_report_ready() {
        let metrics = Registry::new();
        let fleet = WorkerFleet::spawn(1, Backend::Native, metrics.clone());
        let (a4, b4) = blocks(2, 8);
        let mut queue: VecDeque<Assignment> = VecDeque::new();
        queue.push_back(assignment(1, 0, &a4, &b4, FaultAction::Fail));
        queue.push_back(assignment(1, 1, &a4, &b4, FaultAction::None));
        // The faulted item produces no LeafResult, yet the worker's
        // Ready keeps the dispatch loop moving to the healthy item.
        let replies = run_until(&fleet, &mut queue, 1, Duration::from_secs(10));
        assert_eq!(replies.len(), 1);
        assert_eq!(replies[0].task_id, 1);
        assert_eq!(metrics.counter("pool_items_faulted").get(), 1);
        assert_eq!(metrics.counter("pool_items_executed").get(), 1);
        fleet.shutdown();
    }

    #[test]
    fn stragglers_reply_late_without_blocking_the_slot() {
        let fleet = WorkerFleet::spawn(1, Backend::Native, Registry::new());
        let (a4, b4) = blocks(3, 8);
        let t0 = Instant::now();
        let mut queue: VecDeque<Assignment> = VecDeque::new();
        queue.push_back(assignment(1, 0, &a4, &b4, FaultAction::Delay(Duration::from_millis(40))));
        queue.push_back(assignment(1, 1, &a4, &b4, FaultAction::None));
        let replies = run_until(&fleet, &mut queue, 2, Duration::from_secs(10));
        assert_eq!(replies.len(), 2);
        // The single slot is NOT blocked by the straggler: the second,
        // undelayed item must come back first.
        assert_eq!(replies[0].task_id, 1, "undelayed item should arrive first");
        assert_eq!(replies[1].task_id, 0);
        assert!(t0.elapsed() >= Duration::from_millis(40));
        assert!(replies[1].product.is_ok());
        fleet.shutdown();
    }

    #[test]
    fn revoke_purges_the_local_backlog_and_acks_exactly() {
        // Drive the event loop synchronously: queue three assignments, a
        // range revoke, and a shutdown before the loop starts, so the
        // drain order is deterministic. Tasks 1..3 are revoked; task 2
        // is an injected failure (would never have replied anyway).
        let metrics = Registry::new();
        let (mut transport, mut eps) = ChannelTransport::new(1);
        let ep = eps.pop().unwrap();
        let (a4, b4) = blocks(4, 8);
        for t in 0..3 {
            let fault = if t == 2 { FaultAction::Fail } else { FaultAction::None };
            transport.send(0, ToWorker::AssignLeaf(assignment(9, t, &a4, &b4, fault))).unwrap();
        }
        transport.send(0, ToWorker::Revoke { job_id: 9, tasks: 1..3 }).unwrap();
        transport.send(0, ToWorker::Heartbeat { seq: 5 }).unwrap();
        transport.send(0, ToWorker::Shutdown).unwrap();
        event_loop(ep, Backend::Native, WorkerCounters::from_registry(&metrics));
        let mut results = 0;
        let mut acked = None;
        let mut hb = None;
        while let Ok(msg) = transport.recv_timeout(Duration::from_millis(100)) {
            match msg {
                ToCoord::LeafResult { reply, .. } => {
                    assert_eq!(reply.task_id, 0, "only the unrevoked task runs");
                    results += 1;
                }
                ToCoord::RevokeAck { job_id, purged, replying, .. } => {
                    acked = Some((job_id, purged, replying));
                }
                ToCoord::HeartbeatAck { seq, .. } => hb = Some(seq),
                _ => {}
            }
        }
        assert_eq!(results, 1);
        assert_eq!(acked, Some((9, 2, 1)), "failure does not count as replying");
        assert_eq!(hb, Some(5));
        assert_eq!(metrics.counter("pool_items_revoked").get(), 2);
        transport.shutdown();
    }

    #[test]
    fn fault_plan_sampling_frequencies_are_the_exact_marginals() {
        // Regression: straggling used to be sampled conditionally after
        // non-failure, deflating P(Delay) to p_straggle·(1 − p_fail) =
        // 0.1875 here. The model's marginals are p_fail and p_straggle
        // themselves.
        let plan = FaultPlan {
            p_fail: 0.25,
            p_straggle: 0.25,
            delay: Duration::from_millis(1),
        };
        let mut rng = Rng::seeded(5);
        let n = 40_000;
        let mut fails = 0;
        let mut delays = 0;
        for _ in 0..n {
            match plan.sample(&mut rng) {
                FaultAction::Fail => fails += 1,
                FaultAction::Delay(_) => delays += 1,
                FaultAction::None => {}
            }
        }
        let pf = fails as f64 / n as f64;
        let pd = delays as f64 / n as f64;
        assert!((pf - 0.25).abs() < 0.01, "P(fail) {pf} != 0.25");
        assert!((pd - 0.25).abs() < 0.01, "P(delay) {pd} != 0.25");
    }

    #[test]
    fn fault_plan_none_draws_nothing_from_the_rng() {
        // FaultPlan::NONE must not consume RNG state: fault-free runs
        // keep historical RNG streams (and seeded reproducibility).
        let mut a = Rng::seeded(9);
        let mut b = Rng::seeded(9);
        for _ in 0..10 {
            assert_eq!(FaultPlan::NONE.sample(&mut a), FaultAction::None);
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fault_plan_straggle_only_hits_its_marginal() {
        let plan = FaultPlan {
            p_fail: 0.0,
            p_straggle: 0.4,
            delay: Duration::from_millis(1),
        };
        let mut rng = Rng::seeded(6);
        let n = 40_000;
        let delays = (0..n)
            .filter(|_| matches!(plan.sample(&mut rng), FaultAction::Delay(_)))
            .count();
        let pd = delays as f64 / n as f64;
        assert!((pd - 0.4).abs() < 0.01, "P(delay) {pd} != 0.4");
    }

    #[test]
    fn sample_at_is_a_pure_function_of_its_coordinates() {
        // Regression: `sample` draws from a shared stream, so a job's
        // pattern used to depend on how many draws earlier jobs made.
        // `sample_at` must give the same action for the same
        // (seed, job, item) no matter what was sampled before or since.
        let plan = FaultPlan {
            p_fail: 0.3,
            p_straggle: 0.3,
            delay: Duration::from_millis(2),
        };
        let snapshot: Vec<FaultAction> = (0..64)
            .flat_map(|job| (0..16).map(move |item| (job, item)))
            .map(|(job, item)| plan.sample_at(7, job, item))
            .collect();
        // Re-sample in reverse order, interleaved with unrelated draws.
        let mut rng = Rng::seeded(1);
        for (k, (job, item)) in (0..64)
            .flat_map(|job| (0..16).map(move |item| (job, item)))
            .enumerate()
            .collect::<Vec<_>>()
            .into_iter()
            .rev()
        {
            let _ = plan.sample(&mut rng); // unrelated history
            assert_eq!(plan.sample_at(7, job, item), snapshot[k], "job {job} item {item}");
        }
    }

    #[test]
    fn sample_at_varies_across_jobs_items_and_seeds() {
        let plan = FaultPlan {
            p_fail: 0.5,
            p_straggle: 0.0,
            delay: Duration::ZERO,
        };
        let pattern = |seed: u64, job: u64| -> Vec<FaultAction> {
            (0..64).map(|i| plan.sample_at(seed, job, i)).collect()
        };
        assert_ne!(pattern(1, 0), pattern(1, 1), "jobs must not share a pattern");
        assert_ne!(pattern(1, 0), pattern(2, 0), "seeds must not share a pattern");
        assert_eq!(pattern(3, 5), pattern(3, 5));
    }

    #[test]
    fn sample_at_hits_the_exact_marginals() {
        let plan = FaultPlan {
            p_fail: 0.25,
            p_straggle: 0.25,
            delay: Duration::from_millis(1),
        };
        let n = 40_000u64;
        let mut fails = 0;
        let mut delays = 0;
        for item in 0..n {
            match plan.sample_at(11, 0, item) {
                FaultAction::Fail => fails += 1,
                FaultAction::Delay(_) => delays += 1,
                FaultAction::None => {}
            }
        }
        let pf = fails as f64 / n as f64;
        let pd = delays as f64 / n as f64;
        assert!((pf - 0.25).abs() < 0.01, "P(fail) {pf} != 0.25");
        assert!((pd - 0.25).abs() < 0.01, "P(delay) {pd} != 0.25");
    }

    #[test]
    fn sample_at_none_is_free_and_deterministic() {
        for job in 0..4 {
            for item in 0..4 {
                assert_eq!(FaultPlan::NONE.sample_at(3, job, item), FaultAction::None);
            }
        }
    }
}
