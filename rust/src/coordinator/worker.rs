//! The worker fleet: a fixed set of OS threads draining one **shared**
//! work queue, so any idle slot picks up the next item regardless of
//! which job produced it. This is what lets the multiplexed scheduler
//! keep the fleet busy while individual jobs wait on stragglers.
//!
//! Fault injection happens at the node, exactly like the paper's model:
//! a failed node simply never answers; a straggler answers late. A
//! straggler is modeled as a *delayed response* (slow link / slow
//! node-to-master path): the product is computed, handed to a delay
//! line for deferred delivery, and the worker slot immediately picks up
//! the next item. Revoking a job purges its still-queued items so
//! cancelled work never occupies a slot.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::linalg::blocked::encode_operand_into;
use crate::linalg::matrix::Matrix;
use crate::metrics::{Counter, Gauge, Registry};
use crate::runtime::service::PjrtHandle;
use crate::sim::rng::Rng;

/// Compute backend for a worker's block product.
#[derive(Clone)]
pub enum Backend {
    /// Pure-Rust encode + blocked matmul in the worker thread.
    Native,
    /// The AOT Pallas artifact through the PJRT compute service.
    Pjrt(PjrtHandle),
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "Native"),
            Backend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

/// Per-dispatch fault decision (sampled by the scheduler's fault plan).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    None,
    /// Deliver the response this much later (straggler).
    Delay(Duration),
    /// Never respond (the paper's node failure).
    Fail,
}

/// Job-level fault plan: how to sample per-node actions. Failure and
/// straggling are mutually exclusive events with the exact marginal
/// probabilities the paper's model specifies: `P(Fail) = p_fail` and
/// `P(Delay) = p_straggle` (requires `p_fail + p_straggle <= 1`, which
/// [`crate::config::RunConfig::validate`] enforces for CLI runs).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// P(node fails) — the paper's p_e.
    pub p_fail: f64,
    /// P(node straggles by `delay`).
    pub p_straggle: f64,
    pub delay: Duration,
}

impl FaultPlan {
    pub const NONE: FaultPlan =
        FaultPlan { p_fail: 0.0, p_straggle: 0.0, delay: Duration::ZERO };

    /// Sample one node's fault action. A single uniform draw partitions
    /// `[0, 1)` into `[0, p_fail)` → fail, `[p_fail, p_fail +
    /// p_straggle)` → straggle, rest → healthy, so both marginals are
    /// exact. (An earlier version sampled straggling *conditionally
    /// after* non-failure, deflating the effective straggle probability
    /// to `p_straggle·(1 − p_fail)` and skewing every sim-vs-theory
    /// comparison that swept both parameters.)
    pub fn sample(&self, rng: &mut Rng) -> FaultAction {
        debug_assert!(
            self.p_fail + self.p_straggle <= 1.0,
            "fail/straggle are exclusive marginals: p_fail {} + p_straggle {} > 1 \
             silently truncates P(Delay)",
            self.p_fail,
            self.p_straggle
        );
        if self.p_fail <= 0.0 && self.p_straggle <= 0.0 {
            return FaultAction::None;
        }
        let u = rng.uniform();
        self.partition(u)
    }

    /// Sample the fault action of work item `item` of job `job_id` under
    /// master seed `seed` — a **pure function** of its three arguments.
    ///
    /// [`Self::sample`] draws from a shared stream, so a job's fault
    /// pattern depends on how many draws every earlier job made: any
    /// change in backend, pool size, or admission history shifts the
    /// stream and silently re-rolls every later job's faults. Here the
    /// coordinates are hashed (two rounds of the splitmix64 finalizer)
    /// into a private RNG seed and exactly one uniform is drawn, so the
    /// same `(seed, job_id, item)` yields the same action on every
    /// backend, thread count, and in-flight depth — the invariance
    /// `tests/multiplex.rs` and the scheduler regression tests pin.
    pub fn sample_at(&self, seed: u64, job_id: u64, item: u64) -> FaultAction {
        debug_assert!(
            self.p_fail + self.p_straggle <= 1.0,
            "fail/straggle are exclusive marginals: p_fail {} + p_straggle {} > 1 \
             silently truncates P(Delay)",
            self.p_fail,
            self.p_straggle
        );
        if self.p_fail <= 0.0 && self.p_straggle <= 0.0 {
            return FaultAction::None;
        }
        fn mix64(mut z: u64) -> u64 {
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        }
        let mixed = mix64(seed ^ mix64(job_id ^ mix64(item)));
        self.partition(Rng::seeded(mixed).uniform())
    }

    fn partition(&self, u: f64) -> FaultAction {
        if u < self.p_fail {
            FaultAction::Fail
        } else if u < self.p_fail + self.p_straggle {
            FaultAction::Delay(self.delay)
        } else {
            FaultAction::None
        }
    }
}

/// One unit of work for a node.
pub struct WorkItem {
    pub job_id: u64,
    pub task_id: usize,
    pub ca: [f32; 4],
    pub cb: [f32; 4],
    pub a4: Arc<[Matrix; 4]>,
    pub b4: Arc<[Matrix; 4]>,
    pub fault: FaultAction,
    pub reply: Sender<WorkerReply>,
}

/// A node's answer.
#[derive(Debug)]
pub struct WorkerReply {
    pub job_id: u64,
    pub task_id: usize,
    pub product: Result<Matrix, String>,
    pub compute_time: Duration,
}

struct PoolShared {
    queue: Mutex<VecDeque<WorkItem>>,
    available: Condvar,
    shutdown: AtomicBool,
}

#[derive(Clone)]
struct PoolCounters {
    executed: Arc<Counter>,
    faulted: Arc<Counter>,
    revoked: Arc<Counter>,
    busy: Arc<Gauge>,
    queued: Arc<Gauge>,
}

/// Fixed fleet of worker nodes over one shared queue.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    delay_tx: Option<Sender<Delayed>>,
    delay_handle: Option<JoinHandle<()>>,
    counters: PoolCounters,
}

impl WorkerPool {
    /// Spawn `n` nodes on the given backend, recording fleet metrics
    /// (`pool_*` counters/gauges) into `metrics`.
    pub fn spawn(n: usize, backend: Backend, metrics: Registry) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let counters = PoolCounters {
            executed: metrics.counter("pool_items_executed"),
            faulted: metrics.counter("pool_items_faulted"),
            revoked: metrics.counter("pool_items_revoked"),
            busy: metrics.gauge("pool_busy_workers"),
            queued: metrics.gauge("pool_queue_depth"),
        };
        let (delay_tx, delay_rx) = channel::<Delayed>();
        let delay_handle = std::thread::Builder::new()
            .name("delay-line".into())
            .spawn(move || delay_loop(delay_rx))
            .expect("spawn delay line");
        let mut handles = Vec::with_capacity(n);
        for node in 0..n {
            let shared = shared.clone();
            let backend = backend.clone();
            let counters = counters.clone();
            let delay_tx = delay_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{node}"))
                .spawn(move || node_loop(shared, backend, counters, delay_tx))
                .expect("spawn worker");
            handles.push(handle);
        }
        WorkerPool {
            shared,
            handles,
            delay_tx: Some(delay_tx),
            delay_handle: Some(delay_handle),
            counters,
        }
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue one item; any idle worker picks it up.
    pub fn submit(&self, item: WorkItem) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(item);
        self.counters.queued.set(q.len() as u64);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Cancel a job: purge its still-queued items so straggler-freed
    /// slots immediately pick up other jobs' work. Items already being
    /// computed (or sitting in the delay line) still produce replies;
    /// the scheduler drops those by `job_id`. Returns the purge count.
    pub fn revoke(&self, job_id: u64) -> usize {
        let mut q = self.shared.queue.lock().unwrap();
        let before = q.len();
        q.retain(|item| item.job_id != job_id);
        let removed = before - q.len();
        self.counters.queued.set(q.len() as u64);
        drop(q);
        if removed > 0 {
            self.counters.revoked.add(removed as u64);
        }
        removed
    }

    /// Cancel one job's still-queued items within a task-id range — the
    /// group-level cancellation of nested dispatch: once a group's inner
    /// span is recovered, its remaining leaf items are dead work.
    ///
    /// Returns `(removed, would_have_replied)`: the total purge count
    /// and how many of the purged items would have produced a reply
    /// (i.e. were not injected failures) — what the job's
    /// expected-reply accounting must be debited by. Items already
    /// being computed (or in the delay line) still reply; the job state
    /// ignores replies for closed groups.
    pub fn revoke_range(
        &self,
        job_id: u64,
        tasks: std::ops::Range<usize>,
    ) -> (usize, usize) {
        let mut q = self.shared.queue.lock().unwrap();
        let before = q.len();
        let mut replying = 0usize;
        q.retain(|item| {
            let hit = item.job_id == job_id && tasks.contains(&item.task_id);
            if hit && item.fault != FaultAction::Fail {
                replying += 1;
            }
            !hit
        });
        let removed = before - q.len();
        self.counters.queued.set(q.len() as u64);
        drop(q);
        if removed > 0 {
            self.counters.revoked.add(removed as u64);
        }
        (removed, replying)
    }

    /// Graceful shutdown: close the queue and join every thread.
    pub fn shutdown(mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // All worker-held delay senders are gone once workers joined;
        // dropping ours lets the delay line flush and exit.
        drop(self.delay_tx.take());
        if let Some(h) = self.delay_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // If shutdown() was not called, unblock the threads so they can
        // exit; do not join in drop (avoids teardown hangs).
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
    }
}

/// Per-worker-thread reusable encode scratch: the two encoded operands
/// are written into these buffers ([`encode_operand_into`]) instead of
/// allocating two fresh matrices per task — after the first item of a
/// given block size the native encode path allocates nothing but the
/// product it ships back.
struct EncodeScratch {
    left: Matrix,
    right: Matrix,
}

impl EncodeScratch {
    fn new() -> EncodeScratch {
        EncodeScratch { left: Matrix::zeros(0, 0), right: Matrix::zeros(0, 0) }
    }
}

fn node_loop(
    shared: Arc<PoolShared>,
    backend: Backend,
    counters: PoolCounters,
    delay_tx: Sender<Delayed>,
) {
    let mut scratch = EncodeScratch::new();
    loop {
        let item = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(item) = q.pop_front() {
                    counters.queued.set(q.len() as u64);
                    break Some(item);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        let Some(item) = item else { break };
        counters.busy.inc();
        process(item, &backend, &counters, &delay_tx, &mut scratch);
        counters.busy.dec();
    }
}

fn process(
    item: WorkItem,
    backend: &Backend,
    counters: &PoolCounters,
    delay_tx: &Sender<Delayed>,
    scratch: &mut EncodeScratch,
) {
    let delay = match item.fault {
        FaultAction::Fail => {
            // Silently drop (the paper's model: a dead node never answers).
            counters.faulted.inc();
            return;
        }
        FaultAction::Delay(d) => Some(d),
        FaultAction::None => None,
    };
    let t0 = Instant::now();
    let product = compute(backend, &item, scratch);
    let reply = WorkerReply {
        job_id: item.job_id,
        task_id: item.task_id,
        product,
        compute_time: t0.elapsed(),
    };
    counters.executed.inc();
    match delay {
        None => {
            let _ = item.reply.send(reply);
        }
        Some(d) => {
            // Hand off to the delay line; this slot is free again now.
            let _ = delay_tx.send(Delayed {
                due: Instant::now() + d,
                reply,
                out: item.reply,
            });
        }
    }
}

fn compute(
    backend: &Backend,
    item: &WorkItem,
    scratch: &mut EncodeScratch,
) -> Result<Matrix, String> {
    match backend {
        Backend::Native => {
            let ica = to_int(&item.ca);
            let icb = to_int(&item.cb);
            encode_operand_into(&mut scratch.left, &ica, &item.a4);
            encode_operand_into(&mut scratch.right, &icb, &item.b4);
            Ok(scratch.left.matmul(&scratch.right))
        }
        // The Arc clones here bump refcounts; the blocks themselves are
        // shared with the scheduler's work items, never copied.
        Backend::Pjrt(h) => h.worker_task_tagged(
            item.job_id,
            item.ca,
            item.a4.clone(),
            item.cb,
            item.b4.clone(),
        ),
    }
}

fn to_int(c: &[f32; 4]) -> [i32; 4] {
    let mut out = [0i32; 4];
    for (o, &x) in out.iter_mut().zip(c.iter()) {
        *o = x as i32;
    }
    out
}

// --- straggler delay line -----------------------------------------------

struct Delayed {
    due: Instant,
    reply: WorkerReply,
    out: Sender<WorkerReply>,
}

struct HeapEntry {
    due: Instant,
    seq: u64,
    reply: WorkerReply,
    out: Sender<WorkerReply>,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-due first.
        other.due.cmp(&self.due).then(other.seq.cmp(&self.seq))
    }
}

fn delay_loop(rx: Receiver<Delayed>) {
    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    let mut seq = 0u64;
    loop {
        let now = Instant::now();
        while heap.peek().is_some_and(|e| e.due <= now) {
            let e = heap.pop().unwrap();
            let _ = e.out.send(e.reply);
        }
        let msg = match heap.peek() {
            Some(e) => rx.recv_timeout(e.due.saturating_duration_since(Instant::now())),
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
        };
        match msg {
            Ok(d) => {
                seq += 1;
                heap.push(HeapEntry { due: d.due, seq, reply: d.reply, out: d.out });
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Pool is shutting down: flush what is left immediately
                // (receivers are usually gone; send errors are fine).
                for e in heap.into_sorted_vec() {
                    let _ = e.out.send(e.reply);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blocked::split_blocks;

    fn blocks(seed: u64, n: usize) -> (Arc<[Matrix; 4]>, Arc<[Matrix; 4]>) {
        let mut rng = Rng::seeded(seed);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        (Arc::new(split_blocks(&a)), Arc::new(split_blocks(&b)))
    }

    fn item(
        job_id: u64,
        task_id: usize,
        a4: &Arc<[Matrix; 4]>,
        b4: &Arc<[Matrix; 4]>,
        fault: FaultAction,
        tx: &Sender<WorkerReply>,
    ) -> WorkItem {
        WorkItem {
            job_id,
            task_id,
            ca: [1.0, 0.0, 0.0, 0.0],
            cb: [1.0, 0.0, 0.0, 0.0],
            a4: a4.clone(),
            b4: b4.clone(),
            fault,
            reply: tx.clone(),
        }
    }

    #[test]
    fn pool_computes_products() {
        let pool = WorkerPool::spawn(4, Backend::Native, Registry::new());
        let (a4, b4) = blocks(1, 16);
        let (tx, rx) = channel();
        for task_id in 0..4 {
            pool.submit(item(7, task_id, &a4, &b4, FaultAction::None, &tx));
        }
        drop(tx);
        let want = a4[0].matmul(&b4[0]);
        let mut got = 0;
        while let Ok(reply) = rx.recv() {
            assert_eq!(reply.job_id, 7);
            assert!(reply.product.unwrap().approx_eq(&want, 1e-5));
            got += 1;
        }
        assert_eq!(got, 4);
        pool.shutdown();
    }

    #[test]
    fn failed_nodes_never_reply() {
        let pool = WorkerPool::spawn(2, Backend::Native, Registry::new());
        let (a4, b4) = blocks(2, 8);
        let (tx, rx) = channel();
        pool.submit(item(1, 0, &a4, &b4, FaultAction::Fail, &tx));
        pool.submit(item(1, 1, &a4, &b4, FaultAction::None, &tx));
        drop(tx);
        let replies: Vec<WorkerReply> = rx.iter().collect();
        assert_eq!(replies.len(), 1);
        assert_eq!(replies[0].task_id, 1);
        pool.shutdown();
    }

    #[test]
    fn stragglers_reply_late_without_blocking_the_slot() {
        let pool = WorkerPool::spawn(1, Backend::Native, Registry::new());
        let (a4, b4) = blocks(3, 8);
        let (tx, rx) = channel();
        let t0 = Instant::now();
        pool.submit(item(1, 0, &a4, &b4, FaultAction::Delay(Duration::from_millis(40)), &tx));
        // The single slot is NOT blocked by the straggler: a second,
        // undelayed item must come back first.
        pool.submit(item(1, 1, &a4, &b4, FaultAction::None, &tx));
        drop(tx);
        let first = rx.recv().unwrap();
        assert_eq!(first.task_id, 1, "undelayed item should arrive first");
        let second = rx.recv().unwrap();
        assert_eq!(second.task_id, 0);
        assert!(t0.elapsed() >= Duration::from_millis(40));
        assert!(second.product.is_ok());
        pool.shutdown();
    }

    #[test]
    fn revoke_purges_queued_items() {
        // Zero workers: everything stays queued, so revocation is exact.
        let metrics = Registry::new();
        let pool = WorkerPool::spawn(0, Backend::Native, metrics.clone());
        let (a4, b4) = blocks(4, 8);
        let (tx, _rx) = channel();
        for task_id in 0..3 {
            pool.submit(item(9, task_id, &a4, &b4, FaultAction::None, &tx));
        }
        pool.submit(item(10, 0, &a4, &b4, FaultAction::None, &tx));
        assert_eq!(pool.revoke(9), 3);
        assert_eq!(metrics.counter("pool_items_revoked").get(), 3);
        assert_eq!(metrics.gauge("pool_queue_depth").get(), 1);
        assert_eq!(pool.revoke(9), 0, "idempotent");
        pool.shutdown();
    }

    #[test]
    fn revoke_range_purges_only_the_group_and_reports_replying() {
        // Zero workers: everything stays queued, so revocation is exact.
        let metrics = Registry::new();
        let pool = WorkerPool::spawn(0, Backend::Native, metrics.clone());
        let (a4, b4) = blocks(5, 8);
        let (tx, _rx) = channel();
        // Job 9: tasks 0..6; tasks 2..4 are "group 1"; task 3 is an
        // injected failure (would never have replied anyway).
        for task_id in 0..6 {
            let fault = if task_id == 3 { FaultAction::Fail } else { FaultAction::None };
            pool.submit(item(9, task_id, &a4, &b4, fault, &tx));
        }
        pool.submit(item(10, 2, &a4, &b4, FaultAction::None, &tx));
        let (removed, replying) = pool.revoke_range(9, 2..4);
        assert_eq!(removed, 2);
        assert_eq!(replying, 1, "the injected failure does not count");
        assert_eq!(metrics.gauge("pool_queue_depth").get(), 5);
        assert_eq!(pool.revoke_range(9, 2..4), (0, 0), "idempotent");
        // Other jobs' items with ids in the range are untouched.
        assert_eq!(pool.revoke(10), 1);
        pool.shutdown();
    }

    #[test]
    fn fault_plan_sampling_frequencies_are_the_exact_marginals() {
        // Regression: straggling used to be sampled conditionally after
        // non-failure, deflating P(Delay) to p_straggle·(1 − p_fail) =
        // 0.1875 here. The model's marginals are p_fail and p_straggle
        // themselves.
        let plan = FaultPlan {
            p_fail: 0.25,
            p_straggle: 0.25,
            delay: Duration::from_millis(1),
        };
        let mut rng = Rng::seeded(5);
        let n = 40_000;
        let mut fails = 0;
        let mut delays = 0;
        for _ in 0..n {
            match plan.sample(&mut rng) {
                FaultAction::Fail => fails += 1,
                FaultAction::Delay(_) => delays += 1,
                FaultAction::None => {}
            }
        }
        let pf = fails as f64 / n as f64;
        let pd = delays as f64 / n as f64;
        assert!((pf - 0.25).abs() < 0.01, "P(fail) {pf} != 0.25");
        assert!((pd - 0.25).abs() < 0.01, "P(delay) {pd} != 0.25");
    }

    #[test]
    fn fault_plan_none_draws_nothing_from_the_rng() {
        // FaultPlan::NONE must not consume RNG state: fault-free runs
        // keep historical RNG streams (and seeded reproducibility).
        let mut a = Rng::seeded(9);
        let mut b = Rng::seeded(9);
        for _ in 0..10 {
            assert_eq!(FaultPlan::NONE.sample(&mut a), FaultAction::None);
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fault_plan_straggle_only_hits_its_marginal() {
        let plan = FaultPlan {
            p_fail: 0.0,
            p_straggle: 0.4,
            delay: Duration::from_millis(1),
        };
        let mut rng = Rng::seeded(6);
        let n = 40_000;
        let delays = (0..n)
            .filter(|_| matches!(plan.sample(&mut rng), FaultAction::Delay(_)))
            .count();
        let pd = delays as f64 / n as f64;
        assert!((pd - 0.4).abs() < 0.01, "P(delay) {pd} != 0.4");
    }

    #[test]
    fn sample_at_is_a_pure_function_of_its_coordinates() {
        // Regression: `sample` draws from a shared stream, so a job's
        // pattern used to depend on how many draws earlier jobs made.
        // `sample_at` must give the same action for the same
        // (seed, job, item) no matter what was sampled before or since.
        let plan = FaultPlan {
            p_fail: 0.3,
            p_straggle: 0.3,
            delay: Duration::from_millis(2),
        };
        let snapshot: Vec<FaultAction> = (0..64)
            .flat_map(|job| (0..16).map(move |item| (job, item)))
            .map(|(job, item)| plan.sample_at(7, job, item))
            .collect();
        // Re-sample in reverse order, interleaved with unrelated draws.
        let mut rng = Rng::seeded(1);
        for (k, (job, item)) in (0..64)
            .flat_map(|job| (0..16).map(move |item| (job, item)))
            .enumerate()
            .collect::<Vec<_>>()
            .into_iter()
            .rev()
        {
            let _ = plan.sample(&mut rng); // unrelated history
            assert_eq!(plan.sample_at(7, job, item), snapshot[k], "job {job} item {item}");
        }
    }

    #[test]
    fn sample_at_varies_across_jobs_items_and_seeds() {
        let plan = FaultPlan {
            p_fail: 0.5,
            p_straggle: 0.0,
            delay: Duration::ZERO,
        };
        let pattern = |seed: u64, job: u64| -> Vec<FaultAction> {
            (0..64).map(|i| plan.sample_at(seed, job, i)).collect()
        };
        assert_ne!(pattern(1, 0), pattern(1, 1), "jobs must not share a pattern");
        assert_ne!(pattern(1, 0), pattern(2, 0), "seeds must not share a pattern");
        assert_eq!(pattern(3, 5), pattern(3, 5));
    }

    #[test]
    fn sample_at_hits_the_exact_marginals() {
        let plan = FaultPlan {
            p_fail: 0.25,
            p_straggle: 0.25,
            delay: Duration::from_millis(1),
        };
        let n = 40_000u64;
        let mut fails = 0;
        let mut delays = 0;
        for item in 0..n {
            match plan.sample_at(11, 0, item) {
                FaultAction::Fail => fails += 1,
                FaultAction::Delay(_) => delays += 1,
                FaultAction::None => {}
            }
        }
        let pf = fails as f64 / n as f64;
        let pd = delays as f64 / n as f64;
        assert!((pf - 0.25).abs() < 0.01, "P(fail) {pf} != 0.25");
        assert!((pd - 0.25).abs() < 0.01, "P(delay) {pd} != 0.25");
    }

    #[test]
    fn sample_at_none_is_free_and_deterministic() {
        for job in 0..4 {
            for item in 0..4 {
                assert_eq!(FaultPlan::NONE.sample_at(3, job, item), FaultAction::None);
            }
        }
    }
}
