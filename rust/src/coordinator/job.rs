//! Per-job decode state machine: one [`JobState`] per in-flight multiply
//! job, keyed by `job_id`. The scheduler routes each [`WorkerReply`] to
//! its job's state; the job tracks an incremental [`SpanDecoder`], the
//! finished products, and its deadline, and knows how to assemble the
//! final C matrix once (if) the four output targets are spanned.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::decoder::SpanDecoder;
use crate::coordinator::task::TaskGraph;
use crate::coordinator::worker::{Backend, WorkerReply};
use crate::linalg::blocked::join_blocks;
use crate::linalg::matrix::Matrix;
use crate::runtime::artifact::DECODE_SLOTS;

/// Outcome report for one multiply job.
#[derive(Clone, Debug)]
pub struct MultiplyReport {
    pub job_id: u64,
    pub n: usize,
    pub scheme: String,
    /// Wall time from admission (dispatch) to completion.
    pub elapsed: Duration,
    /// Time from dispatch until the output became decodable.
    pub time_to_decodable: Option<Duration>,
    pub dispatched: usize,
    /// Successful replies incorporated into the decode state.
    pub finished: usize,
    /// Faults injected at dispatch time.
    pub injected_failures: usize,
    pub injected_stragglers: usize,
    /// True if the deadline passed and the master computed locally.
    pub fell_back: bool,
}

/// One in-flight job's complete decode state.
pub struct JobState {
    pub job_id: u64,
    pub n: usize,
    /// Operand blocks, shared with the dispatched work items (no second
    /// copy per in-flight job); the local-fallback path reassembles the
    /// operands from these.
    pub a4: Arc<[Matrix; 4]>,
    pub b4: Arc<[Matrix; 4]>,
    /// When the job was submitted (queue wait starts here).
    pub enqueued: Instant,
    /// When the job was admitted and its items dispatched.
    pub started: Instant,
    pub deadline: Instant,
    decoder: SpanDecoder,
    products: Vec<Option<Matrix>>,
    pub finished: usize,
    /// Backend errors (count as node failures for decoding).
    pub errors: usize,
    pub dispatched: usize,
    pub injected_failures: usize,
    pub injected_stragglers: usize,
    pub time_to_decodable: Option<Duration>,
}

impl JobState {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        graph: &TaskGraph,
        job_id: u64,
        a4: Arc<[Matrix; 4]>,
        b4: Arc<[Matrix; 4]>,
        enqueued: Instant,
        started: Instant,
        deadline: Instant,
        injected_failures: usize,
        injected_stragglers: usize,
    ) -> JobState {
        let n = 2 * a4[0].rows();
        JobState {
            job_id,
            n,
            a4,
            b4,
            enqueued,
            started,
            deadline,
            decoder: graph.decoder(),
            products: vec![None; graph.num_tasks()],
            finished: 0,
            errors: 0,
            dispatched: graph.num_tasks(),
            injected_failures,
            injected_stragglers,
            time_to_decodable: None,
        }
    }

    /// Replies that can still arrive (injected failures never answer).
    pub fn expected_replies(&self) -> usize {
        self.dispatched - self.injected_failures
    }

    /// No more replies are coming for this job.
    pub fn all_replies_in(&self) -> bool {
        self.finished + self.errors >= self.expected_replies()
    }

    pub fn is_decodable(&self) -> bool {
        self.decoder.is_decodable()
    }

    /// Fold one worker reply into the decode state. Duplicate replies
    /// for an already-recorded task are ignored.
    pub fn on_reply(&mut self, reply: WorkerReply) {
        debug_assert_eq!(reply.job_id, self.job_id);
        match reply.product {
            Ok(m) => {
                if self.products[reply.task_id].is_some() {
                    return;
                }
                self.products[reply.task_id] = Some(m);
                self.finished += 1;
                if self.decoder.on_finished(reply.task_id) && self.time_to_decodable.is_none() {
                    self.time_to_decodable = Some(self.started.elapsed());
                }
            }
            Err(_) => self.errors += 1,
        }
    }

    /// Weighted-sum assembly of C from the finished products (requires
    /// decodability). Uses the PJRT decode artifact when available,
    /// native axpy otherwise.
    pub fn assemble(&self, backend: &Backend) -> Result<Matrix, String> {
        let bs = self.n / 2;
        let outcome = self.decoder.solve().ok_or("assemble called before decodable")?;
        let weight_sets: Vec<Vec<f32>> = (0..4)
            .map(|t| outcome.weights[t].iter().map(|&w| w as f32).collect())
            .collect();
        if let (Backend::Pjrt(h), true) = (backend, self.products.len() <= DECODE_SLOTS) {
            // One round-trip: the product stack is shipped and staged as
            // a literal once, all four C blocks come back together.
            let blocks = h.decode_combine_multi(weight_sets, self.products.clone(), bs)?;
            let mut it = blocks.into_iter();
            let four: [Matrix; 4] = std::array::from_fn(|_| it.next().unwrap());
            return Ok(join_blocks(&four));
        }
        let mut blocks: Vec<Matrix> = Vec::with_capacity(4);
        for weights in &weight_sets {
            let mut out = Matrix::zeros(bs, bs);
            for (i, p) in self.products.iter().enumerate() {
                if weights[i] != 0.0 {
                    let m = p
                        .as_ref()
                        .ok_or_else(|| format!("weight on unfinished task {i}"))?;
                    out.axpy(weights[i], m);
                }
            }
            blocks.push(out);
        }
        let mut it = blocks.into_iter();
        let four: [Matrix; 4] = std::array::from_fn(|_| it.next().unwrap());
        Ok(join_blocks(&four))
    }

    /// Local fallback: reassemble the operands from the shared blocks
    /// and multiply densely (bit-identical to multiplying the original
    /// operands — `join_blocks ∘ split_blocks` is the identity).
    pub fn fallback_product(&self) -> Matrix {
        join_blocks(&self.a4).matmul(&join_blocks(&self.b4))
    }

    pub fn report(&self, scheme: &str, fell_back: bool) -> MultiplyReport {
        MultiplyReport {
            job_id: self.job_id,
            n: self.n,
            scheme: scheme.to_string(),
            elapsed: self.started.elapsed(),
            time_to_decodable: self.time_to_decodable,
            dispatched: self.dispatched,
            finished: self.finished,
            injected_failures: self.injected_failures,
            injected_stragglers: self.injected_stragglers,
            fell_back,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::scheme::TaskSet;
    use crate::sim::rng::Rng;

    fn reply(job_id: u64, task_id: usize, m: Matrix) -> WorkerReply {
        WorkerReply { job_id, task_id, product: Ok(m), compute_time: Duration::ZERO }
    }

    #[test]
    fn state_machine_tracks_decodability_and_counts() {
        use crate::linalg::blocked::{encode_operand, split_blocks};
        let graph = TaskGraph::new(TaskSet::strassen_winograd(2));
        let mut rng = Rng::seeded(1);
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        let now = Instant::now();
        let mut job = JobState::new(
            &graph,
            3,
            Arc::new(a4.clone()),
            Arc::new(b4.clone()),
            now,
            now,
            now + Duration::from_secs(5),
            2,
            1,
        );
        assert_eq!(job.n, 8);
        assert_eq!(job.expected_replies(), 14);
        assert!(!job.is_decodable());
        assert!(
            job.fallback_product().approx_eq(&a.matmul(&b), 1e-6),
            "fallback reassembles the operands"
        );

        for spec in &graph.specs {
            let ica: [i32; 4] = std::array::from_fn(|i| spec.ca[i] as i32);
            let icb: [i32; 4] = std::array::from_fn(|i| spec.cb[i] as i32);
            let p = encode_operand(&ica, &a4).matmul(&encode_operand(&icb, &b4));
            job.on_reply(reply(3, spec.id, p));
        }
        assert!(job.is_decodable());
        assert_eq!(job.finished, 16);
        assert!(job.time_to_decodable.is_some());
        let c = job.assemble(&Backend::Native).unwrap();
        assert!(c.approx_eq(&a.matmul(&b), 1e-4), "rel {}", c.rel_error(&a.matmul(&b)));
        let r = job.report("sw+2psmm", false);
        assert_eq!(r.dispatched, 16);
        assert_eq!(r.injected_failures, 2);
        assert_eq!(r.injected_stragglers, 1);
        assert!(!r.fell_back);
    }

    fn zero_blocks(bs: usize) -> Arc<[Matrix; 4]> {
        Arc::new(std::array::from_fn(|_| Matrix::zeros(bs, bs)))
    }

    #[test]
    fn duplicate_replies_are_ignored() {
        let graph = TaskGraph::new(TaskSet::strassen_winograd(0));
        let now = Instant::now();
        let mut job = JobState::new(
            &graph,
            1,
            zero_blocks(2),
            zero_blocks(2),
            now,
            now,
            now + Duration::from_secs(1),
            0,
            0,
        );
        job.on_reply(reply(1, 0, Matrix::zeros(2, 2)));
        job.on_reply(reply(1, 0, Matrix::zeros(2, 2)));
        assert_eq!(job.finished, 1);
    }

    #[test]
    fn backend_errors_count_toward_exhaustion() {
        let graph = TaskGraph::new(TaskSet::strassen_winograd(0));
        let now = Instant::now();
        let mut job = JobState::new(
            &graph,
            1,
            zero_blocks(2),
            zero_blocks(2),
            now,
            now,
            now + Duration::from_secs(1),
            0,
            0,
        );
        for t in 0..graph.num_tasks() {
            job.on_reply(WorkerReply {
                job_id: 1,
                task_id: t,
                product: Err("boom".into()),
                compute_time: Duration::ZERO,
            });
        }
        assert!(job.all_replies_in());
        assert!(!job.is_decodable());
        assert_eq!(job.errors, 14);
    }
}
