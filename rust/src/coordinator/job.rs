//! Per-job decode state machine: one [`JobState`] per in-flight multiply
//! job, keyed by `job_id`.
//!
//! The scheduler routes each [`WorkerReply`] to its job's state; the job
//! tracks timing, reply accounting, and one of two decode structures:
//!
//! * **Flat** (the paper's single-level model) — an incremental
//!   [`SpanDecoder`] over the task set; the job is decodable once the
//!   four `C_ij` targets are spanned, and `assemble` combines finished
//!   products with the exact decode weights.
//! * **Nested** (two-level schemes, [`crate::coding::nested`]) — the
//!   **two-stage decoder**: every outer group `g` has its own inner
//!   span decoder over that group's leaf products; the moment a group's
//!   inner span covers its four targets, the group's product
//!   `P_g = L_g · R_g` is recovered (inner solve + block join) and fed
//!   to the *outer* decoder as `on_finished(g)`. The job is decodable
//!   once the recovered groups span the outer targets. Group recoveries
//!   are consumed **incrementally**: in eager mode (`collect_all` off)
//!   [`JobState::on_reply`] returns the newly-recovered group's leaf-id
//!   range so the scheduler can cancel the group's outstanding items;
//!   with `collect_all` on, matrix assembly is deferred to
//!   [`JobState::assemble`] so the decode set — and therefore the output
//!   bits — depend only on the injected faults, never on thread timing.
//!
//! Reply accounting is uniform across both shapes: a job has exhausted
//! its replies when `finished + errors` reaches `dispatched − injected
//! failures − mid-job revocations` ([`JobState::all_replies_in`]), which
//! is what lets the scheduler finish undecodable jobs early instead of
//! waiting out the deadline.

use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::decoder::SpanDecoder;
use crate::coordinator::task::DispatchPlan;
use crate::coordinator::worker::{Backend, WorkerReply};
use crate::linalg::blocked::join_blocks;
use crate::linalg::matrix::Matrix;
use crate::obs::{EventKind, Tracer, NO_LEAF};
use crate::runtime::artifact::DECODE_SLOTS;

/// Outcome report for one multiply job.
#[derive(Clone, Debug)]
pub struct MultiplyReport {
    pub job_id: u64,
    pub n: usize,
    pub scheme: String,
    /// Wall time from admission (dispatch) to completion.
    pub elapsed: Duration,
    /// Time from dispatch until the output became decodable.
    pub time_to_decodable: Option<Duration>,
    pub dispatched: usize,
    /// Successful worker replies received (for nested jobs this counts
    /// leaf replies, including late ones for already-recovered groups).
    pub finished: usize,
    /// Faults injected at dispatch time.
    pub injected_failures: usize,
    pub injected_stragglers: usize,
    /// True if the deadline passed and the master computed locally.
    pub fell_back: bool,
}

/// One inner group's decode state (nested jobs only).
struct GroupDecode {
    decoder: SpanDecoder,
    products: Vec<Option<Matrix>>,
    /// Still accepting replies? Cleared when the group is recovered
    /// eagerly (its remaining items are then revoked).
    open: bool,
    /// Has this group been reported to the outer decoder?
    registered: bool,
}

/// Decode structure of a job: single-level span decoding, or the
/// two-stage nested decoder.
enum Decode {
    Flat {
        decoder: SpanDecoder,
        products: Vec<Option<Matrix>>,
    },
    Nested {
        group_size: usize,
        groups: Vec<GroupDecode>,
        outer: SpanDecoder,
        outer_products: Vec<Option<Matrix>>,
        /// Recover groups (and request cancellation) the moment their
        /// inner span closes. Off under `collect_all`, where assembly
        /// is deferred so outputs are bit-reproducible.
        eager: bool,
    },
}

/// One in-flight job's complete decode state.
pub struct JobState {
    pub job_id: u64,
    pub n: usize,
    /// Operand blocks, shared with the dispatched work items (no second
    /// copy per in-flight job); the local-fallback path reassembles the
    /// operands from these.
    pub a4: Arc<[Matrix; 4]>,
    pub b4: Arc<[Matrix; 4]>,
    /// When the job was submitted (queue wait starts here).
    pub enqueued: Instant,
    /// When the job was admitted and its items dispatched.
    pub started: Instant,
    pub deadline: Instant,
    decode: Decode,
    pub finished: usize,
    /// Backend errors (count as node failures for decoding).
    pub errors: usize,
    pub dispatched: usize,
    pub injected_failures: usize,
    pub injected_stragglers: usize,
    /// Replies that will never arrive because their items were revoked
    /// mid-job (group cancellation).
    revoked: usize,
    pub time_to_decodable: Option<Duration>,
    /// Trace sink for group-recovery events (off unless the owning
    /// tier installed one via [`Self::set_tracer`]).
    tracer: Tracer,
}

impl JobState {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        plan: &DispatchPlan,
        job_id: u64,
        a4: Arc<[Matrix; 4]>,
        b4: Arc<[Matrix; 4]>,
        enqueued: Instant,
        started: Instant,
        deadline: Instant,
        injected_failures: usize,
        injected_stragglers: usize,
        eager: bool,
    ) -> JobState {
        let n = 2 * a4[0].rows();
        let decode = match plan {
            DispatchPlan::Flat(g) => Decode::Flat {
                decoder: g.decoder(),
                products: vec![None; g.num_tasks()],
            },
            DispatchPlan::Nested(g) => Decode::Nested {
                group_size: g.group_size(),
                groups: (0..g.num_groups())
                    .map(|_| GroupDecode {
                        decoder: g.inner.decoder(),
                        products: vec![None; g.group_size()],
                        open: true,
                        registered: false,
                    })
                    .collect(),
                outer: g.outer.decoder(),
                outer_products: vec![None; g.num_groups()],
                eager,
            },
        };
        JobState {
            job_id,
            n,
            a4,
            b4,
            enqueued,
            started,
            deadline,
            decode,
            finished: 0,
            errors: 0,
            dispatched: plan.num_work_items(),
            injected_failures,
            injected_stragglers,
            revoked: 0,
            time_to_decodable: None,
            tracer: Tracer::off(),
        }
    }

    /// Install the owning tier's tracer so group recoveries show up in
    /// the job's span tree.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Replies that can still arrive (injected failures never answer;
    /// revoked items were purged from the queue before execution).
    pub fn expected_replies(&self) -> usize {
        self.dispatched - self.injected_failures - self.revoked
    }

    /// No more replies are coming for this job.
    pub fn all_replies_in(&self) -> bool {
        self.finished + self.errors >= self.expected_replies()
    }

    /// Debit the expected-reply count after a mid-job revocation purged
    /// `n` would-have-replied items from the work queue.
    pub fn note_revoked(&mut self, n: usize) {
        self.revoked += n;
    }

    pub fn is_decodable(&self) -> bool {
        match &self.decode {
            Decode::Flat { decoder, .. } => decoder.is_decodable(),
            Decode::Nested { outer, .. } => outer.is_decodable(),
        }
    }

    /// Outer groups recovered so far (0 for flat jobs).
    pub fn groups_recovered(&self) -> usize {
        match &self.decode {
            Decode::Flat { .. } => 0,
            Decode::Nested { groups, .. } => {
                groups.iter().filter(|g| g.registered).count()
            }
        }
    }

    /// Fold one worker reply into the decode state. Duplicate replies
    /// for an already-recorded task are ignored.
    ///
    /// Returns the leaf-id range of a group that was *just* recovered
    /// eagerly (nested jobs only) — the scheduler revokes that range
    /// from the work queue and debits the purge via [`Self::note_revoked`].
    pub fn on_reply(&mut self, reply: WorkerReply) -> Option<Range<usize>> {
        debug_assert_eq!(reply.job_id, self.job_id);
        let n = self.n;
        match &mut self.decode {
            Decode::Flat { decoder, products } => {
                match reply.product {
                    Ok(m) => {
                        if products[reply.task_id].is_some() {
                            return None;
                        }
                        products[reply.task_id] = Some(m);
                        self.finished += 1;
                        if decoder.on_finished(reply.task_id)
                            && self.time_to_decodable.is_none()
                        {
                            self.time_to_decodable = Some(self.started.elapsed());
                        }
                    }
                    Err(_) => self.errors += 1,
                }
                None
            }
            Decode::Nested { group_size, groups, outer, outer_products, eager } => {
                let m = match reply.product {
                    Ok(m) => m,
                    Err(_) => {
                        self.errors += 1;
                        return None;
                    }
                };
                let g = reply.task_id / *group_size;
                let j = reply.task_id % *group_size;
                let grp = &mut groups[g];
                if !grp.open {
                    // The group is already recovered; the reply still
                    // counts toward exhaustion accounting.
                    self.finished += 1;
                    return None;
                }
                if grp.products[j].is_some() {
                    return None;
                }
                grp.products[j] = Some(m);
                self.finished += 1;
                if grp.decoder.on_finished(j) && !grp.registered {
                    grp.registered = true;
                    self.tracer.emit(EventKind::GroupRecover, self.job_id, NO_LEAF, g as u64);
                    if outer.on_finished(g) && self.time_to_decodable.is_none() {
                        self.time_to_decodable = Some(self.started.elapsed());
                    }
                    if *eager {
                        // Combine the group's borrowed leaf products
                        // straight into its P_g buffer (no per-block
                        // temporaries, no clones).
                        let mut pg = Matrix::zeros(n / 2, n / 2);
                        grp.decoder
                            .combine_into(&grp.products, &mut pg)
                            .expect("inner solve after decodability");
                        outer_products[g] = Some(pg);
                        grp.open = false;
                        grp.products = Vec::new();
                        return Some(g * *group_size..(g + 1) * *group_size);
                    }
                }
                None
            }
        }
    }

    /// Weighted-sum assembly of C from the finished products (requires
    /// decodability), combined straight into the per-job output buffer
    /// from **borrowed** product slices — the decode path performs zero
    /// matrix clones per solve (pinned by `tests/decode_alloc.rs`).
    /// Flat jobs use the PJRT decode artifact when available (the
    /// product stack is serialized once into the wire buffer instead of
    /// cloning every product); nested jobs first recover any deferred
    /// groups (inner solves), then solve the outer span.
    pub fn assemble(&mut self, backend: &Backend) -> Result<Matrix, String> {
        let n = self.n;
        match &mut self.decode {
            Decode::Flat { decoder, products } => {
                let bs = n / 2;
                if let (Backend::Pjrt(h), true) = (backend, products.len() <= DECODE_SLOTS) {
                    let outcome =
                        decoder.solve().ok_or("assemble called before decodable")?;
                    let weight_sets: Vec<Vec<f32>> = (0..4)
                        .map(|t| outcome.weights[t].iter().map(|&w| w as f32).collect())
                        .collect();
                    // One round-trip: the handle borrows the products,
                    // serializes them once into the wire stack (no
                    // Matrix clones), stages the stack as a literal
                    // once, and all four C blocks come back together.
                    let blocks = h.decode_combine_multi(weight_sets, products, bs)?;
                    let mut it = blocks.into_iter();
                    let four: [Matrix; 4] = std::array::from_fn(|_| it.next().unwrap());
                    return Ok(join_blocks(&four));
                }
                let mut out = Matrix::zeros(n, n);
                decoder.combine_into(products, &mut out)?;
                Ok(out)
            }
            Decode::Nested { groups, outer, outer_products, .. } => {
                // Recover groups whose assembly was deferred
                // (collect_all mode, or a race between decodability and
                // completion).
                for (g, grp) in groups.iter().enumerate() {
                    if outer_products[g].is_none() && grp.decoder.is_decodable() {
                        let mut pg = Matrix::zeros(n / 2, n / 2);
                        grp.decoder.combine_into(&grp.products, &mut pg)?;
                        outer_products[g] = Some(pg);
                    }
                }
                let mut out = Matrix::zeros(n, n);
                outer.combine_into(outer_products, &mut out)?;
                Ok(out)
            }
        }
    }

    /// Local fallback: reassemble the operands from the shared blocks
    /// and multiply densely (bit-identical to multiplying the original
    /// operands — `join_blocks ∘ split_blocks` is the identity).
    pub fn fallback_product(&self) -> Matrix {
        join_blocks(&self.a4).matmul(&join_blocks(&self.b4))
    }

    pub fn report(&self, scheme: &str, fell_back: bool) -> MultiplyReport {
        MultiplyReport {
            job_id: self.job_id,
            n: self.n,
            scheme: scheme.to_string(),
            elapsed: self.started.elapsed(),
            time_to_decodable: self.time_to_decodable,
            dispatched: self.dispatched,
            finished: self.finished,
            injected_failures: self.injected_failures,
            injected_stragglers: self.injected_stragglers,
            fell_back,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::nested::NestedTaskSet;
    use crate::coding::scheme::TaskSet;
    use crate::coordinator::task::{NestedGraph, TaskGraph};
    use crate::linalg::blocked::{encode_operand, split_blocks};
    use crate::sim::rng::Rng;

    fn reply(job_id: u64, task_id: usize, m: Matrix) -> WorkerReply {
        WorkerReply { job_id, task_id, product: Ok(m), compute_time: Duration::ZERO }
    }

    fn flat_job(
        graph: &TaskGraph,
        job_id: u64,
        a4: Arc<[Matrix; 4]>,
        b4: Arc<[Matrix; 4]>,
        injected_failures: usize,
        injected_stragglers: usize,
    ) -> JobState {
        let now = Instant::now();
        JobState::new(
            &DispatchPlan::Flat(graph.clone()),
            job_id,
            a4,
            b4,
            now,
            now,
            now + Duration::from_secs(5),
            injected_failures,
            injected_stragglers,
            true,
        )
    }

    #[test]
    fn state_machine_tracks_decodability_and_counts() {
        let graph = TaskGraph::new(TaskSet::strassen_winograd(2));
        let mut rng = Rng::seeded(1);
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        let mut job =
            flat_job(&graph, 3, Arc::new(a4.clone()), Arc::new(b4.clone()), 2, 1);
        assert_eq!(job.n, 8);
        assert_eq!(job.expected_replies(), 14);
        assert!(!job.is_decodable());
        assert_eq!(job.groups_recovered(), 0);
        assert!(
            job.fallback_product().approx_eq(&a.matmul(&b), 1e-6),
            "fallback reassembles the operands"
        );

        for spec in &graph.specs {
            let p = encode_operand(&spec.int_ca(), &a4)
                .matmul(&encode_operand(&spec.int_cb(), &b4));
            assert!(job.on_reply(reply(3, spec.id, p)).is_none());
        }
        assert!(job.is_decodable());
        assert_eq!(job.finished, 16);
        assert!(job.time_to_decodable.is_some());
        let c = job.assemble(&Backend::Native).unwrap();
        assert!(c.approx_eq(&a.matmul(&b), 1e-4), "rel {}", c.rel_error(&a.matmul(&b)));
        let r = job.report("sw+2psmm", false);
        assert_eq!(r.dispatched, 16);
        assert_eq!(r.injected_failures, 2);
        assert_eq!(r.injected_stragglers, 1);
        assert!(!r.fell_back);
    }

    fn zero_blocks(bs: usize) -> Arc<[Matrix; 4]> {
        Arc::new(std::array::from_fn(|_| Matrix::zeros(bs, bs)))
    }

    #[test]
    fn duplicate_replies_are_ignored() {
        let graph = TaskGraph::new(TaskSet::strassen_winograd(0));
        let mut job = flat_job(&graph, 1, zero_blocks(2), zero_blocks(2), 0, 0);
        job.on_reply(reply(1, 0, Matrix::zeros(2, 2)));
        job.on_reply(reply(1, 0, Matrix::zeros(2, 2)));
        assert_eq!(job.finished, 1);
    }

    #[test]
    fn backend_errors_count_toward_exhaustion() {
        let graph = TaskGraph::new(TaskSet::strassen_winograd(0));
        let mut job = flat_job(&graph, 1, zero_blocks(2), zero_blocks(2), 0, 0);
        for t in 0..graph.num_tasks() {
            job.on_reply(WorkerReply {
                job_id: 1,
                task_id: t,
                product: Err("boom".into()),
                compute_time: Duration::ZERO,
            });
        }
        assert!(job.all_replies_in());
        assert!(!job.is_decodable());
        assert_eq!(job.errors, 14);
    }

    /// Compute the leaf product (g, j) exactly as a nested worker would:
    /// inner-encode the blocks of the outer-encoded operands.
    fn leaf_product(
        graph: &NestedGraph,
        a4: &[Matrix; 4],
        b4: &[Matrix; 4],
        g: usize,
        j: usize,
    ) -> Matrix {
        let lo = encode_operand(&graph.outer.specs[g].int_ca(), a4);
        let ro = encode_operand(&graph.outer.specs[g].int_cb(), b4);
        let li = encode_operand(&graph.inner.specs[j].int_ca(), &split_blocks(&lo));
        let ri = encode_operand(&graph.inner.specs[j].int_cb(), &split_blocks(&ro));
        li.matmul(&ri)
    }

    fn nested_job(graph: &NestedGraph, eager: bool) -> (JobState, Matrix, Matrix) {
        let mut rng = Rng::seeded(9);
        // Small-integer operands: every intermediate is exactly
        // representable in f32, so decode equality is bit-exact.
        let n = 8;
        let a = Matrix::from_fn(n, n, |_, _| (rng.below(7) as f32) - 3.0);
        let b = Matrix::from_fn(n, n, |_, _| (rng.below(7) as f32) - 3.0);
        let now = Instant::now();
        let job = JobState::new(
            &DispatchPlan::Nested(graph.clone()),
            1,
            Arc::new(split_blocks(&a)),
            Arc::new(split_blocks(&b)),
            now,
            now,
            now + Duration::from_secs(5),
            0,
            0,
            eager,
        );
        (job, a, b)
    }

    #[test]
    fn nested_two_stage_decode_recovers_exactly() {
        let graph = NestedGraph::new(NestedTaskSet::compose(
            TaskSet::strassen_winograd(2),
            TaskSet::strassen_winograd(2),
        ));
        let (mut job, a, b) = nested_job(&graph, true);
        assert_eq!(job.dispatched, 256);
        let a4 = split_blocks(&join_blocks(&job.a4));
        let b4 = split_blocks(&join_blocks(&job.b4));
        let m2 = graph.group_size();
        // Deliver every leaf; eager mode must revoke each group's
        // remaining items exactly once, right when its span closes.
        let mut revokes = 0;
        for g in 0..graph.num_groups() {
            for j in 0..m2 {
                let p = leaf_product(&graph, &a4, &b4, g, j);
                if let Some(range) = job.on_reply(reply(1, g * m2 + j, p)) {
                    assert_eq!(range, graph.group_range(g));
                    revokes += 1;
                }
            }
        }
        assert_eq!(revokes, graph.num_groups());
        assert_eq!(job.groups_recovered(), graph.num_groups());
        assert!(job.is_decodable());
        let c = job.assemble(&Backend::Native).unwrap();
        assert_eq!(c.as_slice(), a.matmul(&b).as_slice(), "integer decode is exact");
    }

    #[test]
    fn nested_deferred_mode_assembles_at_the_end() {
        let graph = NestedGraph::new(NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(0),
        ));
        let (mut job, a, b) = nested_job(&graph, false);
        let a4 = split_blocks(&join_blocks(&job.a4));
        let b4 = split_blocks(&join_blocks(&job.b4));
        let m2 = graph.group_size();
        for g in 0..graph.num_groups() {
            for j in 0..m2 {
                let p = leaf_product(&graph, &a4, &b4, g, j);
                assert!(
                    job.on_reply(reply(1, g * m2 + j, p)).is_none(),
                    "deferred mode never requests revocation"
                );
            }
        }
        assert!(job.is_decodable());
        assert!(job.all_replies_in());
        let c = job.assemble(&Backend::Native).unwrap();
        assert_eq!(c.as_slice(), a.matmul(&b).as_slice());
    }

    #[test]
    fn nested_revocation_accounting_reaches_exhaustion() {
        let graph = NestedGraph::new(NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(0),
        ));
        let (mut job, _a, _b) = nested_job(&graph, true);
        let a4 = split_blocks(&join_blocks(&job.a4));
        let b4 = split_blocks(&join_blocks(&job.b4));
        let m2 = graph.group_size();
        // Deliver replies group by group, stopping at the reply that
        // closes each group's span; credit the rest of the group as
        // revoked, exactly as the scheduler does after a queue purge.
        for g in 0..graph.num_groups() {
            for j in 0..m2 {
                let p = leaf_product(&graph, &a4, &b4, g, j);
                if let Some(range) = job.on_reply(reply(1, g * m2 + j, p)) {
                    // Pretend the queue still held the rest of the group.
                    let remaining = range.end - (g * m2 + j + 1);
                    job.note_revoked(remaining);
                    break;
                }
            }
        }
        assert!(job.all_replies_in(), "revocation must debit expected replies");
        assert!(job.is_decodable());
    }
}
