//! Message transport between the serving tier and its workers.
//!
//! The [`Transport`] trait is the coordinator's *only* view of the
//! fleet: send a [`ToWorker`] to endpoint `i`, receive the next
//! [`ToCoord`] from anyone. The protocol types carry no channel or
//! thread handles, so a socket transport can implement the same trait
//! over the [`crate::coordinator::proto::wire`] codec without touching
//! the tier.
//!
//! [`ChannelTransport`] is the in-process, dependency-free
//! implementation: one mpsc mailbox per worker, one shared return
//! channel, and a **delay line** modelling slow links — a worker can ask
//! for a message to be delivered `d` later ([`WorkerEndpoint::send_after`]),
//! which is how stragglers reply late without ever blocking a worker
//! slot.

use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver, RecvError, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::proto::{ToCoord, ToWorker};

/// Coordinator-side view of a worker fleet's message fabric.
pub trait Transport {
    /// Number of worker endpoints this transport was built with.
    fn num_workers(&self) -> usize;

    /// Deliver `msg` to worker `worker`'s mailbox. On failure (endpoint
    /// gone) the message is handed back so the caller can requeue it.
    fn send(&self, worker: usize, msg: ToWorker) -> Result<(), ToWorker>;

    /// Receive the next worker message, waiting at most `timeout`.
    fn recv_timeout(&self, timeout: Duration) -> Result<ToCoord, RecvTimeoutError>;

    /// Release transport resources (join helper threads). Called once,
    /// after every worker endpoint has been dropped.
    fn shutdown(&mut self) {}
}

/// In-process transport over std mpsc channels.
pub struct ChannelTransport {
    mailboxes: Vec<Sender<ToWorker>>,
    coord_rx: Receiver<ToCoord>,
    delay_handle: Option<JoinHandle<()>>,
}

impl ChannelTransport {
    /// Build the fabric for `n` workers: the coordinator keeps the
    /// [`ChannelTransport`]; each [`WorkerEndpoint`] moves into its
    /// worker's event loop.
    pub fn new(n: usize) -> (ChannelTransport, Vec<WorkerEndpoint>) {
        let (coord_tx, coord_rx) = channel::<ToCoord>();
        let (delay_tx, delay_rx) = channel::<Delayed>();
        let delay_handle = std::thread::Builder::new()
            .name("delay-line".into())
            .spawn(move || delay_loop(delay_rx))
            .expect("spawn delay line");
        let mut mailboxes = Vec::with_capacity(n);
        let mut endpoints = Vec::with_capacity(n);
        for worker_id in 0..n {
            let (tx, rx) = channel::<ToWorker>();
            mailboxes.push(tx);
            endpoints.push(WorkerEndpoint {
                worker_id,
                rx,
                tx: coord_tx.clone(),
                delay_tx: delay_tx.clone(),
            });
        }
        // `coord_tx`/`delay_tx` clones live only in the endpoints: once
        // every worker exits, the return channel and the delay line see
        // disconnect and wind down on their own.
        (ChannelTransport { mailboxes, coord_rx, delay_handle: Some(delay_handle) }, endpoints)
    }
}

impl Transport for ChannelTransport {
    fn num_workers(&self) -> usize {
        self.mailboxes.len()
    }

    fn send(&self, worker: usize, msg: ToWorker) -> Result<(), ToWorker> {
        match self.mailboxes.get(worker) {
            Some(tx) => tx.send(msg).map_err(|e| e.0),
            None => Err(msg),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<ToCoord, RecvTimeoutError> {
        self.coord_rx.recv_timeout(timeout)
    }

    fn shutdown(&mut self) {
        if let Some(h) = self.delay_handle.take() {
            let _ = h.join();
        }
    }
}

/// Worker-side half of the fabric: a mailbox to drain and a way to
/// answer — immediately or through the delay line (the slow-link
/// straggler model: the reply is late, the slot is not).
pub struct WorkerEndpoint {
    worker_id: usize,
    rx: Receiver<ToWorker>,
    tx: Sender<ToCoord>,
    delay_tx: Sender<Delayed>,
}

impl WorkerEndpoint {
    pub fn worker_id(&self) -> usize {
        self.worker_id
    }

    /// Block until the next coordinator message; `Err` means the
    /// coordinator is gone and the event loop should exit.
    pub fn recv(&self) -> Result<ToWorker, RecvError> {
        self.rx.recv()
    }

    /// Drain one already-delivered message without blocking.
    pub fn try_recv(&self) -> Option<ToWorker> {
        self.rx.try_recv().ok()
    }

    /// Send a message to the coordinator. Errors (coordinator gone
    /// during teardown) are deliberately ignored.
    pub fn send(&self, msg: ToCoord) {
        let _ = self.tx.send(msg);
    }

    /// Deliver `msg` to the coordinator `delay` from now, via the
    /// transport's delay line. Returns immediately.
    pub fn send_after(&self, msg: ToCoord, delay: Duration) {
        let _ = self.delay_tx.send(Delayed {
            due: Instant::now() + delay,
            msg,
            out: self.tx.clone(),
        });
    }
}

// --- straggler delay line -----------------------------------------------

struct Delayed {
    due: Instant,
    msg: ToCoord,
    out: Sender<ToCoord>,
}

struct HeapEntry {
    due: Instant,
    seq: u64,
    msg: ToCoord,
    out: Sender<ToCoord>,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-due first.
        other.due.cmp(&self.due).then(other.seq.cmp(&self.seq))
    }
}

fn delay_loop(rx: Receiver<Delayed>) {
    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    let mut seq = 0u64;
    loop {
        let now = Instant::now();
        while heap.peek().is_some_and(|e| e.due <= now) {
            let e = heap.pop().unwrap();
            let _ = e.out.send(e.msg);
        }
        let msg = match heap.peek() {
            Some(e) => rx.recv_timeout(e.due.saturating_duration_since(Instant::now())),
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
        };
        match msg {
            Ok(d) => {
                seq += 1;
                heap.push(HeapEntry { due: d.due, seq, msg: d.msg, out: d.out });
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Every endpoint is gone: flush what is left immediately
                // (receivers are usually gone too; send errors are fine).
                for e in heap.into_sorted_vec() {
                    let _ = e.out.send(e.msg);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_flow_both_ways() {
        let (mut t, mut eps) = ChannelTransport::new(2);
        assert_eq!(t.num_workers(), 2);
        t.send(1, ToWorker::Heartbeat { seq: 5 }).unwrap();
        let got = eps[1].try_recv().unwrap();
        assert!(matches!(got, ToWorker::Heartbeat { seq: 5 }));
        assert!(eps[0].try_recv().is_none(), "mailboxes are per-worker");
        eps[0].send(ToCoord::Register { worker_id: 0 });
        match t.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToCoord::Register { worker_id } => assert_eq!(worker_id, 0),
            other => panic!("unexpected {other:?}"),
        }
        drop(eps.drain(..));
        t.shutdown();
    }

    #[test]
    fn send_to_dead_endpoint_returns_the_message() {
        let (mut t, eps) = ChannelTransport::new(1);
        drop(eps);
        let back = t.send(0, ToWorker::Heartbeat { seq: 1 }).unwrap_err();
        assert!(matches!(back, ToWorker::Heartbeat { seq: 1 }));
        let back = t.send(7, ToWorker::Shutdown).unwrap_err();
        assert!(matches!(back, ToWorker::Shutdown), "out-of-range endpoint");
        t.shutdown();
    }

    #[test]
    fn delay_line_defers_but_preserves_delivery() {
        let (mut t, mut eps) = ChannelTransport::new(1);
        let ep = eps.pop().unwrap();
        let t0 = Instant::now();
        ep.send_after(ToCoord::Ready { worker_id: 0 }, Duration::from_millis(40));
        ep.send(ToCoord::Register { worker_id: 0 });
        // The undelayed message must arrive first.
        match t.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToCoord::Register { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        match t.recv_timeout(Duration::from_secs(1)).unwrap() {
            ToCoord::Ready { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(40));
        drop(ep);
        t.shutdown();
    }

    #[test]
    fn delay_line_flushes_pending_messages_on_disconnect() {
        let (mut t, mut eps) = ChannelTransport::new(1);
        let ep = eps.pop().unwrap();
        ep.send_after(ToCoord::Ready { worker_id: 0 }, Duration::from_secs(30));
        // Dropping the endpoint disconnects the delay line, which must
        // flush the far-future message instead of sleeping it out.
        drop(ep);
        match t.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToCoord::Ready { .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        t.shutdown();
    }
}
