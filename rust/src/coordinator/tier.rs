//! The message-driven serving tier: tenant fair queuing, dispatch-round
//! batching, and an encoded-operand cache over a [`WorkerFleet`].
//!
//! This is the coordinator half of the protocol split. All scheduling
//! state lives here — per-tenant admission queues, the central dispatch
//! queue, per-job decode state — and the only thing shared with the
//! workers is the message stream itself ([`crate::coordinator::proto`]).
//! Dispatch is **pull-based**: a worker announces itself with `Register`
//! and reports `Ready` after every processed item, and the tier hands
//! out exactly one `AssignLeaf` per free slot. Because at most one
//! assignment is ever at a worker, revocation accounting stays exact and
//! synchronous at the tier (purging the central queue); the `Revoke`
//! broadcast to workers is protocol completeness for transports that
//! buffer more deeply, and its `RevokeAck` debits any worker-side purges.
//!
//! **Admission** is deficit round robin: each tenant has a weight (its
//! quantum) and a quota (max in-flight jobs). The round-robin cursor
//! stays on the tenant it is serving until its deficit is spent, its
//! queue drains, or its quota blocks — so over any window the admitted
//! job shares track the configured weights exactly, even when in-flight
//! slots free one at a time. **Batching** coalesces the admitted jobs of
//! one `admit_ready` pass into dispatch rounds of `batch_window` jobs,
//! so a burst of tiny requests is encoded and enqueued as one round
//! rather than interleaving with replies. **Caching** keys the four left
//! operand blocks by content hash and keeps their per-task encodes in an
//! LRU ([`EncodedCache`]); a hit ships
//! [`OperandPayload::Encoded`] and the worker skips its own encode —
//! bit-identically, since the encode kernel is deterministic.
//!
//! Determinism: job ids are assigned at submission, faults are a pure
//! function of `(seed, job_id, item)`, and under
//! [`MasterConfig::collect_all`] the decode set depends only on the
//! injected faults — so seeded runs decode bit-identically across
//! depth, pool size, tenant layout, batch window, and cache setting
//! (pinned by `tests/serving_tier.rs` against an in-test synchronous
//! reference).

use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::scheme::TaskSet;
use crate::coordinator::job::JobState;
use crate::coordinator::master::MasterConfig;
use crate::coordinator::proto::{Assignment, JobDone, OperandPayload, ToCoord, ToWorker};
use crate::coordinator::task::DispatchPlan;
use crate::coordinator::worker::{Backend, FaultAction, WorkerFleet, WorkerReply};
use crate::linalg::blocked::{encode_operand, encode_operand_into, split_blocks};
use crate::linalg::matrix::Matrix;
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::obs::{EventKind, Tracer, NO_LEAF};

/// Liveness-probe cadence while the tier is polling with jobs in flight.
const HEARTBEAT_EVERY: Duration = Duration::from_millis(300);

/// The single source of truth for every metric name the serving-tier
/// stack records (tier, worker fleet, server facade). Recording sites
/// use these consts — never ad-hoc string literals — and the
/// `metric_names_all_in_table` test fails on any name that escapes the
/// table, so a typo cannot silently fork a metric family.
pub mod names {
    pub const CACHE_HITS: &str = "cache_hits";
    pub const CACHE_MISSES: &str = "cache_misses";
    pub const CACHE_EVICTIONS: &str = "cache_evictions";
    pub const CACHE_ENTRIES: &str = "cache_entries";
    pub const JOBS_CANCELLED: &str = "jobs_cancelled";
    pub const JOBS_DISPATCHED: &str = "jobs_dispatched";
    pub const JOBS_COMPLETED: &str = "jobs_completed";
    pub const JOBS_FELL_BACK: &str = "jobs_fell_back";
    pub const JOBS_FAILED: &str = "jobs_failed";
    pub const BATCH_ROUNDS: &str = "batch_rounds";
    pub const BATCHED_JOBS: &str = "batched_jobs";
    pub const POOL_QUEUE_DEPTH: &str = "pool_queue_depth";
    pub const POOL_ITEMS_REVOKED: &str = "pool_items_revoked";
    pub const POOL_ITEMS_EXECUTED: &str = "pool_items_executed";
    pub const POOL_ITEMS_FAULTED: &str = "pool_items_faulted";
    pub const POOL_BUSY_WORKERS: &str = "pool_busy_workers";
    pub const WORKERS_LIVE: &str = "workers_live";
    pub const WORKER_COMPUTE: &str = "worker_compute";
    pub const WORKER_ERRORS: &str = "worker_errors";
    pub const HEARTBEATS_SENT: &str = "heartbeats_sent";
    pub const HEARTBEAT_ACKS: &str = "heartbeat_acks";
    pub const GROUP_ITEMS_CANCELLED: &str = "group_items_cancelled";
    pub const GROUPS_RECOVERED: &str = "groups_recovered";
    pub const REPLIES_STALE_DROPPED: &str = "replies_stale_dropped";
    pub const JOB_LATENCY: &str = "job_latency";
    pub const QUEUE_WAIT: &str = "queue_wait";
    pub const INFLIGHT_JOBS: &str = "inflight_jobs";
    pub const PENDING_JOBS: &str = "pending_jobs";
    /// Dynamic per-tenant families: `<prefix><tenant name>`.
    pub const TENANT_JOBS_PREFIX: &str = "tenant_jobs_";
    pub const TENANT_LATENCY_PREFIX: &str = "tenant_latency_";
    pub const TENANT_QUEUE_PREFIX: &str = "tenant_queue_";

    /// Every fixed metric name.
    pub const ALL: &[&str] = &[
        CACHE_HITS,
        CACHE_MISSES,
        CACHE_EVICTIONS,
        CACHE_ENTRIES,
        JOBS_CANCELLED,
        JOBS_DISPATCHED,
        JOBS_COMPLETED,
        JOBS_FELL_BACK,
        JOBS_FAILED,
        BATCH_ROUNDS,
        BATCHED_JOBS,
        POOL_QUEUE_DEPTH,
        POOL_ITEMS_REVOKED,
        POOL_ITEMS_EXECUTED,
        POOL_ITEMS_FAULTED,
        POOL_BUSY_WORKERS,
        WORKERS_LIVE,
        WORKER_COMPUTE,
        WORKER_ERRORS,
        HEARTBEATS_SENT,
        HEARTBEAT_ACKS,
        GROUP_ITEMS_CANCELLED,
        GROUPS_RECOVERED,
        REPLIES_STALE_DROPPED,
        JOB_LATENCY,
        QUEUE_WAIT,
        INFLIGHT_JOBS,
        PENDING_JOBS,
    ];

    /// Prefixes of the dynamic (per-tenant) families.
    pub const DYNAMIC_PREFIXES: &[&str] =
        &[TENANT_JOBS_PREFIX, TENANT_LATENCY_PREFIX, TENANT_QUEUE_PREFIX];

    /// Is `name` a registered metric name (fixed or dynamic family)?
    pub fn is_known(name: &str) -> bool {
        ALL.contains(&name) || DYNAMIC_PREFIXES.iter().any(|p| name.starts_with(p))
    }
}

/// A tenant's admission-control contract.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// DRR quantum: relative share of admitted jobs under contention.
    pub weight: u64,
    /// Maximum in-flight jobs for this tenant (admission skips the
    /// tenant while it is at quota; its queue keeps accumulating).
    pub quota: usize,
}

impl TenantSpec {
    pub fn new(name: &str, weight: u64, quota: usize) -> TenantSpec {
        TenantSpec { name: name.to_string(), weight, quota }
    }

    /// Weight-1, unlimited-quota tenant (the single-tenant default).
    pub fn unbounded(name: &str) -> TenantSpec {
        TenantSpec::new(name, 1, usize::MAX)
    }

    /// Parse the CLI form `name:weight:quota` (e.g. `free:1:4`).
    pub fn parse(s: &str) -> Result<TenantSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            return Err(format!("tenant spec {s:?}: expected name:weight:quota"));
        }
        let name = parts[0];
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Err(format!(
                "tenant spec {s:?}: name must be non-empty [A-Za-z0-9_-]"
            ));
        }
        let weight: u64 = parts[1]
            .parse()
            .map_err(|_| format!("tenant spec {s:?}: bad weight {:?}", parts[1]))?;
        if weight == 0 {
            return Err(format!("tenant spec {s:?}: weight must be >= 1"));
        }
        let quota: usize = parts[2]
            .parse()
            .map_err(|_| format!("tenant spec {s:?}: bad quota {:?}", parts[2]))?;
        if quota == 0 {
            return Err(format!("tenant spec {s:?}: quota must be >= 1"));
        }
        Ok(TenantSpec::new(name, weight, quota))
    }
}

impl std::str::FromStr for TenantSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<TenantSpec, String> {
        TenantSpec::parse(s)
    }
}

/// Serving-tier configuration.
#[derive(Clone, Debug)]
pub struct TierConfig {
    /// Per-job policy (deadline, fault plan, seed, fallback, decode mode).
    pub master: MasterConfig,
    /// Maximum concurrently in-flight jobs across all tenants (≥ 1).
    pub depth: usize,
    /// Maximum queued-but-not-admitted jobs across all tenants.
    pub queue_cap: usize,
    /// Tenant roster; empty means one unbounded `"default"` tenant.
    pub tenants: Vec<TenantSpec>,
    /// Jobs coalesced into one dispatch round (≥ 1). Chunks dispatch
    /// only — it never caps admission or skews DRR shares.
    pub batch_window: usize,
    /// Encoded-operand cache capacity in distinct left operands
    /// (0 disables the cache).
    pub cache_cap: usize,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            master: MasterConfig::default(),
            depth: 1,
            queue_cap: usize::MAX,
            tenants: vec![TenantSpec::unbounded("default")],
            batch_window: 1,
            cache_cap: 0,
        }
    }
}

/// A submitted-but-not-admitted job in a tenant's queue.
struct PendingJob {
    job_id: u64,
    a: Matrix,
    b: Matrix,
    enqueued: Instant,
    /// Explicit per-item fault script (tests / replay); `None` samples
    /// pure per-item faults at admission.
    faults: Option<Vec<FaultAction>>,
}

struct TenantState {
    spec: TenantSpec,
    queue: VecDeque<PendingJob>,
    /// DRR deficit in jobs; refilled by one quantum (= weight) each time
    /// the cursor arrives at this tenant, capped at 8 quanta so a
    /// quota-blocked tenant cannot bank an unbounded burst.
    deficit: u64,
    inflight: usize,
    jobs: Arc<Counter>,
    latency: Arc<Histogram>,
    queued: Arc<Gauge>,
}

struct InflightJob {
    state: JobState,
    tenant: usize,
}

// ---------------------------------------------------------------------
// Encoded-operand cache
// ---------------------------------------------------------------------

/// LRU cache of per-task encoded left operands, keyed by a 128-bit
/// content hash of the four blocks (dims + exact f32 bit patterns —
/// mutating a single element changes the key, so a stale encode can
/// never be served). Values are `Arc`s shared with in-flight
/// assignments; eviction only drops the cache's reference.
struct EncodedCache {
    cap: usize,
    map: HashMap<u128, Vec<Arc<Matrix>>>,
    lru: VecDeque<u128>,
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    entries: Arc<Gauge>,
}

impl EncodedCache {
    fn new(cap: usize, metrics: &Registry) -> EncodedCache {
        EncodedCache {
            cap,
            map: HashMap::new(),
            lru: VecDeque::new(),
            hits: metrics.counter(names::CACHE_HITS),
            misses: metrics.counter(names::CACHE_MISSES),
            evictions: metrics.counter(names::CACHE_EVICTIONS),
            entries: metrics.gauge(names::CACHE_ENTRIES),
        }
    }

    fn enabled(&self) -> bool {
        self.cap > 0
    }

    fn get(&mut self, key: u128) -> Option<Vec<Arc<Matrix>>> {
        match self.map.get(&key) {
            Some(v) => {
                self.hits.inc();
                if let Some(pos) = self.lru.iter().position(|&k| k == key) {
                    self.lru.remove(pos);
                    self.lru.push_back(key);
                }
                Some(v.clone())
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    fn put(&mut self, key: u128, v: Vec<Arc<Matrix>>) {
        if self.cap == 0 || self.map.contains_key(&key) {
            return;
        }
        while self.map.len() >= self.cap {
            match self.lru.pop_front() {
                Some(old) => {
                    self.map.remove(&old);
                    self.evictions.inc();
                }
                None => break,
            }
        }
        self.map.insert(key, v);
        self.lru.push_back(key);
        self.entries.set(self.map.len() as u64);
    }
}

fn absorb(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x0000_0100_0000_01b3)
}

fn content_hash(seed: u64, blocks: &[Matrix; 4]) -> u64 {
    let mut h = absorb(0xcbf2_9ce4_8422_2325, seed);
    for m in blocks {
        h = absorb(h, m.rows() as u64);
        h = absorb(h, m.cols() as u64);
        for &x in m.as_slice() {
            h = absorb(h, x.to_bits() as u64);
        }
    }
    h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// Two independently seeded 64-bit content hashes: a collision would
/// need both to collide at once, which at cache-sized populations is
/// vanishingly unlikely.
fn operand_key(blocks: &[Matrix; 4]) -> u128 {
    ((content_hash(0x9e37_79b9_7f4a_7c15, blocks) as u128) << 64)
        | content_hash(0x27d4_eb2f_1656_67c5, blocks) as u128
}

// ---------------------------------------------------------------------
// The serving tier
// ---------------------------------------------------------------------

/// The multi-tenant serving tier (see module docs).
pub struct ServingTier {
    plan: DispatchPlan,
    backend: Backend,
    cfg: TierConfig,
    fleet: WorkerFleet,
    next_job: u64,
    tenants: Vec<TenantState>,
    /// Tenant the DRR cursor is currently serving (deficit not yet spent).
    current: Option<usize>,
    rr_cursor: usize,
    queued_total: usize,
    inflight: HashMap<u64, InflightJob>,
    /// Central dispatch queue: admitted-but-unassigned leaf items. The
    /// tier hands these out one per worker `Ready`, so purging this
    /// queue is exact revocation for everything not at a worker.
    dispatch: VecDeque<Assignment>,
    idle: VecDeque<usize>,
    registered: Vec<bool>,
    hb_seq: u64,
    last_hb: Instant,
    hb_acked: Vec<u64>,
    cache: EncodedCache,
    tracer: Tracer,
    pub metrics: Registry,
}

impl ServingTier {
    /// Build a tier over a flat task set with one worker per task.
    pub fn new(set: TaskSet, backend: Backend, cfg: TierConfig) -> ServingTier {
        ServingTier::with_plan(DispatchPlan::flat(set), backend, cfg, None)
    }

    /// Build a tier for an arbitrary dispatch plan. `workers` overrides
    /// the fleet size (defaults to one node per task for flat plans, a
    /// capped fleet for nested fan-outs).
    pub fn with_plan(
        plan: DispatchPlan,
        backend: Backend,
        cfg: TierConfig,
        workers: Option<usize>,
    ) -> ServingTier {
        ServingTier::with_plan_traced(plan, backend, cfg, workers, Tracer::off())
    }

    /// [`ServingTier::with_plan`] with a trace sink: the tier and every
    /// worker in its fleet emit leaf-lifecycle events through `tracer`.
    /// `Tracer::off()` (what `with_plan` passes) makes every emission
    /// site a single branch.
    pub fn with_plan_traced(
        plan: DispatchPlan,
        backend: Backend,
        cfg: TierConfig,
        workers: Option<usize>,
        tracer: Tracer,
    ) -> ServingTier {
        let metrics = Registry::new();
        let pool_size = workers.unwrap_or_else(|| plan.default_pool_size());
        let fleet =
            WorkerFleet::spawn_traced(pool_size, backend.clone(), metrics.clone(), tracer.clone());
        let mut cfg = cfg;
        if cfg.tenants.is_empty() {
            cfg.tenants.push(TenantSpec::unbounded("default"));
        }
        let tenants = cfg
            .tenants
            .iter()
            .map(|spec| TenantState {
                spec: spec.clone(),
                queue: VecDeque::new(),
                deficit: 0,
                inflight: 0,
                jobs: metrics.counter(&format!("{}{}", names::TENANT_JOBS_PREFIX, spec.name)),
                latency: metrics
                    .histogram(&format!("{}{}", names::TENANT_LATENCY_PREFIX, spec.name)),
                queued: metrics.gauge(&format!("{}{}", names::TENANT_QUEUE_PREFIX, spec.name)),
            })
            .collect();
        let cache = EncodedCache::new(cfg.cache_cap, &metrics);
        ServingTier {
            plan,
            backend,
            cfg,
            fleet,
            next_job: 0,
            tenants,
            current: None,
            rr_cursor: 0,
            queued_total: 0,
            inflight: HashMap::new(),
            dispatch: VecDeque::new(),
            idle: VecDeque::new(),
            registered: vec![false; pool_size],
            hb_seq: 0,
            last_hb: Instant::now(),
            hb_acked: vec![0; pool_size],
            cache,
            tracer,
            metrics,
        }
    }

    /// The tracer this tier (and its fleet) emits through.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    pub fn scheme_name(&self) -> &str {
        self.plan.name()
    }

    pub fn num_workers(&self) -> usize {
        self.fleet.size()
    }

    /// Work items dispatched per job (tasks, or leaves for nested plans).
    pub fn items_per_job(&self) -> usize {
        self.plan.num_work_items()
    }

    /// Configured global in-flight depth (≥ 1).
    pub fn depth(&self) -> usize {
        self.cfg.depth.max(1)
    }

    /// Jobs not yet completed (queued + in flight).
    pub fn outstanding(&self) -> usize {
        self.queued_total + self.inflight.len()
    }

    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.iter().map(|t| t.spec.name.clone()).collect()
    }

    pub fn tenant_inflight(&self, name: &str) -> Option<usize> {
        self.tenants.iter().find(|t| t.spec.name == name).map(|t| t.inflight)
    }

    pub fn tenant_queued(&self, name: &str) -> Option<usize> {
        self.tenants.iter().find(|t| t.spec.name == name).map(|t| t.queue.len())
    }

    /// Submit a multiply job `C = A · B` under `tenant` (square,
    /// dimension divisible per split level: 2 flat, 4 nested).
    pub fn submit(&mut self, tenant: &str, a: Matrix, b: Matrix) -> Result<u64, String> {
        self.submit_job(tenant, a, b, None)
    }

    /// Submit with an explicit per-item fault script (length must equal
    /// [`Self::items_per_job`]) — deterministic replay for tests.
    pub fn submit_with_faults(
        &mut self,
        tenant: &str,
        a: Matrix,
        b: Matrix,
        faults: Vec<FaultAction>,
    ) -> Result<u64, String> {
        if faults.len() != self.plan.num_work_items() {
            return Err(format!(
                "fault script length {} != work items per job {}",
                faults.len(),
                self.plan.num_work_items()
            ));
        }
        self.submit_job(tenant, a, b, Some(faults))
    }

    fn submit_job(
        &mut self,
        tenant: &str,
        a: Matrix,
        b: Matrix,
        faults: Option<Vec<FaultAction>>,
    ) -> Result<u64, String> {
        let ti = self
            .tenants
            .iter()
            .position(|t| t.spec.name == tenant)
            .ok_or_else(|| format!("unknown tenant {tenant:?}"))?;
        let n = a.rows();
        if a.shape() != (n, n) || b.shape() != (n, n) {
            return Err(format!(
                "square matrices required, got {:?} x {:?}",
                a.shape(),
                b.shape()
            ));
        }
        let div = self.plan.block_divisor();
        if n == 0 || n % div != 0 {
            return Err(format!(
                "dimension must be a positive multiple of {div} for {}, got {n}",
                self.plan.name()
            ));
        }
        if self.queued_total >= self.cfg.queue_cap {
            return Err(format!("queue full ({} jobs)", self.queued_total));
        }
        self.next_job += 1;
        let job_id = self.next_job;
        self.tracer.emit(EventKind::JobAdmit, job_id, NO_LEAF, ti as u64);
        self.tenants[ti].queue.push_back(PendingJob {
            job_id,
            a,
            b,
            enqueued: Instant::now(),
            faults,
        });
        self.queued_total += 1;
        self.admit_ready();
        self.update_gauges();
        Ok(job_id)
    }

    /// Cancel a job mid-stream: a still-queued job is removed from its
    /// tenant queue; an in-flight job has its outstanding items revoked
    /// and its decode state dropped (no [`JobDone`] is ever emitted, and
    /// any in-compute replies land as counted stale drops). Returns
    /// whether the job was found.
    pub fn cancel(&mut self, job_id: u64) -> bool {
        for t in self.tenants.iter_mut() {
            if let Some(pos) = t.queue.iter().position(|p| p.job_id == job_id) {
                t.queue.remove(pos);
                self.queued_total -= 1;
                self.metrics.counter(names::JOBS_CANCELLED).inc();
                self.tracer.emit(EventKind::JobFail, job_id, NO_LEAF, 1);
                self.update_gauges();
                return true;
            }
        }
        if let Some(j) = self.inflight.remove(&job_id) {
            let items = self.plan.num_work_items();
            let (removed, _) = self.purge_dispatch(job_id, &(0..items));
            if removed > 0 {
                self.metrics.counter(names::POOL_ITEMS_REVOKED).add(removed as u64);
            }
            self.broadcast_revoke(job_id, 0..items);
            self.tenants[j.tenant].inflight -= 1;
            self.metrics.counter(names::JOBS_CANCELLED).inc();
            self.tracer.emit(EventKind::JobFail, job_id, NO_LEAF, 1);
            self.admit_ready();
            self.update_gauges();
            return true;
        }
        false
    }

    /// Drive the tier until `max_jobs` complete (or nothing is
    /// outstanding), in completion order.
    pub fn drive(&mut self, max_jobs: usize) -> Vec<JobDone> {
        let mut out = Vec::new();
        while out.len() < max_jobs && self.outstanding() > 0 {
            let want = max_jobs - out.len();
            let mut got = self.poll(Duration::from_millis(200), want);
            out.append(&mut got);
        }
        out
    }

    /// Process messages for up to `timeout`, returning at most
    /// `max_completions` finished jobs.
    pub fn poll(&mut self, timeout: Duration, max_completions: usize) -> Vec<JobDone> {
        let mut done = Vec::new();
        let until = Instant::now() + timeout;
        loop {
            self.admit_ready();
            self.reap(&mut done, max_completions);
            if done.len() >= max_completions || self.inflight.is_empty() {
                break;
            }
            let now = Instant::now();
            if now >= until {
                break;
            }
            if self.last_hb.elapsed() >= HEARTBEAT_EVERY {
                self.heartbeat();
            }
            let mut wait = (until - now).min(HEARTBEAT_EVERY);
            if let Some(d) = self.inflight.values().map(|j| j.state.deadline).min() {
                wait = wait.min(d.saturating_duration_since(now));
            }
            match self.fleet.recv_timeout(wait) {
                Ok(msg) => self.on_message(msg, &mut done),
                Err(RecvTimeoutError::Timeout) => {} // re-check deadlines
                Err(RecvTimeoutError::Disconnected) => break, // fleet gone
            }
        }
        self.update_gauges();
        done
    }

    /// Broadcast a liveness probe to every registered worker.
    pub fn heartbeat(&mut self) {
        self.hb_seq += 1;
        let seq = self.hb_seq;
        for w in 0..self.registered.len() {
            if self.registered[w] {
                let _ = self.fleet.send(w, ToWorker::Heartbeat { seq });
            }
        }
        self.metrics.counter(names::HEARTBEATS_SENT).inc();
        self.last_hb = Instant::now();
    }

    /// Shut the fleet down (drains workers, joins event loops).
    pub fn shutdown(self) {
        self.fleet.shutdown();
    }

    // --- admission (DRR + batching) ----------------------------------

    /// Admit queued jobs into free in-flight slots by deficit round
    /// robin, flushing dispatch rounds of `batch_window` jobs.
    fn admit_ready(&mut self) {
        let depth = self.cfg.depth.max(1);
        let window = self.cfg.batch_window.max(1);
        let mut round: Vec<(usize, PendingJob)> = Vec::new();
        while self.inflight.len() + round.len() < depth {
            let Some(ti) = self.next_tenant() else { break };
            let t = &mut self.tenants[ti];
            t.deficit -= 1;
            t.inflight += 1;
            let p = t.queue.pop_front().expect("next_tenant guarantees a queued job");
            self.queued_total -= 1;
            round.push((ti, p));
            if round.len() >= window {
                self.dispatch_round(std::mem::take(&mut round));
            }
        }
        if !round.is_empty() {
            self.dispatch_round(round);
        }
    }

    /// Pick the tenant to admit from: stay on the currently served
    /// tenant while it has deficit, queued jobs, and quota headroom;
    /// otherwise advance the round-robin cursor, granting one quantum
    /// (= weight) on arrival. Returns `None` when no tenant is eligible.
    fn next_tenant(&mut self) -> Option<usize> {
        if let Some(c) = self.current {
            let t = &self.tenants[c];
            if !t.queue.is_empty() && t.deficit >= 1 && t.inflight < t.spec.quota {
                return Some(c);
            }
            if t.queue.is_empty() {
                // An idle tenant banks no deficit (classic DRR reset).
                self.tenants[c].deficit = 0;
            }
            self.current = None;
        }
        let n = self.tenants.len();
        for _ in 0..n {
            let ti = self.rr_cursor % n;
            self.rr_cursor = (self.rr_cursor + 1) % n;
            let t = &mut self.tenants[ti];
            if t.queue.is_empty() || t.inflight >= t.spec.quota {
                continue;
            }
            let w = t.spec.weight.max(1);
            t.deficit = (t.deficit + w).min(w.saturating_mul(8));
            self.current = Some(ti);
            return Some(ti);
        }
        None
    }

    /// Dispatch one coalesced round: encode every job's items into the
    /// central queue, then pump assignments to idle workers once.
    fn dispatch_round(&mut self, round: Vec<(usize, PendingJob)>) {
        if round.is_empty() {
            return;
        }
        self.metrics.counter(names::BATCH_ROUNDS).inc();
        self.metrics.counter(names::BATCHED_JOBS).add(round.len() as u64);
        for (ti, p) in round {
            self.admit(ti, p);
        }
        self.pump();
    }

    fn admit(&mut self, ti: usize, p: PendingJob) {
        let started = Instant::now();
        let a4 = Arc::new(split_blocks(&p.a));
        let b4 = Arc::new(split_blocks(&p.b));
        // Faults are a pure function of (master seed, job_id, item): the
        // pattern cannot shift with tenants, batching, caching, depth,
        // or admission history (scripted jobs sample nothing).
        let faults: Vec<FaultAction> = match p.faults {
            Some(f) => f,
            None => (0..self.plan.num_work_items())
                .map(|i| {
                    self.cfg.master.fault.sample_at(self.cfg.master.seed, p.job_id, i as u64)
                })
                .collect(),
        };
        let mut injected_failures = 0;
        let mut injected_stragglers = 0;
        for fault in &faults {
            match fault {
                FaultAction::Fail => injected_failures += 1,
                FaultAction::Delay(_) => injected_stragglers += 1,
                FaultAction::None => {}
            }
        }
        match &self.plan {
            DispatchPlan::Flat(graph) => {
                // Encoded-operand cache: repeated left operands (same
                // weights, many inputs) reuse their per-task encodes.
                // Native only — the PJRT task protocol ships blocks.
                let mut cache_hit = false;
                let cached: Option<Vec<Arc<Matrix>>> =
                    if self.cache.enabled() && matches!(self.backend, Backend::Native) {
                        let key = operand_key(&a4);
                        match self.cache.get(key) {
                            Some(v) => {
                                cache_hit = true;
                                Some(v)
                            }
                            None => {
                                let v: Vec<Arc<Matrix>> = graph
                                    .specs
                                    .iter()
                                    .map(|s| Arc::new(encode_operand(&s.int_ca(), &a4)))
                                    .collect();
                                // Bulk cache fill at the coordinator:
                                // detail = number of per-task encodes.
                                self.tracer.emit(
                                    EventKind::Encode,
                                    p.job_id,
                                    NO_LEAF,
                                    graph.specs.len() as u64,
                                );
                                self.cache.put(key, v.clone());
                                Some(v)
                            }
                        }
                    } else {
                        None
                    };
                for (spec, fault) in graph.specs.iter().zip(&faults) {
                    if cache_hit {
                        self.tracer.emit(EventKind::CacheHit, p.job_id, spec.id as u32, 0);
                    }
                    let left = match &cached {
                        Some(v) => OperandPayload::Encoded(v[spec.id].clone()),
                        None => OperandPayload::Blocks(a4.clone()),
                    };
                    self.dispatch.push_back(Assignment {
                        job_id: p.job_id,
                        task_id: spec.id,
                        ca: spec.ca,
                        cb: spec.cb,
                        left,
                        right: OperandPayload::Blocks(b4.clone()),
                        fault: *fault,
                    });
                }
            }
            DispatchPlan::Nested(graph) => {
                let m2 = graph.group_size();
                // One encode scratch pair for the whole dispatch; only
                // the level-2 split blocks (shared by the group's leaf
                // items) are allocated per group.
                let mut enc_l = Matrix::zeros(0, 0);
                let mut enc_r = Matrix::zeros(0, 0);
                for (g, ospec) in graph.outer.specs.iter().enumerate() {
                    encode_operand_into(&mut enc_l, &ospec.int_ca(), &a4);
                    encode_operand_into(&mut enc_r, &ospec.int_cb(), &b4);
                    // Level-1 group encode (both sides) at the coordinator.
                    self.tracer.emit(EventKind::Encode, p.job_id, NO_LEAF, 2);
                    let ga4 = Arc::new(split_blocks(&enc_l));
                    let gb4 = Arc::new(split_blocks(&enc_r));
                    for (j, ispec) in graph.inner.specs.iter().enumerate() {
                        let task_id = g * m2 + j;
                        self.dispatch.push_back(Assignment {
                            job_id: p.job_id,
                            task_id,
                            ca: ispec.ca,
                            cb: ispec.cb,
                            left: OperandPayload::Blocks(ga4.clone()),
                            right: OperandPayload::Blocks(gb4.clone()),
                            fault: faults[task_id],
                        });
                    }
                }
            }
        }
        let mut state = JobState::new(
            &self.plan,
            p.job_id,
            a4,
            b4,
            p.enqueued,
            started,
            started + self.cfg.master.deadline,
            injected_failures,
            injected_stragglers,
            !self.cfg.master.collect_all,
        );
        state.set_tracer(self.tracer.clone());
        self.metrics.counter(names::JOBS_DISPATCHED).inc();
        self.inflight.insert(p.job_id, InflightJob { state, tenant: ti });
    }

    // --- dispatch ----------------------------------------------------

    /// Hand queued assignments to idle workers, one each (pull-based:
    /// a worker re-enters `idle` only via `Ready`).
    fn pump(&mut self) {
        while !self.dispatch.is_empty() && !self.idle.is_empty() {
            let w = self.idle.pop_front().expect("checked non-empty");
            let item = self.dispatch.pop_front().expect("checked non-empty");
            let (job_id, task_id) = (item.job_id, item.task_id);
            match self.fleet.send(w, ToWorker::AssignLeaf(item)) {
                Ok(()) => {
                    self.tracer.emit(EventKind::LeafDispatch, job_id, task_id as u32, w as u64);
                }
                Err(msg) => {
                    // Endpoint gone: requeue the item, drop the worker
                    // from the roster.
                    if let ToWorker::AssignLeaf(item) = msg {
                        self.dispatch.push_front(item);
                    }
                    self.registered[w] = false;
                    self.update_worker_gauge();
                }
            }
        }
        self.metrics.gauge(names::POOL_QUEUE_DEPTH).set(self.dispatch.len() as u64);
    }

    /// Purge a job's still-queued items. Emits exactly one `revoke`
    /// trace event per removed item — every `pool_items_revoked`
    /// increment site adds this function's removed count, so the
    /// counter and the event stream agree by construction (pinned by
    /// `tests/obs_trace.rs`).
    fn purge_dispatch(&mut self, job_id: u64, tasks: &Range<usize>) -> (usize, usize) {
        let before = self.dispatch.len();
        let mut replying = 0usize;
        let tracer = self.tracer.clone();
        self.dispatch.retain(|item| {
            let hit = item.job_id == job_id && tasks.contains(&item.task_id);
            if hit {
                tracer.emit(EventKind::Revoke, job_id, item.task_id as u32, 0);
                if item.fault != FaultAction::Fail {
                    replying += 1;
                }
            }
            !hit
        });
        self.metrics.gauge(names::POOL_QUEUE_DEPTH).set(self.dispatch.len() as u64);
        (before - self.dispatch.len(), replying)
    }

    fn broadcast_revoke(&mut self, job_id: u64, tasks: Range<usize>) {
        for w in 0..self.registered.len() {
            if self.registered[w] {
                let _ = self.fleet.send(w, ToWorker::Revoke { job_id, tasks: tasks.clone() });
            }
        }
    }

    fn update_worker_gauge(&self) {
        let live = self.registered.iter().filter(|&&r| r).count();
        self.metrics.gauge(names::WORKERS_LIVE).set(live as u64);
    }

    // --- message handling --------------------------------------------

    fn on_message(&mut self, msg: ToCoord, done: &mut Vec<JobDone>) {
        match msg {
            ToCoord::Register { worker_id } => {
                if worker_id < self.registered.len() && !self.registered[worker_id] {
                    self.registered[worker_id] = true;
                    self.idle.push_back(worker_id);
                    self.update_worker_gauge();
                }
                self.pump();
            }
            ToCoord::Ready { worker_id } => {
                self.idle.push_back(worker_id);
                self.pump();
            }
            ToCoord::LeafResult { reply, .. } => self.on_reply(reply, done),
            ToCoord::RevokeAck { job_id, replying, purged, .. } => {
                // Worker-side backlog purges are disjoint from the
                // central-queue purge (an item is in exactly one place),
                // so debiting both never double-counts.
                if purged > 0 {
                    if let Some(j) = self.inflight.get_mut(&job_id) {
                        j.state.note_revoked(replying);
                    }
                    self.check_complete(job_id, done);
                }
            }
            ToCoord::HeartbeatAck { worker_id, seq } => {
                if worker_id < self.hb_acked.len() {
                    self.hb_acked[worker_id] = seq;
                }
                self.metrics.counter(names::HEARTBEAT_ACKS).inc();
            }
        }
    }

    /// Route one reply to its job; replies for jobs that are no longer
    /// open (completed, cancelled, or never existed) are dropped and
    /// counted — the cross-job leakage guard. A reply that closes a
    /// nested group triggers the group's revocation.
    fn on_reply(&mut self, reply: WorkerReply, done: &mut Vec<JobDone>) {
        let job_id = reply.job_id;
        let task_id = reply.task_id;
        let revoke = {
            let Some(j) = self.inflight.get_mut(&job_id) else {
                self.metrics.counter(names::REPLIES_STALE_DROPPED).inc();
                self.tracer.emit(EventKind::StaleDrop, job_id, task_id as u32, 0);
                return;
            };
            match &reply.product {
                Ok(_) => {
                    self.metrics.histogram(names::WORKER_COMPUTE).observe(reply.compute_time);
                    self.tracer.emit(EventKind::Reply, job_id, task_id as u32, 0);
                }
                Err(_) => {
                    self.metrics.counter(names::WORKER_ERRORS).inc();
                    self.tracer.emit(EventKind::Reply, job_id, task_id as u32, 1);
                }
            }
            j.state.on_reply(reply)
        };
        if let Some(range) = revoke {
            let (removed, replying) = self.purge_dispatch(job_id, &range);
            if removed > 0 {
                self.metrics.counter(names::GROUP_ITEMS_CANCELLED).add(removed as u64);
                self.metrics.counter(names::POOL_ITEMS_REVOKED).add(removed as u64);
            }
            self.broadcast_revoke(job_id, range);
            if let Some(j) = self.inflight.get_mut(&job_id) {
                j.state.note_revoked(replying);
            }
            self.metrics.counter(names::GROUPS_RECOVERED).inc();
        }
        self.check_complete(job_id, done);
    }

    fn check_complete(&mut self, job_id: u64, done: &mut Vec<JobDone>) {
        let Some(j) = self.inflight.get(&job_id) else { return };
        let decodable = j.state.is_decodable();
        let collect_all = self.cfg.master.collect_all;
        let complete = if decodable {
            !collect_all || j.state.all_replies_in()
        } else {
            // Every possible reply is in and the span is still short:
            // no point waiting for the deadline.
            j.state.all_replies_in()
        };
        if complete {
            let j = self.inflight.remove(&job_id).expect("checked present");
            self.finish(j, decodable, done);
        }
    }

    /// Complete jobs that hit their deadline or exhausted their replies,
    /// oldest first, up to the caller's completion budget.
    fn reap(&mut self, done: &mut Vec<JobDone>, max_completions: usize) {
        let now = Instant::now();
        let mut ready: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, j)| now >= j.state.deadline || j.state.all_replies_in())
            .map(|(id, _)| *id)
            .collect();
        ready.sort_unstable();
        for id in ready {
            if done.len() >= max_completions {
                break;
            }
            let j = self.inflight.remove(&id).expect("listed as ready");
            // collect_all promises a decode set that depends only on the
            // injected faults: if the deadline fires before every live
            // reply arrived, fall back (or error) rather than silently
            // decoding from a timing-dependent partial set.
            let decodable = j.state.is_decodable()
                && (!self.cfg.master.collect_all || j.state.all_replies_in());
            self.finish(j, decodable, done);
        }
    }

    /// Finalize one job: revoke its outstanding items, assemble or fall
    /// back, record global and per-tenant metrics, free the tenant slot.
    fn finish(&mut self, j: InflightJob, decodable: bool, done: &mut Vec<JobDone>) {
        let InflightJob { mut state, tenant } = j;
        let job_id = state.job_id;
        let items = self.plan.num_work_items();
        let (removed, _) = self.purge_dispatch(job_id, &(0..items));
        if removed > 0 {
            self.metrics.counter(names::POOL_ITEMS_REVOKED).add(removed as u64);
        }
        self.broadcast_revoke(job_id, 0..items);
        let scheme = self.plan.name().to_string();
        let result = if decodable {
            match state.assemble(&self.backend) {
                Ok(c) => {
                    self.tracer.emit(EventKind::JobDecode, job_id, NO_LEAF, 0);
                    Ok((c, state.report(&scheme, false)))
                }
                Err(e) => {
                    self.tracer.emit(EventKind::JobFail, job_id, NO_LEAF, 0);
                    Err(format!("job {job_id}: {e}"))
                }
            }
        } else if self.cfg.master.fallback_local {
            self.metrics.counter(names::JOBS_FELL_BACK).inc();
            self.tracer.emit(EventKind::JobFallback, job_id, NO_LEAF, 0);
            let c = state.fallback_product();
            Ok((c, state.report(&scheme, true)))
        } else {
            self.tracer.emit(EventKind::JobFail, job_id, NO_LEAF, 0);
            Err(format!(
                "job {job_id}: not decodable within deadline ({} of {} replies)",
                state.finished, state.dispatched
            ))
        };
        if let Ok((_, report)) = &result {
            self.metrics.histogram(names::JOB_LATENCY).observe(report.elapsed);
        }
        self.metrics
            .histogram(names::QUEUE_WAIT)
            .observe(state.started.duration_since(state.enqueued));
        self.metrics.counter(names::JOBS_COMPLETED).inc();
        let total_latency = state.enqueued.elapsed();
        let t = &mut self.tenants[tenant];
        t.inflight -= 1;
        t.jobs.inc();
        t.latency.observe(total_latency);
        done.push(JobDone { job_id, tenant: t.spec.name.clone(), result, total_latency });
        self.admit_ready();
    }

    fn update_gauges(&self) {
        self.metrics.gauge(names::INFLIGHT_JOBS).set(self.inflight.len() as u64);
        self.metrics.gauge(names::PENDING_JOBS).set(self.queued_total as u64);
        for t in &self.tenants {
            t.queued.set(t.queue.len() as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    fn cfg(depth: usize) -> TierConfig {
        TierConfig {
            master: MasterConfig {
                deadline: Duration::from_secs(10),
                ..MasterConfig::default()
            },
            depth,
            ..TierConfig::default()
        }
    }

    fn rand_pair(n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        (Matrix::random(n, n, &mut rng), Matrix::random(n, n, &mut rng))
    }

    #[test]
    fn tenant_spec_parsing_accepts_and_rejects() {
        let t = TenantSpec::parse("team-a:3:8").unwrap();
        assert_eq!(t, TenantSpec::new("team-a", 3, 8));
        let t: TenantSpec = "free_1:1:4".parse().unwrap();
        assert_eq!(t.name, "free_1");
        for bad in [
            "",             // empty
            "a:1",          // missing quota
            "a:1:2:3",      // too many fields
            ":1:2",         // empty name
            "a b:1:2",      // bad name chars
            "a:0:2",        // zero weight
            "a:1:0",        // zero quota
            "a:x:2",        // non-numeric weight
            "a:1:y",        // non-numeric quota
        ] {
            assert!(TenantSpec::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn unknown_tenant_and_full_queue_are_rejected() {
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig { queue_cap: 1, depth: 1, ..cfg(1) },
        );
        let err = tier.submit("nobody", Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap_err();
        assert!(err.contains("unknown tenant"), "{err}");
        // Depth 1: job 1 goes in flight, job 2 occupies the single
        // queue slot, job 3 bounces.
        tier.submit("default", Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap();
        tier.submit("default", Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap();
        let err = tier.submit("default", Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap_err();
        assert!(err.contains("queue full"), "{err}");
        assert_eq!(tier.drive(2).len(), 2);
        tier.shutdown();
    }

    #[test]
    fn drr_shares_track_weights_exactly_at_depth_one() {
        // Depth 1 makes completion order equal admission order, so the
        // DRR schedule is directly observable: weights 3:1 over a
        // 16-completion window must admit exactly 12 vs 4.
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig {
                tenants: vec![
                    TenantSpec::new("heavy", 3, usize::MAX),
                    TenantSpec::new("light", 1, usize::MAX),
                ],
                ..cfg(1)
            },
        );
        for seed in 0..16 {
            let (a, b) = rand_pair(8, seed);
            tier.submit("heavy", a.clone(), b.clone()).unwrap();
            tier.submit("light", a, b).unwrap();
        }
        let done = tier.drive(16);
        assert_eq!(done.len(), 16);
        let heavy = done.iter().filter(|d| d.tenant == "heavy").count();
        let light = done.iter().filter(|d| d.tenant == "light").count();
        assert_eq!((heavy, light), (12, 4), "shares must track 3:1 weights exactly");
        // Drain the rest; every job must still complete correctly.
        let rest = tier.drive(usize::MAX);
        assert_eq!(rest.len(), 16);
        assert!(rest.iter().all(|d| d.result.is_ok()));
        tier.shutdown();
    }

    #[test]
    fn quota_caps_a_tenants_inflight_jobs() {
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig {
                tenants: vec![
                    TenantSpec::new("capped", 1, 2),
                    TenantSpec::unbounded("open"),
                ],
                ..cfg(8)
            },
        );
        for seed in 0..6 {
            let (a, b) = rand_pair(8, seed);
            tier.submit("capped", a, b).unwrap();
        }
        // Depth 8 has room for all six, but the quota holds admission
        // at two; the rest wait in the tenant queue.
        assert_eq!(tier.tenant_inflight("capped"), Some(2));
        assert_eq!(tier.tenant_queued("capped"), Some(4));
        assert_eq!(tier.outstanding(), 6);
        let done = tier.drive(6);
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|d| d.result.is_ok()));
        tier.shutdown();
    }

    #[test]
    fn cancel_removes_pending_and_inflight_jobs() {
        // Zero workers: nothing ever completes, so admission state is
        // fully deterministic when cancel runs.
        let mut tier = ServingTier::with_plan(
            DispatchPlan::flat(TaskSet::strassen_winograd(0)),
            Backend::Native,
            cfg(1),
            Some(0),
        );
        let (a, b) = rand_pair(8, 1);
        let j1 = tier.submit("default", a.clone(), b.clone()).unwrap();
        let j2 = tier.submit("default", a, b).unwrap();
        assert_eq!(tier.in_flight(), 1);
        assert_eq!(tier.outstanding(), 2);
        assert!(tier.cancel(j2), "pending job");
        assert_eq!(tier.outstanding(), 1);
        assert!(tier.cancel(j1), "in-flight job");
        assert_eq!(tier.outstanding(), 0);
        assert!(!tier.cancel(99), "unknown job");
        assert_eq!(tier.metrics.counter("jobs_cancelled").get(), 2);
        tier.shutdown();
    }

    #[test]
    fn cache_reuses_repeated_left_operands_and_evicts_lru() {
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig { cache_cap: 2, ..cfg(2) },
        );
        let (a, b1) = rand_pair(8, 1);
        let (_, b2) = rand_pair(8, 2);
        let want1 = a.matmul(&b1);
        let want2 = a.matmul(&b2);
        // Same left operand three times: one miss, two hits.
        tier.submit("default", a.clone(), b1.clone()).unwrap();
        tier.submit("default", a.clone(), b2.clone()).unwrap();
        tier.submit("default", a.clone(), b1.clone()).unwrap();
        let mut done = tier.drive(3);
        done.sort_by_key(|d| d.job_id);
        for (d, want) in done.iter().zip([&want1, &want2, &want1]) {
            let (c, _) = d.result.as_ref().unwrap();
            assert!(c.approx_eq(want, 1e-4), "cached encode must decode correctly");
        }
        assert_eq!(tier.metrics.counter("cache_misses").get(), 1);
        assert_eq!(tier.metrics.counter("cache_hits").get(), 2);
        // Two more distinct left operands overflow cap=2 → eviction;
        // the original operand then misses again.
        let (a2, _) = rand_pair(8, 3);
        let (a3, _) = rand_pair(8, 4);
        tier.submit("default", a2, b1.clone()).unwrap();
        tier.submit("default", a3, b1.clone()).unwrap();
        tier.submit("default", a, b1).unwrap();
        assert_eq!(tier.drive(3).len(), 3);
        assert!(tier.metrics.counter("cache_evictions").get() >= 1);
        assert_eq!(tier.metrics.counter("cache_misses").get(), 4);
        tier.shutdown();
    }

    #[test]
    fn cache_capacity_zero_disables_the_cache_entirely() {
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig { cache_cap: 0, ..cfg(1) },
        );
        let (a, b) = rand_pair(8, 1);
        tier.submit("default", a.clone(), b.clone()).unwrap();
        tier.submit("default", a, b).unwrap();
        assert_eq!(tier.drive(2).len(), 2);
        assert_eq!(tier.metrics.counter("cache_hits").get(), 0);
        assert_eq!(tier.metrics.counter("cache_misses").get(), 0);
        tier.shutdown();
    }

    #[test]
    fn batch_window_chunks_dispatch_rounds() {
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig { batch_window: 3, ..cfg(8) },
        );
        let mut want = Vec::new();
        for seed in 0..8 {
            let (a, b) = rand_pair(8, seed);
            want.push(a.matmul(&b));
            tier.submit("default", a, b).unwrap();
        }
        let mut done = tier.drive(8);
        assert_eq!(done.len(), 8);
        done.sort_by_key(|d| d.job_id);
        for (d, w) in done.iter().zip(&want) {
            let (c, _) = d.result.as_ref().unwrap();
            assert!(c.approx_eq(w, 1e-4));
        }
        // 8 admitted jobs in windows of 3 → 3 rounds (3 + 3 + 2).
        assert_eq!(tier.metrics.counter("batched_jobs").get(), 8);
        assert!(tier.metrics.counter("batch_rounds").get() <= 3);
        tier.shutdown();
    }

    #[test]
    fn heartbeats_are_sent_and_acked_while_polling() {
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            cfg(1),
        );
        let (a, b) = rand_pair(8, 1);
        // Every item straggles past two heartbeat periods, so the poll
        // loop must probe (and collect acks) while waiting.
        let faults = vec![FaultAction::Delay(Duration::from_millis(700)); 14];
        tier.submit_with_faults("default", a, b, faults).unwrap();
        let done = tier.drive(1);
        assert_eq!(done.len(), 1);
        assert!(done[0].result.is_ok());
        assert!(tier.metrics.counter("heartbeats_sent").get() >= 1);
        assert!(tier.metrics.counter("heartbeat_acks").get() >= 1);
        tier.shutdown();
    }

    #[test]
    fn metric_names_all_in_table() {
        let mut sorted = names::ALL.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), names::ALL.len(), "duplicate entries in names::ALL");
        // Drive a real run (workers + cache + two tenants) and require
        // every name the registry saw to come from the table.
        let mut tier = ServingTier::new(
            TaskSet::strassen_winograd(0),
            Backend::Native,
            TierConfig {
                tenants: vec![
                    TenantSpec::new("team-a", 1, usize::MAX),
                    TenantSpec::unbounded("default"),
                ],
                cache_cap: 2,
                ..cfg(2)
            },
        );
        let (a, b) = rand_pair(8, 1);
        tier.submit("team-a", a.clone(), b.clone()).unwrap();
        tier.submit("default", a.clone(), b.clone()).unwrap();
        tier.submit("team-a", a, b).unwrap();
        assert_eq!(tier.drive(3).len(), 3);
        tier.heartbeat();
        let mut seen: Vec<String> =
            tier.metrics.counters().into_iter().map(|(n, _)| n).collect();
        seen.extend(tier.metrics.gauges().into_iter().map(|(n, _)| n));
        seen.extend(tier.metrics.histograms().into_iter().map(|(n, _)| n));
        assert!(seen.len() > 10, "expected a populated registry, got {seen:?}");
        for name in &seen {
            assert!(names::is_known(name), "metric {name:?} recorded outside names table");
        }
        tier.shutdown();
    }

    #[test]
    fn operand_keys_separate_contents_and_shapes() {
        let mut rng = Rng::seeded(1);
        let a = Matrix::random(8, 8, &mut rng);
        let k1 = operand_key(&split_blocks(&a));
        assert_eq!(k1, operand_key(&split_blocks(&a)), "key is content-determined");
        // Mutating one element must change the key (cache invalidation).
        let mut data: Vec<f32> = a.as_slice().to_vec();
        data[17] += 1.0;
        let a2 = Matrix::from_slice(8, 8, &data);
        assert_ne!(k1, operand_key(&split_blocks(&a2)));
        let b = Matrix::random(16, 16, &mut rng);
        assert_ne!(k1, operand_key(&split_blocks(&b)));
    }
}
