//! The request loop: a multiplexed multiply server over the
//! message-driven serving tier.
//!
//! Jobs are accepted up to an outstanding-job cap (`queue_cap`,
//! admission backpressure) and executed by the [`ServingTier`] with up
//! to `inflight_depth` jobs in flight at once — while one job waits on
//! its last few replies, the fleet's idle slots run the next jobs'
//! items. Multi-tenant deployments construct the server through
//! [`MmServer::with_tier_config`], which exposes the tier's full knob
//! set: per-tenant weights and quotas (deficit-round-robin fair
//! queuing), dispatch batching, and the encoded-operand cache. The
//! server tracks per-job latency, throughput and fault statistics and
//! produces the report the e2e benchmark (and `ft-strassen serve`)
//! prints. This is the moral equivalent of the router/launcher layer of
//! a serving system: config in, metrics out, no Python anywhere.

use std::time::{Duration, Instant};

use crate::coding::scheme::TaskSet;
use crate::coordinator::master::{MasterConfig, MultiplyReport};
use crate::coordinator::task::DispatchPlan;
use crate::coordinator::tier::{names, ServingTier, TenantSpec, TierConfig};
use crate::coordinator::worker::Backend;
use crate::linalg::matrix::Matrix;
use crate::metrics::Registry;
use crate::obs::Tracer;
use crate::sim::rng::Rng;

/// Server configuration (single-tenant; see [`MmServer::with_tier_config`]
/// for the multi-tenant surface).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub master: MasterConfig,
    /// Maximum outstanding jobs (queued + in flight) before `submit`
    /// reports backpressure.
    pub queue_cap: usize,
    /// Maximum concurrently in-flight jobs (1 = the paper's sequential
    /// master; larger values pipeline jobs over the shared fleet).
    pub inflight_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { master: MasterConfig::default(), queue_cap: 1024, inflight_depth: 4 }
    }
}

/// Completed job with its report.
pub struct Completed {
    pub id: u64,
    /// Tenant the job was submitted under ("default" unless the server
    /// was built with explicit tenants).
    pub tenant: String,
    pub c: Matrix,
    pub report: MultiplyReport,
    /// Queue wait + execution.
    pub total_latency: Duration,
}

/// Aggregate statistics after a run.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub jobs: usize,
    pub wall: Duration,
    pub throughput_jobs_per_s: f64,
    pub mean_latency: Duration,
    pub p95_latency: Duration,
    pub decoded: usize,
    pub fell_back: usize,
    pub mean_finished_workers: f64,
}

/// Multiplexed multiply server.
pub struct MmServer {
    tier: ServingTier,
    queue_cap: usize,
    /// Tenant rotation order for [`Self::run_workload`]; `submit` always
    /// targets the first entry.
    tenants: Vec<String>,
    completed_latencies: Vec<Duration>,
    decoded: usize,
    fell_back: usize,
    finished_sum: u64,
    jobs_done: usize,
    /// Failed jobs (id, error) not yet collected via [`Self::take_failures`].
    failures: Vec<(u64, String)>,
}

impl MmServer {
    pub fn new(set: TaskSet, backend: Backend, cfg: ServerConfig) -> MmServer {
        MmServer::with_plan(DispatchPlan::flat(set), backend, cfg, None)
    }

    /// Serve an arbitrary dispatch plan (e.g. a nested two-level scheme)
    /// with an optional worker-pool-size override — the nested fan-out's
    /// leaves multiplex onto the fleet, so "equal node count" comparisons
    /// pin `workers` to the flat scheme's task count.
    pub fn with_plan(
        plan: DispatchPlan,
        backend: Backend,
        cfg: ServerConfig,
        workers: Option<usize>,
    ) -> MmServer {
        MmServer::with_tier_config(
            plan,
            backend,
            TierConfig {
                master: cfg.master,
                depth: cfg.inflight_depth,
                queue_cap: cfg.queue_cap,
                tenants: vec![TenantSpec::unbounded("default")],
                batch_window: 1,
                cache_cap: 0,
            },
            workers,
        )
    }

    /// Serve with the full tier configuration: tenants (DRR weights +
    /// in-flight quotas), batch window, and encoded-operand cache.
    pub fn with_tier_config(
        plan: DispatchPlan,
        backend: Backend,
        cfg: TierConfig,
        workers: Option<usize>,
    ) -> MmServer {
        MmServer::with_tier_config_traced(plan, backend, cfg, workers, Tracer::off())
    }

    /// [`Self::with_tier_config`] plus a trace sink: the tracer is
    /// threaded through the tier, its worker fleet and every job's
    /// decode state, so the whole leaf lifecycle lands in one trace.
    pub fn with_tier_config_traced(
        plan: DispatchPlan,
        backend: Backend,
        cfg: TierConfig,
        workers: Option<usize>,
        tracer: Tracer,
    ) -> MmServer {
        let queue_cap = cfg.queue_cap;
        let tier = ServingTier::with_plan_traced(plan, backend, cfg, workers, tracer);
        let tenants = tier.tenant_names();
        MmServer {
            tier,
            queue_cap,
            tenants,
            completed_latencies: Vec::new(),
            decoded: 0,
            fell_back: 0,
            finished_sum: 0,
            jobs_done: 0,
            failures: Vec::new(),
        }
    }

    /// Enqueue a job under the first tenant. Returns its id, or `Err` on
    /// backpressure.
    pub fn submit(&mut self, a: Matrix, b: Matrix) -> Result<u64, String> {
        let tenant = self.tenants[0].clone();
        self.submit_as(&tenant, a, b)
    }

    /// Enqueue a job under `tenant`. Returns its id, or `Err` on
    /// backpressure or unknown tenant.
    pub fn submit_as(&mut self, tenant: &str, a: Matrix, b: Matrix) -> Result<u64, String> {
        if self.tier.outstanding() >= self.queue_cap {
            return Err(format!("queue full ({} jobs)", self.queue_cap));
        }
        self.tier.submit(tenant, a, b)
    }

    /// Jobs accepted but not yet completed (queued + in flight).
    pub fn queue_depth(&self) -> usize {
        self.tier.outstanding()
    }

    /// Tenant names in admission-rotation order.
    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.clone()
    }

    /// Shared handle to the tier's metric registry (in-flight depth,
    /// slot utilization, stale-reply drops, cancelled items, per-tenant
    /// latency, cache hit rate...).
    pub fn registry(&self) -> Registry {
        self.tier.metrics.clone()
    }

    /// The tracer threaded through the tier (off unless built via
    /// [`Self::with_tier_config_traced`]).
    pub fn tracer(&self) -> &Tracer {
        self.tier.tracer()
    }

    /// Run until up to `max_jobs` jobs complete; returns their results
    /// in completion order. Successful jobs in a batch are always
    /// recorded and returned, even when other jobs in the same batch
    /// failed (possible only with `fallback_local` disabled): failures
    /// are stashed with their job id and error for
    /// [`Self::take_failures`] and counted in the `jobs_failed` metric.
    /// `Err` is returned only when the batch produced no successes at
    /// all, so completed work is never lost.
    pub fn drain(&mut self, max_jobs: usize) -> Result<Vec<Completed>, String> {
        let finished = self.tier.drive(max_jobs);
        let mut out = Vec::with_capacity(finished.len());
        let mut batch_first_err: Option<(u64, String)> = None;
        for f in finished {
            let (c, report) = match f.result {
                Ok(ok) => ok,
                Err(e) => {
                    self.tier.metrics.counter(names::JOBS_FAILED).inc();
                    if batch_first_err.is_none() {
                        batch_first_err = Some((f.job_id, e.clone()));
                    }
                    self.failures.push((f.job_id, e));
                    continue;
                }
            };
            if report.fell_back {
                self.fell_back += 1;
            } else {
                self.decoded += 1;
            }
            self.finished_sum += report.finished as u64;
            self.jobs_done += 1;
            self.completed_latencies.push(f.total_latency);
            out.push(Completed {
                id: f.job_id,
                tenant: f.tenant,
                c,
                report,
                total_latency: f.total_latency,
            });
        }
        match batch_first_err {
            Some((_, e)) if out.is_empty() => Err(e),
            _ => Ok(out),
        }
    }

    /// Drain the accumulated per-job failures (id, error). Non-empty
    /// only when `fallback_local` is disabled.
    pub fn take_failures(&mut self) -> Vec<(u64, String)> {
        std::mem::take(&mut self.failures)
    }

    /// Convenience: run a synthetic workload of `jobs` random multiplies
    /// of size `n`, keeping the in-flight window full, and report
    /// aggregates. Operands are generated in submission order from the
    /// seed, so the job stream is identical at every depth. With
    /// multiple tenants, submission round-robins across them (the tier's
    /// DRR then decides who actually runs).
    ///
    /// Submission is windowed at the in-flight depth (closed loop), not
    /// at `queue_cap`: jobs are only submitted when an admission slot is
    /// free, so reported latencies measure service time rather than
    /// synthetic backlog wait, and only `depth` jobs' operands are ever
    /// held at once.
    pub fn run_workload(&mut self, jobs: usize, n: usize, seed: u64) -> Result<ServerReport, String> {
        self.run_workload_observed(jobs, n, seed, 0, &mut |_, _| {})
    }

    /// [`Self::run_workload`] plus periodic metrics: after every
    /// `metrics_every` completed jobs (0 disables it), `on_metrics` is
    /// called with the completed-job count and a Prometheus text
    /// exposition of the tier registry (the `--metrics-every` flag of
    /// `ft-strassen serve`).
    pub fn run_workload_observed(
        &mut self,
        jobs: usize,
        n: usize,
        seed: u64,
        metrics_every: usize,
        on_metrics: &mut dyn FnMut(usize, &str),
    ) -> Result<ServerReport, String> {
        let mut rng = Rng::seeded(seed);
        let window = self.tier.depth().min(self.queue_cap.max(1));
        let t0 = Instant::now();
        let start_done = self.jobs_done;
        let mut reported = 0usize;
        let mut emit = |srv: &mut MmServer, reported: &mut usize| {
            if metrics_every == 0 {
                return;
            }
            let done = srv.jobs_done - start_done;
            if done / metrics_every > *reported {
                *reported = done / metrics_every;
                let text = crate::obs::prometheus_text(&srv.tier.metrics);
                on_metrics(done, &text);
            }
        };
        let mut submitted = 0usize;
        while submitted < jobs {
            // Closed loop: complete jobs until an in-flight slot frees up.
            while self.tier.outstanding() >= window {
                self.drain(1)?;
                emit(self, &mut reported);
            }
            let a = Matrix::random(n, n, &mut rng);
            let b = Matrix::random(n, n, &mut rng);
            let tenant = self.tenants[submitted % self.tenants.len()].clone();
            self.submit_as(&tenant, a, b)?;
            submitted += 1;
        }
        while self.queue_depth() > 0 {
            self.drain(usize::MAX)?;
            emit(self, &mut reported);
        }
        Ok(self.report(t0.elapsed()))
    }

    /// Build the aggregate report for everything completed so far.
    pub fn report(&self, wall: Duration) -> ServerReport {
        let n = self.completed_latencies.len().max(1);
        let mut sorted = self.completed_latencies.clone();
        sorted.sort();
        let mean = sorted.iter().sum::<Duration>() / n as u32;
        let p95 = sorted
            .get(((n as f64 * 0.95) as usize).min(n - 1))
            .copied()
            .unwrap_or(Duration::ZERO);
        ServerReport {
            jobs: self.jobs_done,
            wall,
            throughput_jobs_per_s: self.jobs_done as f64 / wall.as_secs_f64().max(1e-9),
            mean_latency: mean,
            p95_latency: p95,
            decoded: self.decoded,
            fell_back: self.fell_back,
            mean_finished_workers: self.finished_sum as f64 / self.jobs_done.max(1) as f64,
        }
    }

    /// Metrics snapshot from the underlying tier.
    pub fn metrics(&self) -> String {
        self.tier.metrics.snapshot()
    }

    pub fn shutdown(self) {
        self.tier.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::FaultPlan;

    fn server(fault: FaultPlan) -> MmServer {
        server_at_depth(fault, 2)
    }

    fn server_at_depth(fault: FaultPlan, depth: usize) -> MmServer {
        MmServer::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            ServerConfig {
                master: MasterConfig {
                    deadline: Duration::from_secs(5),
                    fault,
                    seed: 1,
                    fallback_local: true,
                    collect_all: false,
                },
                queue_cap: 8,
                inflight_depth: depth,
            },
        )
    }

    #[test]
    fn workload_runs_and_reports() {
        let mut s = server(FaultPlan::NONE);
        let report = s.run_workload(5, 16, 42).unwrap();
        assert_eq!(report.jobs, 5);
        assert_eq!(report.decoded, 5);
        assert_eq!(report.fell_back, 0);
        assert!(report.throughput_jobs_per_s > 0.0);
        assert!(report.mean_latency > Duration::ZERO);
        // With no faults the decoder stops at rank coverage: between 7
        // (lower bound, impossible to be lower) and 16 replies used.
        assert!(report.mean_finished_workers >= 7.0);
        assert!(report.mean_finished_workers <= 16.0);
        s.shutdown();
    }

    #[test]
    fn backpressure_at_queue_cap() {
        let mut s = server(FaultPlan::NONE);
        for _ in 0..8 {
            s.submit(Matrix::zeros(4, 4), Matrix::zeros(4, 4)).unwrap();
        }
        let err = s.submit(Matrix::zeros(4, 4), Matrix::zeros(4, 4)).unwrap_err();
        assert!(err.contains("queue full"));
        // Draining frees capacity.
        let done = s.drain(3).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(s.queue_depth(), 5);
        s.submit(Matrix::zeros(4, 4), Matrix::zeros(4, 4)).unwrap();
        s.shutdown();
    }

    #[test]
    fn results_are_correct_under_faults() {
        let mut s = server(FaultPlan {
            p_fail: 0.2,
            p_straggle: 0.0,
            delay: Duration::ZERO,
        });
        let mut rng = Rng::seeded(9);
        let a = Matrix::random(16, 16, &mut rng);
        let b = Matrix::random(16, 16, &mut rng);
        let want = a.matmul(&b);
        s.submit(a, b).unwrap();
        let done = s.drain(10).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tenant, "default");
        assert!(done[0].c.approx_eq(&want, 1e-4));
        s.shutdown();
    }

    #[test]
    fn deep_pipeline_matches_dense_ground_truth() {
        let mut s = server_at_depth(
            FaultPlan { p_fail: 0.1, p_straggle: 0.2, delay: Duration::from_millis(10) },
            4,
        );
        let mut rng = Rng::seeded(17);
        let mut want = Vec::new();
        for _ in 0..6 {
            let a = Matrix::random(16, 16, &mut rng);
            let b = Matrix::random(16, 16, &mut rng);
            want.push(a.matmul(&b));
            // queue_cap 8 >= 6: no backpressure expected
            s.submit(a, b).unwrap();
        }
        let mut done = s.drain(usize::MAX).unwrap();
        assert_eq!(done.len(), 6);
        done.sort_by_key(|c| c.id);
        for (d, w) in done.iter().zip(&want) {
            assert!(d.c.approx_eq(w, 1e-4), "job {} rel {}", d.id, d.c.rel_error(w));
        }
        s.shutdown();
    }

    #[test]
    fn stale_straggler_replies_are_dropped_and_counted() {
        // Regression for cross-job reply leakage: job 1's stragglers
        // answer only after job 1 already completed (fallback at its
        // 40 ms deadline); their late replies arrive while later jobs
        // are open and must be dropped by the job_id guard — never
        // spliced into another job's decode state.
        let mut s = MmServer::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            ServerConfig {
                master: MasterConfig {
                    deadline: Duration::from_millis(40),
                    fault: FaultPlan {
                        p_fail: 0.0,
                        p_straggle: 1.0,
                        delay: Duration::from_millis(60),
                    },
                    seed: 1,
                    fallback_local: true,
                    collect_all: false,
                },
                queue_cap: 8,
                inflight_depth: 1,
            },
        );
        let mut rng = Rng::seeded(3);
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        let want = a.matmul(&b);
        for _ in 0..3 {
            s.submit(a.clone(), b.clone()).unwrap();
            let done = s.drain(1).unwrap();
            assert_eq!(done.len(), 1);
            // All 16 replies are delayed past the deadline: every job
            // falls back, and every job's answer is still correct.
            assert!(done[0].report.fell_back);
            assert!(done[0].c.approx_eq(&want, 1e-5));
        }
        let stale = s.registry().counter("replies_stale_dropped").get();
        assert!(stale >= 16, "expected job 1's 16 late replies dropped, got {stale}");
        s.shutdown();
    }

    #[test]
    fn drain_surfaces_failure_when_nothing_succeeded() {
        let mut s = MmServer::new(
            TaskSet::replication(&crate::algorithms::strassen(), 1),
            Backend::Native,
            ServerConfig {
                master: MasterConfig {
                    deadline: Duration::from_millis(200),
                    fault: FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                    seed: 3,
                    fallback_local: false,
                    collect_all: false,
                },
                queue_cap: 8,
                inflight_depth: 2,
            },
        );
        s.submit(Matrix::zeros(8, 8), Matrix::zeros(8, 8)).unwrap();
        let err = s.drain(1).unwrap_err();
        assert!(err.contains("not decodable"), "{err}");
        assert_eq!(s.registry().counter("jobs_failed").get(), 1);
        let failures = s.take_failures();
        assert_eq!(failures.len(), 1);
        assert_eq!(failures[0].0, 1, "failed job id is retained");
        assert!(s.take_failures().is_empty(), "take drains the buffer");
        // A later, empty drain must not resurrect the old failure.
        assert!(s.drain(1).unwrap().is_empty());
        s.shutdown();
    }

    #[test]
    fn nested_plan_serves_a_workload() {
        use crate::coding::nested::NestedTaskSet;
        let plan = DispatchPlan::nested(NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(0),
        ));
        let mut s = MmServer::with_plan(
            plan,
            Backend::Native,
            ServerConfig {
                master: MasterConfig {
                    deadline: Duration::from_secs(10),
                    fault: FaultPlan { p_fail: 0.05, p_straggle: 0.0, delay: Duration::ZERO },
                    seed: 2,
                    fallback_local: true,
                    collect_all: false,
                },
                queue_cap: 8,
                inflight_depth: 2,
            },
            Some(14),
        );
        let report = s.run_workload(3, 16, 5).unwrap();
        assert_eq!(report.jobs, 3);
        assert!(report.decoded >= 2, "196-leaf scheme should survive p=0.05");
        s.shutdown();
    }

    #[test]
    fn multi_tenant_server_round_robins_submissions() {
        let mut s = MmServer::with_tier_config(
            DispatchPlan::flat(TaskSet::strassen_winograd(0)),
            Backend::Native,
            TierConfig {
                master: MasterConfig {
                    deadline: Duration::from_secs(5),
                    fault: FaultPlan::NONE,
                    seed: 1,
                    fallback_local: true,
                    collect_all: false,
                },
                depth: 2,
                queue_cap: 16,
                tenants: vec![TenantSpec::new("alpha", 2, 8), TenantSpec::new("beta", 1, 8)],
                batch_window: 2,
                cache_cap: 4,
            },
            None,
        );
        assert_eq!(s.tenant_names(), vec!["alpha".to_string(), "beta".to_string()]);
        let report = s.run_workload(6, 8, 11).unwrap();
        assert_eq!(report.jobs, 6);
        let reg = s.registry();
        assert_eq!(reg.counter("tenant_jobs_alpha").get(), 3);
        assert_eq!(reg.counter("tenant_jobs_beta").get(), 3);
        s.shutdown();
    }

    #[test]
    fn metrics_snapshot_nonempty_after_jobs() {
        let mut s = server(FaultPlan::NONE);
        s.run_workload(2, 8, 1).unwrap();
        let m = s.metrics();
        assert!(m.contains("jobs_dispatched"), "{m}");
        assert!(m.contains("pool_items_executed"), "{m}");
        s.shutdown();
    }
}
