//! The request loop: a batched multiply server over one [`Master`].
//!
//! Jobs are accepted into a FIFO queue and executed by the master; the
//! server tracks per-job latency, throughput and fault statistics and
//! produces the report the e2e benchmark (and `ft-strassen serve`)
//! prints. This is the moral equivalent of the router/launcher layer of
//! a serving system: config in, metrics out, no Python anywhere.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coding::scheme::TaskSet;
use crate::coordinator::master::{Master, MasterConfig, MultiplyReport};
use crate::coordinator::worker::Backend;
use crate::linalg::matrix::Matrix;
use crate::sim::rng::Rng;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub master: MasterConfig,
    /// Maximum queued jobs before `submit` reports backpressure.
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { master: MasterConfig::default(), queue_cap: 1024 }
    }
}

/// One queued multiply job.
pub struct Job {
    pub id: u64,
    pub a: Matrix,
    pub b: Matrix,
}

/// Completed job with its report.
pub struct Completed {
    pub id: u64,
    pub c: Matrix,
    pub report: MultiplyReport,
    /// Queue wait + execution.
    pub total_latency: Duration,
}

/// Aggregate statistics after a run.
#[derive(Clone, Debug)]
pub struct ServerReport {
    pub jobs: usize,
    pub wall: Duration,
    pub throughput_jobs_per_s: f64,
    pub mean_latency: Duration,
    pub p95_latency: Duration,
    pub decoded: usize,
    pub fell_back: usize,
    pub mean_finished_workers: f64,
}

/// Batched multiply server.
pub struct MmServer {
    master: Master,
    queue: VecDeque<(Job, Instant)>,
    cfg: ServerConfig,
    completed_latencies: Vec<Duration>,
    decoded: usize,
    fell_back: usize,
    finished_sum: u64,
    jobs_done: usize,
    next_id: u64,
}

impl MmServer {
    pub fn new(set: TaskSet, backend: Backend, cfg: ServerConfig) -> MmServer {
        MmServer {
            master: Master::new(set, backend, cfg.master.clone()),
            queue: VecDeque::new(),
            cfg,
            completed_latencies: Vec::new(),
            decoded: 0,
            fell_back: 0,
            finished_sum: 0,
            jobs_done: 0,
            next_id: 0,
        }
    }

    /// Enqueue a job. Returns its id, or `Err` on backpressure.
    pub fn submit(&mut self, a: Matrix, b: Matrix) -> Result<u64, String> {
        if self.queue.len() >= self.cfg.queue_cap {
            return Err(format!("queue full ({} jobs)", self.cfg.queue_cap));
        }
        self.next_id += 1;
        let id = self.next_id;
        self.queue.push_back((Job { id, a, b }, Instant::now()));
        Ok(id)
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Run at most `max_jobs` queued jobs; returns their results.
    pub fn drain(&mut self, max_jobs: usize) -> Result<Vec<Completed>, String> {
        let mut out = Vec::new();
        for _ in 0..max_jobs {
            let Some((job, enqueued)) = self.queue.pop_front() else {
                break;
            };
            let (c, report) = self.master.multiply(&job.a, &job.b)?;
            let total_latency = enqueued.elapsed();
            if report.fell_back {
                self.fell_back += 1;
            } else {
                self.decoded += 1;
            }
            self.finished_sum += report.finished as u64;
            self.jobs_done += 1;
            self.completed_latencies.push(total_latency);
            out.push(Completed { id: job.id, c, report, total_latency });
        }
        Ok(out)
    }

    /// Convenience: run a synthetic workload of `jobs` random multiplies
    /// of size `n`, draining as we go, and report aggregates.
    pub fn run_workload(&mut self, jobs: usize, n: usize, seed: u64) -> Result<ServerReport, String> {
        let mut rng = Rng::seeded(seed);
        let t0 = Instant::now();
        for _ in 0..jobs {
            let a = Matrix::random(n, n, &mut rng);
            let b = Matrix::random(n, n, &mut rng);
            self.submit(a, b)?;
            // Immediate drain keeps queue depth at 1 — the paper's
            // one-job-at-a-time master. Larger batches are exercised by
            // the e2e bench via submit-all-then-drain.
            self.drain(1)?;
        }
        Ok(self.report(t0.elapsed()))
    }

    /// Build the aggregate report for everything completed so far.
    pub fn report(&self, wall: Duration) -> ServerReport {
        let n = self.completed_latencies.len().max(1);
        let mut sorted = self.completed_latencies.clone();
        sorted.sort();
        let mean = sorted.iter().sum::<Duration>() / n as u32;
        let p95 = sorted
            .get(((n as f64 * 0.95) as usize).min(n - 1))
            .copied()
            .unwrap_or(Duration::ZERO);
        ServerReport {
            jobs: self.jobs_done,
            wall,
            throughput_jobs_per_s: self.jobs_done as f64 / wall.as_secs_f64().max(1e-9),
            mean_latency: mean,
            p95_latency: p95,
            decoded: self.decoded,
            fell_back: self.fell_back,
            mean_finished_workers: self.finished_sum as f64 / self.jobs_done.max(1) as f64,
        }
    }

    /// Metrics snapshot from the underlying master.
    pub fn metrics(&self) -> String {
        self.master.metrics.snapshot()
    }

    pub fn shutdown(self) {
        self.master.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::FaultPlan;

    fn server(fault: FaultPlan) -> MmServer {
        MmServer::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            ServerConfig {
                master: MasterConfig {
                    deadline: Duration::from_secs(5),
                    fault,
                    seed: 1,
                    fallback_local: true,
                },
                queue_cap: 8,
            },
        )
    }

    #[test]
    fn workload_runs_and_reports() {
        let mut s = server(FaultPlan::NONE);
        let report = s.run_workload(5, 16, 42).unwrap();
        assert_eq!(report.jobs, 5);
        assert_eq!(report.decoded, 5);
        assert_eq!(report.fell_back, 0);
        assert!(report.throughput_jobs_per_s > 0.0);
        assert!(report.mean_latency > Duration::ZERO);
        // With no faults the decoder stops at rank coverage: between 7
        // (lower bound, impossible to be lower) and 16 replies used.
        assert!(report.mean_finished_workers >= 7.0);
        assert!(report.mean_finished_workers <= 16.0);
        s.shutdown();
    }

    #[test]
    fn backpressure() {
        let mut s = server(FaultPlan::NONE);
        for _ in 0..8 {
            s.submit(Matrix::zeros(4, 4), Matrix::zeros(4, 4)).unwrap();
        }
        let err = s.submit(Matrix::zeros(4, 4), Matrix::zeros(4, 4)).unwrap_err();
        assert!(err.contains("queue full"));
        // Draining frees capacity.
        let done = s.drain(3).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(s.queue_depth(), 5);
        s.submit(Matrix::zeros(4, 4), Matrix::zeros(4, 4)).unwrap();
        s.shutdown();
    }

    #[test]
    fn results_are_correct_under_faults() {
        let mut s = server(FaultPlan {
            p_fail: 0.2,
            p_straggle: 0.0,
            delay: Duration::ZERO,
        });
        let mut rng = Rng::seeded(9);
        let a = Matrix::random(16, 16, &mut rng);
        let b = Matrix::random(16, 16, &mut rng);
        let want = a.matmul(&b);
        s.submit(a, b).unwrap();
        let done = s.drain(10).unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].c.approx_eq(&want, 1e-4));
        s.shutdown();
    }

    #[test]
    fn metrics_snapshot_nonempty_after_jobs() {
        let mut s = server(FaultPlan::NONE);
        s.run_workload(2, 8, 1).unwrap();
        let m = s.metrics();
        assert!(m.contains("jobs_dispatched"), "{m}");
        s.shutdown();
    }
}
