//! The distributed coordinator — the paper's system realized as a
//! message-driven serving tier over an event-loop worker fleet, with
//! fault injection, tenant fair queuing, dispatch batching and an
//! encoded-operand cache.
//!
//! Architecture (the protocol-split refactor):
//!
//! * [`proto`] — the typed message protocol: [`proto::ToWorker`]
//!   (`AssignLeaf`, `Revoke`, `Heartbeat`, `Shutdown`) and
//!   [`proto::ToCoord`] (`Register`, `Ready`, `LeafResult`,
//!   `RevokeAck`, `HeartbeatAck`), plus [`proto::JobDone`] for
//!   completions. Messages own their payloads (no channel or thread
//!   handles), and [`proto::wire`] gives them a length-prefixed binary
//!   framing — the same protocol can run over sockets.
//! * [`transport`] — the [`transport::Transport`] trait (the tier's
//!   only view of the fleet) and the in-process
//!   [`transport::ChannelTransport`]: per-worker mailboxes, one return
//!   channel, and a delay line so stragglers reply late without
//!   blocking a worker slot.
//! * [`worker`] — workers as independent event-loop tasks: each drains
//!   its mailbox, computes assigned leaves (native or PJRT), applies
//!   its injected [`worker::FaultAction`] (failed nodes never answer;
//!   stragglers answer through the delay line), and pulls more work by
//!   sending `Ready`. [`worker::WorkerFleet`] owns the threads and the
//!   transport.
//! * [`job`] — the per-job decode state machine: an incremental
//!   `SpanDecoder` (or, for nested two-level schemes, one inner decoder
//!   per outer group plus the outer decoder — the two-stage path), the
//!   finished products and the deadline for one multiply job, keyed by
//!   `job_id`.
//! * [`task`] — the dispatch plans: a flat [`TaskGraph`] (one item per
//!   task, the paper's model) or a nested `NestedGraph` (M₁·M₂ leaf
//!   items, grouped by outer product, ids contiguous per group).
//! * [`tier`] — the serving tier proper: per-tenant admission queues
//!   drained by deficit round robin (weights = relative shares, quotas
//!   = per-tenant in-flight caps), dispatch rounds coalesced up to a
//!   batch window, an LRU cache of encoded left operands keyed by
//!   content hash, pull-based dispatch (one assignment per worker
//!   `Ready`), stale-reply guarding, eager group revocation, and
//!   heartbeat liveness. Fault stamps stay a pure function of
//!   (seed, job, item), so seeded streams are bit-reproducible across
//!   depth, pool size, batching, tenant layout and cache state.
//! * [`scheduler`] — the legacy single-tenant facade over the tier
//!   (exact `submit`/`drive`/`poll` surface of the multiplexed
//!   scheduler it replaced).
//! * [`master`] — the sequential facade: encode → dispatch → collect
//!   with online span decoding → recover → assemble, exactly the
//!   master-node role of the paper's Fig. 1, implemented as a depth-1
//!   scheduler.
//! * [`server`] — the request loop: admission **backpressure** at an
//!   outstanding-job cap, pipelined draining, per-tenant submission,
//!   latency/throughput reports and the tier's metric registry.

pub mod job;
pub mod master;
pub mod proto;
pub mod scheduler;
pub mod server;
pub mod task;
pub mod tier;
pub mod transport;
pub mod worker;

pub use job::JobState;
pub use master::{Master, MasterConfig, MultiplyReport};
pub use proto::JobDone;
pub use scheduler::{FinishedJob, Scheduler, SchedulerConfig};
pub use server::{MmServer, ServerConfig, ServerReport};
pub use task::{DispatchPlan, NestedGraph, TaskGraph};
pub use tier::{ServingTier, TenantSpec, TierConfig};
pub use transport::{ChannelTransport, Transport};
pub use worker::{Backend, FaultPlan, WorkerFleet};
