//! The distributed coordinator — the paper's system realized on a
//! shared thread-pool fleet with fault injection, serving many multiply
//! jobs concurrently.
//!
//! Scheduling model (the multiplexed-coordinator refactor):
//!
//! * [`worker`] — the shared worker fleet: a fixed set of node threads
//!   draining ONE work queue, so any idle slot executes the next item
//!   regardless of which job produced it. Stragglers are modeled as
//!   delayed replies (a delay line defers delivery without blocking the
//!   slot); failed nodes never answer.
//! * [`job`] — the per-job decode state machine: an incremental
//!   `SpanDecoder` (or, for nested two-level schemes, one inner decoder
//!   per outer group plus the outer decoder — the two-stage path), the
//!   finished products and the deadline for one multiply job, keyed by
//!   `job_id`.
//! * [`task`] — the dispatch plans: a flat [`TaskGraph`] (one item per
//!   task, the paper's model) or a nested `NestedGraph` (M₁·M₂ leaf
//!   items, grouped by outer product, ids contiguous per group).
//! * [`scheduler`] — the job multiplexer: admits jobs up to a
//!   configurable **in-flight depth**, stamps each work item's fault at
//!   admission as a pure function of (seed, job, item) — so seeded
//!   streams see identical fault patterns at every depth, pool size and
//!   thread count — routes
//!   replies to their job by `job_id` — dropping and counting replies
//!   for closed jobs (the cross-job leakage guard) — and **cancels**
//!   a completed job's outstanding items so straggler-freed slots
//!   immediately pick up the next job's work. Nested jobs additionally
//!   cancel an entire inner group's queued leaves the moment that
//!   group's product is recovered.
//! * [`master`] — the sequential facade: encode → dispatch → collect
//!   with online span decoding → recover → assemble, exactly the
//!   master-node role of the paper's Fig. 1, implemented as a depth-1
//!   scheduler.
//! * [`server`] — the request loop: admission **backpressure** at an
//!   outstanding-job cap, pipelined draining, latency/throughput
//!   reports and a fleet-level metric registry (in-flight depth, slot
//!   utilization, stale drops, cancelled items).

pub mod job;
pub mod master;
pub mod scheduler;
pub mod server;
pub mod task;
pub mod worker;

pub use job::JobState;
pub use master::{Master, MasterConfig, MultiplyReport};
pub use scheduler::{FinishedJob, Scheduler, SchedulerConfig};
pub use server::{MmServer, ServerConfig, ServerReport};
pub use task::{DispatchPlan, NestedGraph, TaskGraph};
pub use worker::{Backend, FaultPlan, WorkerPool};
