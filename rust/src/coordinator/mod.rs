//! The distributed coordinator — the paper's system realized on a
//! thread-per-worker pool with fault injection.
//!
//! * [`task`] — the dispatchable task graph derived from a
//!   [`crate::coding::scheme::TaskSet`].
//! * [`worker`] — the worker pool: each node computes exactly one encoded
//!   block product per job, on the native or PJRT backend, with
//!   configurable fault/straggler injection.
//! * [`master`] — encode → dispatch → collect with an online span decoder
//!   → recover → assemble, exactly the master-node role of the paper's
//!   Fig. 1 (plus a deadline/fallback policy the paper leaves implicit).
//! * [`server`] — a batched request loop over the master for serving
//!   streams of multiply jobs, with metrics.

pub mod master;
pub mod server;
pub mod task;
pub mod worker;

pub use master::{Master, MasterConfig, MultiplyReport};
pub use server::{MmServer, ServerConfig, ServerReport};
pub use task::TaskGraph;
pub use worker::{Backend, FaultPlan, WorkerPool};
