//! The dispatchable task graph: per-node encoding coefficients plus the
//! decode machinery (relations, decoder seeds) derived once per task set
//! and shared by every job.

use std::sync::Arc;

use crate::coding::decoder::SpanDecoder;
use crate::coding::scheme::TaskSet;

/// One dispatchable task (a worker's entire job description).
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: usize,
    pub name: String,
    /// Left/right encoding coefficients as f32 (what the encoder kernel
    /// consumes).
    pub ca: [f32; 4],
    pub cb: [f32; 4],
}

/// The full graph for a task set.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    pub set: Arc<TaskSet>,
    pub specs: Vec<TaskSpec>,
}

impl TaskGraph {
    pub fn new(set: TaskSet) -> TaskGraph {
        let specs = set
            .tasks
            .iter()
            .enumerate()
            .map(|(id, t)| {
                let f = |c: &[i32; 4]| {
                    let mut out = [0.0f32; 4];
                    for (o, &x) in out.iter_mut().zip(c.iter()) {
                        *o = x as f32;
                    }
                    out
                };
                TaskSpec { id, name: t.name.clone(), ca: f(&t.u), cb: f(&t.v) }
            })
            .collect();
        TaskGraph { set: Arc::new(set), specs }
    }

    pub fn num_tasks(&self) -> usize {
        self.specs.len()
    }

    /// A fresh online decoder for one job.
    pub fn decoder(&self) -> SpanDecoder {
        SpanDecoder::new(&self.set)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_carry_scheme_coefficients() {
        let g = TaskGraph::new(TaskSet::strassen_winograd(2));
        assert_eq!(g.num_tasks(), 16);
        // S1 = (M11 + M22)(B11 + B22)
        assert_eq!(g.specs[0].ca, [1.0, 0.0, 0.0, 1.0]);
        assert_eq!(g.specs[0].cb, [1.0, 0.0, 0.0, 1.0]);
        assert_eq!(g.specs[0].name, "S1");
        // W2 = M12 B21
        assert_eq!(g.specs[8].ca, [0.0, 1.0, 0.0, 0.0]);
        assert_eq!(g.specs[8].cb, [0.0, 0.0, 1.0, 0.0]);
        // PSMM names
        assert_eq!(g.specs[14].name, "P1");
        assert_eq!(g.specs[15].name, "P2");
    }

    #[test]
    fn decoder_is_fresh_per_call() {
        let g = TaskGraph::new(TaskSet::strassen_winograd(0));
        let mut d1 = g.decoder();
        for i in 0..14 {
            d1.on_finished(i);
        }
        assert!(d1.is_decodable());
        let d2 = g.decoder();
        assert!(!d2.is_decodable());
    }
}
