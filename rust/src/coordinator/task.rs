//! The dispatchable task graph: per-node encoding coefficients plus the
//! decode machinery (relations, decoder seeds) derived once per task set
//! and shared by every job — for flat single-level sets and for nested
//! two-level sets ([`DispatchPlan`]).

use std::ops::Range;
use std::sync::Arc;

use crate::coding::decoder::SpanDecoder;
use crate::coding::nested::NestedTaskSet;
use crate::coding::scheme::TaskSet;

/// One dispatchable task (a worker's entire job description).
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub id: usize,
    pub name: String,
    /// Left/right encoding coefficients as f32 (what the encoder kernel
    /// consumes).
    pub ca: [f32; 4],
    pub cb: [f32; 4],
}

impl TaskSpec {
    /// Integer view of the left coefficients (they are small integers).
    pub fn int_ca(&self) -> [i32; 4] {
        std::array::from_fn(|i| self.ca[i] as i32)
    }

    /// Integer view of the right coefficients.
    pub fn int_cb(&self) -> [i32; 4] {
        std::array::from_fn(|i| self.cb[i] as i32)
    }
}

/// The full graph for a task set.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    pub set: Arc<TaskSet>,
    pub specs: Vec<TaskSpec>,
}

impl TaskGraph {
    pub fn new(set: TaskSet) -> TaskGraph {
        let specs = set
            .tasks
            .iter()
            .enumerate()
            .map(|(id, t)| {
                let f = |c: &[i32; 4]| {
                    let mut out = [0.0f32; 4];
                    for (o, &x) in out.iter_mut().zip(c.iter()) {
                        *o = x as f32;
                    }
                    out
                };
                TaskSpec { id, name: t.name.clone(), ca: f(&t.u), cb: f(&t.v) }
            })
            .collect();
        TaskGraph { set: Arc::new(set), specs }
    }

    pub fn num_tasks(&self) -> usize {
        self.specs.len()
    }

    /// A fresh online decoder for one job.
    pub fn decoder(&self) -> SpanDecoder {
        SpanDecoder::new(&self.set)
    }
}

/// The two-level graph for a nested task set: the outer graph indexes
/// the M₁ groups, the inner graph the M₂ leaves of every group. Leaf
/// work-item ids are `g * M₂ + j` (group-major), so one group's items
/// form a contiguous range — what group-level cancellation revokes.
#[derive(Clone, Debug)]
pub struct NestedGraph {
    pub set: Arc<NestedTaskSet>,
    pub outer: TaskGraph,
    pub inner: TaskGraph,
}

impl NestedGraph {
    pub fn new(set: NestedTaskSet) -> NestedGraph {
        let outer = TaskGraph::new(set.outer.clone());
        let inner = TaskGraph::new(set.inner.clone());
        NestedGraph { set: Arc::new(set), outer, inner }
    }

    pub fn num_groups(&self) -> usize {
        self.outer.num_tasks()
    }

    pub fn group_size(&self) -> usize {
        self.inner.num_tasks()
    }

    pub fn num_leaves(&self) -> usize {
        self.num_groups() * self.group_size()
    }

    /// Group of a leaf work-item id.
    pub fn group_of(&self, task_id: usize) -> usize {
        task_id / self.group_size()
    }

    /// The contiguous leaf id range of one group.
    pub fn group_range(&self, g: usize) -> Range<usize> {
        g * self.group_size()..(g + 1) * self.group_size()
    }
}

/// What the scheduler dispatches for one job: a flat single-level task
/// set (one work item per task, as in the paper) or a nested two-level
/// set (one work item per leaf, grouped by outer product).
#[derive(Clone, Debug)]
pub enum DispatchPlan {
    Flat(TaskGraph),
    Nested(NestedGraph),
}

impl DispatchPlan {
    pub fn flat(set: TaskSet) -> DispatchPlan {
        DispatchPlan::Flat(TaskGraph::new(set))
    }

    pub fn nested(set: NestedTaskSet) -> DispatchPlan {
        DispatchPlan::Nested(NestedGraph::new(set))
    }

    /// Scheme display name.
    pub fn name(&self) -> &str {
        match self {
            DispatchPlan::Flat(g) => &g.set.name,
            DispatchPlan::Nested(g) => &g.set.name,
        }
    }

    /// Work items dispatched per job (tasks, or leaves for nested).
    pub fn num_work_items(&self) -> usize {
        match self {
            DispatchPlan::Flat(g) => g.num_tasks(),
            DispatchPlan::Nested(g) => g.num_leaves(),
        }
    }

    /// Matrix dimension must be divisible by this (one 2×2 split level
    /// per nesting level).
    pub fn block_divisor(&self) -> usize {
        match self {
            DispatchPlan::Flat(_) => 2,
            DispatchPlan::Nested(_) => 4,
        }
    }

    /// Default worker-pool size: one node per task for flat sets (the
    /// paper's model); for nested fan-outs the pool is capped — leaves
    /// are multiplexed onto the fleet, they do not each own a thread.
    pub fn default_pool_size(&self) -> usize {
        match self {
            DispatchPlan::Flat(g) => g.num_tasks(),
            DispatchPlan::Nested(g) => g.num_leaves().min(64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_carry_scheme_coefficients() {
        let g = TaskGraph::new(TaskSet::strassen_winograd(2));
        assert_eq!(g.num_tasks(), 16);
        // S1 = (M11 + M22)(B11 + B22)
        assert_eq!(g.specs[0].ca, [1.0, 0.0, 0.0, 1.0]);
        assert_eq!(g.specs[0].cb, [1.0, 0.0, 0.0, 1.0]);
        assert_eq!(g.specs[0].name, "S1");
        assert_eq!(g.specs[0].int_ca(), [1, 0, 0, 1]);
        // W2 = M12 B21
        assert_eq!(g.specs[8].ca, [0.0, 1.0, 0.0, 0.0]);
        assert_eq!(g.specs[8].cb, [0.0, 0.0, 1.0, 0.0]);
        assert_eq!(g.specs[8].int_cb(), [0, 0, 1, 0]);
        // PSMM names
        assert_eq!(g.specs[14].name, "P1");
        assert_eq!(g.specs[15].name, "P2");
    }

    #[test]
    fn decoder_is_fresh_per_call() {
        let g = TaskGraph::new(TaskSet::strassen_winograd(0));
        let mut d1 = g.decoder();
        for i in 0..14 {
            d1.on_finished(i);
        }
        assert!(d1.is_decodable());
        let d2 = g.decoder();
        assert!(!d2.is_decodable());
    }

    #[test]
    fn nested_graph_indexing() {
        let g = NestedGraph::new(NestedTaskSet::compose(
            TaskSet::strassen_winograd(2),
            TaskSet::strassen_winograd(0),
        ));
        assert_eq!(g.num_groups(), 16);
        assert_eq!(g.group_size(), 14);
        assert_eq!(g.num_leaves(), 224);
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(13), 0);
        assert_eq!(g.group_of(14), 1);
        assert_eq!(g.group_range(2), 28..42);
    }

    #[test]
    fn plan_shapes() {
        let flat = DispatchPlan::flat(TaskSet::strassen_winograd(2));
        assert_eq!(flat.num_work_items(), 16);
        assert_eq!(flat.block_divisor(), 2);
        assert_eq!(flat.default_pool_size(), 16);
        let nested = DispatchPlan::nested(NestedTaskSet::compose(
            TaskSet::strassen_winograd(2),
            TaskSet::strassen_winograd(2),
        ));
        assert_eq!(nested.num_work_items(), 256);
        assert_eq!(nested.block_divisor(), 4);
        assert_eq!(nested.default_pool_size(), 64, "leaves multiplex onto a capped fleet");
        assert_eq!(nested.name(), "S+W +2 PSMM:S+W +2 PSMM");
    }
}
