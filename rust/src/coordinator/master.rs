//! The master node: encode → dispatch → collect (online decode) →
//! recover → assemble, exactly the master-node role of the paper's
//! Fig. 1 (plus a deadline/fallback policy the paper leaves implicit).
//!
//! Since the protocol-split refactor, `Master` is a thin sequential
//! facade over [`crate::coordinator::scheduler::Scheduler`] (itself a
//! single-tenant adapter over the message-driven
//! [`crate::coordinator::tier::ServingTier`]) at in-flight depth 1: one
//! blocking multiply at a time, same decode state machine
//! ([`crate::coordinator::job::JobState`]) as the concurrent server —
//! every dispatch travels the same `AssignLeaf`/`LeafResult` protocol
//! as the multi-tenant tier. Decode policy: an incremental `SpanDecoder` is
//! updated as replies arrive; the moment the four output targets are
//! spanned the master stops waiting (stragglers' late replies are
//! discarded by the `job_id` guard), solves the exact decode weights,
//! and assembles the C blocks as weighted sums of the finished products.
//! If the deadline passes without decodability (the paper's
//! "reconstruction failure") the master falls back to computing the
//! product locally and flags it in the report.

use std::time::Duration;

use crate::coding::scheme::TaskSet;
use crate::coordinator::scheduler::{Scheduler, SchedulerConfig};
use crate::coordinator::task::DispatchPlan;
use crate::coordinator::worker::{Backend, FaultPlan};
use crate::linalg::matrix::Matrix;
use crate::metrics::Registry;

pub use crate::coordinator::job::MultiplyReport;

/// Master configuration (per-job policy, shared with the scheduler).
#[derive(Clone, Debug)]
pub struct MasterConfig {
    /// How long to wait for worker replies before declaring failure.
    pub deadline: Duration,
    /// Fault injection applied to every dispatch.
    pub fault: FaultPlan,
    /// RNG seed for fault sampling (deterministic jobs).
    pub seed: u64,
    /// Compute the locally-correct answer on decode failure instead of
    /// erroring (graceful degradation).
    pub fallback_local: bool,
    /// Wait for every live worker's reply before decoding, instead of
    /// stopping at first decodability. The finished set then depends
    /// only on the injected faults — not on thread timing — which makes
    /// outputs bit-reproducible across runs and scheduler depths
    /// (used by the verification suite; slower under stragglers). If
    /// the deadline fires before every live reply arrived, the job
    /// falls back locally (or errors) instead of decoding from a
    /// timing-dependent partial set — pick a deadline well above the
    /// straggler delay in this mode.
    pub collect_all: bool,
}

impl Default for MasterConfig {
    fn default() -> Self {
        MasterConfig {
            deadline: Duration::from_secs(5),
            fault: FaultPlan::NONE,
            seed: 0,
            fallback_local: true,
            collect_all: false,
        }
    }
}

/// The master node: a depth-1 scheduler serving one job at a time.
pub struct Master {
    sched: Scheduler,
    /// Shared handle to the scheduler's metric registry.
    pub metrics: Registry,
}

impl Master {
    /// Build a master with one worker thread per task.
    pub fn new(set: TaskSet, backend: Backend, cfg: MasterConfig) -> Master {
        Master::with_plan(DispatchPlan::flat(set), backend, cfg, None)
    }

    /// Build a master over an arbitrary dispatch plan (e.g. a nested
    /// two-level scheme), optionally pinning the worker-pool size — the
    /// same sequential one-multiply-at-a-time facade, so `multiply`
    /// works identically for flat and nested schemes.
    pub fn with_plan(
        plan: DispatchPlan,
        backend: Backend,
        cfg: MasterConfig,
        workers: Option<usize>,
    ) -> Master {
        let sched = Scheduler::with_plan(
            plan,
            backend,
            SchedulerConfig { master: cfg, depth: 1 },
            workers,
        );
        let metrics = sched.metrics.clone();
        Master { sched, metrics }
    }

    pub fn scheme_name(&self) -> &str {
        self.sched.scheme_name()
    }

    pub fn num_workers(&self) -> usize {
        self.sched.num_workers()
    }

    /// Fault-tolerant multiply: `C = A · B` (square, even dimension).
    ///
    /// Clones the operands once to hand them to the scheduler (whose
    /// submit queue owns its inputs); the scheduler itself keeps only
    /// the split blocks, shared with the dispatched work items.
    pub fn multiply(&mut self, a: &Matrix, b: &Matrix) -> Result<(Matrix, MultiplyReport), String> {
        self.sched.submit(a.clone(), b.clone())?;
        let mut done = self.sched.drive(1);
        let job = done.pop().ok_or("scheduler returned no completion")?;
        job.result
    }

    /// Shut the pool down (otherwise worker threads exit only when the
    /// process does).
    pub fn shutdown(self) {
        self.sched.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::strassen;
    use crate::sim::rng::Rng;
    use crate::testkit::{check_panics, PropConfig};

    fn master(set: TaskSet, fault: FaultPlan, seed: u64) -> Master {
        Master::new(
            set,
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_secs(10),
                fault,
                seed,
                fallback_local: true,
                collect_all: false,
            },
        )
    }

    fn rand_pair(n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        (Matrix::random(n, n, &mut rng), Matrix::random(n, n, &mut rng))
    }

    #[test]
    fn multiply_no_faults_exact() {
        let mut m = master(TaskSet::strassen_winograd(2), FaultPlan::NONE, 1);
        let (a, b) = rand_pair(32, 1);
        let (c, report) = m.multiply(&a, &b).unwrap();
        assert!(c.approx_eq(&a.matmul(&b), 1e-4), "rel {}", c.rel_error(&a.matmul(&b)));
        assert!(!report.fell_back);
        assert!(report.time_to_decodable.is_some());
        assert_eq!(report.dispatched, 16);
        m.shutdown();
    }

    #[test]
    fn multiply_with_failures_still_exact() {
        // p_fail = 0.15 over many jobs: decode must stay exact whenever
        // it reports success without fallback.
        let mut m = master(
            TaskSet::strassen_winograd(2),
            FaultPlan { p_fail: 0.15, p_straggle: 0.0, delay: Duration::ZERO },
            7,
        );
        let mut decoded = 0;
        for seed in 0..20 {
            let (a, b) = rand_pair(16, seed);
            let (c, report) = m.multiply(&a, &b).unwrap();
            let want = a.matmul(&b);
            assert!(
                c.approx_eq(&want, 1e-4),
                "job {} rel {} (fell_back={})",
                report.job_id,
                c.rel_error(&want),
                report.fell_back
            );
            if !report.fell_back {
                decoded += 1;
            }
        }
        assert!(decoded >= 15, "only {decoded}/20 decoded at p=0.15");
        m.shutdown();
    }

    #[test]
    fn single_copy_falls_back_on_any_failure() {
        // Strassen x1 with a guaranteed failure cannot decode.
        let mut m = Master::new(
            TaskSet::replication(&strassen(), 1),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_millis(300),
                fault: FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                seed: 3,
                fallback_local: true,
                collect_all: false,
            },
        );
        let (a, b) = rand_pair(8, 3);
        let (c, report) = m.multiply(&a, &b).unwrap();
        assert!(report.fell_back);
        assert_eq!(report.finished, 0);
        assert!(c.approx_eq(&a.matmul(&b), 1e-5));
        m.shutdown();
    }

    #[test]
    fn no_fallback_mode_errors() {
        let mut m = Master::new(
            TaskSet::replication(&strassen(), 1),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_millis(200),
                fault: FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                seed: 3,
                fallback_local: false,
                collect_all: false,
            },
        );
        let (a, b) = rand_pair(8, 4);
        let err = m.multiply(&a, &b).unwrap_err();
        assert!(err.contains("not decodable"), "{err}");
        m.shutdown();
    }

    #[test]
    fn nested_plan_facade_multiplies() {
        use crate::coding::nested::NestedTaskSet;
        let mut m = Master::with_plan(
            DispatchPlan::nested(NestedTaskSet::compose(
                TaskSet::strassen_winograd(0),
                TaskSet::strassen_winograd(0),
            )),
            Backend::Native,
            MasterConfig::default(),
            Some(14),
        );
        assert_eq!(m.num_workers(), 14);
        let (a, b) = rand_pair(16, 21);
        let (c, report) = m.multiply(&a, &b).unwrap();
        assert_eq!(report.dispatched, 196);
        assert!(!report.fell_back);
        assert!(c.approx_eq(&a.matmul(&b), 1e-3));
        // Nested plans split twice: n must be divisible by 4.
        assert!(m.multiply(&Matrix::zeros(6, 6), &Matrix::zeros(6, 6)).is_err());
        m.shutdown();
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut m = master(TaskSet::strassen_winograd(0), FaultPlan::NONE, 1);
        let a = Matrix::zeros(8, 8);
        let b = Matrix::zeros(8, 6);
        assert!(m.multiply(&a, &b).is_err());
        let a = Matrix::zeros(6, 6);
        let b = Matrix::zeros(6, 6);
        assert!(m.multiply(&a, &b).is_ok());
        let a = Matrix::zeros(7, 7);
        let b = Matrix::zeros(7, 7);
        assert!(m.multiply(&a, &b).is_err());
        m.shutdown();
    }

    #[test]
    fn straggler_tolerance_beats_waiting() {
        // With S+W+2PSMM and stragglers injected at p = 0.2, the master
        // should usually decode from the fast replies without waiting
        // out the 250 ms delay.
        let mut m = Master::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_secs(10),
                fault: FaultPlan {
                    p_fail: 0.0,
                    p_straggle: 0.2,
                    delay: Duration::from_millis(250),
                },
                seed: 5,
                fallback_local: false,
                collect_all: false,
            },
        );
        let (a, b) = rand_pair(16, 5);
        let mut fast = 0;
        for _ in 0..5 {
            let (c, report) = m.multiply(&a, &b).unwrap();
            assert!(c.approx_eq(&a.matmul(&b), 1e-4));
            if report.injected_stragglers > 0
                && report.elapsed < Duration::from_millis(250)
            {
                fast += 1;
            }
        }
        assert!(fast >= 1, "never decoded around stragglers");
        m.shutdown();
    }

    #[test]
    fn property_decode_exactness_over_random_faults() {
        let mut m = master(
            TaskSet::strassen_winograd(1),
            FaultPlan { p_fail: 0.2, p_straggle: 0.0, delay: Duration::ZERO },
            11,
        );
        check_panics("master decode exact", PropConfig { cases: 12, base_seed: 99 }, |rng| {
            let n = 8 * (1 + rng.below(3) as usize); // 8, 16, 24
            let a = Matrix::random(n, n, rng);
            let b = Matrix::random(n, n, rng);
            let (c, _) = m.multiply(&a, &b).unwrap();
            let want = a.matmul(&b);
            assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
        });
        m.shutdown();
    }

    #[test]
    fn collect_all_mode_is_bit_reproducible() {
        let make = || {
            Master::new(
                TaskSet::strassen_winograd(2),
                Backend::Native,
                MasterConfig {
                    deadline: Duration::from_secs(10),
                    fault: FaultPlan { p_fail: 0.2, p_straggle: 0.0, delay: Duration::ZERO },
                    seed: 13,
                    fallback_local: true,
                    collect_all: true,
                },
            )
        };
        let (a, b) = rand_pair(16, 9);
        let mut m1 = make();
        let mut m2 = make();
        for _ in 0..5 {
            let (c1, _) = m1.multiply(&a, &b).unwrap();
            let (c2, _) = m2.multiply(&a, &b).unwrap();
            assert_eq!(c1.as_slice(), c2.as_slice(), "collect_all must be bit-exact");
        }
        m1.shutdown();
        m2.shutdown();
    }
}
