//! The master node: encode → dispatch → collect (online decode) →
//! recover → assemble. One `Master` owns a worker pool and serves
//! multiply jobs sequentially; the [`crate::coordinator::server`] layer
//! batches jobs on top.
//!
//! Decode policy: an incremental [`SpanDecoder`] is updated as replies
//! arrive; the moment the four output targets are spanned the master
//! stops waiting (stragglers' late replies are discarded), solves the
//! exact decode weights, and assembles the C blocks as weighted sums of
//! the finished products — on the PJRT decode artifact when available,
//! natively otherwise. If the deadline passes without decodability (the
//! paper's "reconstruction failure") the master falls back to computing
//! the product locally and flags it in the report.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::scheme::TaskSet;
use crate::coordinator::task::TaskGraph;
use crate::coordinator::worker::{Backend, FaultAction, FaultPlan, WorkItem, WorkerPool};
use crate::linalg::blocked::{join_blocks, split_blocks};
use crate::linalg::matrix::Matrix;
use crate::metrics::Registry;
use crate::runtime::artifact::DECODE_SLOTS;
use crate::sim::rng::Rng;

/// Master configuration.
#[derive(Clone, Debug)]
pub struct MasterConfig {
    /// How long to wait for worker replies before declaring failure.
    pub deadline: Duration,
    /// Fault injection applied to every dispatch.
    pub fault: FaultPlan,
    /// RNG seed for fault sampling (deterministic jobs).
    pub seed: u64,
    /// Compute the locally-correct answer on decode failure instead of
    /// erroring (graceful degradation).
    pub fallback_local: bool,
}

impl Default for MasterConfig {
    fn default() -> Self {
        MasterConfig {
            deadline: Duration::from_secs(5),
            fault: FaultPlan::NONE,
            seed: 0,
            fallback_local: true,
        }
    }
}

/// Outcome report for one multiply job.
#[derive(Clone, Debug)]
pub struct MultiplyReport {
    pub job_id: u64,
    pub n: usize,
    pub scheme: String,
    /// Total wall time of the job.
    pub elapsed: Duration,
    /// Time from dispatch until the output became decodable.
    pub time_to_decodable: Option<Duration>,
    pub dispatched: usize,
    /// Replies actually used (received before decodability).
    pub finished: usize,
    /// Faults injected at dispatch time.
    pub injected_failures: usize,
    pub injected_stragglers: usize,
    /// True if the deadline passed and the master computed locally.
    pub fell_back: bool,
}

/// The master node.
pub struct Master {
    graph: TaskGraph,
    pool: WorkerPool,
    backend: Backend,
    cfg: MasterConfig,
    rng: Rng,
    next_job: u64,
    pub metrics: Registry,
}

impl Master {
    /// Build a master with one worker thread per task.
    pub fn new(set: TaskSet, backend: Backend, cfg: MasterConfig) -> Master {
        let graph = TaskGraph::new(set);
        let pool = WorkerPool::spawn(graph.num_tasks(), backend.clone());
        let rng = Rng::seeded(cfg.seed);
        Master {
            graph,
            pool,
            backend,
            cfg,
            rng,
            next_job: 0,
            metrics: Registry::new(),
        }
    }

    pub fn scheme_name(&self) -> &str {
        &self.graph.set.name
    }

    pub fn num_workers(&self) -> usize {
        self.pool.size()
    }

    /// Fault-tolerant multiply: `C = A · B` (square, even dimension).
    pub fn multiply(&mut self, a: &Matrix, b: &Matrix) -> Result<(Matrix, MultiplyReport), String> {
        let n = a.rows();
        if a.shape() != (n, n) || b.shape() != (n, n) {
            return Err(format!("square matrices required, got {:?} x {:?}", a.shape(), b.shape()));
        }
        if n % 2 != 0 {
            return Err(format!("dimension must be even, got {n}"));
        }
        let t_start = Instant::now();
        self.next_job += 1;
        let job_id = self.next_job;

        let a4 = Arc::new(split_blocks(a));
        let b4 = Arc::new(split_blocks(b));
        let (tx, rx) = channel();

        // Dispatch every task with a sampled fault action.
        let mut injected_failures = 0;
        let mut injected_stragglers = 0;
        for spec in &self.graph.specs {
            let fault = self.cfg.fault.sample(&mut self.rng);
            match fault {
                FaultAction::Fail => injected_failures += 1,
                FaultAction::Delay(_) => injected_stragglers += 1,
                FaultAction::None => {}
            }
            self.pool.dispatch(
                spec.id,
                WorkItem {
                    job_id,
                    task_id: spec.id,
                    ca: spec.ca,
                    cb: spec.cb,
                    a4: a4.clone(),
                    b4: b4.clone(),
                    fault,
                    reply: tx.clone(),
                },
            );
        }
        drop(tx);
        self.metrics.counter("jobs_dispatched").inc();

        // Collect with online decoding.
        let mut products: Vec<Option<Matrix>> = vec![None; self.graph.num_tasks()];
        let mut decoder = self.graph.decoder();
        let mut finished = 0usize;
        let mut time_to_decodable = None;
        let deadline = t_start + self.cfg.deadline;
        while time_to_decodable.is_none() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(reply) if reply.job_id == job_id => {
                    match reply.product {
                        Ok(m) => {
                            self.metrics
                                .histogram("worker_compute")
                                .observe(reply.compute_time);
                            products[reply.task_id] = Some(m);
                            finished += 1;
                            if decoder.on_finished(reply.task_id) {
                                time_to_decodable = Some(t_start.elapsed());
                            }
                        }
                        Err(e) => {
                            // Backend error == node failure for decoding.
                            self.metrics.counter("worker_errors").inc();
                            let _ = e;
                        }
                    }
                }
                Ok(_) => {} // stale reply from a previous job's straggler
                Err(_) => break, // timeout or all senders gone
            }
        }

        let (c, fell_back) = if time_to_decodable.is_some() {
            (join_blocks(&self.assemble(&decoder, &products, n / 2)?), false)
        } else if self.cfg.fallback_local {
            self.metrics.counter("jobs_fell_back").inc();
            (a.matmul(b), true)
        } else {
            return Err(format!(
                "job {job_id}: not decodable within deadline ({} of {} replies)",
                finished,
                self.graph.num_tasks()
            ));
        };

        let report = MultiplyReport {
            job_id,
            n,
            scheme: self.graph.set.name.clone(),
            elapsed: t_start.elapsed(),
            time_to_decodable,
            dispatched: self.graph.num_tasks(),
            finished,
            injected_failures,
            injected_stragglers,
            fell_back,
        };
        self.metrics.histogram("job_latency").observe(report.elapsed);
        Ok((c, report))
    }

    /// Weighted-sum assembly of the four C blocks from finished products.
    fn assemble(
        &self,
        decoder: &crate::coding::decoder::SpanDecoder,
        products: &[Option<Matrix>],
        bs: usize,
    ) -> Result<[Matrix; 4], String> {
        let outcome = decoder.solve().ok_or("assemble called before decodable")?;
        let weight_sets: Vec<Vec<f32>> = (0..4)
            .map(|t| outcome.weights[t].iter().map(|&w| w as f32).collect())
            .collect();
        if let (Backend::Pjrt(h), true) = (&self.backend, products.len() <= DECODE_SLOTS) {
            // One round-trip: the product stack is shipped and staged as
            // a literal once, all four C blocks come back together
            // (previously 4 trips with a full stack clone each — §Perf).
            let blocks =
                h.decode_combine_multi(weight_sets, products.to_vec(), bs)?;
            let mut it = blocks.into_iter();
            return Ok(std::array::from_fn(|_| it.next().unwrap()));
        }
        let mut blocks: Vec<Matrix> = Vec::with_capacity(4);
        for weights in &weight_sets {
            let mut out = Matrix::zeros(bs, bs);
            for (i, p) in products.iter().enumerate() {
                if weights[i] != 0.0 {
                    let m = p
                        .as_ref()
                        .ok_or_else(|| format!("weight on unfinished task {i}"))?;
                    out.axpy(weights[i], m);
                }
            }
            blocks.push(out);
        }
        let mut it = blocks.into_iter();
        Ok(std::array::from_fn(|_| it.next().unwrap()))
    }

    /// Shut the pool down (otherwise worker threads exit when the Master
    /// is dropped and their queues close).
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::strassen;
    use crate::testkit::{check_panics, PropConfig};

    fn master(set: TaskSet, fault: FaultPlan, seed: u64) -> Master {
        Master::new(
            set,
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_secs(10),
                fault,
                seed,
                fallback_local: true,
            },
        )
    }

    fn rand_pair(n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        (Matrix::random(n, n, &mut rng), Matrix::random(n, n, &mut rng))
    }

    #[test]
    fn multiply_no_faults_exact() {
        let mut m = master(TaskSet::strassen_winograd(2), FaultPlan::NONE, 1);
        let (a, b) = rand_pair(32, 1);
        let (c, report) = m.multiply(&a, &b).unwrap();
        assert!(c.approx_eq(&a.matmul(&b), 1e-4), "rel {}", c.rel_error(&a.matmul(&b)));
        assert!(!report.fell_back);
        assert!(report.time_to_decodable.is_some());
        assert_eq!(report.dispatched, 16);
        m.shutdown();
    }

    #[test]
    fn multiply_with_failures_still_exact() {
        // p_fail = 0.15 over many jobs: decode must stay exact whenever
        // it reports success without fallback.
        let mut m = master(
            TaskSet::strassen_winograd(2),
            FaultPlan { p_fail: 0.15, p_straggle: 0.0, delay: Duration::ZERO },
            7,
        );
        let mut decoded = 0;
        for seed in 0..20 {
            let (a, b) = rand_pair(16, seed);
            let (c, report) = m.multiply(&a, &b).unwrap();
            let want = a.matmul(&b);
            assert!(
                c.approx_eq(&want, 1e-4),
                "job {} rel {} (fell_back={})",
                report.job_id,
                c.rel_error(&want),
                report.fell_back
            );
            if !report.fell_back {
                decoded += 1;
            }
        }
        assert!(decoded >= 15, "only {decoded}/20 decoded at p=0.15");
        m.shutdown();
    }

    #[test]
    fn single_copy_falls_back_on_any_failure() {
        // Strassen x1 with a guaranteed failure cannot decode.
        let mut m = Master::new(
            TaskSet::replication(&strassen(), 1),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_millis(300),
                fault: FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                seed: 3,
                fallback_local: true,
            },
        );
        let (a, b) = rand_pair(8, 3);
        let (c, report) = m.multiply(&a, &b).unwrap();
        assert!(report.fell_back);
        assert_eq!(report.finished, 0);
        assert!(c.approx_eq(&a.matmul(&b), 1e-5));
        m.shutdown();
    }

    #[test]
    fn no_fallback_mode_errors() {
        let mut m = Master::new(
            TaskSet::replication(&strassen(), 1),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_millis(200),
                fault: FaultPlan { p_fail: 1.0, p_straggle: 0.0, delay: Duration::ZERO },
                seed: 3,
                fallback_local: false,
            },
        );
        let (a, b) = rand_pair(8, 4);
        let err = m.multiply(&a, &b).unwrap_err();
        assert!(err.contains("not decodable"), "{err}");
        m.shutdown();
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut m = master(TaskSet::strassen_winograd(0), FaultPlan::NONE, 1);
        let a = Matrix::zeros(8, 8);
        let b = Matrix::zeros(8, 6);
        assert!(m.multiply(&a, &b).is_err());
        let a = Matrix::zeros(6, 6); // even required... 6 is even; use 7
        let b = Matrix::zeros(6, 6);
        assert!(m.multiply(&a, &b).is_ok());
        let a = Matrix::zeros(7, 7);
        let b = Matrix::zeros(7, 7);
        assert!(m.multiply(&a, &b).is_err());
        m.shutdown();
    }

    #[test]
    fn straggler_tolerance_beats_waiting() {
        // With S+W+2PSMM and 3 guaranteed stragglers, the master should
        // decode from the fast 13 without waiting for the slow ones.
        let mut m = Master::new(
            TaskSet::strassen_winograd(2),
            Backend::Native,
            MasterConfig {
                deadline: Duration::from_secs(10),
                fault: FaultPlan::NONE,
                seed: 5,
                fallback_local: false,
            },
        );
        // Manually mark tasks 0..3 as stragglers via a fault plan with
        // p_straggle = 0.2: statistical check over a few jobs.
        m.cfg.fault = FaultPlan {
            p_fail: 0.0,
            p_straggle: 0.2,
            delay: Duration::from_millis(250),
        };
        let (a, b) = rand_pair(16, 5);
        let mut fast = 0;
        for _ in 0..5 {
            let (c, report) = m.multiply(&a, &b).unwrap();
            assert!(c.approx_eq(&a.matmul(&b), 1e-4));
            if report.injected_stragglers > 0
                && report.elapsed < Duration::from_millis(250)
            {
                fast += 1;
            }
        }
        assert!(fast >= 1, "never decoded around stragglers");
        m.shutdown();
    }

    #[test]
    fn property_decode_exactness_over_random_faults() {
        let mut m = master(
            TaskSet::strassen_winograd(1),
            FaultPlan { p_fail: 0.2, p_straggle: 0.0, delay: Duration::ZERO },
            11,
        );
        check_panics("master decode exact", PropConfig { cases: 12, base_seed: 99 }, |rng| {
            let n = 8 * (1 + rng.below(3) as usize); // 8, 16, 24
            let a = Matrix::random(n, n, rng);
            let b = Matrix::random(n, n, rng);
            let (c, _) = m.multiply(&a, &b).unwrap();
            let want = a.matmul(&b);
            assert!(c.approx_eq(&want, 1e-3), "rel {}", c.rel_error(&want));
        });
        m.shutdown();
    }
}
