//! Golden vectors for the computer-aided search (paper Table II).
//!
//! `golden_sw_relations.txt` is the full `search_lp` output over the 14
//! joint Strassen+Winograd products with the default options (`max_k =
//! 8`, minimal relations only), serialized once and checked in. Tests
//! that only need the *relations* — the peeling decoder, the Table-II
//! summaries — load this fixture instead of re-running the exhaustive
//! ~3^14-node enumeration, and `search::relations` pins the live search
//! against it so the fixture can never drift from the code.
//!
//! Format: one relation per line, `TARGET ±IDX ±IDX …`, targets named
//! `C11`/`C12`/`C21`/`C22`, indices 0..6 = S1..S7 and 7..13 = W1..W7,
//! lines sorted by `(target, terms)` — the canonical order of
//! [`crate::search::relations::dedup`].

use crate::algebra::form::Target;
use crate::search::searchlp::LocalRelation;

/// Number of products the fixture's indices range over (S1..S7, W1..W7).
pub const SW_NUM_PRODUCTS: usize = 14;

const SW_RELATIONS_TXT: &str = include_str!("golden_sw_relations.txt");

/// Parse the golden Strassen+Winograd relation fixture.
///
/// Panics on any malformed line — a broken fixture should fail loudly in
/// whatever test loads it, not decode incorrectly.
pub fn sw_relations() -> Vec<LocalRelation> {
    SW_RELATIONS_TXT
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(parse_line)
        .collect()
}

fn parse_line(line: &str) -> LocalRelation {
    let mut fields = line.split_whitespace();
    let tname = fields.next().unwrap_or_else(|| panic!("empty fixture line"));
    let target = Target::ALL
        .into_iter()
        .find(|t| t.name() == tname)
        .unwrap_or_else(|| panic!("bad target {tname:?} in fixture line {line:?}"));
    let terms: Vec<(usize, i32)> = fields
        .map(|tok| {
            let (sign, digits) = match tok.as_bytes()[0] {
                b'+' => (1, &tok[1..]),
                b'-' => (-1, &tok[1..]),
                _ => panic!("term {tok:?} missing sign in fixture line {line:?}"),
            };
            let idx: usize = digits
                .parse()
                .unwrap_or_else(|e| panic!("bad index {digits:?} in {line:?}: {e}"));
            assert!(idx < SW_NUM_PRODUCTS, "index {idx} out of range in {line:?}");
            (idx, sign)
        })
        .collect();
    assert!(!terms.is_empty(), "relation with no terms in {line:?}");
    LocalRelation { target, terms }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{strassen, winograd};
    use crate::search::relations::verify_all;

    #[test]
    fn fixture_parses_and_every_relation_verifies_symbolically() {
        let rels = sw_relations();
        assert_eq!(rels.len(), 43);
        let mut forms = strassen().forms();
        forms.extend(winograd().forms());
        verify_all(&rels, &forms).unwrap();
    }

    #[test]
    fn fixture_contains_the_papers_numbered_equations() {
        let rels = sw_relations();
        // Eq. (1): C11 = S1 + S4 - S5 + S7.
        assert!(rels.contains(&LocalRelation {
            target: Target::C11,
            terms: vec![(0, 1), (3, 1), (4, -1), (6, 1)],
        }));
        // Eq. (3): C21 = S2 + S4.
        assert!(rels
            .contains(&LocalRelation { target: Target::C21, terms: vec![(1, 1), (3, 1)] }));
        // Eq. (8): C22 = S3 + S5 + W4 - W6.
        assert!(rels.contains(&LocalRelation {
            target: Target::C22,
            terms: vec![(2, 1), (4, 1), (10, 1), (12, -1)],
        }));
    }

    #[test]
    fn fixture_is_in_canonical_dedup_order() {
        let rels = sw_relations();
        let mut sorted = rels.clone();
        crate::search::relations::dedup(&mut sorted);
        assert_eq!(rels, sorted, "fixture lines out of canonical order");
    }

    #[test]
    #[should_panic(expected = "missing sign")]
    fn parser_rejects_unsigned_terms() {
        let _ = parse_line("C11 3");
    }
}
