//! Minimal property-testing harness (offline substitute for `proptest`).
//!
//! Runs a property over many seeded random cases; on failure it reports
//! the case index and seed so the exact input can be reproduced with
//! `Rng::seeded(seed)`. Used by the coordinator/coding test suites for
//! randomized invariants (routing, batching, decode state machines).

use crate::sim::rng::Rng;

pub mod golden;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: u64,
    /// Base seed; case i uses `Rng::seeded(base_seed ^ i)`.
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 128, base_seed: 0x5eed_f00d }
    }
}

/// Run `property` over `cfg.cases` seeded RNGs. The property returns
/// `Err(msg)` to fail. Panics with the failing seed for reproduction.
pub fn check<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.base_seed ^ case;
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Like [`check`] but the property panics directly (for assert!-style
/// bodies); the harness catches nothing, it just seeds deterministically.
pub fn check_panics<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Rng),
{
    check(name, cfg, |rng| {
        property(rng);
        Ok(())
    });
}

/// Generators for common shapes.
pub mod gen {
    use crate::linalg::matrix::Dense;
    use crate::linalg::scalar::Scalar;
    use crate::sim::rng::Rng;

    /// Random small-integer matrix over any scalar backend. Each entry is
    /// `S::from_i64(x)` with `x` uniform in `[-max_abs, max_abs]`; the
    /// integer draws depend only on the RNG state, so the same seed
    /// yields the *same underlying integer matrix* on every backend —
    /// the foundation of the cross-backend conformance suite
    /// (`tests/scalar_conformance.rs`), which compares decoded outputs
    /// with `==` across `f32`/`f64`/`i64`/`Fp`.
    pub fn int_matrix<S: Scalar>(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        max_abs: i64,
    ) -> Dense<S> {
        assert!(max_abs >= 0);
        let span = (2 * max_abs + 1) as u64;
        Dense::from_i64_fn(rows, cols, |_, _| rng.below(span) as i64 - max_abs)
    }

    /// Random subset of 0..n as a bitmask.
    pub fn subset_mask(rng: &mut Rng, n: usize) -> u64 {
        assert!(n <= 64);
        if n == 64 {
            rng.next_u64()
        } else {
            rng.next_u64() & ((1u64 << n) - 1)
        }
    }

    /// Random size in [lo, hi] (inclusive).
    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// Random ±1/0 coefficient vector with at least one nonzero.
    pub fn sign_coeffs(rng: &mut Rng) -> [i32; 4] {
        loop {
            let mut c = [0i32; 4];
            for x in c.iter_mut() {
                *x = match rng.below(3) {
                    0 => -1,
                    1 => 0,
                    _ => 1,
                };
            }
            if c.iter().any(|&x| x != 0) {
                return c;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("count", PropConfig { cases: 10, base_seed: 1 }, |_| {
            ran += 1;
            Ok(())
        });
        assert_eq!(ran, 10);
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("boom", PropConfig::default(), |rng| {
            if rng.uniform() < 2.0 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_are_in_range() {
        let mut rng = Rng::seeded(3);
        for _ in 0..100 {
            let m = gen::subset_mask(&mut rng, 16);
            assert_eq!(m >> 16, 0);
            let s = gen::size(&mut rng, 2, 5);
            assert!((2..=5).contains(&s));
            let c = gen::sign_coeffs(&mut rng);
            assert!(c.iter().any(|&x| x != 0));
            assert!(c.iter().all(|&x| (-1..=1).contains(&x)));
        }
    }

    #[test]
    fn int_matrix_draws_the_same_integers_on_every_backend() {
        use crate::algebra::fp::Fp31;
        use crate::linalg::matrix::Dense;
        use crate::linalg::scalar::Scalar;
        let a: Dense<i64> = gen::int_matrix(&mut Rng::seeded(9), 5, 3, 4);
        let b: Dense<f32> = gen::int_matrix(&mut Rng::seeded(9), 5, 3, 4);
        let c: Dense<Fp31> = gen::int_matrix(&mut Rng::seeded(9), 5, 3, 4);
        for i in 0..5 {
            for j in 0..3 {
                assert!((-4..=4).contains(&a[(i, j)]));
                assert_eq!(b[(i, j)], f32::from_i64(a[(i, j)]));
                assert_eq!(c[(i, j)], Fp31::from_i64(a[(i, j)]));
            }
        }
    }
}
