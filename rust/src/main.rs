//! `ft-strassen` — launcher CLI for the fault-tolerant Strassen-like
//! matrix multiplication system.
//!
//! Subcommands:
//! * `info`      — schemes, Table I, hex codec, artifact status
//! * `search`    — run Algorithm 1; print relations (Table II) and PSMMs
//! * `fc`        — exhaustive FC(k) tables for every Fig.-2 scheme
//! * `theory`    — analytical P_f (eqs. (9)/(10)) over a p_e sweep
//! * `sim`       — Monte-Carlo P_f, cross-checked against theory
//! * `fig2`      — full Fig.-2 regeneration (theory + MC + ASCII plot + CSV)
//! * `nested`    — two-level nested schemes: theory + Monte-Carlo P_f
//!   curves at fan-outs 196–256 (the Fig.-2 analogue for nesting)
//! * `multiply`  — one fault-tolerant multiply (native or PJRT backend;
//!   `--nest outer:inner` dispatches the two-level composition)
//! * `serve`     — batched request loop with straggler injection
//!   (`--nest` serves the nested fan-out over a fixed-size fleet;
//!   `--trace-out` records the run, `--metrics-every` prints
//!   Prometheus text every N completed jobs)
//! * `trace`     — replay a seeded serve workload with tracing on and
//!   dump the Chrome trace + logical digest + span-tree check
//! * `localmm`   — single-node recursive-vs-flat probe: times one flat
//!   kernel multiply against recursive Strassen at the configured
//!   crossover (`--kernel {naive,packed,simd} --cutoff --max-depth`)
//! * `simfleet`  — discrete-event fleet campaign: 10k-node simulated
//!   cluster running nested coded multiplies, measured P_f checked
//!   against `theory::nested_failure_probability` over a p_e sweep

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use ft_strassen::algebra::form::{BilinearForm, Target};
use ft_strassen::bench::plot::{ascii_loglog, Series};
use ft_strassen::cli::Args;
use ft_strassen::coding::fc::fc_table;
use ft_strassen::coding::scheme::TaskSet;
use ft_strassen::coding::theory::{failure_probability, log_pe_grid};
use ft_strassen::coding::nested::{NestedOracle, NestedTaskSet};
use ft_strassen::coding::theory::nested_failure_probability;
use ft_strassen::config::{BackendKind, NestSpec, RunConfig, SchemeKind};
use ft_strassen::coordinator::master::{Master, MasterConfig};
use ft_strassen::coordinator::server::MmServer;
use ft_strassen::coordinator::task::DispatchPlan;
use ft_strassen::coordinator::tier::{names, TenantSpec};
use ft_strassen::coordinator::worker::{Backend, FaultPlan};
use ft_strassen::linalg::kernel::{self, KernelKind};
use ft_strassen::linalg::matrix::Matrix;
use ft_strassen::obs::{
    self, check_span_tree, chrome_trace_json, logical_digest, RingRecorder, Tracer,
};
use ft_strassen::runtime::service::ComputeService;
use ft_strassen::search::relations::summarize;
use ft_strassen::search::searchlp::{search_lp, SearchOptions};
use ft_strassen::sim::des::{policy_by_name, ArrivalProcess, Campaign, SimPlan};
use ft_strassen::sim::latency::LatencyModel;
use ft_strassen::sim::montecarlo::MonteCarlo;
use ft_strassen::sim::rng::Rng;

const USAGE: &str = "\
ft-strassen <subcommand> [options]

subcommands:
  info                           scheme & artifact overview
  search   [--max-k K]           Algorithm 1: local relations + PSMMs
  fc                             FC(k) tables for all Fig.-2 schemes
  theory   [--points N]          analytical P_f sweep
  sim      [--p-e P] [--trials N]  Monte-Carlo P_f vs theory
  fig2     [--trials N] [--out D]  regenerate Fig. 2 (CSV + ASCII)
  nested   [--trials N] [--points N] [--out D]  nested-scheme P_f curves
  multiply [--n N] [--scheme S] [--backend B] [--p-e P] [--nest O:I]
  serve    [--jobs J] [--n N] [--scheme S] [--backend B] [--p-straggle P]
           [--depth D] [--queue-cap Q] [--nest O:I] [--workers W]
           [--tenants SPECS] [--batch-window W] [--cache-cap C]
           [--trace-out PATH] [--metrics-every N]
  trace    [serve options] [--trace-out PATH]
           replay a seeded serve workload with tracing on; dump the
           Chrome trace, span-tree check and logical digest
  localmm  [--n N] [--kernel K] [--cutoff C] [--max-depth D]
           single-node probe: flat kernel vs recursive Strassen
  simfleet [--workers W] [--jobs J] [--nest O:I] [--policies P,..]
           [--pe-sweep P,..] [--points N] [--arrival SPEC]
           discrete-event fleet campaign: simulated P_f vs theory

common options:
  --config FILE                  TOML config (CLI overrides it)
  --scheme S                     strassen-x1|x2|x3, winograd-x1, sw+{0,1,2}psmm
  --nest O:I                     nested two-level scheme, e.g.
                                 sw+2psmm:sw+2psmm (256 leaf tasks; n % 4 == 0)
  --backend B                    native | pjrt
  --kernel K                     native matmul kernel: naive | packed | simd
                                 (default packed; small products always naive;
                                 simd needs AVX2+FMA or NEON, else runs packed)
  --kernel-threads T             packed-kernel row-panel threads (default 1;
                                 keep 1 when the worker pool is the parallelism)
  --cutoff C                     recursive split/leaf crossover for localmm
                                 (default 64; leaves at or below C use --kernel)
  --max-depth D                  recursion depth cap for localmm (0 = unlimited)
  --artifacts DIR                artifact directory (default: artifacts)
  --straggle-ms MS               injected straggler delay (default 50)
  --deadline-ms MS               per-job decode deadline (default 1000)

serve options:
  --depth D                      max in-flight jobs (default 4; 1 = the
                                 paper's sequential one-job-at-a-time master)
  --queue-cap Q                  outstanding-job cap before submit reports
                                 backpressure (default 4096)
  --tenants SPECS                comma-separated name:weight:quota tenant
                                 specs, e.g. heavy:3:8,light:1:8 (weight =
                                 DRR share, quota = max in-flight jobs; the
                                 workload round-robins submissions across
                                 tenants; default: one unbounded tenant)
  --batch-window W               jobs coalesced per dispatch round
                                 (default 1 = no batching)
  --cache-cap C                  encoded-operand LRU cache capacity, in
                                 operands (default 0 = disabled; native
                                 backend, flat schemes)
  --trace-out PATH               record the run's span events and write
                                 a chrome://tracing-loadable JSON file;
                                 also prints the logical-trace digest
                                 (seeded runs reproduce it bit-for-bit)
  --metrics-every N              print a Prometheus text exposition of
                                 the tier registry (plus kernel/arena
                                 profiling histograms) after every N
                                 completed jobs (default 0 = off)
  (TOML: [serve] depth/queue_cap/batch_window, [tenants] specs,
   [cache] cap — CLI overrides the file)

simfleet options:
  --workers W                    simulated fleet size (default 10000)
  --jobs J                       jobs per campaign (default 300)
  --policies P,..                scheduling policies to run, from
                                 random|fastest|locality|speculative
                                 (default random)
  --pe-sweep P,..                explicit comma-separated p_e values;
                                 without it, --points N log-spaced
                                 values over [5e-3, 0.5] (default 5)
  --arrival SPEC                 uniform:DT | poisson:RATE |
                                 diurnal:BASE:PEAK:PERIOD (jobs/s;
                                 default uniform:0.02)
  --leaf-latency M               per-leaf service model det:T |
                                 sexp:SHIFT:RATE | bimodal:BASE:P:F
                                 (default det:0.01)
  --speed M                      per-worker slowness multiplier
                                 distribution (same spellings;
                                 default det:1 = homogeneous)
  --rack-size R --p-rack P       rack topology + per-(job,rack) outage
  --link-latency-ms L --link-gbps G  link-cost model (bytes charged
                                 per encoded block, 0 gbps = infinite)
  --max-attempts A               per-leaf attempt cap (default 4)
  --trace-out PATH               dump the first policy's final-sweep
                                 campaign through the shared trace
                                 exporter (Chrome JSON + logical digest)
  (TOML: [fleet] rack_size/p_rack/link_latency_ms/link_gbps/speed)
";

fn main() {
    let args = match Args::from_env(&["verbose", "latency"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("search") => cmd_search(&args),
        Some("fc") => cmd_fc(&args),
        Some("theory") => cmd_theory(&args),
        Some("sim") => cmd_sim(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("nested") => cmd_nested(&args),
        Some("multiply") => cmd_multiply(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("localmm") => cmd_localmm(&args),
        Some("simfleet") => cmd_simfleet(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<RunConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(s) = args.get("scheme") {
        cfg.scheme = SchemeKind::parse(s)?;
    }
    if let Some(s) = args.get("nest") {
        cfg.nest = Some(NestSpec::parse(s)?);
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    cfg.n = args.get_parsed_or("n", cfg.n).map_err(|e| e.to_string())?;
    cfg.p_e = args.get_parsed_or("p-e", cfg.p_e).map_err(|e| e.to_string())?;
    cfg.p_straggle = args
        .get_parsed_or("p-straggle", cfg.p_straggle)
        .map_err(|e| e.to_string())?;
    cfg.straggle_ms = args
        .get_parsed_or("straggle-ms", cfg.straggle_ms)
        .map_err(|e| e.to_string())?;
    cfg.deadline_ms = args
        .get_parsed_or("deadline-ms", cfg.deadline_ms)
        .map_err(|e| e.to_string())?;
    cfg.seed = args.get_parsed_or("seed", cfg.seed).map_err(|e| e.to_string())?;
    if let Some(k) = args.get("kernel") {
        cfg.kernel = KernelKind::parse(k)?;
    }
    cfg.kernel_threads = args
        .get_parsed_or("kernel-threads", cfg.kernel_threads)
        .map_err(|e| e.to_string())?;
    cfg.crossover = args.get_parsed_or("cutoff", cfg.crossover).map_err(|e| e.to_string())?;
    cfg.max_depth = args
        .get_parsed_or("max-depth", cfg.max_depth)
        .map_err(|e| e.to_string())?;
    cfg.depth = args.get_parsed_or("depth", cfg.depth).map_err(|e| e.to_string())?;
    cfg.queue_cap = args
        .get_parsed_or("queue-cap", cfg.queue_cap)
        .map_err(|e| e.to_string())?;
    cfg.batch_window = args
        .get_parsed_or("batch-window", cfg.batch_window)
        .map_err(|e| e.to_string())?;
    cfg.cache_cap = args
        .get_parsed_or("cache-cap", cfg.cache_cap)
        .map_err(|e| e.to_string())?;
    if let Some(t) = args.get("tenants") {
        cfg.tenants = t
            .split(',')
            .map(TenantSpec::parse)
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| format!("--tenants: {e}"))?;
    }
    cfg.rack_size = args
        .get_parsed_or("rack-size", cfg.rack_size)
        .map_err(|e| e.to_string())?;
    cfg.p_rack = args.get_parsed_or("p-rack", cfg.p_rack).map_err(|e| e.to_string())?;
    cfg.link_latency_ms = args
        .get_parsed_or("link-latency-ms", cfg.link_latency_ms)
        .map_err(|e| e.to_string())?;
    cfg.link_gbps = args
        .get_parsed_or("link-gbps", cfg.link_gbps)
        .map_err(|e| e.to_string())?;
    if let Some(s) = args.get("speed") {
        cfg.fleet_speed = LatencyModel::parse(s)?;
    }
    cfg.validate()?;
    // The kernel policy is process-wide: every matmul below here (worker
    // encode products, decode fallback, reference checks) dispatches
    // through it.
    kernel::set_default(cfg.kernel);
    kernel::set_threads(cfg.kernel_threads);
    Ok(cfg)
}

fn backend_for(cfg: &RunConfig) -> Result<(Backend, Option<ComputeService>), String> {
    match cfg.backend {
        BackendKind::Native => Ok((Backend::Native, None)),
        BackendKind::Pjrt => {
            // Flat workers multiply n/2 blocks; nested leaves n/4.
            let sizes: Vec<usize> = if cfg.nest.is_some() {
                vec![cfg.n / 2, cfg.n / 4]
            } else {
                vec![cfg.n / 2]
            };
            let svc = ComputeService::spawn(&cfg.artifacts_dir, &sizes)?;
            println!("pjrt: {}", svc.handle().platform()?);
            Ok((Backend::Pjrt(svc.handle()), Some(svc)))
        }
    }
}

fn cmd_info(args: &Args) -> Result<(), String> {
    println!("Fault-Tolerant Strassen-Like Matrix Multiplication");
    println!("(Güney & Arslan, CS.DC 2022) — rust + JAX/Pallas reproduction\n");
    println!("schemes (Fig. 2):");
    for ts in TaskSet::fig2_schemes() {
        let fc = fc_table(&ts);
        println!(
            "  {:16} nodes={:2}  first fatal k={}  FC(2)={}",
            ts.name,
            ts.num_tasks(),
            fc.first_loss(),
            fc.counts.get(2).copied().unwrap_or(0),
        );
    }
    println!("\noutput targets (hex support codec, our M·B convention):");
    for t in Target::ALL {
        println!("  {} = {}  {}", t.name(), t.form(), t.form().hex_support());
    }
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    match ft_strassen::runtime::artifact::Manifest::load(dir) {
        Ok(m) => println!(
            "\nartifacts: {} entries in {}, worker block sizes {:?}",
            m.entries.len(),
            dir.display(),
            m.worker_block_sizes()
        ),
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<(), String> {
    let max_k = args.get_parsed_or("max-k", 8usize).map_err(|e| e.to_string())?;
    let ts = TaskSet::strassen_winograd(0);
    let names = ts.names();
    let forms = ts.forms();
    let opts = SearchOptions { max_k, ..Default::default() };
    let t0 = std::time::Instant::now();
    let res = search_lp(&forms, &opts);
    println!(
        "Algorithm 1 over {} products, K <= {max_k}: {} local relations, {} parity candidates ({:?})\n",
        forms.len(),
        res.num_relations(),
        res.parities.len(),
        t0.elapsed()
    );
    println!("{}", summarize(&res, max_k));
    println!("relations per target (paper Table II layout):");
    for t in Target::ALL {
        println!("-- {}", t.name());
        for r in res.for_target(t) {
            println!("   {}", r.render(&names));
        }
    }
    println!("\nPSMM selection:");
    let psmm_ts = TaskSet::strassen_winograd(2);
    for task in &psmm_ts.tasks[14..] {
        println!("  {} = {}", task.name, BilinearForm::from_uv(&task.u, &task.v));
    }
    Ok(())
}

fn cmd_fc(_args: &Args) -> Result<(), String> {
    for ts in TaskSet::fig2_schemes() {
        let fc = fc_table(&ts);
        println!("{} (M = {}):", ts.name, fc.m);
        print!("  FC(k): ");
        for (k, c) in fc.counts.iter().enumerate() {
            if *c > 0 {
                print!("k={k}:{c} ");
            }
        }
        println!("\n");
    }
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<(), String> {
    let points = args.get_parsed_or("points", 9usize).map_err(|e| e.to_string())?;
    let schemes = TaskSet::fig2_schemes();
    let tables: Vec<_> = schemes.iter().map(fc_table).collect();
    print!("{:>8} |", "p_e");
    for ts in &schemes {
        print!(" {:>14}", ts.name);
    }
    println!();
    for p in log_pe_grid(points) {
        print!("{p:>8.4} |");
        for fc in &tables {
            print!(" {:>14.6e}", failure_probability(fc, p));
        }
        println!();
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<(), String> {
    let p_e = args.get_parsed_or("p-e", 0.1f64).map_err(|e| e.to_string())?;
    let trials = args.get_parsed_or("trials", 200_000u64).map_err(|e| e.to_string())?;
    let seed = args.get_parsed_or("seed", 1u64).map_err(|e| e.to_string())?;
    println!("Monte Carlo ({trials} trials, seed {seed}) vs theory at p_e = {p_e}:\n");
    for ts in TaskSet::fig2_schemes() {
        let fc = fc_table(&ts);
        let theory = failure_probability(&fc, p_e);
        let oracle = ft_strassen::coding::fc::DecodeOracle::build(&ts);
        let mc = MonteCarlo::new(trials, seed)
            .failure_probability(p_e, ts.num_tasks(), |mask| oracle.is_decodable(mask));
        println!(
            "  {:16} theory={:.6e}  mc={:.6e} (±{:.1e})",
            ts.name, theory, mc.mean, mc.std_err
        );
    }
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<(), String> {
    let trials = args.get_parsed_or("trials", 100_000u64).map_err(|e| e.to_string())?;
    let points = args.get_parsed_or("points", 9usize).map_err(|e| e.to_string())?;
    let seed = args.get_parsed_or("seed", 1u64).map_err(|e| e.to_string())?;
    let out = args.get_or("out", "target/fig2");
    let grid = log_pe_grid(points);
    let schemes = TaskSet::fig2_schemes();
    let mut theory_series = Vec::new();
    let mut mc_series = Vec::new();
    let mut csv = String::from("scheme,p_e,theory_pf,mc_pf,mc_stderr\n");
    for ts in &schemes {
        let fc = fc_table(ts);
        let oracle = ft_strassen::coding::fc::DecodeOracle::build(ts);
        let mut tpts = Vec::new();
        let mut mpts = Vec::new();
        for &p in &grid {
            let t = failure_probability(&fc, p);
            let mc = MonteCarlo::new(trials, seed)
                .failure_probability(p, ts.num_tasks(), |m| oracle.is_decodable(m));
            csv.push_str(&format!("{},{p},{t},{},{}\n", ts.name, mc.mean, mc.std_err));
            tpts.push((p, t));
            if mc.mean > 0.0 {
                mpts.push((p, mc.mean));
            }
        }
        theory_series.push(Series::new(ts.name.clone(), tpts));
        mc_series.push(Series::new(format!("{} (mc)", ts.name), mpts));
    }
    println!("Fig. 2 (theory):\n{}", ascii_loglog(&theory_series, 72, 24));
    std::fs::create_dir_all(out).map_err(|e| e.to_string())?;
    let csv_path = Path::new(out).join("fig2.csv");
    std::fs::write(&csv_path, csv).map_err(|e| e.to_string())?;
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn cmd_nested(args: &Args) -> Result<(), String> {
    let trials = args.get_parsed_or("trials", 20_000u64).map_err(|e| e.to_string())?;
    let points = args.get_parsed_or("points", 7usize).map_err(|e| e.to_string())?;
    let seed = args.get_parsed_or("seed", 1u64).map_err(|e| e.to_string())?;
    let out = args.get_or("out", "target/nested");
    let grid = log_pe_grid(points);
    let specs = [
        ("sw+0psmm:sw+0psmm", TaskSet::strassen_winograd(0), TaskSet::strassen_winograd(0)),
        ("sw+2psmm:sw+2psmm", TaskSet::strassen_winograd(2), TaskSet::strassen_winograd(2)),
        (
            "strassen-x2:strassen-x2",
            TaskSet::replication(&ft_strassen::algorithms::strassen(), 2),
            TaskSet::replication(&ft_strassen::algorithms::strassen(), 2),
        ),
    ];
    let mut csv = String::from("scheme,leaves,first_loss,p_e,theory_pf,mc_pf,mc_stderr\n");
    let mut series = Vec::new();
    println!("nested two-level schemes ({trials} MC trials, seed {seed}):\n");
    for (name, outer, inner) in specs {
        let fc_o = fc_table(&outer);
        let fc_i = fc_table(&inner);
        let nested = NestedTaskSet::compose(outer, inner);
        let oracle = NestedOracle::build(&nested);
        let first_loss = fc_o.first_loss() * fc_i.first_loss();
        println!(
            "  {:24} leaves={:3}  first fatal k={}",
            name,
            nested.num_leaves(),
            first_loss
        );
        let mut pts = Vec::new();
        for &p in &grid {
            let theory = nested_failure_probability(&fc_o, &fc_i, p);
            let mc = MonteCarlo::new(trials, seed).nested_failure_probability(p, &oracle);
            csv.push_str(&format!(
                "{},{},{},{p},{theory},{},{}\n",
                name,
                nested.num_leaves(),
                first_loss,
                mc.mean,
                mc.std_err
            ));
            println!(
                "    p_e={p:<8.4} theory={theory:.6e}  mc={:.6e} (±{:.1e})",
                mc.mean, mc.std_err
            );
            if theory > 0.0 {
                pts.push((p, theory));
            }
        }
        series.push(Series::new(name.to_string(), pts));
    }
    println!("\nP_f vs p_e (theory):\n{}", ascii_loglog(&series, 72, 24));
    std::fs::create_dir_all(out).map_err(|e| e.to_string())?;
    let csv_path = Path::new(out).join("nested_curves.csv");
    std::fs::write(&csv_path, csv).map_err(|e| e.to_string())?;
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn master_config(cfg: &RunConfig) -> MasterConfig {
    MasterConfig {
        deadline: Duration::from_millis(cfg.deadline_ms),
        fault: FaultPlan {
            p_fail: cfg.p_e,
            p_straggle: cfg.p_straggle,
            delay: Duration::from_millis(cfg.straggle_ms),
        },
        seed: cfg.seed,
        fallback_local: true,
        collect_all: false,
    }
}

fn cmd_multiply(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let (backend, _svc) = backend_for(&cfg)?;
    let mut rng = Rng::seeded(cfg.seed);
    let a = Matrix::random(cfg.n, cfg.n, &mut rng);
    let b = Matrix::random(cfg.n, cfg.n, &mut rng);
    // One facade for both shapes: nested plans multiplex their leaves
    // onto a fixed fleet of `workers` threads.
    let mut master = match cfg.nest {
        Some(nest) => Master::with_plan(
            DispatchPlan::nested(nest.task_set()),
            backend,
            master_config(&cfg),
            Some(cfg.workers),
        ),
        None => Master::new(cfg.scheme.task_set(), backend, master_config(&cfg)),
    };
    let (c, report) = master.multiply(&a, &b)?;
    let scheme_name = master.scheme_name().to_string();
    let workers = master.num_workers();
    master.shutdown();
    let want = a.matmul(&b);
    println!(
        "scheme={} n={} backend={:?} kernel={} workers={} tasks={}",
        scheme_name,
        cfg.n,
        cfg.backend,
        cfg.kernel.display_name(),
        workers,
        report.dispatched
    );
    println!(
        "elapsed={:?} decodable_after={:?} finished={}/{} injected: {} fail, {} straggle, fell_back={}",
        report.elapsed,
        report.time_to_decodable,
        report.finished,
        report.dispatched,
        report.injected_failures,
        report.injected_stragglers,
        report.fell_back
    );
    println!("rel_error vs dense = {:.3e}", c.rel_error(&want));
    Ok(())
}

/// Ring capacity comfortably above the expected event count of a
/// `jobs`-job workload (≈ 5 events per leaf + job-level events),
/// bounded to keep the buffer a few tens of MB at worst.
fn trace_capacity(jobs: usize, leaves: usize) -> usize {
    (jobs.saturating_mul(leaves * 5 + 16)).clamp(1 << 12, 1 << 21)
}

/// Build the serve-shape `MmServer` from the shared config surface
/// (`serve` and `trace` construct identical servers, so a seeded
/// replay reproduces the serve run's logical trace). Returns the
/// server, the scheme display name and the leaf fan-out per job.
fn build_server(
    cfg: &RunConfig,
    args: &Args,
    backend: Backend,
    tracer: Tracer,
) -> Result<(MmServer, String, usize), String> {
    let tier_cfg = cfg.tier_config(master_config(cfg));
    // Explicit --workers pins the fleet size for either shape; without
    // it, flat schemes keep one node per task (the paper's model) and
    // nested fan-outs use the configured fleet size.
    let workers_override: Option<usize> = match args.get("workers") {
        Some(s) => Some(s.parse().map_err(|e| format!("--workers {s}: {e}"))?),
        None => None,
    };
    Ok(match cfg.nest {
        Some(nest) => {
            let name = nest.display_name();
            let set = nest.task_set();
            let leaves = set.num_leaves();
            let plan = DispatchPlan::nested(set);
            let workers = workers_override.unwrap_or(cfg.workers);
            (
                MmServer::with_tier_config_traced(plan, backend, tier_cfg, Some(workers), tracer),
                name,
                leaves,
            )
        }
        None => {
            let set = cfg.scheme.task_set();
            let leaves = set.num_tasks();
            (
                MmServer::with_tier_config_traced(
                    DispatchPlan::flat(set),
                    backend,
                    tier_cfg,
                    workers_override,
                    tracer,
                ),
                cfg.scheme.display_name(),
                leaves,
            )
        }
    })
}

/// Drain a trace ring, write the Chrome JSON, and report the logical
/// digest (plus a loss warning if the ring wrapped).
fn export_trace(ring: &RingRecorder, path: &str, process_name: &str) -> Result<u64, String> {
    let events = ring.drain();
    let digest = logical_digest(&events);
    std::fs::write(path, chrome_trace_json(&events, process_name))
        .map_err(|e| format!("write {path}: {e}"))?;
    println!(
        "trace: wrote {path} ({} events, logical digest 0x{digest:016x})",
        events.len()
    );
    if ring.dropped() > 0 {
        println!(
            "trace: WARNING {} events lost to ring wrap-around (capacity {})",
            ring.dropped(),
            ring.capacity()
        );
    }
    Ok(digest)
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let jobs = args.get_parsed_or("jobs", 32usize).map_err(|e| e.to_string())?;
    let metrics_every =
        args.get_parsed_or("metrics-every", 0usize).map_err(|e| e.to_string())?;
    let trace_out = args.get("trace-out").map(str::to_string);
    let (backend, _svc) = backend_for(&cfg)?;
    // Only pay for profiling when something will surface it.
    if metrics_every > 0 {
        obs::prof::set_profiling(true);
    }
    // Size the ring after the per-job leaf fan-out so seeded runs
    // never wrap (a wrapped ring still runs, but loses early spans).
    let probe_leaves = match &cfg.nest {
        Some(nest) => nest.task_set().num_leaves(),
        None => cfg.scheme.task_set().num_tasks(),
    };
    let ring = trace_out
        .as_ref()
        .map(|_| Arc::new(RingRecorder::with_capacity(trace_capacity(jobs, probe_leaves))));
    let tracer = match &ring {
        Some(r) => Tracer::new(r.clone()),
        None => Tracer::off(),
    };
    let (mut server, scheme_name, _) = build_server(&cfg, args, backend, tracer)?;
    let mut on_metrics = |done: usize, text: &str| {
        println!("--- metrics after {done} jobs ---");
        print!("{text}");
        print!("{}", obs::prof::prometheus_text());
    };
    let report =
        server.run_workload_observed(jobs, cfg.n, cfg.seed, metrics_every, &mut on_metrics)?;
    println!(
        "scheme={} n={} jobs={} depth={} batch_window={} cache_cap={}: \
         {:.2} jobs/s, mean latency {:?}, p95 {:?}",
        scheme_name,
        cfg.n,
        report.jobs,
        cfg.depth,
        cfg.batch_window,
        cfg.cache_cap,
        report.throughput_jobs_per_s,
        report.mean_latency,
        report.p95_latency
    );
    println!(
        "decoded={} fell_back={} mean workers used={:.1}",
        report.decoded, report.fell_back, report.mean_finished_workers
    );
    let reg = server.registry();
    let tenant_names = server.tenant_names();
    if tenant_names.len() > 1 {
        println!("tenants (DRR shares):");
        for t in &tenant_names {
            println!(
                "  {:12} jobs={:4} mean latency {:?}",
                t,
                reg.counter(&format!("{}{t}", names::TENANT_JOBS_PREFIX)).get(),
                reg.histogram(&format!("{}{t}", names::TENANT_LATENCY_PREFIX)).mean()
            );
        }
    }
    if cfg.cache_cap > 0 {
        let hits = reg.counter(names::CACHE_HITS).get();
        let misses = reg.counter(names::CACHE_MISSES).get();
        println!(
            "encoded-operand cache: {hits} hits / {misses} misses ({:.0}% hit rate)",
            100.0 * hits as f64 / (hits + misses).max(1) as f64
        );
    }
    if args.flag("verbose") {
        println!("\nmetrics:\n{}", server.metrics());
    }
    server.shutdown();
    if let (Some(ring), Some(path)) = (&ring, &trace_out) {
        export_trace(ring, path, &format!("serve {scheme_name}"))?;
    }
    Ok(())
}

/// `trace` — replay a seeded serve workload with tracing always on.
///
/// Builds the server through the same `build_server` path as `serve`,
/// so for a given `(--config, --seed, --scheme/--nest, --jobs, ...)`
/// the logical-trace digest matches the one `serve --trace-out`
/// printed for the same configuration (in race-free configs: no
/// injected faults, stragglers, or deadline pressure — worker timing
/// still races otherwise and can reorder terminal outcomes).
fn cmd_trace(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let jobs = args.get_parsed_or("jobs", 32usize).map_err(|e| e.to_string())?;
    let path = args.get_or("trace-out", "trace.json");
    let (backend, _svc) = backend_for(&cfg)?;
    let probe_leaves = match &cfg.nest {
        Some(nest) => nest.task_set().num_leaves(),
        None => cfg.scheme.task_set().num_tasks(),
    };
    let ring = Arc::new(RingRecorder::with_capacity(trace_capacity(jobs, probe_leaves)));
    let tracer = Tracer::new(ring.clone());
    let (mut server, scheme_name, _) = build_server(&cfg, args, backend, tracer)?;
    let report = server.run_workload(jobs, cfg.n, cfg.seed)?;
    server.shutdown();

    let events = ring.drain();
    println!(
        "trace: scheme={} n={} jobs={} seed={}: {} events recorded",
        scheme_name,
        cfg.n,
        report.jobs,
        cfg.seed,
        events.len()
    );
    match check_span_tree(&events, false) {
        Ok(s) => println!(
            "span tree OK: {} jobs ({} decoded, {} fell back, {} failed), \
             {} leaf dispatches, {} replies, {} revokes, {} stale drops, {} cache hits",
            s.jobs,
            s.decoded,
            s.fell_back,
            s.failed,
            s.dispatched_leaves,
            s.replies,
            s.revokes,
            s.stale_drops,
            s.cache_hits
        ),
        Err(e) => println!("span tree VIOLATION: {e}"),
    }
    let digest = logical_digest(&events);
    std::fs::write(&path, chrome_trace_json(&events, &format!("trace {scheme_name}")))
        .map_err(|e| format!("write {path}: {e}"))?;
    println!("wrote {path}; logical digest 0x{digest:016x}");
    if ring.dropped() > 0 {
        println!(
            "WARNING: {} events lost to ring wrap-around (capacity {})",
            ring.dropped(),
            ring.capacity()
        );
    }
    Ok(())
}

fn cmd_localmm(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let rc = cfg.recursive_config();
    if cfg.kernel == KernelKind::Simd && kernel::effective_kind(cfg.kernel) != KernelKind::Simd {
        println!("note: CPU lacks AVX2+FMA/NEON — simd runs the scalar packed kernel");
    }
    let mut rng = Rng::seeded(cfg.seed);
    let a = Matrix::random(cfg.n, cfg.n, &mut rng);
    let b = Matrix::random(cfg.n, cfg.n, &mut rng);
    // Warm both paths once so allocator/arena growth is not timed, then
    // time one flat kernel multiply against one recursive multiply.
    let mut flat = Matrix::zeros(0, 0);
    let mut rec = Matrix::zeros(0, 0);
    kernel::matmul_into(cfg.kernel, &a, &b, &mut flat, cfg.kernel_threads);
    strassen_mm_into(&a, &b, &mut rec, &rc);
    let t0 = std::time::Instant::now();
    kernel::matmul_into(cfg.kernel, &a, &b, &mut flat, cfg.kernel_threads);
    let flat_t = t0.elapsed();
    let t0 = std::time::Instant::now();
    strassen_mm_into(&a, &b, &mut rec, &rc);
    let rec_t = t0.elapsed();
    let depth_str = if rc.max_depth == usize::MAX {
        "unlimited".to_string()
    } else {
        rc.max_depth.to_string()
    };
    println!(
        "localmm n={} kernel={} (effective {}) cutoff={} max_depth={depth_str}",
        cfg.n,
        cfg.kernel.display_name(),
        kernel::effective_kind(cfg.kernel).display_name(),
        rc.crossover
    );
    println!(
        "flat={flat_t:?} recursive={rec_t:?} speedup=x{:.2}",
        flat_t.as_secs_f64() / rec_t.as_secs_f64().max(f64::MIN_POSITIVE)
    );
    println!("rel_error recursive vs flat = {:.3e}", rec.rel_error(&flat));
    Ok(())
}

/// Recursive Strassen into a caller-owned buffer (localmm helper).
fn strassen_mm_into(
    a: &Matrix,
    b: &Matrix,
    out: &mut Matrix,
    rc: &ft_strassen::linalg::recursive::RecursiveConfig,
) {
    ft_strassen::linalg::scheme_mm_into(&ft_strassen::algorithms::strassen(), a, b, out, rc);
}

/// Parse an `--arrival` spec: `uniform:DT`, `poisson:RATE`, or
/// `diurnal:BASE:PEAK:PERIOD`.
fn parse_arrival(s: &str, jobs: usize) -> Result<ArrivalProcess, String> {
    let parts: Vec<&str> = s.trim().split(':').collect();
    let num = |x: &str| -> Result<f64, String> {
        x.parse::<f64>().map_err(|_| format!("bad number `{x}` in arrival spec `{s}`"))
    };
    match parts.as_slice() {
        ["uniform", dt] => Ok(ArrivalProcess::Uniform { count: jobs, interarrival: num(dt)? }),
        ["poisson", rate] => Ok(ArrivalProcess::Poisson { count: jobs, rate: num(rate)? }),
        ["diurnal", base, peak, period] => Ok(ArrivalProcess::Diurnal {
            count: jobs,
            base_rate: num(base)?,
            peak_rate: num(peak)?,
            period: num(period)?,
        }),
        _ => Err(format!(
            "unknown arrival spec `{s}` (uniform:DT | poisson:RATE | diurnal:BASE:PEAK:PERIOD)"
        )),
    }
}

fn cmd_simfleet(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let workers = args.get_parsed_or("workers", 10_000usize).map_err(|e| e.to_string())?;
    let jobs = args.get_parsed_or("jobs", 300usize).map_err(|e| e.to_string())?;
    if workers == 0 || jobs == 0 {
        return Err("simfleet needs workers >= 1 and jobs >= 1".into());
    }
    let nest = match cfg.nest {
        Some(n) => n,
        None => NestSpec::parse("sw+2psmm:sw+2psmm")?,
    };
    let sweep: Vec<f64> = args.get_list_parsed("pe-sweep", &[]).map_err(|e| e.to_string())?;
    let sweep = if sweep.is_empty() {
        let points = args.get_parsed_or("points", 5usize).map_err(|e| e.to_string())?;
        log_pe_grid(points)
    } else {
        sweep
    };
    for &p in &sweep {
        if !(0.0..=1.0).contains(&p) || p + cfg.p_straggle > 1.0 {
            return Err(format!(
                "sweep point p_e = {p} invalid (needs 0 <= p_e and p_e + p_straggle <= 1)"
            ));
        }
    }
    let policies: Vec<String> = args
        .get_list_parsed("policies", &["random".to_string()])
        .map_err(|e| e.to_string())?;
    let leaf_latency = match args.get("leaf-latency") {
        Some(s) => LatencyModel::parse(s)?,
        None => LatencyModel::Deterministic { t: 0.01 },
    };
    let arrivals = match args.get("arrival") {
        Some(s) => parse_arrival(s, jobs)?,
        None => ArrivalProcess::Uniform { count: jobs, interarrival: 0.02 },
    };
    let max_attempts = args.get_parsed_or("max-attempts", 4u16).map_err(|e| e.to_string())?;
    // `take()`n by the first policy's digest campaign below.
    let mut trace_out = args.get("trace-out").map(str::to_string);

    let fleet = cfg.fleet_spec(workers, leaf_latency);
    let set = nest.task_set();
    let fc_o = fc_table(&set.outer);
    let fc_i = fc_table(&set.inner);
    let leaves = set.num_leaves();
    let plan = SimPlan::Nested(set);
    // Each leaf multiplies two (n/4)-sized encoded blocks.
    let block_bytes = ((cfg.n / 4) * (cfg.n / 4) * 8) as u64;
    println!(
        "simfleet: {} ({leaves} leaves/job), {workers} workers in {} racks, \
         {jobs} jobs, seed {}",
        nest.display_name(),
        workers.div_ceil(cfg.rack_size),
        cfg.seed
    );
    // Rule-of-three slack: at P_f below ~3/jobs, a campaign of this
    // size cannot resolve the theory value and zero failures is the
    // expected observation — such points count as (unresolved).
    let slack = 3.0 / jobs as f64;
    let mut mismatches = 0usize;
    for name in &policies {
        let mut policy = policy_by_name(name)?;
        println!("\npolicy {name}:");
        println!(
            "{:>8}  {:>12}  {:>12}  {:>9}  {:>10}  {:>8}  {:>7}  agree",
            "p_e", "theory_pf", "measured_pf", "stderr", "mean_s", "p95_s", "backups"
        );
        for &p in &sweep {
            let campaign = Campaign {
                fleet,
                arrivals: arrivals.clone(),
                fault: FaultPlan {
                    p_fail: p,
                    p_straggle: cfg.p_straggle,
                    delay: Duration::from_millis(cfg.straggle_ms),
                },
                block_bytes,
                seed: cfg.seed,
                max_attempts,
                heap_capacity: jobs * leaves / 4,
                record_trace: false,
            };
            let r = campaign.run(&plan, policy.as_mut()).summary;
            let theory = nested_failure_probability(&fc_o, &fc_i, p);
            // Rack outages are an extra fault process on top of the
            // paper's model: with p_rack > 0 the theory curve is only a
            // lower bound, so the agreement check is p_rack = 0 only.
            let agree = if cfg.p_rack > 0.0 {
                "(p_rack)".to_string()
            } else if r.measured_pf.agrees_with(theory, 4.0, slack) {
                "yes".to_string()
            } else {
                mismatches += 1;
                "NO".to_string()
            };
            println!(
                "{p:>8.4}  {theory:>12.4e}  {:>12.4e}  {:>9.1e}  {:>10.4}  {:>8.4}  {:>7}  {agree}",
                r.measured_pf.mean,
                r.measured_pf.std_err,
                r.mean_completion_s,
                r.p95_completion_s,
                r.backups,
            );
        }
        // The digests make `simfleet` runs comparable byte-for-byte:
        // same seed + config => identical output, any machine.
        let last = sweep[sweep.len() - 1];
        let campaign = Campaign {
            fleet,
            arrivals: arrivals.clone(),
            fault: FaultPlan {
                p_fail: last,
                p_straggle: cfg.p_straggle,
                delay: Duration::from_millis(cfg.straggle_ms),
            },
            block_bytes,
            seed: cfg.seed,
            max_attempts,
            heap_capacity: 0,
            record_trace: false,
        };
        // The first policy's digest campaign doubles as the traced run
        // when --trace-out is given: the DES calendar streams through
        // the same exporter and schema as a live `serve --trace-out`.
        let s = if let Some(path) = trace_out.take() {
            let ring =
                Arc::new(RingRecorder::with_capacity(trace_capacity(jobs, leaves)));
            let tracer = Tracer::new(ring.clone());
            let s = campaign.run_traced(&plan, policy.as_mut(), &tracer).summary;
            export_trace(&ring, &path, &format!("simfleet {name}"))?;
            s
        } else {
            campaign.run(&plan, policy.as_mut()).summary
        };
        println!(
            "  at p_e={last:.4}: events={} dispatches={} requeues={} network_bytes={} \
             trace_digest={:016x} outcome_digest={:016x}",
            s.events, s.dispatches, s.requeues, s.network_bytes, s.trace_digest, s.outcome_digest
        );
    }
    if mismatches > 0 {
        return Err(format!(
            "{mismatches} sweep point(s) disagreed with theory beyond 4 sigma + {slack:.1e}"
        ));
    }
    println!("\nall sweep points agree with nested theory (4 sigma + {slack:.1e} slack)");
    Ok(())
}
