//! # ft-strassen — Fault-Tolerant Strassen-Like Matrix Multiplication
//!
//! Production-quality reproduction of *"Fault-Tolerant Strassen-Like
//! Matrix Multiplication"* (Güney & Arslan, CS.DC 2022): distributed
//! 2×2-blocked matrix multiplication where each worker computes one
//! sub-matrix product, made straggler-tolerant by running **two distinct
//! Strassen-like algorithms** (Strassen + Winograd) plus up to two parity
//! sub-matrix multiplications (PSMMs), and decoding the output blocks from
//! any decodable subset of finished workers.
//!
//! Three-layer architecture (see `DESIGN.md`):
//! * **L1** — Pallas block-matmul / encode kernels (build-time Python),
//! * **L2** — JAX graphs lowered AOT to HLO text in `artifacts/`
//!   (executed through PJRT when built with the `pjrt` feature),
//! * **L3** — this crate: the coordinator, the fault-tolerance coding
//!   layer, the computer-aided search of the paper's Algorithm 1, the
//!   analytical + Monte-Carlo evaluation (Fig. 2), and a PJRT runtime
//!   that executes the AOT artifacts on the request path with **no
//!   Python anywhere at runtime**.
//!
//! ## Serving model (the message-driven serving tier)
//!
//! The coordinator treats the worker fleet as a **shared resource under
//! continuous load**, not a per-job appendage:
//!
//! * the tier and its [`coordinator::WorkerFleet`] communicate only
//!   through the typed [`coordinator::proto`] protocol (`AssignLeaf`,
//!   `LeafResult`, `Revoke`, `Heartbeat`, ...) over a
//!   [`coordinator::Transport`] — workers are independent event-loop
//!   tasks that pull one assignment per `Ready`, so any idle node slot
//!   executes the next item from *any* job;
//! * each multiply job is a per-job decode state machine
//!   ([`coordinator::JobState`], keyed by `job_id`) fed by the
//!   [`coordinator::ServingTier`] (or its single-tenant facade,
//!   [`coordinator::Scheduler`]);
//! * [`coordinator::MmServer`] admits jobs up to a configurable
//!   **in-flight depth** and reports **backpressure** once the
//!   outstanding-job cap is hit (`submit` returns queue-full); tenants
//!   get deficit-round-robin fair shares with per-tenant in-flight
//!   quotas, dispatch rounds batch small jobs, and an LRU cache reuses
//!   encoded left operands by content hash;
//! * once a job's four output targets are spanned, its outstanding
//!   items are **cancelled** (queued items revoked; late replies
//!   dropped — and counted — by the `job_id` guard), so straggler-freed
//!   slots immediately pick up the next job's items;
//! * **nested two-level schemes** ([`coding::nested::NestedTaskSet`])
//!   compose two task sets so each level-1 product is itself
//!   distributed via a level-2 scheme — M₁·M₂ leaf tasks (196–256)
//!   decoded in two stages (inner group spans first, then the outer
//!   span), with whole inner groups cancelled the moment their product
//!   is recovered. Straggler tolerance compounds multiplicatively:
//!   `first_loss(outer) × first_loss(inner)` leaf failures are needed
//!   before any pattern defeats the decoder.
//!
//! With stragglers injected, depth ≥ 4 serving more than doubles the
//! jobs/s of the sequential depth-1 master on the paper's 16-node
//! configuration (see `benches/e2e_throughput.rs`, which emits the
//! `BENCH_e2e.json` trajectory), while depth-1 outputs remain
//! bit-identical to the sequential [`coordinator::Master`] on seeded
//! job streams (`tests/multiplex.rs`).
//!
//! Quick taste (pure-Rust backend, no artifacts needed):
//! ```no_run
//! // (no_run: doctest executables can't locate libxla_extension's rpath
//! //  in this offline image; `cargo test` covers the same API.)
//! use ft_strassen::prelude::*;
//!
//! let scheme = TaskSet::strassen_winograd(2);       // 16 tasks, 2 PSMMs
//! assert_eq!(scheme.num_tasks(), 16);
//! // every single-node failure is decodable:
//! assert_eq!(scheme.fc_table()[1], 0);
//! ```

pub mod algebra;
pub mod algorithms;
pub mod bench;
pub mod cli;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod search;
pub mod sim;
pub mod testkit;

/// Convenient re-exports of the main public types.
pub mod prelude {
    pub use crate::algebra::form::{BilinearForm, Target, ELEM_DIM};
    pub use crate::algorithms::scheme::BilinearScheme;
    pub use crate::coding::decoder::{DecodeOutcome, PeelingDecoder, SpanDecoder};
    pub use crate::coding::nested::{NestedOracle, NestedTaskSet};
    pub use crate::coding::scheme::TaskSet;
    pub use crate::coding::theory::{
        failure_probability, nested_failure_probability, replication_fc,
    };
    pub use crate::coordinator::master::{Master, MasterConfig};
    pub use crate::coordinator::scheduler::{FinishedJob, Scheduler, SchedulerConfig};
    pub use crate::coordinator::server::{MmServer, ServerConfig};
    pub use crate::coordinator::task::DispatchPlan;
    pub use crate::coordinator::tier::{ServingTier, TenantSpec, TierConfig};
    pub use crate::coordinator::worker::{Backend, FaultPlan};
    pub use crate::algebra::fp::{Fp, Fp31};
    pub use crate::linalg::kernel::KernelKind;
    pub use crate::linalg::matrix::{Dense, Matrix};
    pub use crate::linalg::scalar::Scalar;
    pub use crate::obs::{
        check_span_tree, chrome_trace_json, logical_digest, prometheus_text, EventKind,
        RingRecorder, SpanSummary, TraceEvent, TraceSink, Tracer, NO_LEAF,
    };
    pub use crate::search::searchlp::{search_lp, SearchResult};
    pub use crate::sim::des::{
        policy_by_name, ArrivalProcess, Calendar, Campaign, CampaignResult, CampaignSummary,
        Fleet, FleetSpec, LinkModel, SchedPolicy, SimPlan,
    };
    pub use crate::sim::latency::LatencyModel;
    pub use crate::sim::montecarlo::{Estimate, MonteCarlo};
    pub use crate::sim::rng::Rng;
}
