//! The scalar-backend abstraction under [`Dense`]: one trait capturing
//! exactly the arithmetic the Strassen-like stack needs — ring ops
//! (add, mul, neg) plus **exact division by the small integers the
//! decoder emits** (LCMs of dyadic weight denominators).
//!
//! Backends:
//!
//! | backend | arithmetic | exact? | fast kernels |
//! |---------|-----------|--------|--------------|
//! | `f32`   | IEEE single | dyadic-exact only | packed/SIMD + thread-local recursion arena |
//! | `f64`   | IEEE double | dyadic-exact only | naive reference loop |
//! | `i64`   | machine integers (overflow-checked in debug builds) | yes | naive reference loop |
//! | [`Fp<P>`](crate::algebra::fp::Fp) | prime field, Barrett reduction | yes | naive reference loop |
//!
//! The `f32` impl overrides the three kernel hooks so the serving hot
//! path is byte-for-byte the pre-refactor code: `matmul` still routes
//! through `kernel::dispatch`, recursive leaves still hit
//! [`kernel::matmul_into`], and the recursion scratch still lives in
//! the thread-local arena pinned by `tests/recursive_arena.rs`. Every
//! other backend takes the default hooks (naive loop, fresh per-call
//! scratch) — correctness-first paths exercised by
//! `tests/scalar_conformance.rs`.

use std::fmt::{Debug, Display};
use std::ops::{Add, Mul, Neg, Sub};

use crate::linalg::kernel::{self, KernelKind};
use crate::linalg::matrix::Dense;
use crate::linalg::recursive::{self, RecScratch};

/// Element type of [`Dense`]: a commutative ring with the extra
/// operations the coded-multiplication stack needs.
///
/// The contract that makes exact decoding a theorem rather than a
/// tolerance: for any integers `n` and `d ≠ 0` representable in the
/// backend, if a matrix entry holds a value `x` with `x = d · y` for
/// some representable `y`, then `x.exact_div(d) == y` exactly. The
/// decoder only ever divides by LCMs of its weight denominators (powers
/// of two for the paper's schemes), after scaling the combination to
/// integer weights — see `SpanDecoder::combine_exact_into`.
pub trait Scalar:
    Copy
    + PartialEq
    + Debug
    + Display
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
{
    /// Short stable name used in test/bench labels (`"f32"`, `"fp"`, …).
    const BACKEND_NAME: &'static str;

    /// True when ring arithmetic is exact (no rounding): `i64` and
    /// [`Fp<P>`](crate::algebra::fp::Fp). Float backends are exact only on dyadic values within
    /// mantissa range, which the conformance suite exploits but cannot
    /// assume in general.
    const IS_EXACT: bool;

    /// Additive identity.
    fn zero() -> Self;

    /// Multiplicative identity.
    fn one() -> Self;

    /// Canonical image of an integer (ring homomorphism from ℤ; reduces
    /// mod `P` for prime fields, lossy above 2^24/2^53 for f32/f64).
    fn from_i64(v: i64) -> Self;

    /// Exact division by a nonzero integer `d`, assuming divisibility
    /// (see the trait docs). Panics when the quotient is not
    /// representable: `i64` asserts divisibility, [`Fp<P>`](crate::algebra::fp::Fp) asserts
    /// `gcd(d, P) == 1`.
    fn exact_div(self, d: i64) -> Self;

    /// Allocating matmul hook behind [`Dense::matmul`]. Default: the
    /// naive reference loop. `f32` overrides to the process-wide kernel
    /// dispatch (packed/SIMD above the size break-even).
    fn matmul_alloc(lhs: &Dense<Self>, rhs: &Dense<Self>) -> Dense<Self> {
        lhs.matmul_naive(rhs)
    }

    /// Leaf-kernel hook for the recursive multiply: compute
    /// `lhs · rhs` into `out` with an explicitly requested kernel.
    /// Default ignores `kind`/`threads` and runs the naive loop; `f32`
    /// overrides to [`kernel::matmul_into`] so `--kernel
    /// {naive,packed,simd}` keeps selecting real kernels.
    fn kernel_matmul_into(
        kind: KernelKind,
        lhs: &Dense<Self>,
        rhs: &Dense<Self>,
        out: &mut Dense<Self>,
        threads: usize,
    ) {
        let _ = (kind, threads);
        lhs.matmul_naive_into(rhs, out);
    }

    /// Recursion-scratch hook for `scheme_mm`: hand `f` an arena of at
    /// least `depth_bound` levels. Default allocates a fresh arena per
    /// call (correct everywhere, cold path); `f32` overrides to the
    /// thread-local arena that makes warm recursive multiplies
    /// allocation-free.
    fn with_rec_arena<R>(depth_bound: usize, f: impl FnOnce(&mut [RecScratch<Self>]) -> R) -> R {
        let mut arena: Vec<RecScratch<Self>> = Vec::new();
        arena.resize_with(depth_bound, RecScratch::empty);
        f(&mut arena)
    }
}

impl Scalar for f32 {
    const BACKEND_NAME: &'static str = "f32";
    const IS_EXACT: bool = false;

    fn zero() -> f32 {
        0.0
    }

    fn one() -> f32 {
        1.0
    }

    fn from_i64(v: i64) -> f32 {
        v as f32
    }

    fn exact_div(self, d: i64) -> f32 {
        // Exact whenever `self = d·y` with both representable (the
        // decoder's divisors are powers of two, where this is a pure
        // exponent shift).
        self / d as f32
    }

    fn matmul_alloc(lhs: &Dense<f32>, rhs: &Dense<f32>) -> Dense<f32> {
        kernel::dispatch(lhs, rhs)
    }

    fn kernel_matmul_into(
        kind: KernelKind,
        lhs: &Dense<f32>,
        rhs: &Dense<f32>,
        out: &mut Dense<f32>,
        threads: usize,
    ) {
        kernel::matmul_into(kind, lhs, rhs, out, threads);
    }

    fn with_rec_arena<R>(depth_bound: usize, f: impl FnOnce(&mut [RecScratch<f32>]) -> R) -> R {
        recursive::with_thread_local_arena(depth_bound, f)
    }
}

impl Scalar for f64 {
    const BACKEND_NAME: &'static str = "f64";
    const IS_EXACT: bool = false;

    fn zero() -> f64 {
        0.0
    }

    fn one() -> f64 {
        1.0
    }

    fn from_i64(v: i64) -> f64 {
        v as f64
    }

    fn exact_div(self, d: i64) -> f64 {
        self / d as f64
    }
}

impl Scalar for i64 {
    const BACKEND_NAME: &'static str = "i64";
    const IS_EXACT: bool = true;

    fn zero() -> i64 {
        0
    }

    fn one() -> i64 {
        1
    }

    fn from_i64(v: i64) -> i64 {
        v
    }

    fn exact_div(self, d: i64) -> i64 {
        assert!(d != 0, "exact_div by zero");
        assert!(self % d == 0, "exact_div: {self} is not divisible by {d}");
        self / d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::fp::Fp31;

    #[test]
    fn integer_images_are_ring_homomorphic() {
        for v in [-7i64, -1, 0, 1, 2, 63] {
            assert_eq!(f32::from_i64(v), v as f32);
            assert_eq!(f64::from_i64(v), v as f64);
            assert_eq!(i64::from_i64(v), v);
            for w in [-3i64, 0, 5] {
                assert_eq!(Fp31::from_i64(v) + Fp31::from_i64(w), Fp31::from_i64(v + w));
                assert_eq!(Fp31::from_i64(v) * Fp31::from_i64(w), Fp31::from_i64(v * w));
                assert_eq!(-Fp31::from_i64(v), Fp31::from_i64(-v));
            }
        }
    }

    #[test]
    fn exact_div_inverts_integer_scaling() {
        for d in [1i64, 2, 4, 8, -2] {
            for y in [-5i64, 0, 3, 17] {
                let x = d * y;
                assert_eq!(i64::from_i64(x).exact_div(d), y);
                assert_eq!(f32::from_i64(x).exact_div(d), y as f32);
                assert_eq!(f64::from_i64(x).exact_div(d), y as f64);
                assert_eq!(Fp31::from_i64(x).exact_div(d), Fp31::from_i64(y));
            }
        }
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn i64_exact_div_checks_divisibility() {
        let _ = 7i64.exact_div(2);
    }

    #[test]
    fn backend_names_are_distinct() {
        let names = [
            <f32 as Scalar>::BACKEND_NAME,
            <f64 as Scalar>::BACKEND_NAME,
            <i64 as Scalar>::BACKEND_NAME,
            <Fp31 as Scalar>::BACKEND_NAME,
        ];
        for (i, a) in names.iter().enumerate() {
            for b in names.iter().skip(i + 1) {
                assert_ne!(a, b);
            }
        }
    }
}
