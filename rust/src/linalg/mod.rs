//! Dense matrix substrate: storage, blocked operations, naive and
//! Strassen-like recursive multiplication in pure Rust.
//!
//! This is the numeric fallback/verification backend of the coordinator
//! (the production hot path executes the AOT Pallas artifacts through
//! PJRT — see [`crate::runtime`]); it also provides the 2×2 block
//! partition/assembly used on both backends and the reference results
//! every integration test checks against.

pub mod blocked;
pub mod kernel;
pub mod matrix;
pub mod recursive;
pub mod scalar;

pub use blocked::{join_blocks, split_blocks, split_blocks_into};
pub use kernel::KernelKind;
pub use matrix::{Dense, Matrix};
pub use recursive::{scheme_mm, scheme_mm_into, strassen_mm, winograd_mm, RecursiveConfig};
pub use scalar::Scalar;
