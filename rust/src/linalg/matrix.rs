//! Row-major dense matrix, generic over the [`Scalar`] backend, with
//! the operations the coordinator needs on its hot path: add/sub/scale/
//! AXPY-style combines and a matmul that dispatches through the
//! backend's kernel hook ([`Scalar::matmul_alloc`] — the cache-blocked
//! packed/SIMD kernels for `f32`, the naive reference loop for every
//! other backend).
//!
//! [`Matrix`] is the historical `f32` instantiation; all pre-existing
//! call sites keep compiling (and inferring `f32`) through that alias.

use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Neg, Sub};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::scalar::Scalar;
use crate::sim::rng::Rng;

/// Deep copies of [`Dense`] since process start — the observable the
/// alloc-regression tests/benches use to pin "zero matrix clones per
/// decode solve" (`tests/decode_alloc.rs`). One relaxed increment per
/// clone; negligible next to the `memcpy` it counts. Shared by every
/// backend instantiation (the tests that pin deltas run f32-only
/// workloads in single-test binaries, so cross-backend sharing cannot
/// skew them).
static CLONES: AtomicU64 = AtomicU64::new(0);

/// Fresh data-buffer allocations (constructors, clones, and `reset`
/// calls that outgrow the existing capacity) since process start — the
/// second alloc-regression observable: `tests/recursive_arena.rs` pins
/// "zero matrix allocations per warm recursive multiply" with this.
static ALLOCS: AtomicU64 = AtomicU64::new(0);

#[inline]
fn note_alloc() {
    ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// Dense row-major matrix over any [`Scalar`] backend.
#[derive(PartialEq)]
pub struct Dense<S> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

/// Dense row-major `f32` matrix — the serving hot path's type. Alias of
/// [`Dense<f32>`] so the whole historical API keeps inferring `f32`.
pub type Matrix = Dense<f32>;

impl<S: Clone> Clone for Dense<S> {
    fn clone(&self) -> Dense<S> {
        CLONES.fetch_add(1, Ordering::Relaxed);
        note_alloc();
        Dense { rows: self.rows, cols: self.cols, data: self.data.clone() }
    }
}

impl<S: Scalar> Dense<S> {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        note_alloc();
        Dense { rows, cols, data: vec![S::zero(); rows * cols] }
    }

    /// Identity (square).
    pub fn identity(n: usize) -> Self {
        let mut m = Dense::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = S::one();
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> S) -> Self {
        note_alloc();
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Dense { rows, cols, data }
    }

    /// From a row-major slice.
    pub fn from_slice(rows: usize, cols: usize, data: &[S]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        note_alloc();
        Dense { rows, cols, data: data.to_vec() }
    }

    /// Integer-entry matrix via [`Scalar::from_i64`] — the conformance
    /// suite's cross-backend generator (the same `i64` seed matrix maps
    /// to every backend, so exact `==` comparisons are meaningful).
    pub fn from_i64_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> i64) -> Self {
        Dense::from_fn(rows, cols, |i, j| S::from_i64(f(i, j)))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn as_slice(&self) -> &[S] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [S] {
        &mut self.data
    }

    /// Matmul `self · rhs`, dispatched through the backend's kernel
    /// policy ([`Scalar::matmul_alloc`]). For `f32` that is the packed
    /// cache-blocked kernel (scalar or explicit-SIMD microkernel per
    /// `--kernel {packed,simd}`) for large products and the naive
    /// reference kernel below the size break-even or when `--kernel
    /// naive` is selected ([`crate::linalg::kernel::set_default`]);
    /// `naive` and `packed` accumulate each element in the same
    /// ascending-`k` order, so those two are bit-identical, while
    /// `simd` fuses each accumulation step and is equal only up to the
    /// documented bound ([`crate::linalg::kernel::simd_abs_bound`]).
    /// Every other backend routes to [`Dense::matmul_naive`].
    pub fn matmul(&self, rhs: &Dense<S>) -> Dense<S> {
        assert_eq!(self.cols, rhs.rows, "matmul dims: {:?} x {:?}", self.shape(), rhs.shape());
        S::matmul_alloc(self, rhs)
    }

    /// Reference `(i, k, j)` kernel — the oracle the packed kernel is
    /// property-tested against. Full IEEE semantics on float backends:
    /// zero lhs entries are NOT skipped, so `0·NaN = NaN` and `0·∞ =
    /// NaN` propagate from `rhs` exactly as a textbook inner product
    /// would. (An earlier version skipped `a == 0.0` rows as a
    /// throughput hack, silently laundering non-finite `rhs` rows into
    /// zeros.)
    ///
    /// §Perf note: a 4-row-blocked variant (reusing each B row across 4
    /// accumulator streams) was tried and measured ~10% SLOWER at n =
    /// 128/256 on this single-core box (register pressure beats the L2
    /// traffic saving); the packed kernel in [`crate::linalg::kernel`]
    /// is the fast path instead.
    pub fn matmul_naive(&self, rhs: &Dense<S>) -> Dense<S> {
        let mut out = Dense::zeros(0, 0);
        self.matmul_naive_into(rhs, &mut out);
        out
    }

    /// [`Dense::matmul_naive`] into a caller-owned buffer (reshaped
    /// and zeroed in place, allocation-free once warm).
    pub fn matmul_naive_into(&self, rhs: &Dense<S>, out: &mut Dense<S>) {
        assert_eq!(self.cols, rhs.rows, "matmul dims: {:?} x {:?}", self.shape(), rhs.shape());
        out.reset(self.rows, rhs.cols);
        let n = rhs.cols;
        for i in 0..self.rows {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                let brow = &rhs.data[k * n..(k + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow.iter()) {
                    *o = *o + a * *b;
                }
            }
        }
    }

    /// Reshape to `rows × cols` and zero-fill, reusing the existing
    /// allocation when capacity allows — the scratch-buffer primitive
    /// behind the workers' zero-allocation encode path.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        if rows * cols > self.data.capacity() {
            note_alloc();
        }
        self.data.clear();
        self.data.resize(rows * cols, S::zero());
    }

    /// In-place `self[top.., left..] += s * other` over an
    /// `other`-shaped region — the decode combine writes each output
    /// quadrant straight into the final buffer with this, skipping the
    /// per-block temporaries and the `join_blocks` copy.
    pub fn add_scaled_region(&mut self, top: usize, left: usize, s: S, other: &Dense<S>) {
        let (r, c) = other.shape();
        assert!(
            top + r <= self.rows && left + c <= self.cols,
            "region {:?}+({top},{left}) exceeds {:?}",
            other.shape(),
            self.shape()
        );
        for i in 0..r {
            let dst = &mut self.data[(top + i) * self.cols + left..][..c];
            let src = &other.data[i * c..(i + 1) * c];
            for (d, x) in dst.iter_mut().zip(src.iter()) {
                *d = *d + s * *x;
            }
        }
    }

    /// In-place `self += s * other` (the decode/assembly primitive).
    pub fn axpy(&mut self, s: S, other: &Dense<S>) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = *a + s * *b;
        }
    }

    /// `Σ w[i] * mats[i]` with preallocated output — the zero-extra-copy
    /// decode combine on the native backend. Matrices whose weight
    /// compares equal to zero are skipped entirely (on `f32` that keeps
    /// NaN-filled unfinished worker slots from poisoning the output; a
    /// NaN *weight* still propagates because `NaN == 0.0` is false).
    pub fn weighted_sum_into(out: &mut Dense<S>, weights: &[S], mats: &[&Dense<S>]) {
        assert_eq!(weights.len(), mats.len());
        out.data.fill(S::zero());
        for (&w, m) in weights.iter().zip(mats.iter()) {
            if w != S::zero() {
                out.axpy(w, m);
            }
        }
    }

    /// In-place exact division of every entry by the integer `d`
    /// ([`Scalar::exact_div`]) — the final step of the exact decode
    /// combine, after products have been accumulated with LCM-scaled
    /// integer weights.
    pub fn exact_div_assign(&mut self, d: i64) {
        for x in self.data.iter_mut() {
            *x = x.exact_div(d);
        }
    }

    /// Transpose.
    pub fn transpose(&self) -> Dense<S> {
        Dense::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Deep copies of [`Dense`] since process start (alloc-regression
    /// observability; see the `CLONES` static's doc). Process-global
    /// across all backends.
    pub fn clone_count() -> u64 {
        CLONES.load(Ordering::Relaxed)
    }

    /// Fresh data-buffer allocations since process start: constructors,
    /// clones, and [`Dense::reset`] calls that had to grow. Warm
    /// scratch reuse (reset within capacity) does NOT count — which is
    /// exactly what the recursion-arena tests pin to zero.
    pub fn alloc_count() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }
}

/// `f32`-only operations: RNG fill, float error metrics, and the direct
/// packed-kernel entry point. These stay on the concrete type because
/// they are meaningless (or lossy) over exact backends.
impl Dense<f32> {
    /// Uniform(-1, 1) random entries.
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Dense::from_fn(rows, cols, |_, _| (rng.uniform() * 2.0 - 1.0) as f32)
    }

    /// Packed cache-blocked matmul with the configured thread count
    /// ([`crate::linalg::kernel::threads`]), bypassing the size
    /// heuristic.
    pub fn matmul_packed(&self, rhs: &Matrix) -> Matrix {
        crate::linalg::kernel::matmul_packed(self, rhs, crate::linalg::kernel::threads())
    }

    /// Max absolute entry difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm, accumulated in f64: an f32 running sum loses the
    /// tail of large matrices' squared entries (at 10⁶ elements the f32
    /// accumulator's ulp exceeds small entries' squares entirely),
    /// which skewed the e2e relative-error assertions that divide by
    /// this norm.
    pub fn frobenius(&self) -> f32 {
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt() as f32
    }

    /// Relative error vs a reference (||self - ref|| / ||ref||).
    pub fn rel_error(&self, reference: &Matrix) -> f32 {
        let denom = reference.frobenius().max(f32::MIN_POSITIVE);
        let mut diff = self.clone();
        diff.axpy(-1.0, reference);
        diff.frobenius() / denom
    }

    /// Approximate equality with relative tolerance on the Frobenius norm.
    pub fn approx_eq(&self, other: &Matrix, rtol: f32) -> bool {
        self.shape() == other.shape() && self.rel_error(other) <= rtol
    }
}

impl<S> Index<(usize, usize)> for Dense<S> {
    type Output = S;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &S {
        &self.data[i * self.cols + j]
    }
}

impl<S> IndexMut<(usize, usize)> for Dense<S> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut S {
        &mut self.data[i * self.cols + j]
    }
}

impl<S: Scalar> Add for &Dense<S> {
    type Output = Dense<S>;
    fn add(self, rhs: &Dense<S>) -> Dense<S> {
        let mut out = self.clone();
        out.axpy(S::one(), rhs);
        out
    }
}

impl<S: Scalar> Sub for &Dense<S> {
    type Output = Dense<S>;
    fn sub(self, rhs: &Dense<S>) -> Dense<S> {
        let mut out = self.clone();
        out.axpy(-S::one(), rhs);
        out
    }
}

impl<S: Scalar> Neg for &Dense<S> {
    type Output = Dense<S>;
    fn neg(self) -> Dense<S> {
        let mut out = self.clone();
        for x in out.data.iter_mut() {
            *x = -*x;
        }
        out
    }
}

impl<S: Scalar> Mul<S> for &Dense<S> {
    type Output = Dense<S>;
    fn mul(self, s: S) -> Dense<S> {
        let mut out = self.clone();
        for x in out.data.iter_mut() {
            *x = *x * s;
        }
        out
    }
}

impl<S: Scalar> fmt::Debug for Dense<S> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>9} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::fp::Fp31;

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::seeded(1);
        let a = Matrix::random(5, 5, &mut rng);
        let i = Matrix::identity(5);
        assert!(a.matmul(&i).approx_eq(&a, 1e-6));
        assert!(i.matmul(&a).approx_eq(&a, 1e-6));
    }

    #[test]
    fn matmul_matches_manual() {
        let a = Matrix::from_slice(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_slice(3, 2, &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_nonsquare_shapes() {
        let mut rng = Rng::seeded(7);
        let a = Matrix::random(3, 8, &mut rng);
        let b = Matrix::random(8, 5, &mut rng);
        let c = a.matmul(&b);
        assert_eq!(c.shape(), (3, 5));
        // spot check one entry
        let mut want = 0.0;
        for k in 0..8 {
            want += a[(2, k)] * b[(k, 4)];
        }
        assert!((c[(2, 4)] - want).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "matmul dims")]
    fn matmul_dim_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn axpy_and_ops() {
        let a = Matrix::from_slice(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_slice(2, 2, &[4.0, 3.0, 2.0, 1.0]);
        assert_eq!((&a + &b).as_slice(), &[5.0; 4]);
        assert_eq!((&a - &b).as_slice(), &[-3.0, -1.0, 1.0, 3.0]);
        assert_eq!((&a * 2.0).as_slice(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!((-&a).as_slice(), &[-1.0, -2.0, -3.0, -4.0]);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert_eq!(c.as_slice(), &[3.0, 3.5, 4.0, 4.5]);
    }

    #[test]
    fn weighted_sum_skips_zero_weights() {
        let a = Matrix::from_slice(1, 2, &[1.0, 1.0]);
        let b = Matrix::from_slice(1, 2, &[f32::NAN, f32::NAN]);
        let mut out = Matrix::zeros(1, 2);
        // NaN matrix must be skipped when its weight is exactly 0 — the
        // master relies on this for unfinished worker slots.
        Matrix::weighted_sum_into(&mut out, &[2.0, 0.0], &[&a, &b]);
        assert_eq!(out.as_slice(), &[2.0, 2.0]);
    }

    #[test]
    fn error_metrics() {
        let a = Matrix::identity(3);
        let mut b = a.clone();
        b[(0, 0)] = 1.001;
        assert!(a.rel_error(&a) == 0.0);
        assert!(a.max_abs_diff(&b) - 0.001 < 1e-6);
        assert!(a.approx_eq(&b, 1e-2));
        assert!(!a.approx_eq(&b, 1e-5));
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::seeded(3);
        let a = Matrix::random(4, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_propagates_nan_through_zero_lhs_entries() {
        // Regression: the old kernel skipped a == 0.0 lhs entries, so a
        // NaN/Inf row of rhs multiplied by a zero coefficient silently
        // vanished instead of poisoning the output (IEEE: 0·NaN = NaN).
        let a = Matrix::from_slice(1, 2, &[0.0, 1.0]);
        let b = Matrix::from_slice(2, 2, &[f32::NAN, f32::INFINITY, 2.0, 3.0]);
        for c in [a.matmul(&b), a.matmul_naive(&b)] {
            assert!(c[(0, 0)].is_nan(), "0*NaN + 1*2 must be NaN");
            assert!(c[(0, 1)].is_nan(), "0*Inf + 1*3 must be NaN (0*Inf = NaN)");
        }
    }

    #[test]
    fn dispatch_is_bit_identical_to_naive_above_threshold() {
        // 64x64x64 sits exactly at PACKED_MIN_FLOPS: dispatch takes the
        // packed path, which must be bit-identical to the oracle.
        let mut rng = Rng::seeded(41);
        let a = Matrix::random(64, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        assert_eq!(a.matmul(&b).as_slice(), a.matmul_naive(&b).as_slice());
        assert_eq!(a.matmul_packed(&b).as_slice(), a.matmul_naive(&b).as_slice());
    }

    #[test]
    fn frobenius_accumulates_in_f64() {
        // One large entry followed by many small ones: an f32 running
        // sum absorbs the small squares entirely (1e8 + 1.0 == 1e8 in
        // f32), underestimating the norm by ~0.5.
        let n = 100;
        let mut m = Matrix::zeros(n, n);
        m[(0, 0)] = 1.0e4;
        for i in 0..n {
            for j in 0..n {
                if (i, j) != (0, 0) {
                    m[(i, j)] = 1.0;
                }
            }
        }
        let want = (1.0e8f64 + (n * n - 1) as f64).sqrt();
        let got = m.frobenius() as f64;
        assert!(
            (got - want).abs() < 1e-2,
            "got {got}, want {want} (f32 accumulation would give 1e4)"
        );
    }

    #[test]
    fn reset_reuses_and_zeroes() {
        let mut m = Matrix::from_slice(2, 3, &[1.0; 6]);
        m.reset(3, 2);
        assert_eq!(m.shape(), (3, 2));
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
        m.reset(1, 10);
        assert_eq!(m.shape(), (1, 10));
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn add_scaled_region_writes_one_quadrant() {
        let mut out = Matrix::zeros(4, 4);
        let blk = Matrix::from_slice(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        out.add_scaled_region(2, 0, 2.0, &blk); // bottom-left quadrant
        assert_eq!(out[(2, 0)], 2.0);
        assert_eq!(out[(3, 1)], 8.0);
        assert_eq!(out[(0, 0)], 0.0);
        assert_eq!(out[(2, 2)], 0.0);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn add_scaled_region_bounds_checked() {
        let mut out = Matrix::zeros(2, 2);
        out.add_scaled_region(1, 1, 1.0, &Matrix::zeros(2, 2));
    }

    #[test]
    fn clone_counter_observes_deep_copies() {
        let m = Matrix::zeros(4, 4);
        let before = Matrix::clone_count();
        let _copy = m.clone();
        assert!(Matrix::clone_count() > before);
    }

    #[test]
    fn alloc_counter_observes_fresh_buffers() {
        // Only the monotone direction is assertable here: tests in this
        // binary run in parallel and share the process-global counter.
        // The exact warm-reuse delta (zero) is pinned by the
        // single-test binary `tests/recursive_arena.rs`.
        let before = Matrix::alloc_count();
        let m = Matrix::zeros(8, 8);
        assert!(Matrix::alloc_count() > before);
        let before = Matrix::alloc_count();
        let _c = m.clone();
        assert!(Matrix::alloc_count() > before);
    }

    #[test]
    fn matmul_naive_into_reuses_a_stale_buffer() {
        let mut rng = Rng::seeded(43);
        let a = Matrix::random(6, 9, &mut rng);
        let b = Matrix::random(9, 4, &mut rng);
        let want = a.matmul_naive(&b);
        let mut out = Matrix::from_slice(2, 2, &[7.0; 4]);
        a.matmul_naive_into(&b, &mut out);
        assert_eq!(out.shape(), (6, 4));
        assert_eq!(out.as_slice(), want.as_slice());
    }

    #[test]
    fn generic_matmul_is_exact_over_i64_and_fp() {
        // Same integer seed matrices over three exact-capable backends
        // must agree entry-for-entry once mapped through from_i64.
        let ents_a = |i: usize, j: usize| (i * 3 + j) as i64 - 4;
        let ents_b = |i: usize, j: usize| 2 - (i as i64) * (j as i64);
        let ai: Dense<i64> = Dense::from_i64_fn(3, 3, ents_a);
        let bi: Dense<i64> = Dense::from_i64_fn(3, 3, ents_b);
        let ci = ai.matmul(&bi);
        let af: Dense<Fp31> = Dense::from_i64_fn(3, 3, ents_a);
        let bf: Dense<Fp31> = Dense::from_i64_fn(3, 3, ents_b);
        let cf = af.matmul(&bf);
        let a32: Matrix = Dense::from_i64_fn(3, 3, ents_a);
        let b32: Matrix = Dense::from_i64_fn(3, 3, ents_b);
        let c32 = a32.matmul_naive(&b32);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(Fp31::from_i64(ci[(i, j)]), cf[(i, j)]);
                assert_eq!(ci[(i, j)] as f32, c32[(i, j)]);
            }
        }
    }

    #[test]
    fn exact_div_assign_divides_entries() {
        let mut m: Dense<i64> = Dense::from_slice(1, 3, &[6, -12, 0]);
        m.exact_div_assign(3);
        assert_eq!(m.as_slice(), &[2, -4, 0]);
        let mut f = Matrix::from_slice(1, 2, &[1.0, 3.0]);
        f.exact_div_assign(2);
        assert_eq!(f.as_slice(), &[0.5, 1.5]);
    }
}
