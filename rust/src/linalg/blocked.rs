//! 2×2 block partition / assembly and encoded-operand construction —
//! the native-side mirror of the L1 `encode` kernel.

use crate::linalg::matrix::Matrix;

/// Split an even-dimensioned matrix into its four blocks
/// `[X11, X12, X21, X22]`.
pub fn split_blocks(x: &Matrix) -> [Matrix; 4] {
    let (r, c) = x.shape();
    assert!(r % 2 == 0 && c % 2 == 0, "odd shape {:?} cannot be 2x2-blocked", x.shape());
    let (hr, hc) = (r / 2, c / 2);
    let src = x.as_slice();
    let block = |bi: usize, bj: usize| {
        // Row-contiguous copies (two memcpys per source row pair beat a
        // per-element closure with div/mod — see EXPERIMENTS.md §Perf).
        let mut m = Matrix::zeros(hr, hc);
        let dst = m.as_mut_slice();
        for i in 0..hr {
            let s = (bi * hr + i) * c + bj * hc;
            dst[i * hc..(i + 1) * hc].copy_from_slice(&src[s..s + hc]);
        }
        m
    };
    [block(0, 0), block(0, 1), block(1, 0), block(1, 1)]
}

/// Reassemble four equally-shaped blocks into one matrix.
pub fn join_blocks(b: &[Matrix; 4]) -> Matrix {
    let (hr, hc) = b[0].shape();
    for blk in b.iter() {
        assert_eq!(blk.shape(), (hr, hc), "ragged blocks");
    }
    let mut out = Matrix::zeros(2 * hr, 2 * hc);
    let c = 2 * hc;
    let dst = out.as_mut_slice();
    for (idx, blk) in b.iter().enumerate() {
        let (bi, bj) = (idx / 2, idx % 2);
        let src = blk.as_slice();
        for i in 0..hr {
            let d = (bi * hr + i) * c + bj * hc;
            dst[d..d + hc].copy_from_slice(&src[i * hc..(i + 1) * hc]);
        }
    }
    out
}

/// Encode an operand: `Σ_p coeffs[p] * blocks[p]` (the ±1 sums the
/// master sends to a worker). Zero-coefficient blocks are skipped.
pub fn encode_operand(coeffs: &[i32; 4], blocks: &[Matrix; 4]) -> Matrix {
    let (r, c) = blocks[0].shape();
    let mut out = Matrix::zeros(r, c);
    for (p, &s) in coeffs.iter().enumerate() {
        if s != 0 {
            out.axpy(s as f32, &blocks[p]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    #[test]
    fn split_join_roundtrip() {
        let mut rng = Rng::seeded(5);
        let x = Matrix::random(8, 12, &mut rng);
        let blocks = split_blocks(&x);
        assert_eq!(join_blocks(&blocks), x);
    }

    #[test]
    fn block_layout() {
        let x = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let b = split_blocks(&x);
        assert_eq!(b[0].as_slice(), &[0.0, 1.0, 4.0, 5.0]); // X11
        assert_eq!(b[1].as_slice(), &[2.0, 3.0, 6.0, 7.0]); // X12
        assert_eq!(b[2].as_slice(), &[8.0, 9.0, 12.0, 13.0]); // X21
        assert_eq!(b[3].as_slice(), &[10.0, 11.0, 14.0, 15.0]); // X22
    }

    #[test]
    #[should_panic(expected = "odd shape")]
    fn odd_split_panics() {
        let _ = split_blocks(&Matrix::zeros(3, 4));
    }

    #[test]
    fn encode_matches_manual_sum() {
        let mut rng = Rng::seeded(9);
        let x = Matrix::random(8, 8, &mut rng);
        let b = split_blocks(&x);
        // S6's left operand: M21 - M11
        let e = encode_operand(&[-1, 0, 1, 0], &b);
        let want = &b[2] - &b[0];
        assert!(e.approx_eq(&want, 1e-6));
    }

    #[test]
    fn blockwise_matmul_identity() {
        // C blocks via explicit block formula == dense matmul.
        let mut rng = Rng::seeded(11);
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        let ab = split_blocks(&a);
        let bb = split_blocks(&b);
        let c = [
            &ab[0].matmul(&bb[0]) + &ab[1].matmul(&bb[2]),
            &ab[0].matmul(&bb[1]) + &ab[1].matmul(&bb[3]),
            &ab[2].matmul(&bb[0]) + &ab[3].matmul(&bb[2]),
            &ab[2].matmul(&bb[1]) + &ab[3].matmul(&bb[3]),
        ];
        assert!(join_blocks(&c).approx_eq(&a.matmul(&b), 1e-5));
    }
}
