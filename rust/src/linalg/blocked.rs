//! 2×2 block partition / assembly and encoded-operand construction —
//! the native-side mirror of the L1 `encode` kernel — plus the
//! **two-level** (4×4 = 16-block) variants for nested coded schemes:
//! a leaf task of a nested scheme computes, semantically, a product of
//! operands encoded with the Kronecker coefficients `u ⊗ u'` over the
//! 16 two-level blocks ([`kron_coeffs`], [`split_blocks16`],
//! [`encode_operand16`]). The coordinator dispatches the same
//! computation level by level (outer encode, split, inner encode); the
//! flattened helpers here pin that equivalence and back the nested
//! coding-layer analysis.

use crate::linalg::matrix::Dense;
use crate::linalg::scalar::Scalar;

/// Split an even-dimensioned matrix into its four blocks
/// `[X11, X12, X21, X22]`.
pub fn split_blocks<S: Scalar>(x: &Dense<S>) -> [Dense<S>; 4] {
    let mut out = [
        Dense::zeros(0, 0),
        Dense::zeros(0, 0),
        Dense::zeros(0, 0),
        Dense::zeros(0, 0),
    ];
    split_blocks_into(&mut out, x);
    out
}

/// [`split_blocks`] into caller-owned block buffers, each reshaped in
/// place (allocation-free once warm) — the recursion arena's per-level
/// split path.
pub fn split_blocks_into<S: Scalar>(out: &mut [Dense<S>; 4], x: &Dense<S>) {
    let (r, c) = x.shape();
    assert!(r % 2 == 0 && c % 2 == 0, "odd shape {:?} cannot be 2x2-blocked", x.shape());
    let (hr, hc) = (r / 2, c / 2);
    let src = x.as_slice();
    for (idx, m) in out.iter_mut().enumerate() {
        let (bi, bj) = (idx / 2, idx % 2);
        // Row-contiguous copies (two memcpys per source row pair beat a
        // per-element closure with div/mod — see EXPERIMENTS.md §Perf).
        m.reset(hr, hc);
        let dst = m.as_mut_slice();
        for i in 0..hr {
            let s = (bi * hr + i) * c + bj * hc;
            dst[i * hc..(i + 1) * hc].copy_from_slice(&src[s..s + hc]);
        }
    }
}

/// Reassemble four equally-shaped blocks into one matrix.
pub fn join_blocks<S: Scalar>(b: &[Dense<S>; 4]) -> Dense<S> {
    let (hr, hc) = b[0].shape();
    for blk in b.iter() {
        assert_eq!(blk.shape(), (hr, hc), "ragged blocks");
    }
    let mut out = Dense::zeros(2 * hr, 2 * hc);
    let c = 2 * hc;
    let dst = out.as_mut_slice();
    for (idx, blk) in b.iter().enumerate() {
        let (bi, bj) = (idx / 2, idx % 2);
        let src = blk.as_slice();
        for i in 0..hr {
            let d = (bi * hr + i) * c + bj * hc;
            dst[d..d + hc].copy_from_slice(&src[i * hc..(i + 1) * hc]);
        }
    }
    out
}

/// Encode an operand: `Σ_p coeffs[p] * blocks[p]` (the ±1 sums the
/// master sends to a worker). Zero-coefficient blocks are skipped —
/// that skip is the *definition* of the encode (the sum runs over the
/// coefficient support), not a floating-point shortcut.
pub fn encode_operand<S: Scalar>(coeffs: &[i32; 4], blocks: &[Dense<S>; 4]) -> Dense<S> {
    let mut out = Dense::zeros(0, 0);
    encode_operand_into(&mut out, coeffs, blocks);
    out
}

/// [`encode_operand`] into a caller-owned buffer, which is reshaped and
/// zeroed in place (allocation-free once warm) — the worker threads'
/// per-thread encode scratch path.
pub fn encode_operand_into<S: Scalar>(out: &mut Dense<S>, coeffs: &[i32; 4], blocks: &[Dense<S>; 4]) {
    let (r, c) = blocks[0].shape();
    out.reset(r, c);
    for (p, &s) in coeffs.iter().enumerate() {
        if s != 0 {
            out.axpy(S::from_i64(s as i64), &blocks[p]);
        }
    }
}

/// Split a dimension-divisible-by-4 matrix into its 16 two-level blocks,
/// outer-major: entry `p * 4 + r` is inner block `r` of outer block `p`
/// (i.e. `split_blocks` applied twice).
pub fn split_blocks16<S: Scalar>(x: &Dense<S>) -> [Dense<S>; 16] {
    let (r, c) = x.shape();
    assert!(
        r % 4 == 0 && c % 4 == 0,
        "shape {:?} cannot be 4x4-blocked",
        x.shape()
    );
    let outer = split_blocks(x);
    let mut out: Vec<Dense<S>> = Vec::with_capacity(16);
    for blk in &outer {
        out.extend(split_blocks(blk));
    }
    match out.try_into() {
        Ok(a) => a,
        Err(_) => unreachable!("4 outer blocks x 4 inner blocks"),
    }
}

/// Reassemble 16 two-level blocks (outer-major order, as produced by
/// [`split_blocks16`]) into one matrix.
pub fn join_blocks16<S: Scalar>(b: &[Dense<S>; 16]) -> Dense<S> {
    let quad = |p: usize| -> [Dense<S>; 4] {
        std::array::from_fn(|r| b[p * 4 + r].clone())
    };
    let outer: [Dense<S>; 4] = std::array::from_fn(|p| join_blocks(&quad(p)));
    join_blocks(&outer)
}

/// Flattened two-level encode: `Σ_p Σ_r coeffs[p*4 + r] * blocks[p*4 + r]`.
///
/// The *semantic* description of a nested leaf's operand: with
/// Kronecker coefficients [`kron_coeffs`]`(u, u')` this equals the
/// level-by-level encode the coordinator actually performs at dispatch
/// (outer encode, split, inner encode — see `coordinator::scheduler`);
/// the equivalence is pinned by the tests below and is what makes the
/// nested analysis in `coding::nested` (flat 256-dim leaf forms) speak
/// about the dispatched computation.
pub fn encode_operand16<S: Scalar>(coeffs: &[i32; 16], blocks: &[Dense<S>; 16]) -> Dense<S> {
    let (r, c) = blocks[0].shape();
    let mut out = Dense::zeros(r, c);
    for (p, &s) in coeffs.iter().enumerate() {
        if s != 0 {
            out.axpy(S::from_i64(s as i64), &blocks[p]);
        }
    }
    out
}

/// Kronecker product of an outer and an inner 4-vector of encoding
/// coefficients: `out[p*4 + r] = outer[p] * inner[r]`, matching the
/// block order of [`split_blocks16`]. Encoding with the Kronecker
/// coefficients over 16 blocks equals encoding with `inner` over the
/// blocks of the `outer`-encoded operand — the identity nested dispatch
/// relies on (pinned by the tests below).
pub fn kron_coeffs(outer: &[i32; 4], inner: &[i32; 4]) -> [i32; 16] {
    let mut out = [0i32; 16];
    for p in 0..4 {
        for r in 0..4 {
            out[p * 4 + r] = outer[p] * inner[r];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::sim::rng::Rng;

    #[test]
    fn split_join_roundtrip() {
        let mut rng = Rng::seeded(5);
        let x = Matrix::random(8, 12, &mut rng);
        let blocks = split_blocks(&x);
        assert_eq!(join_blocks(&blocks), x);
    }

    #[test]
    fn block_layout() {
        let x = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let b = split_blocks(&x);
        assert_eq!(b[0].as_slice(), &[0.0, 1.0, 4.0, 5.0]); // X11
        assert_eq!(b[1].as_slice(), &[2.0, 3.0, 6.0, 7.0]); // X12
        assert_eq!(b[2].as_slice(), &[8.0, 9.0, 12.0, 13.0]); // X21
        assert_eq!(b[3].as_slice(), &[10.0, 11.0, 14.0, 15.0]); // X22
    }

    #[test]
    #[should_panic(expected = "odd shape")]
    fn odd_split_panics() {
        let _ = split_blocks(&Matrix::zeros(3, 4));
    }

    #[test]
    fn encode_matches_manual_sum() {
        let mut rng = Rng::seeded(9);
        let x = Matrix::random(8, 8, &mut rng);
        let b = split_blocks(&x);
        // S6's left operand: M21 - M11
        let e = encode_operand(&[-1, 0, 1, 0], &b);
        let want = &b[2] - &b[0];
        assert!(e.approx_eq(&want, 1e-6));
    }

    #[test]
    fn encode_into_reuses_a_stale_buffer() {
        let mut rng = Rng::seeded(10);
        let x = Matrix::random(8, 8, &mut rng);
        let b = split_blocks(&x);
        // A scratch with wrong shape and stale garbage must come out
        // identical to the allocating path.
        let mut scratch = Matrix::from_slice(1, 3, &[9.0, 9.0, 9.0]);
        encode_operand_into(&mut scratch, &[1, 1, 0, -1], &b);
        let want = encode_operand(&[1, 1, 0, -1], &b);
        assert_eq!(scratch.as_slice(), want.as_slice());
        assert_eq!(scratch.shape(), (4, 4));
    }

    #[test]
    fn split_into_reuses_stale_buffers() {
        let mut rng = Rng::seeded(13);
        let x = Matrix::random(6, 10, &mut rng);
        let want = split_blocks(&x);
        // Wrong-shaped, garbage-filled scratch blocks must come out
        // identical to the allocating path.
        let mut scratch = [
            Matrix::from_slice(1, 1, &[9.0]),
            Matrix::zeros(7, 7),
            Matrix::zeros(0, 0),
            Matrix::from_slice(2, 2, &[1.0, 2.0, 3.0, 4.0]),
        ];
        split_blocks_into(&mut scratch, &x);
        for (got, want) in scratch.iter().zip(want.iter()) {
            assert_eq!(got.shape(), (3, 5));
            assert_eq!(got.as_slice(), want.as_slice());
        }
    }

    #[test]
    fn split16_join16_roundtrip() {
        let mut rng = Rng::seeded(21);
        let x = Matrix::random(8, 16, &mut rng);
        let b = split_blocks16(&x);
        assert_eq!(b[0].shape(), (2, 4));
        assert_eq!(join_blocks16(&b), x);
    }

    #[test]
    fn split16_is_split_of_split() {
        let mut rng = Rng::seeded(22);
        let x = Matrix::random(8, 8, &mut rng);
        let b16 = split_blocks16(&x);
        let outer = split_blocks(&x);
        for (p, blk) in outer.iter().enumerate() {
            let inner = split_blocks(blk);
            for (r, want) in inner.iter().enumerate() {
                assert_eq!(&b16[p * 4 + r], want, "block ({p},{r})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "4x4-blocked")]
    fn split16_rejects_non_divisible() {
        let _ = split_blocks16(&Matrix::zeros(6, 6));
    }

    #[test]
    fn kron_encode_equals_two_level_encode() {
        // encode16(u ⊗ u', split16(A)) == encode(u', split(encode(u, split(A))))
        let mut rng = Rng::seeded(23);
        let x = Matrix::random(16, 16, &mut rng);
        let u = [1, 0, -1, 1];
        let ui = [0, 1, 1, -1];
        let flat = encode_operand16(&kron_coeffs(&u, &ui), &split_blocks16(&x));
        let outer_enc = encode_operand(&u, &split_blocks(&x));
        let two_level = encode_operand(&ui, &split_blocks(&outer_enc));
        assert!(flat.approx_eq(&two_level, 1e-6));
    }

    #[test]
    fn blockwise_matmul_identity() {
        // C blocks via explicit block formula == dense matmul.
        let mut rng = Rng::seeded(11);
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        let ab = split_blocks(&a);
        let bb = split_blocks(&b);
        let c = [
            &ab[0].matmul(&bb[0]) + &ab[1].matmul(&bb[2]),
            &ab[0].matmul(&bb[1]) + &ab[1].matmul(&bb[3]),
            &ab[2].matmul(&bb[0]) + &ab[3].matmul(&bb[2]),
            &ab[2].matmul(&bb[1]) + &ab[3].matmul(&bb[3]),
        ];
        assert!(join_blocks(&c).approx_eq(&a.matmul(&b), 1e-5));
    }
}
