//! Multi-level recursive Strassen-like multiplication in pure Rust.
//!
//! Applies any [`BilinearScheme`] recursively with a cutoff to the naive
//! kernel — the classical O(n^log2 7) construction the paper builds on.
//! The distributed coordinator applies the scheme at the *top* level only
//! (one worker per product); this module provides the single-node
//! substrate and the ground truth for benchmarks.

use crate::algorithms::scheme::BilinearScheme;
use crate::linalg::blocked::{encode_operand, join_blocks, split_blocks};
use crate::linalg::matrix::Matrix;

/// Recursion parameters.
///
/// ```
/// use ft_strassen::linalg::matrix::Matrix;
/// use ft_strassen::linalg::recursive::{strassen_mm, RecursiveConfig};
/// use ft_strassen::sim::rng::Rng;
///
/// let mut rng = Rng::seeded(1);
/// let a = Matrix::random(16, 16, &mut rng);
/// let b = Matrix::random(16, 16, &mut rng);
/// // Two levels of 2x2 splitting, naive below 4x4 — the single-node
/// // ground truth the nested e2e tests compare against.
/// let cfg = RecursiveConfig { cutoff: 4, max_depth: 2 };
/// let c = strassen_mm(&a, &b, &cfg);
/// assert!(c.approx_eq(&a.matmul(&b), 1e-4));
/// ```
#[derive(Clone, Copy, Debug)]
pub struct RecursiveConfig {
    /// Below this dimension, fall back to the naive matmul.
    pub cutoff: usize,
    /// Maximum recursion depth (levels of 2×2 splitting).
    pub max_depth: usize,
}

impl Default for RecursiveConfig {
    fn default() -> Self {
        RecursiveConfig { cutoff: 64, max_depth: usize::MAX }
    }
}

/// Multiply with a Strassen-like scheme applied recursively.
///
/// Requires square matrices whose dimension is divisible by 2 at every
/// applied level (power-of-two sizes always work; otherwise recursion
/// stops early at odd dimensions).
pub fn scheme_mm(scheme: &BilinearScheme, a: &Matrix, b: &Matrix, cfg: &RecursiveConfig) -> Matrix {
    mm_rec(scheme, a, b, cfg, 0)
}

fn mm_rec(scheme: &BilinearScheme, a: &Matrix, b: &Matrix, cfg: &RecursiveConfig, depth: usize) -> Matrix {
    let n = a.rows();
    if n <= cfg.cutoff || n % 2 != 0 || depth >= cfg.max_depth || a.cols() % 2 != 0 || b.cols() % 2 != 0 {
        return a.matmul(b);
    }
    let ab = split_blocks(a);
    let bb = split_blocks(b);
    let products: Vec<Matrix> = scheme
        .products
        .iter()
        .map(|p| {
            let left = encode_operand(&p.u, &ab);
            let right = encode_operand(&p.v, &bb);
            mm_rec(scheme, &left, &right, cfg, depth + 1)
        })
        .collect();
    let (hr, hc) = (a.rows() / 2, b.cols() / 2);
    let mut cblocks = [
        Matrix::zeros(hr, hc),
        Matrix::zeros(hr, hc),
        Matrix::zeros(hr, hc),
        Matrix::zeros(hr, hc),
    ];
    for (t, cblock) in cblocks.iter_mut().enumerate() {
        for (i, &coef) in scheme.output[t].iter().enumerate() {
            if coef != 0 {
                cblock.axpy(coef as f32, &products[i]);
            }
        }
    }
    join_blocks(&cblocks)
}

/// Recursive Strassen multiply.
pub fn strassen_mm(a: &Matrix, b: &Matrix, cfg: &RecursiveConfig) -> Matrix {
    scheme_mm(&crate::algorithms::strassen(), a, b, cfg)
}

/// Recursive Winograd multiply.
pub fn winograd_mm(a: &Matrix, b: &Matrix, cfg: &RecursiveConfig) -> Matrix {
    scheme_mm(&crate::algorithms::winograd(), a, b, cfg)
}

/// Number of scalar multiplications a scheme needs at a given size and
/// cutoff — the complexity model behind the paper's O(n^log2 7) claim.
pub fn multiplication_count(num_products: usize, n: usize, cutoff: usize) -> u128 {
    if n <= cutoff || n % 2 != 0 {
        return (n as u128).pow(3);
    }
    num_products as u128 * multiplication_count(num_products, n / 2, cutoff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{naive8, strassen, winograd};
    use crate::sim::rng::Rng;

    fn check(scheme: &BilinearScheme, n: usize, cutoff: usize) {
        let mut rng = Rng::seeded(n as u64 * 31 + cutoff as u64);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let got = scheme_mm(scheme, &a, &b, &RecursiveConfig { cutoff, max_depth: usize::MAX });
        let want = a.matmul(&b);
        assert!(
            got.approx_eq(&want, 1e-4),
            "{} n={} cutoff={} rel_err={}",
            scheme.name,
            n,
            cutoff,
            got.rel_error(&want)
        );
    }

    #[test]
    fn strassen_recursive_matches_naive() {
        for (n, cutoff) in [(8, 2), (16, 4), (64, 8), (128, 32)] {
            check(&strassen(), n, cutoff);
        }
    }

    #[test]
    fn winograd_recursive_matches_naive() {
        for (n, cutoff) in [(8, 2), (16, 4), (64, 8)] {
            check(&winograd(), n, cutoff);
        }
    }

    #[test]
    fn naive8_recursive_matches_naive() {
        check(&naive8(), 32, 4);
    }

    #[test]
    fn odd_sizes_fall_back() {
        let mut rng = Rng::seeded(77);
        let a = Matrix::random(30, 30, &mut rng); // 30 -> 15 odd at depth 1
        let b = Matrix::random(30, 30, &mut rng);
        let got = strassen_mm(&a, &b, &RecursiveConfig { cutoff: 4, max_depth: 8 });
        assert!(got.approx_eq(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn depth_limit_respected() {
        let mut rng = Rng::seeded(78);
        let a = Matrix::random(16, 16, &mut rng);
        let b = Matrix::random(16, 16, &mut rng);
        let got = strassen_mm(&a, &b, &RecursiveConfig { cutoff: 1, max_depth: 1 });
        assert!(got.approx_eq(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn multiplication_count_asymptotics() {
        // One level of Strassen on n=2m: 7 m^3 vs 8 m^3 naive.
        assert_eq!(multiplication_count(7, 4, 2), 7 * 8);
        assert_eq!(multiplication_count(8, 4, 2), 8 * 8);
        // Full recursion to cutoff 1: 7^k for n = 2^k.
        assert_eq!(multiplication_count(7, 8, 1), 343);
    }
}
