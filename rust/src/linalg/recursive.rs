//! Multi-level recursive Strassen-like multiplication in pure Rust,
//! generic over the [`Scalar`] backend.
//!
//! Applies any [`BilinearScheme`] recursively down to a measured
//! crossover, where leaves route **explicitly** to a compute kernel
//! ([`RecursiveConfig::leaf`] → [`Scalar::kernel_matmul_into`], which
//! for `f32` is [`kernel::matmul_into`]) instead of through
//! `Matrix::matmul`'s process-wide dispatch — a recursion benchmark or
//! test can therefore never be skewed by global kernel state. The
//! distributed coordinator applies the scheme at the *top* level only
//! (one worker per product); this module provides the single-node
//! substrate and the ground truth for benchmarks. Over exact backends
//! (`i64`, `Fp<P>`) the recursion is exact end-to-end: every encode
//! coefficient and output coefficient is an integer, so no division
//! ever happens (`tests/scalar_conformance.rs` pins `==` equality with
//! the naive oracle).
//!
//! # Recursion arena
//!
//! Every level of the recursion needs scratch: the four blocks of each
//! operand, the two encoded leaf operands, the product buffer, and (for
//! odd dimensions) zero-padded operand/result images. A naive
//! implementation allocates all of these per level per call — 17+
//! allocations per node of the recursion tree. For the `f32` hot path
//! this module instead keeps a **thread-local arena**: a
//! `Vec<RecScratch>` indexed by recursion level, pre-sized before
//! descent, with every buffer grown in place via [`Dense::reset`] and
//! reused across calls on the same thread. At steady state a warm
//! recursive multiply performs **zero** matrix allocations and zero
//! clones (pinned by `tests/recursive_arena.rs` via
//! [`Dense::alloc_count`] / [`Dense::clone_count`]). Other backends
//! take [`Scalar::with_rec_arena`]'s default — a fresh arena per call —
//! because they are correctness/test paths, not the serving hot path.
//!
//! Ownership during descent is handled by slice splitting: level `d`
//! takes the head of the remaining arena slice (`split_first_mut`) and
//! recurses with the tail, so each level's buffers are borrowed
//! disjointly — no `RefCell` juggling inside the hot path and no
//! aliasing, enforced at compile time.
//!
//! # Odd dimensions
//!
//! A dimension that is odd at some level no longer abandons recursion
//! for the whole subtree: the operands are zero-padded by one
//! row/column to even (exact for the retained entries — the padded
//! products contribute only zeros there), the padded product is
//! computed recursively at the same depth, and the top-left `m×n`
//! window is copied out. `1000×1000` therefore still enjoys Strassen
//! savings instead of silently falling back to a dense kernel at
//! `125×125`.

use crate::algorithms::scheme::BilinearScheme;
use crate::linalg::blocked::{encode_operand_into, split_blocks_into};
use crate::linalg::kernel::{self, KernelKind};
use crate::linalg::matrix::Dense;
use crate::linalg::scalar::Scalar;
use std::cell::RefCell;

/// Recursion parameters.
///
/// ```
/// use ft_strassen::linalg::matrix::Matrix;
/// use ft_strassen::linalg::recursive::{strassen_mm, RecursiveConfig};
/// use ft_strassen::sim::rng::Rng;
///
/// let mut rng = Rng::seeded(1);
/// let a = Matrix::random(16, 16, &mut rng);
/// let b = Matrix::random(16, 16, &mut rng);
/// // Two levels of 2x2 splitting, leaf kernel below 4x4 — the
/// // single-node ground truth the nested e2e tests compare against.
/// let cfg = RecursiveConfig { crossover: 4, max_depth: 2, ..Default::default() };
/// let c = strassen_mm(&a, &b, &cfg);
/// assert!(c.approx_eq(&a.matmul(&b), 1e-4));
/// ```
#[derive(Clone, Copy, Debug)]
pub struct RecursiveConfig {
    /// The measured split/leaf crossover: at or below this dimension
    /// the multiply goes straight to the leaf kernel; above it, keep
    /// splitting. (`BENCH_recursive.json` carries the sweep that
    /// justifies the default; treated as at least 1.)
    pub crossover: usize,
    /// Maximum recursion depth (levels of 2×2 splitting; padding does
    /// not consume depth).
    pub max_depth: usize,
    /// Kernel the leaves route to — explicit, NOT the process-wide
    /// [`kernel::set_default`] choice. `Simd` falls back to the scalar
    /// packed kernel on CPUs without the features. Only the `f32`
    /// backend has real kernel variants; other backends run the naive
    /// loop regardless.
    pub leaf: KernelKind,
}

impl Default for RecursiveConfig {
    fn default() -> Self {
        RecursiveConfig { crossover: 64, max_depth: usize::MAX, leaf: KernelKind::Packed }
    }
}

/// Per-level recursion scratch: operand blocks, encoded leaf operands,
/// the product buffer, and the odd-dimension padding images. All
/// buffers start empty and grow in place on first use at their level's
/// size. Public only because it appears in the [`Scalar::with_rec_arena`]
/// hook signature; the fields are implementation detail.
pub struct RecScratch<S> {
    ablocks: [Dense<S>; 4],
    bblocks: [Dense<S>; 4],
    left: Dense<S>,
    right: Dense<S>,
    prod: Dense<S>,
    a_pad: Dense<S>,
    b_pad: Dense<S>,
    c_pad: Dense<S>,
}

impl<S: Scalar> RecScratch<S> {
    /// All-empty scratch (buffers grow on first use at their level).
    pub fn empty() -> Self {
        let z = || Dense::zeros(0, 0);
        RecScratch {
            ablocks: [z(), z(), z(), z()],
            bblocks: [z(), z(), z(), z()],
            left: z(),
            right: z(),
            prod: z(),
            a_pad: z(),
            b_pad: z(),
            c_pad: z(),
        }
    }
}

thread_local! {
    /// The f32 recursion arena, reused across every recursive multiply
    /// on this thread (worker threads are persistent, so the buffers
    /// reach steady state after the first call at a given size).
    static ARENA: RefCell<Vec<RecScratch<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` against the thread-local f32 arena, grown to at least
/// `depth_bound` levels — the `f32` override of
/// [`Scalar::with_rec_arena`].
pub(crate) fn with_thread_local_arena<R>(
    depth_bound: usize,
    f: impl FnOnce(&mut [RecScratch<f32>]) -> R,
) -> R {
    ARENA.with(|cell| {
        let mut arena = cell.borrow_mut();
        if arena.len() < depth_bound {
            if crate::obs::prof::profiling_enabled() {
                crate::obs::prof::ARENA_GROWS.fetch_add(
                    (depth_bound - arena.len()) as u64,
                    std::sync::atomic::Ordering::Relaxed,
                );
            }
            arena.resize_with(depth_bound, RecScratch::empty);
        }
        f(&mut arena[..])
    })
}

/// Worst-case arena levels for an `n`-row multiply: each halving step
/// consumes at most two levels (one padding + one split), and `n`
/// strictly shrinks per halving, so `2·⌈log₂ n⌉ + 4` always suffices.
fn arena_depth_bound(n: usize) -> usize {
    2 * (usize::BITS - n.leading_zeros()) as usize + 4
}

/// Multiply with a Strassen-like scheme applied recursively.
///
/// Any shapes multiply: dimensions odd at some level are zero-padded to
/// even for that level (see the module docs), so non-square and
/// non-power-of-two sizes keep their recursion savings.
pub fn scheme_mm<S: Scalar>(
    scheme: &BilinearScheme,
    a: &Dense<S>,
    b: &Dense<S>,
    cfg: &RecursiveConfig,
) -> Dense<S> {
    let mut out = Dense::zeros(0, 0);
    scheme_mm_into(scheme, a, b, &mut out, cfg);
    out
}

/// [`scheme_mm`] into a caller-owned buffer (reshaped and zeroed in
/// place) — together with the warm arena, a steady-state recursive
/// multiply that performs zero matrix allocations on the `f32` backend.
pub fn scheme_mm_into<S: Scalar>(
    scheme: &BilinearScheme,
    a: &Dense<S>,
    b: &Dense<S>,
    out: &mut Dense<S>,
    cfg: &RecursiveConfig,
) {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul dims: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let bound = arena_depth_bound(a.rows().max(1));
    if crate::obs::prof::profiling_enabled() {
        crate::obs::prof::record_arena(bound as u64, 0);
    }
    S::with_rec_arena(bound, |arena| {
        mm_rec(scheme, a, b, out, cfg, 0, arena);
    });
}

fn mm_rec<S: Scalar>(
    scheme: &BilinearScheme,
    a: &Dense<S>,
    b: &Dense<S>,
    out: &mut Dense<S>,
    cfg: &RecursiveConfig,
    depth: usize,
    arena: &mut [RecScratch<S>],
) {
    let (m, k) = a.shape();
    let n = b.cols();
    // `m <= 1` is a leaf regardless of the crossover: a 1-row operand
    // would otherwise pad to 2 and split back to 1 forever.
    if m <= cfg.crossover.max(1) || depth >= cfg.max_depth {
        S::kernel_matmul_into(cfg.leaf, a, b, out, kernel::threads());
        return;
    }
    let Some((lvl, rest)) = arena.split_first_mut() else {
        // Unreachable for the bound computed in `scheme_mm_into`
        // (debug-checked); degrade to a leaf rather than crash.
        debug_assert!(false, "recursion arena exhausted at depth {depth}");
        S::kernel_matmul_into(cfg.leaf, a, b, out, kernel::threads());
        return;
    };
    if m % 2 != 0 || k % 2 != 0 || n % 2 != 0 {
        // One level of zero-padding to even, then recurse at the SAME
        // depth — the padded multiply does the actual splitting.
        let RecScratch { a_pad, b_pad, c_pad, .. } = lvl;
        pad_to_even_into(a_pad, a);
        pad_to_even_into(b_pad, b);
        mm_rec(scheme, a_pad, b_pad, c_pad, cfg, depth, rest);
        copy_top_left_into(out, c_pad, m, n);
        return;
    }
    let RecScratch { ablocks, bblocks, left, right, prod, .. } = lvl;
    split_blocks_into(ablocks, a);
    split_blocks_into(bblocks, b);
    let (hr, hc) = (m / 2, n / 2);
    out.reset(m, n);
    for (i, p) in scheme.products.iter().enumerate() {
        encode_operand_into(left, &p.u, ablocks);
        encode_operand_into(right, &p.v, bblocks);
        mm_rec(scheme, left, right, prod, cfg, depth + 1, rest);
        // Accumulate the product straight into the output quadrants,
        // ascending product index per target — the same per-element
        // accumulation order as materializing all products first and
        // then combining per quadrant, so results are bit-identical to
        // that formulation (each output element sees the identical
        // float addition chain).
        for (t, coeffs) in scheme.output.iter().enumerate() {
            let coef = coeffs[i];
            if coef != 0 {
                out.add_scaled_region((t / 2) * hr, (t % 2) * hc, S::from_i64(coef as i64), prod);
            }
        }
    }
}

/// Zero-pad `x` by one trailing row/column as needed to even dims.
fn pad_to_even_into<S: Scalar>(out: &mut Dense<S>, x: &Dense<S>) {
    let (r, c) = x.shape();
    let (pr, pc) = (r + r % 2, c + c % 2);
    out.reset(pr, pc); // zeroed: the pad row/column stays 0
    let src = x.as_slice();
    let dst = out.as_mut_slice();
    for i in 0..r {
        dst[i * pc..i * pc + c].copy_from_slice(&src[i * c..(i + 1) * c]);
    }
}

/// Copy the top-left `r × c` window of `padded` into `out`.
fn copy_top_left_into<S: Scalar>(out: &mut Dense<S>, padded: &Dense<S>, r: usize, c: usize) {
    debug_assert!(padded.rows() >= r && padded.cols() >= c);
    out.reset(r, c);
    let pc = padded.cols();
    let src = padded.as_slice();
    let dst = out.as_mut_slice();
    for i in 0..r {
        dst[i * c..(i + 1) * c].copy_from_slice(&src[i * pc..i * pc + c]);
    }
}

/// Recursive Strassen multiply.
pub fn strassen_mm<S: Scalar>(a: &Dense<S>, b: &Dense<S>, cfg: &RecursiveConfig) -> Dense<S> {
    scheme_mm(&crate::algorithms::strassen(), a, b, cfg)
}

/// Recursive Winograd multiply.
pub fn winograd_mm<S: Scalar>(a: &Dense<S>, b: &Dense<S>, cfg: &RecursiveConfig) -> Dense<S> {
    scheme_mm(&crate::algorithms::winograd(), a, b, cfg)
}

/// Number of scalar multiplications a scheme needs at a given size and
/// crossover — the complexity model behind the paper's O(n^log2 7)
/// claim. (Models the classic even-split recursion; the one-row/column
/// padding's second-order term is ignored.)
pub fn multiplication_count(num_products: usize, n: usize, crossover: usize) -> u128 {
    if n <= crossover || n % 2 != 0 {
        return (n as u128).pow(3);
    }
    num_products as u128 * multiplication_count(num_products, n / 2, crossover)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::fp::Fp31;
    use crate::algorithms::{naive8, strassen, winograd};
    use crate::linalg::matrix::Matrix;
    use crate::sim::rng::Rng;

    fn check(scheme: &BilinearScheme, n: usize, crossover: usize) {
        let mut rng = Rng::seeded(n as u64 * 31 + crossover as u64);
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let cfg = RecursiveConfig { crossover, max_depth: usize::MAX, ..Default::default() };
        let got = scheme_mm(scheme, &a, &b, &cfg);
        let want = a.matmul(&b);
        assert!(
            got.approx_eq(&want, 1e-4),
            "{} n={} crossover={} rel_err={}",
            scheme.name,
            n,
            crossover,
            got.rel_error(&want)
        );
    }

    #[test]
    fn strassen_recursive_matches_naive() {
        for (n, crossover) in [(8, 2), (16, 4), (64, 8), (128, 32)] {
            check(&strassen(), n, crossover);
        }
    }

    #[test]
    fn winograd_recursive_matches_naive() {
        for (n, crossover) in [(8, 2), (16, 4), (64, 8)] {
            check(&winograd(), n, crossover);
        }
    }

    #[test]
    fn naive8_recursive_matches_naive() {
        check(&naive8(), 32, 4);
    }

    #[test]
    fn exact_backends_recurse_exactly() {
        // Over i64 and Fp the recursion involves no division at all, so
        // the result must equal the naive oracle with `==` — the
        // single-node version of the conformance suite's theorem.
        let mut rng = Rng::seeded(83);
        let ents: Vec<i64> = (0..2 * 24 * 24).map(|_| rng.below(7) as i64 - 3).collect();
        let cfg = RecursiveConfig { crossover: 4, max_depth: 8, ..Default::default() };

        let a: Dense<i64> = Dense::from_i64_fn(24, 24, |i, j| ents[i * 24 + j]);
        let b: Dense<i64> = Dense::from_i64_fn(24, 24, |i, j| ents[24 * 24 + i * 24 + j]);
        assert_eq!(strassen_mm(&a, &b, &cfg), a.matmul_naive(&b));

        let af: Dense<Fp31> = Dense::from_i64_fn(24, 24, |i, j| ents[i * 24 + j]);
        let bf: Dense<Fp31> = Dense::from_i64_fn(24, 24, |i, j| ents[24 * 24 + i * 24 + j]);
        assert_eq!(winograd_mm(&af, &bf, &cfg), af.matmul_naive(&bf));
    }

    #[test]
    fn odd_sizes_pad_and_keep_recursing() {
        // 30 → 15 (odd) at depth 1: padding to 16 keeps the subtree
        // recursive instead of falling back to a dense 15×15 leaf.
        let mut rng = Rng::seeded(77);
        let a = Matrix::random(30, 30, &mut rng);
        let b = Matrix::random(30, 30, &mut rng);
        let cfg = RecursiveConfig { crossover: 4, max_depth: 8, ..Default::default() };
        let got = strassen_mm(&a, &b, &cfg);
        assert!(got.approx_eq(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn odd_and_nonsquare_shapes_match_the_naive_oracle() {
        let mut rng = Rng::seeded(79);
        for (m, k, n) in [(25, 25, 25), (30, 31, 29), (1, 9, 7), (63, 17, 41)] {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let cfg = RecursiveConfig { crossover: 4, max_depth: 8, ..Default::default() };
            let got = strassen_mm(&a, &b, &cfg);
            let want = a.matmul_naive(&b);
            assert_eq!(got.shape(), (m, n));
            assert!(
                got.approx_eq(&want, 1e-4),
                "{m}x{k}x{n} rel_err={}",
                got.rel_error(&want)
            );
        }
    }

    #[test]
    fn large_odd_size_keeps_strassen_savings() {
        // The motivating case: an odd-reachable size well above the
        // crossover must both recurse (padding, not fallback) and match
        // the oracle. 250 → 125 (odd) → pad 126 → 63 ≤ 64 leaf.
        let mut rng = Rng::seeded(80);
        let a = Matrix::random(250, 250, &mut rng);
        let b = Matrix::random(250, 250, &mut rng);
        let got = strassen_mm(&a, &b, &RecursiveConfig::default());
        let want = a.matmul(&b);
        assert!(
            got.approx_eq(&want, 1e-4),
            "rel_err={}",
            got.rel_error(&want)
        );
    }

    #[test]
    fn depth_limit_respected() {
        let mut rng = Rng::seeded(78);
        let a = Matrix::random(16, 16, &mut rng);
        let b = Matrix::random(16, 16, &mut rng);
        let cfg = RecursiveConfig { crossover: 1, max_depth: 1, ..Default::default() };
        let got = strassen_mm(&a, &b, &cfg);
        assert!(got.approx_eq(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn leaf_kind_is_explicit_and_all_kinds_agree() {
        let mut rng = Rng::seeded(81);
        let a = Matrix::random(32, 32, &mut rng);
        let b = Matrix::random(32, 32, &mut rng);
        let mk = |leaf| RecursiveConfig { crossover: 8, max_depth: 8, leaf };
        let via_naive = strassen_mm(&a, &b, &mk(KernelKind::Naive));
        let via_packed = strassen_mm(&a, &b, &mk(KernelKind::Packed));
        let via_simd = strassen_mm(&a, &b, &mk(KernelKind::Simd));
        // naive and packed leaves are bit-identical; simd leaves are
        // epsilon-close (exact here only when the CPU lacks SIMD).
        assert_eq!(via_naive.as_slice(), via_packed.as_slice());
        assert!(via_simd.approx_eq(&via_packed, 1e-4));
    }

    #[test]
    fn into_variant_reuses_a_stale_buffer() {
        let mut rng = Rng::seeded(82);
        let a = Matrix::random(16, 16, &mut rng);
        let b = Matrix::random(16, 16, &mut rng);
        let cfg = RecursiveConfig { crossover: 4, max_depth: 2, ..Default::default() };
        let want = strassen_mm(&a, &b, &cfg);
        let mut out = Matrix::from_slice(1, 2, &[5.0, 5.0]);
        scheme_mm_into(&crate::algorithms::strassen(), &a, &b, &mut out, &cfg);
        assert_eq!(out.as_slice(), want.as_slice());
        assert_eq!(out.shape(), (16, 16));
    }

    #[test]
    #[should_panic(expected = "matmul dims")]
    fn dim_mismatch_panics() {
        let _ = strassen_mm(
            &Matrix::zeros(4, 5),
            &Matrix::zeros(4, 5),
            &RecursiveConfig::default(),
        );
    }

    #[test]
    fn multiplication_count_asymptotics() {
        // One level of Strassen on n=2m: 7 m^3 vs 8 m^3 naive.
        assert_eq!(multiplication_count(7, 4, 2), 7 * 8);
        assert_eq!(multiplication_count(8, 4, 2), 8 * 8);
        // Full recursion to crossover 1: 7^k for n = 2^k.
        assert_eq!(multiplication_count(7, 8, 1), 343);
    }
}
