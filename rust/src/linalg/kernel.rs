//! The packed matmul kernel family: cache-blocked `MC×KC×NC` tiling
//! with panel-packed operands, an `MR×NR` register microkernel — scalar
//! (auto-vectorized, bit-exact) or explicit SIMD (AVX2/FMA on x86_64,
//! NEON on aarch64, runtime-detected) — and an opt-in thread-parallel
//! outer loop over row panels. Pure std, no dependencies.
//!
//! # Kernel selection
//!
//! [`Matrix::matmul`] dispatches through this module: the process-wide
//! default kind ([`set_default`], CLI `--kernel {naive,packed,simd}`)
//! picks the family, and a size heuristic ([`PACKED_MIN_FLOPS`]) keeps
//! tiny products on the naive `(i,k,j)` kernel, whose loop
//! overhead-free inner loop wins below the packing break-even point.
//! The naive kernel ([`Matrix::matmul_naive`]) is the reference oracle:
//! the property suite (`tests/kernel_packed.rs`, `tests/kernel_simd.rs`)
//! pins the packed kernel against it bit-exactly and the SIMD kernel
//! against the packed kernel under the documented epsilon bound.
//!
//! Recursive Strassen/Winograd (`linalg/recursive.rs`) does NOT go
//! through the process-wide default: its leaves route explicitly via
//! [`matmul_into`] with the leaf kind carried in `RecursiveConfig`, so
//! a recursion benchmark cannot be silently skewed by global state.
//!
//! # Bit-exactness and the FMA policy
//!
//! The **scalar packed** kernel accumulates every output element in
//! ascending-`k` order — the `kk` block loop is the outermost reduction
//! loop and the microkernel walks `p` upward inside each block — which
//! is exactly the naive kernel's per-element order. Rust does not
//! contract `a*b+c` to FMA, so for every input (finite or not) the
//! packed result is **bit-identical** to the naive result, and the
//! coordinator's decode bit-reproducibility guarantees (`collect_all`)
//! are unaffected by choosing `naive` vs `packed`. Zero-padded panel
//! tails only feed accumulator lanes that are never written back.
//!
//! The **SIMD** kernel keeps the same ascending-`k` accumulation order
//! but fuses each `acc += a·b` step into one FMA instruction (single
//! rounding instead of two). Its results are therefore NOT bit-identical
//! to the oracles; they are *more* accurate per step, and the elementwise
//! difference from the scalar kernel is bounded by [`simd_abs_bound`]
//! (two forward-error cones around the exact dot product, Higham ch. 3).
//! NaN/Inf positions still match the oracle: fusion changes rounding,
//! not IEEE propagation, away from the overflow boundary. Selecting
//! `--kernel simd` trades decode bit-reproducibility across kernel
//! choices for throughput; reproducibility across *runs and thread
//! counts* is retained (the kernel is deterministic).
//!
//! [`KernelKind::Simd`] is honored only when the CPU reports the
//! features at runtime (`is_x86_feature_detected!("avx2")` + `"fma"`,
//! NEON on aarch64); otherwise every SIMD entry point silently runs the
//! scalar packed path ([`effective_kind`] reports the substitution).
//!
//! # Parallelism
//!
//! `threads > 1` splits the *output rows* into contiguous `MC`-aligned
//! chunks, one scoped thread per chunk, each with private pack buffers.
//! The thread count is clamped to the row-panel count, so a thread
//! never receives an empty chunk. Each output element is still produced
//! by exactly one thread with the same accumulation order, so results
//! are identical for every thread count. Parallelism is opt-in
//! (default 1): the worker pool already runs one kernel per worker
//! thread, and oversubscribing it would slow the fleet down.
//! `--kernel-threads N` (or [`set_threads`]) enables it for single
//! large multiplies (e.g. the master's local fallback).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

use crate::linalg::matrix::Matrix;

thread_local! {
    /// Per-thread pack buffers, reused across calls on persistent
    /// threads — the worker pool and the serial path, where a fresh
    /// ~576 KiB allocation pair per matmul would put an allocator
    /// round-trip on the hot path the encode scratch just removed.
    /// (The opt-in multi-threaded path spawns scoped threads per call,
    /// so each pays one allocation; thread-spawn cost dominates there.)
    /// The packing loops fully overwrite every panel slot they expose
    /// (padding included), so the buffers are grown but never re-zeroed.
    static PACK_BUFS: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Rows of the register microkernel tile.
pub const MR: usize = 8;
/// Columns of the register microkernel tile (one 8-lane f32 vector on
/// AVX2; two 4-lane vectors on NEON).
pub const NR: usize = 8;
/// Rows per packed A block (multiple of `MR`; A pack = MC×KC ≈ 64 KiB).
pub const MC: usize = 64;
/// Depth of one cache block (shared by the A and B packs).
pub const KC: usize = 256;
/// Columns per packed B block (multiple of `NR`; B pack = KC×NC floats).
pub const NC: usize = 512;

/// Below this `m·k·n` product the naive kernel wins (packing overhead
/// is linear in the operand sizes but the break-even is empirical:
/// ~64³ on the boxes this repo targets).
pub const PACKED_MIN_FLOPS: usize = 64 * 64 * 64;

/// Which matmul kernel family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Reference `(i,k,j)` kernel — the oracle the packed kernel is
    /// property-tested against.
    Naive,
    /// Cache-blocked panel-packed kernel with the scalar microkernel
    /// (bit-identical to `Naive`).
    Packed,
    /// Packed kernel with the explicit-SIMD FMA microkernel
    /// (AVX2/FMA or NEON; falls back to `Packed` when the CPU lacks
    /// the features — see [`simd_available`]).
    Simd,
}

impl KernelKind {
    /// Parse `naive` / `packed` / `simd` (the CLI `--kernel` values).
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.trim().to_lowercase().as_str() {
            "naive" => Ok(KernelKind::Naive),
            "packed" => Ok(KernelKind::Packed),
            "simd" => Ok(KernelKind::Simd),
            other => Err(format!("unknown kernel `{other}` (naive|packed|simd)")),
        }
    }

    pub fn display_name(&self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Packed => "packed",
            KernelKind::Simd => "simd",
        }
    }
}

/// Which microkernel a packed call runs — resolved ONCE per call, after
/// feature detection, so the inner loops never re-check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Micro {
    Scalar,
    Simd,
}

// Process-wide kernel policy. 0 = packed (default), 1 = naive, 2 = simd.
static KERNEL_KIND: AtomicU8 = AtomicU8::new(0);
// Worker threads for the packed kernel's row-panel loop (>= 1).
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(1);
// Call counters: top-level packed/SIMD kernel invocations since process
// start. Observability for the recursion-routing tests and benches —
// one relaxed increment per matmul, negligible next to the compute.
static PACKED_CALLS: AtomicU64 = AtomicU64::new(0);
static SIMD_CALLS: AtomicU64 = AtomicU64::new(0);

/// Set the process-wide default kernel (CLI `--kernel`).
pub fn set_default(kind: KernelKind) {
    let v = match kind {
        KernelKind::Packed => 0,
        KernelKind::Naive => 1,
        KernelKind::Simd => 2,
    };
    KERNEL_KIND.store(v, Ordering::Relaxed);
}

/// The process-wide default kernel (as requested; see
/// [`effective_kind`] for what actually runs).
pub fn default_kind() -> KernelKind {
    match KERNEL_KIND.load(Ordering::Relaxed) {
        1 => KernelKind::Naive,
        2 => KernelKind::Simd,
        _ => KernelKind::Packed,
    }
}

/// The kernel that will actually execute for a requested kind:
/// `Simd` degrades to `Packed` when the CPU lacks the features.
pub fn effective_kind(kind: KernelKind) -> KernelKind {
    match kind {
        KernelKind::Simd if !simd_available() => KernelKind::Packed,
        k => k,
    }
}

/// Set the packed kernel's worker-thread count (CLI `--kernel-threads`).
/// Clamped to >= 1; 1 disables parallelism (the default — worker-pool
/// threads each run their own kernel and must not oversubscribe).
pub fn set_threads(threads: usize) {
    KERNEL_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// The packed kernel's configured worker-thread count.
pub fn threads() -> usize {
    KERNEL_THREADS.load(Ordering::Relaxed).max(1)
}

/// Top-level scalar packed kernel calls since process start.
pub fn packed_call_count() -> u64 {
    PACKED_CALLS.load(Ordering::Relaxed)
}

/// Top-level SIMD kernel calls since process start (only bumped when
/// the SIMD microkernel actually ran, not on the fallback).
pub fn simd_call_count() -> u64 {
    SIMD_CALLS.load(Ordering::Relaxed)
}

#[cfg(target_arch = "x86_64")]
fn simd_available_impl() -> bool {
    // Both are required: the microkernel issues vfmadd231ps on ymm.
    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
}

#[cfg(target_arch = "aarch64")]
fn simd_available_impl() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn simd_available_impl() -> bool {
    false
}

/// Whether this CPU can run the explicit-SIMD microkernel (AVX2+FMA on
/// x86_64, NEON on aarch64). The std detection macros cache, so this is
/// cheap to call per matmul.
pub fn simd_available() -> bool {
    simd_available_impl()
}

/// Elementwise bound on `|simd − scalar|` for one output element of an
/// `m×k · k×n` product whose operand entries are bounded by `a_max` /
/// `b_max` in magnitude.
///
/// Both kernels compute the same ascending-`k` sum; each is within the
/// standard dot-product forward-error cone `γ_k · Σ|aᵢ·bᵢ|` of the
/// exact value (`γ_k = k·ε/(1−k·ε)`, ε = `f32::EPSILON`/2; FMA is
/// strictly tighter). The difference of the two is therefore at most
/// `2·γ_k·Σ|aᵢ·bᵢ| ≤ 2·k·ε·k·a_max·b_max` to first order. This is a
/// *worst-case* bound — observed differences are typically ~√k smaller —
/// used by `tests/kernel_simd.rs` as the acceptance epsilon.
pub fn simd_abs_bound(k: usize, a_max: f32, b_max: f32) -> f32 {
    let kf = k as f32;
    2.0 * kf * f32::EPSILON * kf * a_max * b_max
}

/// Profiling tap for a naive-kernel call (one relaxed-load branch when
/// `obs::prof` is disabled). Packed/SIMD calls record inside
/// [`packed_into`], where the effective microkernel is known.
#[inline]
fn profile_naive(m: usize, k: usize, n: usize) {
    if crate::obs::prof::profiling_enabled() {
        crate::obs::prof::record_kernel(0, 2 * (m as u64) * (k as u64) * (n as u64), 0);
    }
}

/// Kernel dispatch for [`Matrix::matmul`]: the configured default kind,
/// with small products routed to the naive kernel by the size heuristic.
pub(crate) fn dispatch(lhs: &Matrix, rhs: &Matrix) -> Matrix {
    let flops = lhs.rows() * lhs.cols() * rhs.cols();
    match default_kind() {
        KernelKind::Naive => {
            profile_naive(lhs.rows(), lhs.cols(), rhs.cols());
            lhs.matmul_naive(rhs)
        }
        _ if flops < PACKED_MIN_FLOPS => {
            profile_naive(lhs.rows(), lhs.cols(), rhs.cols());
            lhs.matmul_naive(rhs)
        }
        KernelKind::Packed => matmul_packed(lhs, rhs, threads()),
        KernelKind::Simd => matmul_simd(lhs, rhs, threads()),
    }
}

/// Multiply `lhs · rhs` into a caller-owned buffer (reshaped and zeroed
/// in place, allocation-free once warm) with an explicit kernel kind —
/// the recursion leaves' entry point, deliberately independent of the
/// process-wide default.
pub fn matmul_into(
    kind: KernelKind,
    lhs: &Matrix,
    rhs: &Matrix,
    out: &mut Matrix,
    threads: usize,
) {
    match kind {
        KernelKind::Naive => {
            profile_naive(lhs.rows(), lhs.cols(), rhs.cols());
            lhs.matmul_naive_into(rhs, out)
        }
        KernelKind::Packed => matmul_packed_into(lhs, rhs, out, threads),
        KernelKind::Simd => matmul_simd_into(lhs, rhs, out, threads),
    }
}

/// Scalar packed matmul with an explicit thread count (1 = serial).
/// Panics on a dimension mismatch, like [`Matrix::matmul`].
pub fn matmul_packed(lhs: &Matrix, rhs: &Matrix, threads: usize) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    matmul_packed_into(lhs, rhs, &mut out, threads);
    out
}

/// [`matmul_packed`] into a caller-owned buffer.
pub fn matmul_packed_into(lhs: &Matrix, rhs: &Matrix, out: &mut Matrix, threads: usize) {
    packed_into(lhs, rhs, out, threads, Micro::Scalar);
}

/// SIMD packed matmul with an explicit thread count; runs the scalar
/// packed kernel when the CPU lacks the features (see module docs).
pub fn matmul_simd(lhs: &Matrix, rhs: &Matrix, threads: usize) -> Matrix {
    let mut out = Matrix::zeros(0, 0);
    matmul_simd_into(lhs, rhs, &mut out, threads);
    out
}

/// [`matmul_simd`] into a caller-owned buffer.
pub fn matmul_simd_into(lhs: &Matrix, rhs: &Matrix, out: &mut Matrix, threads: usize) {
    let micro = if simd_available() {
        Micro::Simd
    } else {
        Micro::Scalar
    };
    packed_into(lhs, rhs, out, threads, micro);
}

/// Shared packed driver: tiling, packing and the thread split are
/// identical for both microkernels; only the innermost rank-`kc` update
/// differs.
fn packed_into(lhs: &Matrix, rhs: &Matrix, out: &mut Matrix, threads: usize, micro: Micro) {
    assert_eq!(
        lhs.cols(),
        rhs.rows(),
        "matmul dims: {:?} x {:?}",
        lhs.shape(),
        rhs.shape()
    );
    match micro {
        Micro::Scalar => PACKED_CALLS.fetch_add(1, Ordering::Relaxed),
        Micro::Simd => SIMD_CALLS.fetch_add(1, Ordering::Relaxed),
    };
    let (m, k) = lhs.shape();
    let n = rhs.cols();
    if crate::obs::prof::profiling_enabled() {
        let kind = match micro {
            Micro::Scalar => 1,
            Micro::Simd => 2,
        };
        // Per-call work and pack traffic: one A panel copy (m·k floats)
        // plus one B panel copy (k·n floats) per call in the serial
        // path; threaded calls duplicate B panels, not counted here.
        crate::obs::prof::record_kernel(
            kind,
            2 * (m as u64) * (k as u64) * (n as u64),
            4 * ((m as u64) * (k as u64) + (k as u64) * (n as u64)),
        );
    }
    out.reset(m, n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // At most one thread per MC row panel; each thread gets a contiguous
    // MC-aligned row chunk so no two threads share an output row, and
    // the clamp to `panels` guarantees every spawned chunk is non-empty.
    let panels = m.div_ceil(MC);
    let t = threads.max(1).min(panels);
    if t <= 1 {
        packed_serial(lhs.as_slice(), rhs.as_slice(), out.as_mut_slice(), m, k, n, micro);
        return;
    }
    let rows_per_chunk = panels.div_ceil(t) * MC;
    let a = lhs.as_slice();
    let b = rhs.as_slice();
    std::thread::scope(|s| {
        let mut rest = out.as_mut_slice();
        let mut row = 0;
        while row < m {
            let rows = rows_per_chunk.min(m - row);
            debug_assert!(rows > 0, "empty thread chunk at row {row} of {m}");
            let (chunk, tail) = rest.split_at_mut(rows * n);
            rest = tail;
            let a_sub = &a[row * k..(row + rows) * k];
            s.spawn(move || packed_serial(a_sub, b, chunk, rows, k, n, micro));
            row += rows;
        }
    });
}

/// Serial packed kernel over one row range: `out += a · b` with `out`
/// pre-zeroed, `a` of shape `m×k`, `b` of shape `k×n`, all row-major.
///
/// When called from the threaded outer loop, each thread packs its own
/// copy of the shared B panels: at the sizes this system serves the
/// duplicated packing is ~1–2% of the thread's compute, and avoiding it
/// would need cross-thread synchronization on the pack buffer.
fn packed_serial(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    micro: Micro,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    PACK_BUFS.with(|bufs| {
        let (apack, bpack) = &mut *bufs.borrow_mut();
        if apack.len() < MC * KC {
            apack.resize(MC * KC, 0.0);
        }
        if bpack.len() < NC * KC {
            bpack.resize(NC * KC, 0.0);
        }
        // jj (output columns) and ii (output rows) are pure partition
        // loops; kk is the reduction loop and therefore sits INSIDE
        // them in ascending order so each element accumulates in naive
        // k-order.
        let mut jj = 0;
        while jj < n {
            let nc = NC.min(n - jj);
            let mut kk = 0;
            while kk < k {
                let kc = KC.min(k - kk);
                pack_b(b, n, kk, kc, jj, nc, bpack);
                let mut ii = 0;
                while ii < m {
                    let mc = MC.min(m - ii);
                    pack_a(a, k, ii, mc, kk, kc, apack);
                    macro_block(apack, bpack, out, n, ii, mc, jj, nc, kc, micro);
                    ii += mc;
                }
                kk += kc;
            }
            jj += nc;
        }
    });
}

/// Pack an `mc×kc` block of A (rows `ii..`, cols `kk..`) into MR-tall
/// row panels: element `(r, p)` of panel `pi` lands at
/// `pi·(MR·kc) + p·MR + r`. Short tail panels are zero-padded.
fn pack_a(a: &[f32], lda: usize, ii: usize, mc: usize, kk: usize, kc: usize, apack: &mut [f32]) {
    let mut pi = 0;
    let mut i0 = 0;
    while i0 < mc {
        let mr = MR.min(mc - i0);
        let panel = &mut apack[pi * MR * kc..(pi + 1) * MR * kc];
        for p in 0..kc {
            let col = &mut panel[p * MR..(p + 1) * MR];
            for (r, slot) in col.iter_mut().enumerate() {
                *slot = if r < mr {
                    a[(ii + i0 + r) * lda + kk + p]
                } else {
                    0.0
                };
            }
        }
        pi += 1;
        i0 += mr;
    }
}

/// Pack a `kc×nc` block of B (rows `kk..`, cols `jj..`) into NR-wide
/// column panels: element `(p, c)` of panel `pj` lands at
/// `pj·(NR·kc) + p·NR + c`. Short tail panels are zero-padded.
fn pack_b(b: &[f32], ldb: usize, kk: usize, kc: usize, jj: usize, nc: usize, bpack: &mut [f32]) {
    let mut pj = 0;
    let mut j0 = 0;
    while j0 < nc {
        let nr = NR.min(nc - j0);
        let panel = &mut bpack[pj * NR * kc..(pj + 1) * NR * kc];
        for p in 0..kc {
            let src = &b[(kk + p) * ldb + jj + j0..];
            let row = &mut panel[p * NR..(p + 1) * NR];
            for (c, slot) in row.iter_mut().enumerate() {
                *slot = if c < nr { src[c] } else { 0.0 };
            }
        }
        pj += 1;
        j0 += nr;
    }
}

/// One `mc×nc` macro block: every (MR panel of A) × (NR panel of B)
/// microkernel, accumulating into `out`.
#[allow(clippy::too_many_arguments)]
fn macro_block(
    apack: &[f32],
    bpack: &[f32],
    out: &mut [f32],
    ldo: usize,
    ii: usize,
    mc: usize,
    jj: usize,
    nc: usize,
    kc: usize,
    micro: Micro,
) {
    let mut pj = 0;
    let mut j0 = 0;
    while j0 < nc {
        let nr = NR.min(nc - j0);
        let bpanel = &bpack[pj * NR * kc..(pj + 1) * NR * kc];
        let mut pi = 0;
        let mut i0 = 0;
        while i0 < mc {
            let mr = MR.min(mc - i0);
            let apanel = &apack[pi * MR * kc..(pi + 1) * MR * kc];
            // Load the live output lanes into the accumulator BEFORE
            // the rank-kc update: the per-element accumulation chain
            // then continues the previous kk blocks' partial sum term
            // by term, in exactly the naive kernel's order — float
            // addition is not associative, so summing a block into a
            // fresh accumulator and adding it afterwards would NOT be
            // bit-identical once k > KC. Padded lanes start at 0 and
            // are never stored back. (The same ordering argument gives
            // the SIMD path its epsilon bound: it runs the identical
            // chain, just with each step fused.)
            let mut acc = [[0.0f32; NR]; MR];
            for (r, acc_row) in acc.iter_mut().enumerate().take(mr) {
                let src = &out[(ii + i0 + r) * ldo + jj + j0..][..nr];
                acc_row[..nr].copy_from_slice(src);
            }
            micro_update(micro, apanel, bpanel, kc, &mut acc);
            for (r, acc_row) in acc.iter().enumerate().take(mr) {
                let dst = &mut out[(ii + i0 + r) * ldo + jj + j0..][..nr];
                dst.copy_from_slice(&acc_row[..nr]);
            }
            pi += 1;
            i0 += mr;
        }
        pj += 1;
        j0 += nr;
    }
}

/// Rank-`kc` update of one `MR×NR` tile with the resolved microkernel.
#[inline]
fn micro_update(
    micro: Micro,
    apanel: &[f32],
    bpanel: &[f32],
    kc: usize,
    acc: &mut [[f32; NR]; MR],
) {
    debug_assert!(apanel.len() >= kc * MR && bpanel.len() >= kc * NR);
    match micro {
        Micro::Scalar => microkernel(apanel, bpanel, kc, acc),
        Micro::Simd => {
            // SAFETY: `Micro::Simd` is only constructed in
            // `matmul_simd_into` after `simd_available()` confirmed the
            // target features, and the debug_assert above re-states the
            // panel-length contract the pointer arithmetic relies on.
            #[cfg(target_arch = "x86_64")]
            unsafe {
                microkernel_avx2(apanel, bpanel, kc, acc);
            }
            #[cfg(target_arch = "aarch64")]
            unsafe {
                microkernel_neon(apanel, bpanel, kc, acc);
            }
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            microkernel(apanel, bpanel, kc, acc);
        }
    }
}

/// The `MR×NR` scalar register microkernel: a fixed-shape rank-`kc`
/// update of the pre-loaded accumulator, which the compiler unrolls
/// into vector mul+add (Rust never contracts to FMA, preserving
/// bit-exactness).
#[inline]
fn microkernel(apanel: &[f32], bpanel: &[f32], kc: usize, acc: &mut [[f32; NR]; MR]) {
    for p in 0..kc {
        let a: &[f32; MR] = apanel[p * MR..(p + 1) * MR].try_into().unwrap();
        let b: &[f32; NR] = bpanel[p * NR..(p + 1) * NR].try_into().unwrap();
        for r in 0..MR {
            let ar = a[r];
            let row = &mut acc[r];
            for c in 0..NR {
                row[c] += ar * b[c];
            }
        }
    }
}

/// AVX2/FMA microkernel: one 8-lane `ymm` accumulator per tile row,
/// `vfmadd231ps` per (row, k) step. Same ascending-`k` chain as the
/// scalar kernel, each step fused (see the module's FMA policy).
///
/// # Safety
/// Caller must have verified `avx2` and `fma` via
/// [`simd_available`], and `apanel`/`bpanel` must hold at least
/// `kc·MR` / `kc·NR` elements.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn microkernel_avx2(apanel: &[f32], bpanel: &[f32], kc: usize, acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    let mut vacc: [__m256; MR] = [_mm256_setzero_ps(); MR];
    for r in 0..MR {
        vacc[r] = _mm256_loadu_ps(acc[r].as_ptr());
    }
    let ap = apanel.as_ptr();
    let bp = bpanel.as_ptr();
    for p in 0..kc {
        let bv = _mm256_loadu_ps(bp.add(p * NR));
        let arow = ap.add(p * MR);
        for r in 0..MR {
            let av = _mm256_set1_ps(*arow.add(r));
            vacc[r] = _mm256_fmadd_ps(av, bv, vacc[r]);
        }
    }
    for r in 0..MR {
        _mm256_storeu_ps(acc[r].as_mut_ptr(), vacc[r]);
    }
}

/// NEON microkernel: two 4-lane `v`-register accumulators per tile row
/// (NR = 8), `fmla` per (row, k, half) step. Same ascending-`k` chain
/// as the scalar kernel, each step fused.
///
/// # Safety
/// Caller must have verified NEON via [`simd_available`], and
/// `apanel`/`bpanel` must hold at least `kc·MR` / `kc·NR` elements.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn microkernel_neon(apanel: &[f32], bpanel: &[f32], kc: usize, acc: &mut [[f32; NR]; MR]) {
    use std::arch::aarch64::*;
    let mut lo: [float32x4_t; MR] = [vdupq_n_f32(0.0); MR];
    let mut hi: [float32x4_t; MR] = [vdupq_n_f32(0.0); MR];
    for r in 0..MR {
        lo[r] = vld1q_f32(acc[r].as_ptr());
        hi[r] = vld1q_f32(acc[r].as_ptr().add(4));
    }
    let ap = apanel.as_ptr();
    let bp = bpanel.as_ptr();
    for p in 0..kc {
        let b_lo = vld1q_f32(bp.add(p * NR));
        let b_hi = vld1q_f32(bp.add(p * NR + 4));
        let arow = ap.add(p * MR);
        for r in 0..MR {
            let av = vdupq_n_f32(*arow.add(r));
            lo[r] = vfmaq_f32(lo[r], av, b_lo);
            hi[r] = vfmaq_f32(hi[r], av, b_hi);
        }
    }
    for r in 0..MR {
        vst1q_f32(acc[r].as_mut_ptr(), lo[r]);
        vst1q_f32(acc[r].as_mut_ptr().add(4), hi[r]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    /// Elementwise equality that also accepts NaN == NaN (packed and
    /// naive produce NaN at the same positions).
    fn same_values(a: &Matrix, b: &Matrix) -> bool {
        a.shape() == b.shape()
            && a.as_slice()
                .iter()
                .zip(b.as_slice().iter())
                .all(|(x, y)| (x.is_nan() && y.is_nan()) || x == y)
    }

    #[test]
    fn packed_matches_naive_on_blocked_and_tail_shapes() {
        let mut rng = Rng::seeded(31);
        // Shapes straddling every panel boundary: exact multiples, ±1
        // tails, degenerate 1×N, tall/flat.
        for &(m, k, n) in &[
            (8usize, 8usize, 8usize),
            (16, 16, 16),
            (64, 64, 64),
            (65, 63, 66),
            (1, 40, 17),
            (33, 1, 9),
            (7, 300, 5),
            (70, 70, 1),
        ] {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let want = a.matmul_naive(&b);
            let got = matmul_packed(&a, &b, 1);
            assert!(same_values(&got, &want), "{m}x{k}x{n} mismatch");
        }
    }

    #[test]
    fn packed_is_threadcount_invariant() {
        let mut rng = Rng::seeded(32);
        let a = Matrix::random(130, 70, &mut rng);
        let b = Matrix::random(70, 90, &mut rng);
        let serial = matmul_packed(&a, &b, 1);
        for t in [2, 3, 4, 8] {
            let par = matmul_packed(&a, &b, t);
            assert_eq!(
                par.as_slice(),
                serial.as_slice(),
                "threads={t} changed the result"
            );
        }
    }

    #[test]
    fn threads_beyond_panel_count_are_clamped() {
        // m = 9 is a single MC panel: 1000 threads must degrade to the
        // serial path without spawning empty chunks or changing bits.
        let mut rng = Rng::seeded(33);
        let a = Matrix::random(9, 33, &mut rng);
        let b = Matrix::random(33, 21, &mut rng);
        assert_eq!(
            matmul_packed(&a, &b, 1000).as_slice(),
            matmul_packed(&a, &b, 1).as_slice()
        );
        // Two panels, many threads: exactly two non-empty chunks.
        let a = Matrix::random(MC + 1, 17, &mut rng);
        let b = Matrix::random(17, 5, &mut rng);
        assert_eq!(
            matmul_packed(&a, &b, 64).as_slice(),
            matmul_packed(&a, &b, 1).as_slice()
        );
    }

    #[test]
    fn packed_handles_empty_reduction() {
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 5);
        let c = matmul_packed(&a, &b, 2);
        assert_eq!(c.shape(), (4, 5));
        assert!(c.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "matmul dims")]
    fn packed_rejects_dim_mismatch() {
        let _ = matmul_packed(&Matrix::zeros(2, 3), &Matrix::zeros(2, 3), 1);
    }

    #[test]
    fn packed_into_reuses_a_stale_buffer() {
        let mut rng = Rng::seeded(34);
        let a = Matrix::random(20, 30, &mut rng);
        let b = Matrix::random(30, 10, &mut rng);
        let want = matmul_packed(&a, &b, 1);
        let mut out = Matrix::from_slice(1, 3, &[9.0, 9.0, 9.0]);
        matmul_packed_into(&a, &b, &mut out, 1);
        assert_eq!(out.as_slice(), want.as_slice());
        assert_eq!(out.shape(), (20, 10));
    }

    #[test]
    fn simd_matches_scalar_within_bound_or_exactly() {
        // On CPUs without the features the SIMD entry points run the
        // scalar kernel, so this test is meaningful either way.
        let mut rng = Rng::seeded(35);
        for &(m, k, n) in &[(16usize, 16usize, 16usize), (65, 63, 66), (7, 300, 5)] {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let scalar = matmul_packed(&a, &b, 1);
            let simd = matmul_simd(&a, &b, 1);
            let bound = simd_abs_bound(k, 1.0, 1.0);
            for (i, (x, y)) in simd.as_slice().iter().zip(scalar.as_slice()).enumerate() {
                assert!(
                    (x - y).abs() <= bound,
                    "{m}x{k}x{n} elem {i}: |{x} - {y}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn kernel_kind_parse_and_globals() {
        assert_eq!(KernelKind::parse("Packed").unwrap(), KernelKind::Packed);
        assert_eq!(KernelKind::parse("naive").unwrap(), KernelKind::Naive);
        assert_eq!(KernelKind::parse("SIMD").unwrap(), KernelKind::Simd);
        assert!(KernelKind::parse("fast").is_err());
        assert_eq!(KernelKind::Packed.display_name(), "packed");
        assert_eq!(KernelKind::Simd.display_name(), "simd");
        let before = threads();
        set_threads(0);
        assert_eq!(threads(), 1, "thread count clamps to >= 1");
        set_threads(before);
        // effective_kind only substitutes Simd, and only when the CPU
        // lacks the features.
        assert_eq!(effective_kind(KernelKind::Naive), KernelKind::Naive);
        assert_eq!(effective_kind(KernelKind::Packed), KernelKind::Packed);
        let eff = effective_kind(KernelKind::Simd);
        if simd_available() {
            assert_eq!(eff, KernelKind::Simd);
        } else {
            assert_eq!(eff, KernelKind::Packed);
        }
    }
}
