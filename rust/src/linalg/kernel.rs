//! The packed matmul kernel: cache-blocked `MC×KC×NC` tiling with
//! panel-packed operands, an `MR×NR` register microkernel written to
//! auto-vectorize, and an opt-in thread-parallel outer loop over row
//! panels — pure std, no dependencies.
//!
//! # Kernel selection
//!
//! [`Matrix::matmul`] dispatches through this module: the process-wide
//! default kind ([`set_default`], CLI `--kernel {naive,packed}`) picks
//! the family, and a size heuristic ([`PACKED_MIN_FLOPS`]) keeps tiny
//! products on the naive `(i,k,j)` kernel, whose loop overhead-free
//! inner loop wins below the packing break-even point. The naive kernel
//! ([`Matrix::matmul_naive`]) is the reference oracle: the property
//! suite (`tests/kernel_packed.rs`) pins the packed kernel against it
//! on random shapes — including non-square, non-divisible and 1×N —
//! and on NaN/Inf operands.
//!
//! # Bit-exactness
//!
//! The packed kernel accumulates every output element in ascending-`k`
//! order — the `kk` block loop is the outermost reduction loop and the
//! microkernel walks `p` upward inside each block — which is exactly
//! the naive kernel's per-element order. Rust does not contract `a*b+c`
//! to FMA, so for every input (finite or not) the packed result is
//! **bit-identical** to the naive result, and the coordinator's decode
//! bit-reproducibility guarantees (`collect_all`) are unaffected by
//! kernel choice. Zero-padded panel tails only feed accumulator lanes
//! that are never written back.
//!
//! # Parallelism
//!
//! `threads > 1` splits the *output rows* into contiguous `MC`-aligned
//! chunks, one scoped thread per chunk, each with private pack buffers.
//! Each output element is still produced by exactly one thread with the
//! same accumulation order, so results are identical for every thread
//! count. Parallelism is opt-in (default 1): the worker pool already
//! runs one kernel per worker thread, and oversubscribing it would slow
//! the fleet down. `--kernel-threads N` (or [`set_threads`]) enables it
//! for single large multiplies (e.g. the master's local fallback).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};

use crate::linalg::matrix::Matrix;

thread_local! {
    /// Per-thread pack buffers, reused across calls on persistent
    /// threads — the worker pool and the serial path, where a fresh
    /// ~576 KiB allocation pair per matmul would put an allocator
    /// round-trip on the hot path the encode scratch just removed.
    /// (The opt-in multi-threaded path spawns scoped threads per call,
    /// so each pays one allocation; thread-spawn cost dominates there.)
    /// The packing loops fully overwrite every panel slot they expose
    /// (padding included), so the buffers are grown but never re-zeroed.
    static PACK_BUFS: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Rows of the register microkernel tile.
pub const MR: usize = 8;
/// Columns of the register microkernel tile (one 8-lane f32 vector).
pub const NR: usize = 8;
/// Rows per packed A block (multiple of `MR`; A pack = MC×KC ≈ 64 KiB).
pub const MC: usize = 64;
/// Depth of one cache block (shared by the A and B packs).
pub const KC: usize = 256;
/// Columns per packed B block (multiple of `NR`; B pack = KC×NC floats).
pub const NC: usize = 512;

/// Below this `m·k·n` product the naive kernel wins (packing overhead
/// is linear in the operand sizes but the break-even is empirical:
/// ~64³ on the boxes this repo targets).
pub const PACKED_MIN_FLOPS: usize = 64 * 64 * 64;

/// Which matmul kernel family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Reference `(i,k,j)` kernel — the oracle the packed kernel is
    /// property-tested against.
    Naive,
    /// Cache-blocked panel-packed kernel (this module).
    Packed,
}

impl KernelKind {
    /// Parse `naive` / `packed` (the CLI `--kernel` values).
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.trim().to_lowercase().as_str() {
            "naive" => Ok(KernelKind::Naive),
            "packed" => Ok(KernelKind::Packed),
            other => Err(format!("unknown kernel `{other}` (naive|packed)")),
        }
    }

    pub fn display_name(&self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Packed => "packed",
        }
    }
}

// Process-wide kernel policy. 0 = packed (default), 1 = naive.
static KERNEL_KIND: AtomicU8 = AtomicU8::new(0);
// Worker threads for the packed kernel's row-panel loop (>= 1).
static KERNEL_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide default kernel (CLI `--kernel`).
pub fn set_default(kind: KernelKind) {
    KERNEL_KIND.store(matches!(kind, KernelKind::Naive) as u8, Ordering::Relaxed);
}

/// The process-wide default kernel.
pub fn default_kind() -> KernelKind {
    if KERNEL_KIND.load(Ordering::Relaxed) == 1 {
        KernelKind::Naive
    } else {
        KernelKind::Packed
    }
}

/// Set the packed kernel's worker-thread count (CLI `--kernel-threads`).
/// Clamped to >= 1; 1 disables parallelism (the default — worker-pool
/// threads each run their own kernel and must not oversubscribe).
pub fn set_threads(threads: usize) {
    KERNEL_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// The packed kernel's configured worker-thread count.
pub fn threads() -> usize {
    KERNEL_THREADS.load(Ordering::Relaxed).max(1)
}

/// Kernel dispatch for [`Matrix::matmul`]: the configured default kind,
/// with small products routed to the naive kernel by the size heuristic.
pub(crate) fn dispatch(lhs: &Matrix, rhs: &Matrix) -> Matrix {
    let flops = lhs.rows() * lhs.cols() * rhs.cols();
    match default_kind() {
        KernelKind::Naive => lhs.matmul_naive(rhs),
        KernelKind::Packed if flops >= PACKED_MIN_FLOPS => {
            matmul_packed(lhs, rhs, threads())
        }
        KernelKind::Packed => lhs.matmul_naive(rhs),
    }
}

/// Packed matmul with an explicit thread count (1 = serial). Panics on
/// a dimension mismatch, like [`Matrix::matmul`].
pub fn matmul_packed(lhs: &Matrix, rhs: &Matrix, threads: usize) -> Matrix {
    assert_eq!(
        lhs.cols(),
        rhs.rows(),
        "matmul dims: {:?} x {:?}",
        lhs.shape(),
        rhs.shape()
    );
    let (m, k) = lhs.shape();
    let n = rhs.cols();
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    // At most one thread per MC row panel; each thread gets a contiguous
    // MC-aligned row chunk so no two threads share an output row.
    let panels = (m + MC - 1) / MC;
    let t = threads.max(1).min(panels);
    if t <= 1 {
        packed_serial(lhs.as_slice(), rhs.as_slice(), out.as_mut_slice(), m, k, n);
        return out;
    }
    let panels_per_thread = (panels + t - 1) / t;
    let rows_per_chunk = panels_per_thread * MC;
    let a = lhs.as_slice();
    let b = rhs.as_slice();
    std::thread::scope(|s| {
        let mut rest = out.as_mut_slice();
        let mut row = 0;
        while row < m {
            let rows = rows_per_chunk.min(m - row);
            let (chunk, tail) = rest.split_at_mut(rows * n);
            rest = tail;
            let a_sub = &a[row * k..(row + rows) * k];
            s.spawn(move || packed_serial(a_sub, b, chunk, rows, k, n));
            row += rows;
        }
    });
    out
}

/// Serial packed kernel over one row range: `out += a · b` with `out`
/// pre-zeroed, `a` of shape `m×k`, `b` of shape `k×n`, all row-major.
///
/// When called from the threaded outer loop, each thread packs its own
/// copy of the shared B panels: at the sizes this system serves the
/// duplicated packing is ~1–2% of the thread's compute, and avoiding it
/// would need cross-thread synchronization on the pack buffer.
fn packed_serial(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    PACK_BUFS.with(|bufs| {
        let (apack, bpack) = &mut *bufs.borrow_mut();
        if apack.len() < MC * KC {
            apack.resize(MC * KC, 0.0);
        }
        if bpack.len() < NC * KC {
            bpack.resize(NC * KC, 0.0);
        }
        // jj (output columns) and ii (output rows) are pure partition
        // loops; kk is the reduction loop and therefore sits INSIDE
        // them in ascending order so each element accumulates in naive
        // k-order.
        let mut jj = 0;
        while jj < n {
            let nc = NC.min(n - jj);
            let mut kk = 0;
            while kk < k {
                let kc = KC.min(k - kk);
                pack_b(b, n, kk, kc, jj, nc, bpack);
                let mut ii = 0;
                while ii < m {
                    let mc = MC.min(m - ii);
                    pack_a(a, k, ii, mc, kk, kc, apack);
                    macro_block(apack, bpack, out, n, ii, mc, jj, nc, kc);
                    ii += mc;
                }
                kk += kc;
            }
            jj += nc;
        }
    });
}

/// Pack an `mc×kc` block of A (rows `ii..`, cols `kk..`) into MR-tall
/// row panels: element `(r, p)` of panel `pi` lands at
/// `pi·(MR·kc) + p·MR + r`. Short tail panels are zero-padded.
fn pack_a(a: &[f32], lda: usize, ii: usize, mc: usize, kk: usize, kc: usize, apack: &mut [f32]) {
    let mut pi = 0;
    let mut i0 = 0;
    while i0 < mc {
        let mr = MR.min(mc - i0);
        let panel = &mut apack[pi * MR * kc..(pi + 1) * MR * kc];
        for p in 0..kc {
            let col = &mut panel[p * MR..(p + 1) * MR];
            for (r, slot) in col.iter_mut().enumerate() {
                *slot = if r < mr {
                    a[(ii + i0 + r) * lda + kk + p]
                } else {
                    0.0
                };
            }
        }
        pi += 1;
        i0 += mr;
    }
}

/// Pack a `kc×nc` block of B (rows `kk..`, cols `jj..`) into NR-wide
/// column panels: element `(p, c)` of panel `pj` lands at
/// `pj·(NR·kc) + p·NR + c`. Short tail panels are zero-padded.
fn pack_b(b: &[f32], ldb: usize, kk: usize, kc: usize, jj: usize, nc: usize, bpack: &mut [f32]) {
    let mut pj = 0;
    let mut j0 = 0;
    while j0 < nc {
        let nr = NR.min(nc - j0);
        let panel = &mut bpack[pj * NR * kc..(pj + 1) * NR * kc];
        for p in 0..kc {
            let src = &b[(kk + p) * ldb + jj + j0..];
            let row = &mut panel[p * NR..(p + 1) * NR];
            for (c, slot) in row.iter_mut().enumerate() {
                *slot = if c < nr { src[c] } else { 0.0 };
            }
        }
        pj += 1;
        j0 += nr;
    }
}

/// One `mc×nc` macro block: every (MR panel of A) × (NR panel of B)
/// microkernel, accumulating into `out`.
#[allow(clippy::too_many_arguments)]
fn macro_block(
    apack: &[f32],
    bpack: &[f32],
    out: &mut [f32],
    ldo: usize,
    ii: usize,
    mc: usize,
    jj: usize,
    nc: usize,
    kc: usize,
) {
    let mut pj = 0;
    let mut j0 = 0;
    while j0 < nc {
        let nr = NR.min(nc - j0);
        let bpanel = &bpack[pj * NR * kc..(pj + 1) * NR * kc];
        let mut pi = 0;
        let mut i0 = 0;
        while i0 < mc {
            let mr = MR.min(mc - i0);
            let apanel = &apack[pi * MR * kc..(pi + 1) * MR * kc];
            // Load the live output lanes into the accumulator BEFORE
            // the rank-kc update: the per-element accumulation chain
            // then continues the previous kk blocks' partial sum term
            // by term, in exactly the naive kernel's order — float
            // addition is not associative, so summing a block into a
            // fresh accumulator and adding it afterwards would NOT be
            // bit-identical once k > KC. Padded lanes start at 0 and
            // are never stored back.
            let mut acc = [[0.0f32; NR]; MR];
            for (r, acc_row) in acc.iter_mut().enumerate().take(mr) {
                let src = &out[(ii + i0 + r) * ldo + jj + j0..][..nr];
                acc_row[..nr].copy_from_slice(src);
            }
            microkernel(apanel, bpanel, kc, &mut acc);
            for (r, acc_row) in acc.iter().enumerate().take(mr) {
                let dst = &mut out[(ii + i0 + r) * ldo + jj + j0..][..nr];
                dst.copy_from_slice(&acc_row[..nr]);
            }
            pi += 1;
            i0 += mr;
        }
        pj += 1;
        j0 += nr;
    }
}

/// The `MR×NR` register microkernel: a fixed-shape rank-`kc` update of
/// the pre-loaded accumulator, which the compiler unrolls into vector
/// mul+add (Rust never contracts to FMA, preserving bit-exactness).
#[inline]
fn microkernel(apanel: &[f32], bpanel: &[f32], kc: usize, acc: &mut [[f32; NR]; MR]) {
    for p in 0..kc {
        let a: &[f32; MR] = apanel[p * MR..(p + 1) * MR].try_into().unwrap();
        let b: &[f32; NR] = bpanel[p * NR..(p + 1) * NR].try_into().unwrap();
        for r in 0..MR {
            let ar = a[r];
            let row = &mut acc[r];
            for c in 0..NR {
                row[c] += ar * b[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    /// Elementwise equality that also accepts NaN == NaN (packed and
    /// naive produce NaN at the same positions).
    fn same_values(a: &Matrix, b: &Matrix) -> bool {
        a.shape() == b.shape()
            && a.as_slice()
                .iter()
                .zip(b.as_slice().iter())
                .all(|(x, y)| (x.is_nan() && y.is_nan()) || x == y)
    }

    #[test]
    fn packed_matches_naive_on_blocked_and_tail_shapes() {
        let mut rng = Rng::seeded(31);
        // Shapes straddling every panel boundary: exact multiples, ±1
        // tails, degenerate 1×N, tall/flat.
        for &(m, k, n) in &[
            (8usize, 8usize, 8usize),
            (16, 16, 16),
            (64, 64, 64),
            (65, 63, 66),
            (1, 40, 17),
            (33, 1, 9),
            (7, 300, 5),
            (70, 70, 1),
        ] {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let want = a.matmul_naive(&b);
            let got = matmul_packed(&a, &b, 1);
            assert!(same_values(&got, &want), "{m}x{k}x{n} mismatch");
        }
    }

    #[test]
    fn packed_is_threadcount_invariant() {
        let mut rng = Rng::seeded(32);
        let a = Matrix::random(130, 70, &mut rng);
        let b = Matrix::random(70, 90, &mut rng);
        let serial = matmul_packed(&a, &b, 1);
        for t in [2, 3, 4, 8] {
            let par = matmul_packed(&a, &b, t);
            assert_eq!(
                par.as_slice(),
                serial.as_slice(),
                "threads={t} changed the result"
            );
        }
    }

    #[test]
    fn packed_handles_empty_reduction() {
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 5);
        let c = matmul_packed(&a, &b, 2);
        assert_eq!(c.shape(), (4, 5));
        assert!(c.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "matmul dims")]
    fn packed_rejects_dim_mismatch() {
        let _ = matmul_packed(&Matrix::zeros(2, 3), &Matrix::zeros(2, 3), 1);
    }

    #[test]
    fn kernel_kind_parse_and_globals() {
        assert_eq!(KernelKind::parse("Packed").unwrap(), KernelKind::Packed);
        assert_eq!(KernelKind::parse("naive").unwrap(), KernelKind::Naive);
        assert!(KernelKind::parse("fast").is_err());
        assert_eq!(KernelKind::Packed.display_name(), "packed");
        let before = threads();
        set_threads(0);
        assert_eq!(threads(), 1, "thread count clamps to >= 1");
        set_threads(before);
    }
}
