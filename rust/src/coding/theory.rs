//! Analytical model: the paper's eq. (9) (reconstruction-failure
//! probability) and eq. (10) (closed-form FC(k) for replication).

use crate::coding::fc::{binomial, FcTable};

/// Eq. (10): FC(k) for `c`-copy replication of a 7-product algorithm —
///
/// ```text
/// FC(k) = Σ_{n=1}^{⌊k/c⌋} (-1)^{n+1} C(7, n) C(7c - cn, k - cn) · 1(k ≥ c)
/// ```
///
/// (inclusion–exclusion over which products lose all `c` copies).
pub fn replication_fc(c: usize, k: usize) -> u64 {
    let m = 7 * c;
    if k < c || k > m {
        return 0;
    }
    let mut total: i128 = 0;
    for n in 1..=(k / c).min(7) {
        let sign = if n % 2 == 1 { 1i128 } else { -1 };
        total += sign
            * binomial(7, n as u64) as i128
            * binomial((m - c * n) as u64, (k - c * n) as u64) as i128;
    }
    total.max(0) as u64
}

/// Eq. (9): `P_f = Σ_k FC(k) p_e^k (1 - p_e)^(M-k)`.
pub fn failure_probability(fc: &FcTable, p_e: f64) -> f64 {
    let m = fc.m;
    let mut pf = 0.0;
    for (k, &count) in fc.counts.iter().enumerate() {
        if count > 0 {
            pf += count as f64
                * p_e.powi(k as i32)
                * (1.0 - p_e).powi((m - k) as i32);
        }
    }
    pf
}

/// Closed-form P_f for c-copy replication (eqs. (9)+(10) combined).
pub fn replication_failure_probability(c: usize, p_e: f64) -> f64 {
    // P(some product loses all c copies) = 1 - (1 - p_e^c)^7.
    1.0 - (1.0 - p_e.powi(c as i32)).powi(7)
}

/// P_f of a two-level nested scheme under i.i.d. Bernoulli leaf failures
/// — the compositional form of eq. (9): groups fail independently with
/// probability `q = P_f_inner(p_e)` (each group is an independent run of
/// the inner scheme over its own leaves), so the nested failure
/// probability is the outer eq. (9) evaluated at `q`:
///
/// ```text
/// P_f_nested(p_e) = Σ_k FC_outer(k) q^k (1 - q)^(M₁ - k),
///     q = Σ_k FC_inner(k) p_e^k (1 - p_e)^(M₂ - k)
/// ```
///
/// Exact for the two-stage decoder of
/// [`crate::coding::nested::NestedTaskSet`]; cross-validated against
/// per-leaf Monte-Carlo in `sim::montecarlo`.
pub fn nested_failure_probability(outer: &FcTable, inner: &FcTable, p_e: f64) -> f64 {
    failure_probability(outer, failure_probability(inner, p_e))
}

/// Log-spaced p_e grid over Fig. 2's x-range [5e-3, 0.5] — the sweep
/// used by the `theory`, `sim`, `fig2`, and `simfleet` subcommands.
/// `points == 1` yields the single left endpoint.
pub fn log_pe_grid(points: usize) -> Vec<f64> {
    assert!(points >= 1, "grid needs at least one point");
    let (lo, hi) = (5e-3f64, 0.5f64);
    if points == 1 {
        return vec![lo];
    }
    (0..points)
        .map(|i| {
            let f = i as f64 / (points - 1) as f64;
            lo * (hi / lo).powf(f)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::strassen;
    use crate::coding::fc::fc_table;
    use crate::coding::scheme::TaskSet;

    #[test]
    fn eq10_matches_exhaustive_for_two_copies() {
        let t = fc_table(&TaskSet::replication(&strassen(), 2));
        for k in 0..=14 {
            assert_eq!(t.counts[k], replication_fc(2, k), "k={k}");
        }
    }

    #[test]
    fn eq10_single_copy_reduces_to_binomial() {
        // Paper: "FC(k) for single copy can be reduced to C(M, k)".
        for k in 1..=7 {
            assert_eq!(replication_fc(1, k), binomial(7, k as u64) as u64);
        }
        assert_eq!(replication_fc(1, 0), 0);
    }

    #[test]
    fn eq9_sums_to_closed_form_for_replication() {
        for c in 1..=3usize {
            let t = fc_table(&TaskSet::replication(&strassen(), c));
            for p_e in [0.01, 0.05, 0.1, 0.3, 0.5] {
                let via_table = failure_probability(&t, p_e);
                let closed = replication_failure_probability(c, p_e);
                assert!(
                    (via_table - closed).abs() < 1e-12,
                    "c={c} p={p_e}: {via_table} vs {closed}"
                );
            }
        }
    }

    #[test]
    fn nested_single_copy_reduces_to_49_node_closed_form() {
        // strassen-x1 nested in strassen-x1: every one of the 49 leaves
        // is essential, so P_f = 1 - (1 - p)^49 exactly.
        let fc1 = fc_table(&TaskSet::replication(&strassen(), 1));
        for p in [0.01, 0.05, 0.1, 0.3] {
            let nested = nested_failure_probability(&fc1, &fc1, p);
            let closed = 1.0 - (1.0 - p).powi(49);
            assert!(
                (nested - closed).abs() < 1e-12,
                "p={p}: {nested} vs {closed}"
            );
        }
    }

    #[test]
    fn nested_beats_flat_at_small_pe() {
        // The headline of nesting: at small p_e the 256-leaf nested
        // sw+2psmm² (first_loss 9) has a far lower P_f than the flat
        // 16-node sw+2psmm (first_loss 3) despite 16x the nodes.
        let fc = fc_table(&TaskSet::strassen_winograd(2));
        for p in [0.005, 0.01, 0.02] {
            let flat = failure_probability(&fc, p);
            let nested = nested_failure_probability(&fc, &fc, p);
            assert!(nested < flat, "p={p}: nested {nested} vs flat {flat}");
        }
    }

    #[test]
    fn log_pe_grid_spans_fig2_range() {
        let g = log_pe_grid(40);
        assert_eq!(g.len(), 40);
        assert!((g[0] - 5e-3).abs() < 1e-15);
        assert!((g[39] - 0.5).abs() < 1e-12);
        assert!(g.windows(2).all(|w| w[0] < w[1]), "not increasing");
        // Log spacing: constant ratio between neighbors.
        let r0 = g[1] / g[0];
        assert!(g.windows(2).all(|w| (w[1] / w[0] - r0).abs() < 1e-9));
        assert_eq!(log_pe_grid(1), vec![5e-3]);
    }

    #[test]
    fn pf_monotone_in_pe() {
        let t = fc_table(&TaskSet::strassen_winograd(2));
        let mut last = 0.0;
        for i in 1..=20 {
            let p = i as f64 * 0.025;
            let pf = failure_probability(&t, p);
            assert!(pf >= last - 1e-15, "P_f not monotone at p={p}");
            last = pf;
        }
    }

    #[test]
    fn pf_bounds() {
        let t = fc_table(&TaskSet::strassen_winograd(2));
        assert_eq!(failure_probability(&t, 0.0), 0.0);
        let pf1 = failure_probability(&t, 1.0);
        assert!((pf1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_ordering_at_moderate_pe() {
        // Fig. 2's qualitative ordering at moderate p_e:
        // S x1 >> S x2 > S+W+0 > S+W+1 > S+W+2 > S x3,
        // i.e. the proposed 14-node scheme already beats 14-node 2-copy
        // replication ("outperforms a Strassen-like algorithm with two
        // copies"), and each PSMM tightens it toward 21-node 3-copy.
        for p in [0.05, 0.1, 0.2] {
            let pf = |ts: &TaskSet| failure_probability(&fc_table(ts), p);
            let s1 = replication_failure_probability(1, p);
            let s2 = replication_failure_probability(2, p);
            let s3 = replication_failure_probability(3, p);
            let sw0 = pf(&TaskSet::strassen_winograd(0));
            let sw1 = pf(&TaskSet::strassen_winograd(1));
            let sw2 = pf(&TaskSet::strassen_winograd(2));
            assert!(s1 > s2, "p={p}: 1-copy {s1} vs 2-copy {s2}");
            assert!(s2 > sw0, "p={p}: 2-copy {s2} vs S+W+0 {sw0}");
            assert!(sw0 > sw1, "p={p}: S+W+0 {sw0} vs S+W+1 {sw1}");
            assert!(sw1 > sw2, "p={p}: S+W+1 {sw1} vs S+W+2 {sw2}");
            assert!(sw2 > s3, "p={p}: S+W+2 {sw2} vs 3-copy {s3}");
            // Headline: 16 nodes within one decade of 21-node 3-copy.
            assert!(sw2 < 10.0 * s3, "S+W+2 {sw2} vs 3-copy {s3}");
        }
        // At very small p_e the two top curves nearly coincide (ratio
        // ~1.3 at p=0.005), the paper's "very close performance".
        let sw2 = fc_table(&TaskSet::strassen_winograd(2));
        let ratio = failure_probability(&sw2, 0.005)
            / replication_failure_probability(3, 0.005);
        assert!(ratio < 1.5, "small-p ratio {ratio}");
    }
}
