//! Exhaustive FC(k) computation: for every failure cardinality `k`, the
//! number of k-failure node combinations from which C cannot be
//! recovered (the input to eq. (9)).
//!
//! The paper computes these "with the aid of a computer" for the proposed
//! schemes; we enumerate all `2^M` failure patterns with an exact
//! fraction-free integer rank test (entries are ±1, minors are bounded
//! far below i128 range, so no overflow and no floating point).
//! Replication task sets short-circuit to the structural test (a pattern
//! is undecodable iff it wipes out all copies of some product), which is
//! also how eq. (10) is cross-validated.

use crate::algebra::form::{BilinearForm, Target, ELEM_DIM};
use crate::coding::scheme::TaskSet;

/// FC(k) counts for one task set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FcTable {
    /// Number of nodes M.
    pub m: usize,
    /// `counts[k]` = number of k-failure combinations that are NOT
    /// decodable, for k = 0..=M.
    pub counts: Vec<u64>,
}

impl FcTable {
    /// Smallest k with FC(k) > 0 — the scheme's "minimum distance - 1"
    /// analogue (it tolerates any k-1 ... below this).
    ///
    /// ```
    /// use ft_strassen::coding::fc::fc_table;
    /// use ft_strassen::coding::scheme::TaskSet;
    /// use ft_strassen::algorithms::strassen;
    ///
    /// // 2-copy replication survives any single loss, not every pair.
    /// let fc = fc_table(&TaskSet::replication(&strassen(), 2));
    /// assert_eq!(fc.first_loss(), 2);
    /// // Out-of-range k has no patterns at all, hence no fatal ones.
    /// assert_eq!(fc.fatal_fraction(100), 0.0);
    /// ```
    pub fn first_loss(&self) -> usize {
        self.counts
            .iter()
            .position(|&c| c > 0)
            .unwrap_or(self.m + 1)
    }

    /// Fraction of k-failure patterns that are fatal. For `k > m` there
    /// are no k-failure patterns, so the fatal fraction is 0 (rather
    /// than an out-of-bounds index into the counts).
    pub fn fatal_fraction(&self, k: usize) -> f64 {
        if k > self.m {
            return 0.0;
        }
        let total = binomial(self.m as u64, k as u64) as f64;
        self.counts[k] as f64 / total
    }
}

/// Binomial coefficient in u128 (exact for the sizes used here).
pub fn binomial(n: u64, k: u64) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num: u128 = 1;
    for i in 0..k {
        num = num * (n - i) as u128 / (i + 1) as u128;
    }
    num
}

/// Exact rank of integer rows via fraction-free Gaussian elimination.
fn int_rank(rows: &mut Vec<[i128; ELEM_DIM]>) -> usize {
    let mut rank = 0;
    for col in 0..ELEM_DIM {
        let Some(pivot_row) = (rank..rows.len()).find(|&r| rows[r][col] != 0) else {
            continue;
        };
        rows.swap(rank, pivot_row);
        let pivot = rows[rank][col];
        for r in (rank + 1)..rows.len() {
            let factor = rows[r][col];
            if factor != 0 {
                let mut g: i128 = 0;
                for c in col..ELEM_DIM {
                    rows[r][c] = rows[r][c] * pivot - rows[rank][c] * factor;
                    g = gcd_i128(g, rows[r][c]);
                }
                // Normalize to keep magnitudes small across eliminations.
                if g > 1 {
                    for c in col..ELEM_DIM {
                        rows[r][c] /= g;
                    }
                }
            }
        }
        rank += 1;
        if rank == rows.len() {
            break;
        }
    }
    rank
}

fn gcd_i128(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

fn to_row(f: &BilinearForm) -> [i128; ELEM_DIM] {
    let mut r = [0i128; ELEM_DIM];
    for (o, &c) in r.iter_mut().zip(f.coeffs.iter()) {
        *o = c as i128;
    }
    r
}

/// Fast decodability oracle: rank(alive) == rank(alive ∪ targets).
pub fn decodable_mask(forms: &[[i128; ELEM_DIM]], targets: &[[i128; ELEM_DIM]], failed: u64) -> bool {
    let mut alive: Vec<[i128; ELEM_DIM]> = Vec::with_capacity(forms.len() + 4);
    for (i, f) in forms.iter().enumerate() {
        if failed & (1 << i) == 0 {
            alive.push(*f);
        }
    }
    let r_alive = int_rank(&mut alive.clone());
    alive.extend_from_slice(targets);
    let r_aug = int_rank(&mut alive);
    r_alive == r_aug
}

/// Precomputed decodability over every failure pattern of a task set —
/// one bit per mask. Makes the Monte-Carlo inner loop a table lookup
/// instead of a Gaussian elimination (see EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
pub struct DecodabilityTable {
    m: usize,
    bits: Vec<u64>,
}

impl DecodabilityTable {
    /// Enumerate all 2^M patterns (M <= 24 guard).
    pub fn build(ts: &TaskSet) -> DecodabilityTable {
        let m = ts.num_tasks();
        assert!(m <= 24, "exhaustive table over 2^{m} patterns is not practical");
        let forms: Vec<[i128; ELEM_DIM]> = ts.forms().iter().map(to_row).collect();
        let targets: Vec<[i128; ELEM_DIM]> =
            Target::ALL.iter().map(|t| to_row(&t.form())).collect();
        let n_masks = 1usize << m;
        let mut bits = vec![0u64; n_masks.div_ceil(64)];
        for failed in 0..n_masks as u64 {
            if decodable_mask(&forms, &targets, failed) {
                bits[(failed / 64) as usize] |= 1 << (failed % 64);
            }
        }
        DecodabilityTable { m, bits }
    }

    /// Is the pattern (bit i = task i FAILED) decodable?
    #[inline]
    pub fn is_decodable(&self, failed_mask: u64) -> bool {
        debug_assert!(failed_mask < (1u64 << self.m));
        self.bits[(failed_mask / 64) as usize] & (1 << (failed_mask % 64)) != 0
    }

    pub fn num_nodes(&self) -> usize {
        self.m
    }

    /// Derive the FC(k) table.
    pub fn fc(&self) -> FcTable {
        let mut counts = vec![0u64; self.m + 1];
        for failed in 0..(1u64 << self.m) {
            if !self.is_decodable(failed) {
                counts[failed.count_ones() as usize] += 1;
            }
        }
        FcTable { m: self.m, counts }
    }
}

/// Compute the FC table for a task set.
///
/// Uses the structural shortcut for pure replication sets; otherwise
/// exhausts all `2^M` patterns (`M <= 24` guard).
pub fn fc_table(ts: &TaskSet) -> FcTable {
    if let Some((groups, m)) = replication_structure(ts) {
        return fc_replication_structural(&groups, m);
    }
    DecodabilityTable::build(ts).fc()
}

/// A fast decodability oracle: O(1) per query after precomputation.
///
/// * replication sets (any node count): per-group survivor masks,
/// * general sets: the exhaustive [`DecodabilityTable`].
#[derive(Clone, Debug)]
pub enum DecodeOracle {
    Replication { group_masks: Vec<u64> },
    Table(DecodabilityTable),
}

impl DecodeOracle {
    pub fn build(ts: &TaskSet) -> DecodeOracle {
        if let Some((groups, _)) = replication_structure(ts) {
            let num_groups = groups.iter().max().unwrap() + 1;
            let mut group_masks = vec![0u64; num_groups];
            for (i, &g) in groups.iter().enumerate() {
                group_masks[g] |= 1 << i;
            }
            DecodeOracle::Replication { group_masks }
        } else {
            DecodeOracle::Table(DecodabilityTable::build(ts))
        }
    }

    /// Is the failure pattern decodable?
    #[inline]
    pub fn is_decodable(&self, failed_mask: u64) -> bool {
        match self {
            DecodeOracle::Replication { group_masks } => group_masks
                .iter()
                .all(|&gm| failed_mask & gm != gm),
            DecodeOracle::Table(t) => t.is_decodable(failed_mask),
        }
    }
}

/// If the task set is an exact c-copy replication of a decodable base
/// algorithm, return the per-task group ids and M.
fn replication_structure(ts: &TaskSet) -> Option<(Vec<usize>, usize)> {
    let forms = ts.forms();
    let m = forms.len();
    // Group identical forms.
    let mut groups: Vec<usize> = vec![usize::MAX; m];
    let mut reps: Vec<BilinearForm> = Vec::new();
    for (i, f) in forms.iter().enumerate() {
        let g = reps.iter().position(|r| r == f).unwrap_or_else(|| {
            reps.push(*f);
            reps.len() - 1
        });
        groups[i] = g;
    }
    // Replication iff: every group same size c, and the base set is
    // exactly-decodable (full set decodes, any base-product loss fatal).
    let c = m / reps.len();
    if c * reps.len() != m {
        return None;
    }
    let mut sizes = vec![0usize; reps.len()];
    for &g in &groups {
        sizes[g] += 1;
    }
    if !sizes.iter().all(|&s| s == c) || c == 1 {
        // c == 1 falls through to exhaustive (cheap and fully general).
        return None;
    }
    // Check the structural criterion holds for the base: losing any one
    // base product must be fatal, full base must decode.
    let base_rows: Vec<[i128; ELEM_DIM]> = reps.iter().map(to_row).collect();
    let targets: Vec<[i128; ELEM_DIM]> =
        Target::ALL.iter().map(|t| to_row(&t.form())).collect();
    if !decodable_mask(&base_rows, &targets, 0) {
        return None;
    }
    for i in 0..reps.len() {
        if decodable_mask(&base_rows, &targets, 1 << i) {
            return None; // redundancy inside the base: not plain replication
        }
    }
    Some((groups, m))
}

/// FC(k) for replication via the structural criterion: a pattern is
/// fatal iff some group is entirely failed. Counted by inclusion-
/// exclusion over which groups are wiped out — the combinatorial identity
/// behind the paper's eq. (10).
fn fc_replication_structural(groups: &[usize], m: usize) -> FcTable {
    let num_groups = groups.iter().max().unwrap() + 1;
    let c = m / num_groups;
    let mut counts = vec![0u64; m + 1];
    for k in 0..=m {
        let mut total: i128 = 0;
        for n in 1..=(k / c).max(0) {
            if n > num_groups {
                break;
            }
            let sign = if n % 2 == 1 { 1i128 } else { -1 };
            let ways = binomial(num_groups as u64, n as u64) as i128
                * binomial((m - c * n) as u64, (k - c * n) as u64) as i128;
            total += sign * ways;
        }
        counts[k] = total.max(0) as u64;
    }
    FcTable { m, counts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::strassen;

    #[test]
    fn binomial_basics() {
        assert_eq!(binomial(7, 0), 1);
        assert_eq!(binomial(7, 3), 35);
        assert_eq!(binomial(21, 10), 352716);
        assert_eq!(binomial(5, 9), 0);
    }

    #[test]
    fn single_copy_fc_is_all_combinations() {
        // M = 7, any failure fatal: FC(k) = C(7, k) for k >= 1.
        let t = fc_table(&TaskSet::replication(&strassen(), 1));
        assert_eq!(t.counts[0], 0);
        for k in 1..=7 {
            assert_eq!(t.counts[k], binomial(7, k as u64) as u64, "k={k}");
        }
        assert_eq!(t.first_loss(), 1);
    }

    #[test]
    fn two_copy_structural_matches_exhaustive() {
        // Force the exhaustive path by building an equivalent "anonymous"
        // set and compare with the structural fast path.
        let ts = TaskSet::replication(&strassen(), 2);
        let structural = fc_table(&ts);
        // exhaustive: bypass detection by computing directly
        let forms: Vec<[i128; ELEM_DIM]> = ts.forms().iter().map(to_row).collect();
        let targets: Vec<[i128; ELEM_DIM]> =
            Target::ALL.iter().map(|t| to_row(&t.form())).collect();
        let mut counts = vec![0u64; 15];
        for failed in 0u64..(1 << 14) {
            if !decodable_mask(&forms, &targets, failed) {
                counts[failed.count_ones() as usize] += 1;
            }
        }
        assert_eq!(structural.counts, counts);
        assert_eq!(structural.first_loss(), 2);
    }

    #[test]
    fn proposed_zero_psmm_first_loss_is_two() {
        let t = fc_table(&TaskSet::strassen_winograd(0));
        assert_eq!(t.counts[1], 0, "every single failure decodable");
        assert!(t.counts[2] > 0, "paper: some pairs (S3,W5),(S7,W2) fatal");
    }

    #[test]
    fn proposed_two_psmm_first_loss_is_three() {
        let t = fc_table(&TaskSet::strassen_winograd(2));
        assert_eq!(t.counts[1], 0);
        assert_eq!(t.counts[2], 0, "2 PSMMs cover all pairs");
        assert!(t.counts[3] > 0);
        assert_eq!(t.first_loss(), 3);
    }

    #[test]
    fn psmm_monotonicity() {
        // Adding PSMMs can only reduce the fatal fraction at every k.
        let t0 = fc_table(&TaskSet::strassen_winograd(0));
        let t1 = fc_table(&TaskSet::strassen_winograd(1));
        let t2 = fc_table(&TaskSet::strassen_winograd(2));
        for k in 0..=14 {
            assert!(t1.fatal_fraction(k) <= t0.fatal_fraction(k) + 1e-12, "k={k}");
            assert!(t2.fatal_fraction(k) <= t1.fatal_fraction(k) + 1e-12, "k={k}");
        }
    }

    #[test]
    fn extreme_ks() {
        for ts in [TaskSet::strassen_winograd(2), TaskSet::replication(&strassen(), 2)] {
            let t = fc_table(&ts);
            let m = t.m;
            assert_eq!(t.counts[0], 0, "no failures is decodable");
            assert_eq!(t.counts[m], 1, "all failed is fatal");
            // k = m-1, m-2: fewer than 7 products survive -> all fatal.
            assert_eq!(t.counts[m - 1], binomial(m as u64, 1) as u64);
            assert_eq!(t.counts[m - 2], binomial(m as u64, 2) as u64);
        }
    }

    #[test]
    fn oracle_matches_direct_decodability() {
        for ts in [
            TaskSet::strassen_winograd(2),
            TaskSet::replication(&strassen(), 2),
        ] {
            let oracle = DecodeOracle::build(&ts);
            let m = ts.num_tasks();
            // spot-check a spread of masks against the exact GE oracle
            let mut mask = 0x9e3779b97f4a7c15u64;
            for _ in 0..500 {
                mask ^= mask << 13;
                mask ^= mask >> 7;
                mask ^= mask << 17;
                let failed = mask & ((1 << m) - 1);
                assert_eq!(
                    oracle.is_decodable(failed),
                    ts.decodable_with_failures(failed),
                    "{} mask {failed:#x}",
                    ts.name
                );
            }
        }
    }

    #[test]
    fn oracle_replication_path_is_structural() {
        let ts = TaskSet::replication(&strassen(), 3);
        let oracle = DecodeOracle::build(&ts);
        assert!(matches!(oracle, DecodeOracle::Replication { .. }));
        // all three copies of S1 failed -> fatal
        let kill_s1 = 1u64 | (1 << 7) | (1 << 14);
        assert!(!oracle.is_decodable(kill_s1));
        // any two copies -> fine
        assert!(oracle.is_decodable(1u64 | (1 << 7)));
    }

    #[test]
    fn fatal_fraction_guards_out_of_range_k() {
        let t = fc_table(&TaskSet::replication(&strassen(), 1));
        assert_eq!(t.fatal_fraction(t.m + 1), 0.0);
        assert_eq!(t.fatal_fraction(usize::MAX), 0.0);
        assert_eq!(t.fatal_fraction(t.m), 1.0, "all-failed is fatal");
    }

    #[test]
    fn decodability_table_fc_roundtrip() {
        let ts = TaskSet::strassen_winograd(1);
        let t = DecodabilityTable::build(&ts);
        assert_eq!(t.fc().counts, fc_table(&ts).counts);
        assert_eq!(t.num_nodes(), 15);
    }

    #[test]
    fn three_copy_structural_counts() {
        let t = fc_table(&TaskSet::replication(&strassen(), 3));
        assert_eq!(t.m, 21);
        assert_eq!(t.first_loss(), 3);
        assert_eq!(t.counts[3], 7, "one way per product to lose all 3 copies");
        // eq. (10) at k=4: C(7,1) C(18,1) = 126.
        assert_eq!(t.counts[4], 126);
    }
}
