//! Decoders: exact span decoding (production path) and the paper's
//! peeling decoder over searched local relations.
//!
//! **SpanDecoder** — maintains an incremental row-reduced basis of the
//! finished tasks' bilinear forms; the output is decodable exactly when
//! all four `C_ij` targets lie in the span, and the decode weights are
//! the solution of the corresponding exact linear system (computed once,
//! when decodable). This is information-theoretically optimal: it
//! recovers C from *every* recoverable pattern.
//!
//! **PeelingDecoder** — the operational procedure the paper describes
//! (§III.B example): iterate over the enumerated local relations; any
//! relation whose terms are all known yields its C block; any relation
//! with a known C block and exactly one unknown product recovers that
//! product (chained local computations). Cheaper per event, and its
//! success set is compared against the span decoder in tests/benches.

use crate::algebra::form::{BilinearForm, Target};
use crate::algebra::frac::Frac;
use crate::algebra::gauss::SpanBasis;
use crate::coding::scheme::TaskSet;
use crate::linalg::matrix::{Dense, Matrix};
use crate::linalg::scalar::Scalar;
use crate::search::searchlp::{search_lp, LocalRelation, SearchOptions};

/// `lcm` over the small positive denominators the decode weights carry.
fn lcm_i128(a: i128, b: i128) -> i128 {
    fn gcd(mut a: i128, mut b: i128) -> i128 {
        while b != 0 {
            let t = a % b;
            a = b;
            b = t;
        }
        a
    }
    a / gcd(a, b) * b
}

/// Decode result: per-target weights over the task list.
#[derive(Clone, Debug, PartialEq)]
pub struct DecodeOutcome {
    /// `weights[t][i]` = coefficient of task `i` in target `t`'s
    /// reconstruction (f64-exact: all built-in schemes decode with small
    /// rationals).
    pub weights: [Vec<f64>; 4],
}

/// Exact online decoder (Gaussian elimination over ℚ).
#[derive(Clone, Debug)]
pub struct SpanDecoder {
    forms: Vec<BilinearForm>,
    finished: Vec<usize>,
    basis: SpanBasis,
    targets_left: Vec<Target>,
}

impl SpanDecoder {
    pub fn new(ts: &TaskSet) -> Self {
        SpanDecoder {
            forms: ts.forms(),
            finished: Vec::with_capacity(ts.num_tasks()),
            basis: SpanBasis::new(),
            targets_left: Target::ALL.to_vec(),
        }
    }

    /// Record task `i` as finished. Returns `true` once the output became
    /// decodable (and stays `true`).
    pub fn on_finished(&mut self, i: usize) -> bool {
        self.finished.push(i);
        if self.basis.insert(&self.forms[i]) {
            // Rank increased: some targets may have become reachable.
            self.targets_left.retain(|t| !self.basis.contains(&t.form()));
        }
        self.is_decodable()
    }

    pub fn is_decodable(&self) -> bool {
        self.targets_left.is_empty()
    }

    pub fn num_finished(&self) -> usize {
        self.finished.len()
    }

    /// Solve for the decode weights over ALL tasks (zeros for unfinished).
    /// `None` if not yet decodable. One shared Gaussian elimination
    /// produces all four targets' weights (§Perf).
    ///
    /// The finished tasks are canonicalized (sorted, deduplicated)
    /// before solving, so the weights are a pure function of the
    /// finished *set* — reply arrival order (thread timing) cannot
    /// change the assembled output. The multiplexed coordinator's
    /// bit-reproducibility guarantees rest on this.
    pub fn solve(&self) -> Option<DecodeOutcome> {
        let exact = self.solve_exact()?;
        let mut weights: [Vec<f64>; 4] = Default::default();
        for t in Target::ALL {
            weights[t.index()] = exact[t.index()].iter().map(Frac::to_f64).collect();
        }
        Some(DecodeOutcome { weights })
    }

    /// The decode weights as exact rationals over ALL tasks (zeros for
    /// unfinished), before any float conversion — what [`Self::solve`]
    /// rounds to `f64` and what the exact combine consumes. `None` if
    /// not yet decodable. Same canonicalization as [`Self::solve`]:
    /// weights are a pure function of the finished *set*.
    pub fn solve_exact(&self) -> Option<[Vec<Frac>; 4]> {
        if !self.is_decodable() {
            return None;
        }
        let mut finished = self.finished.clone();
        finished.sort_unstable();
        finished.dedup();
        let finished_forms: Vec<BilinearForm> =
            finished.iter().map(|&i| self.forms[i]).collect();
        let target_forms: Vec<BilinearForm> =
            Target::ALL.iter().map(|t| t.form()).collect();
        let sols = crate::algebra::gauss::solve_in_span_multi(&finished_forms, &target_forms);
        let mut weights: [Vec<Frac>; 4] = Default::default();
        for t in Target::ALL {
            let w = sols[t.index()].as_ref()?;
            let mut full = vec![Frac::ZERO; self.forms.len()];
            for (pos, &task_idx) in finished.iter().enumerate() {
                full[task_idx] += w[pos];
            }
            weights[t.index()] = full;
        }
        Some(weights)
    }

    /// Solve the decode weights and combine **borrowed** finished
    /// products straight into the quadrants of `out` (the caller's
    /// per-job combine buffer, side `2·bs` for `bs×bs` products):
    /// target `t` lands in quadrant `(t/2, t%2)`, matching
    /// [`crate::linalg::blocked::join_blocks`] layout. No product is
    /// cloned and no per-block temporary is allocated; each output
    /// element is the same weighted sum, added in the same task order,
    /// as the historical solve-then-join path, so assembled outputs
    /// are bit-identical to it.
    ///
    /// Errors when called before decodability, or if a non-zero weight
    /// lands on a missing product (cannot happen for weights produced
    /// by [`Self::solve`], which only weights finished tasks).
    pub fn combine_into(
        &self,
        products: &[Option<Matrix>],
        out: &mut Matrix,
    ) -> Result<(), String> {
        let outcome = self.solve().ok_or("assemble called before decodable")?;
        let bs = products
            .iter()
            .flatten()
            .next()
            .map(|m| m.rows())
            .ok_or("combine_into with no finished products")?;
        assert_eq!(
            out.shape(),
            (2 * bs, 2 * bs),
            "combine buffer must be 2bs x 2bs"
        );
        out.as_mut_slice().fill(0.0);
        for (t, weights) in outcome.weights.iter().enumerate() {
            let (bi, bj) = (t / 2, t % 2);
            for (i, p) in products.iter().enumerate() {
                let w = weights[i] as f32;
                if w != 0.0 {
                    let m = p
                        .as_ref()
                        .ok_or_else(|| format!("weight on unfinished task {i}"))?;
                    out.add_scaled_region(bi * bs, bj * bs, w, m);
                }
            }
        }
        Ok(())
    }

    /// Exact decode combine over any [`Scalar`] backend: reconstruct
    /// each target quadrant of `out` from borrowed finished products
    /// using the **exact rational** weights of [`Self::solve_exact`],
    /// with no floating-point weight conversion anywhere.
    ///
    /// Per target, the rational combination `C = Σ wᵢ·Pᵢ` is scaled by
    /// `L = lcm(denominators)` to the integer identity `L·C = Σ nᵢ·Pᵢ`
    /// (with `nᵢ = num(wᵢ)·L/den(wᵢ)`), accumulated with integer-image
    /// weights ([`Scalar::from_i64`]), and finished with one exact
    /// division by `L` ([`Scalar::exact_div`]). Over ℤ the identity
    /// guarantees divisibility entry-wise, so `i64` never truncates;
    /// over a prime field `L` (a power of two for the paper's schemes)
    /// is invertible; over floats `L` is a power of two and the
    /// division is a pure exponent shift. This is the method the
    /// conformance suite pins to `==` equality with the ground truth.
    ///
    /// Cold path: allocates one block-sized temporary (contrast with
    /// the allocation-free f32 [`Self::combine_into`] on the serving
    /// path).
    pub fn combine_exact_into<S: Scalar>(
        &self,
        products: &[Option<Dense<S>>],
        out: &mut Dense<S>,
    ) -> Result<(), String> {
        let weights = self.solve_exact().ok_or("assemble called before decodable")?;
        let bs = products
            .iter()
            .flatten()
            .next()
            .map(|m| m.rows())
            .ok_or("combine_exact_into with no finished products")?;
        assert_eq!(
            out.shape(),
            (2 * bs, 2 * bs),
            "combine buffer must be 2bs x 2bs"
        );
        out.as_mut_slice().fill(S::zero());
        let mut blk: Dense<S> = Dense::zeros(bs, bs);
        for (t, w) in weights.iter().enumerate() {
            let mut l: i128 = 1;
            for f in w {
                if !f.is_zero() {
                    l = lcm_i128(l, f.denominator());
                }
            }
            let l_i64 = i64::try_from(l).map_err(|_| format!("decode LCM {l} overflows i64"))?;
            blk.as_mut_slice().fill(S::zero());
            for (i, p) in products.iter().enumerate() {
                if w[i].is_zero() {
                    continue;
                }
                let m = p
                    .as_ref()
                    .ok_or_else(|| format!("weight on unfinished task {i}"))?;
                let n = w[i].numerator() * (l / w[i].denominator());
                let n = i64::try_from(n).map_err(|_| format!("decode weight {n} overflows i64"))?;
                blk.axpy(S::from_i64(n), m);
            }
            blk.exact_div_assign(l_i64);
            out.add_scaled_region((t / 2) * bs, (t % 2) * bs, S::one(), &blk);
        }
        Ok(())
    }
}

/// The paper's peeling decoder over precomputed local relations.
#[derive(Clone, Debug)]
pub struct PeelingDecoder {
    num_tasks: usize,
    relations: Vec<LocalRelation>,
}

/// Result of a peeling pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PeelingOutcome {
    /// All four C blocks recovered?
    pub decoded: bool,
    /// Which products ended up known (finished or locally recovered).
    pub known_products: Vec<bool>,
    /// Which C targets ended up known.
    pub known_targets: [bool; 4],
    /// Peeling steps taken (for the §Perf accounting).
    pub steps: usize,
}

impl PeelingDecoder {
    /// Build from a task set by running Algorithm 1 over its forms.
    pub fn new(ts: &TaskSet, opts: &SearchOptions) -> Self {
        let relations = search_lp(&ts.forms(), opts).relations;
        PeelingDecoder { num_tasks: ts.num_tasks(), relations }
    }

    /// Build from an explicit relation list (e.g. cached).
    pub fn from_relations(num_tasks: usize, relations: Vec<LocalRelation>) -> Self {
        PeelingDecoder { num_tasks, relations }
    }

    pub fn num_relations(&self) -> usize {
        self.relations.len()
    }

    /// Run peeling to fixpoint given the finished-task mask.
    pub fn run(&self, finished_mask: u64) -> PeelingOutcome {
        let mut known_products: Vec<bool> = (0..self.num_tasks)
            .map(|i| finished_mask & (1 << i) != 0)
            .collect();
        let mut known_targets = [false; 4];
        let mut steps = 0;
        loop {
            let mut progress = false;
            for r in &self.relations {
                let t = r.target.index();
                let unknown: Vec<usize> = r
                    .terms
                    .iter()
                    .filter(|(i, _)| !known_products[*i])
                    .map(|(i, _)| *i)
                    .collect();
                match (known_targets[t], unknown.len()) {
                    (false, 0) => {
                        // All terms known: compute the C block.
                        known_targets[t] = true;
                        steps += 1;
                        progress = true;
                    }
                    (true, 1) => {
                        // C known, one product missing: solve for it
                        // (the paper's §III.B chained recovery).
                        known_products[unknown[0]] = true;
                        steps += 1;
                        progress = true;
                    }
                    _ => {}
                }
            }
            if !progress {
                break;
            }
        }
        PeelingOutcome {
            decoded: known_targets.iter().all(|&k| k),
            known_products,
            known_targets,
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::strassen;

    fn peeler(ts: &TaskSet) -> PeelingDecoder {
        PeelingDecoder::new(ts, &SearchOptions::default())
    }

    /// Peeler for the plain 14-task S+W set, built from the checked-in
    /// Table-II fixture instead of re-running the exhaustive search
    /// (the fixture is pinned against the live search in
    /// `search::relations`).
    fn golden_peeler() -> PeelingDecoder {
        PeelingDecoder::from_relations(
            crate::testkit::golden::SW_NUM_PRODUCTS,
            crate::testkit::golden::sw_relations(),
        )
    }

    #[test]
    fn span_decoder_full_strassen() {
        let ts = TaskSet::replication(&strassen(), 1);
        let mut d = SpanDecoder::new(&ts);
        for i in 0..6 {
            assert!(!d.on_finished(i), "decodable too early at {i}");
        }
        assert!(d.on_finished(6));
        let out = d.solve().unwrap();
        // C11 = S1 + S4 - S5 + S7 (unique for rank-7 scheme).
        assert_eq!(out.weights[0], vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0, 1.0]);
    }

    #[test]
    fn span_decoder_sw_survives_single_failure() {
        let ts = TaskSet::strassen_winograd(0);
        for dead in 0..14 {
            let mut d = SpanDecoder::new(&ts);
            let mut ok = false;
            for i in 0..14 {
                if i != dead {
                    ok = d.on_finished(i);
                }
            }
            assert!(ok, "death of task {dead} should be decodable");
            let out = d.solve().unwrap();
            // Weight of the dead task must be zero in every target.
            for t in 0..4 {
                assert_eq!(out.weights[t][dead], 0.0, "target {t} uses dead task");
            }
        }
    }

    #[test]
    fn decode_weights_reconstruct_targets_symbolically() {
        let ts = TaskSet::strassen_winograd(2);
        let forms = ts.forms();
        let mut d = SpanDecoder::new(&ts);
        // Kill S3 and W5 (covered only thanks to PSMM-1).
        for i in 0..16 {
            if i != 2 && i != 11 {
                d.on_finished(i);
            }
        }
        assert!(d.is_decodable());
        let out = d.solve().unwrap();
        for t in Target::ALL {
            let mut acc = [0.0f64; 16];
            for (i, w) in out.weights[t.index()].iter().enumerate() {
                for (j, a) in acc.iter_mut().enumerate() {
                    *a += w * forms[i].coeffs[j] as f64;
                }
            }
            for (j, a) in acc.iter().enumerate() {
                assert!(
                    (a - t.form().coeffs[j] as f64).abs() < 1e-9,
                    "{t}: coeff {j} = {a}"
                );
            }
        }
    }

    #[test]
    fn solve_is_arrival_order_independent() {
        let ts = TaskSet::strassen_winograd(0);
        let mut fwd = SpanDecoder::new(&ts);
        let mut rev = SpanDecoder::new(&ts);
        for i in 0..14 {
            fwd.on_finished(i);
        }
        for i in (0..14).rev() {
            rev.on_finished(i);
        }
        assert_eq!(
            fwd.solve().unwrap(),
            rev.solve().unwrap(),
            "weights must depend on the finished set, not arrival order"
        );
    }

    #[test]
    fn peeling_reproduces_paper_example() {
        // §III.B: S2, S5, W2, W5 all delayed -> chained recovery succeeds.
        let ts = TaskSet::strassen_winograd(0);
        let p = golden_peeler();
        // Indices: S2=1, S5=4, W2=8, W5=11.
        let failed: u64 = (1 << 1) | (1 << 4) | (1 << 8) | (1 << 11);
        let finished = !failed & ((1 << 14) - 1);
        let out = p.run(finished);
        assert!(out.decoded, "paper's example pattern must peel");
        // The chain recovers the delayed products too.
        assert!(out.known_products[1], "S2 recovered");
        assert!(out.known_products[11], "W5 recovered");
    }

    #[test]
    fn peeling_fails_on_uncoverable_pair() {
        let ts = TaskSet::strassen_winograd(0);
        let p = golden_peeler();
        let failed: u64 = (1 << 2) | (1 << 11); // (S3, W5)
        let out = p.run(!failed & ((1 << 14) - 1));
        assert!(!out.decoded);
    }

    #[test]
    fn peeling_never_beats_span() {
        // Safety: peeling success implies span success, on every pattern
        // of the 14-task configuration.
        let ts = TaskSet::strassen_winograd(0);
        let p = golden_peeler();
        let m = ts.num_tasks();
        for failed in 0u64..(1 << m) {
            let finished = !failed & ((1 << m) - 1);
            if p.run(finished).decoded {
                assert!(
                    ts.decodable_with_failures(failed),
                    "peeling decoded a span-undecodable pattern {failed:#x}"
                );
            }
        }
    }

    #[test]
    fn combine_into_matches_solve_then_join() {
        use crate::linalg::blocked::{encode_operand, join_blocks, split_blocks};
        use crate::sim::rng::Rng;
        let ts = TaskSet::strassen_winograd(2);
        let mut rng = Rng::seeded(77);
        let a = Matrix::random(8, 8, &mut rng);
        let b = Matrix::random(8, 8, &mut rng);
        let a4 = split_blocks(&a);
        let b4 = split_blocks(&b);
        let mut d = SpanDecoder::new(&ts);
        let mut products: Vec<Option<Matrix>> = vec![None; ts.num_tasks()];
        for (i, task) in ts.tasks.iter().enumerate() {
            if i == 3 {
                continue; // one failure, still decodable
            }
            let p = encode_operand(&task.u, &a4).matmul(&encode_operand(&task.v, &b4));
            products[i] = Some(p);
            d.on_finished(i);
        }
        assert!(d.is_decodable());
        // Historical path: per-target block sums, then join.
        let outcome = d.solve().unwrap();
        let mut blocks: Vec<Matrix> = Vec::new();
        for weights in &outcome.weights {
            let mut blk = Matrix::zeros(4, 4);
            for (i, p) in products.iter().enumerate() {
                let w = weights[i] as f32;
                if w != 0.0 {
                    blk.axpy(w, p.as_ref().unwrap());
                }
            }
            blocks.push(blk);
        }
        let four: [Matrix; 4] = std::array::from_fn(|i| blocks[i].clone());
        let want = join_blocks(&four);
        // New path: straight into the combine buffer.
        let mut got = Matrix::zeros(8, 8);
        d.combine_into(&products, &mut got).unwrap();
        assert_eq!(got.as_slice(), want.as_slice(), "must be bit-identical");
        assert!(got.approx_eq(&a.matmul(&b), 1e-4));
    }

    #[test]
    fn combine_into_before_decodable_is_error() {
        let ts = TaskSet::strassen_winograd(0);
        let mut d = SpanDecoder::new(&ts);
        d.on_finished(0);
        let products: Vec<Option<Matrix>> =
            (0..ts.num_tasks()).map(|i| (i == 0).then(|| Matrix::zeros(2, 2))).collect();
        let mut out = Matrix::zeros(4, 4);
        assert!(d.combine_into(&products, &mut out).is_err());
    }

    #[test]
    fn exact_weights_are_small_dyadic_rationals() {
        // The invariant the exact combine leans on: every decode weight
        // of the built-in schemes has a power-of-two denominator (so
        // f32/f64 division by the LCM is exact, and Fp inversion of the
        // LCM never hits the modulus).
        for psmms in [0, 2] {
            let ts = TaskSet::strassen_winograd(psmms);
            let mut d = SpanDecoder::new(&ts);
            for i in 0..ts.num_tasks() {
                d.on_finished(i);
            }
            let exact = d.solve_exact().unwrap();
            for (t, w) in exact.iter().enumerate() {
                for (i, f) in w.iter().enumerate() {
                    let den = f.denominator();
                    assert!(
                        den > 0 && (den & (den - 1)) == 0,
                        "target {t} task {i}: denominator {den} is not a power of two"
                    );
                }
            }
        }
    }

    #[test]
    fn combine_exact_into_recovers_the_product_exactly() {
        use crate::algebra::fp::Fp31;
        use crate::linalg::blocked::{encode_operand, split_blocks};

        fn check<S: Scalar>(dead: usize) {
            let ts = TaskSet::strassen_winograd(2);
            let a: Dense<S> = Dense::from_i64_fn(8, 8, |i, j| (i * 8 + j) as i64 % 7 - 3);
            let b: Dense<S> = Dense::from_i64_fn(8, 8, |i, j| 2 - ((i * 3 + j) as i64 % 5));
            let a4 = split_blocks(&a);
            let b4 = split_blocks(&b);
            let mut d = SpanDecoder::new(&ts);
            let mut products: Vec<Option<Dense<S>>> = vec![None; ts.num_tasks()];
            for (i, task) in ts.tasks.iter().enumerate() {
                if i == dead {
                    continue;
                }
                let p = encode_operand(&task.u, &a4)
                    .matmul_naive(&encode_operand(&task.v, &b4));
                products[i] = Some(p);
                d.on_finished(i);
            }
            assert!(d.is_decodable());
            let mut got: Dense<S> = Dense::zeros(8, 8);
            d.combine_exact_into(&products, &mut got).unwrap();
            assert_eq!(
                got,
                a.matmul_naive(&b),
                "backend {} dead task {dead}: exact decode mismatch",
                S::BACKEND_NAME
            );
        }
        for dead in [2, 11] {
            check::<i64>(dead);
            check::<Fp31>(dead);
            check::<f64>(dead);
        }
    }

    #[test]
    fn peeling_with_no_failures_decodes_quickly() {
        let ts = TaskSet::strassen_winograd(2);
        let p = peeler(&ts);
        let out = p.run((1 << 16) - 1);
        assert!(out.decoded);
        assert!(out.steps >= 4, "at least one step per target");
    }
}
