//! Nested two-level coded task sets: compose two (possibly distinct)
//! [`TaskSet`]s level by level, so that each level-1 (outer) product is
//! itself distributed via the level-2 (inner) scheme.
//!
//! The paper applies its coding at a single 2×2 split level (M ≤ 21
//! nodes). Wang & Duursma's *Parity-Checked Strassen Algorithm*
//! (PAPERS.md) observes that nesting parity-checked schemes compounds
//! straggler tolerance **multiplicatively**: an outer scheme with M₁
//! tasks whose every task is re-distributed through an inner scheme with
//! M₂ tasks yields M₁·M₂ leaf tasks (e.g. 16×16 = 256, or 14×14 = 196),
//! and the minimum number of leaf failures that defeats the two-stage
//! decoder is the *product* of the per-level minima
//! ([`NestedTaskSet::first_loss`]).
//!
//! Decoding is operationally **two-stage** (the path `coordinator/job.rs`
//! implements): the inner span decoder of each outer group recovers that
//! group's product P_g = L_g · R_g first, and recovered groups then feed
//! the outer span decoder that solves the four C blocks. A failure
//! pattern, given as one failed-leaf mask per group, is *nested-decodable*
//! iff the set of unrecoverable groups is an outer-decodable failure set
//! ([`NestedTaskSet::decodable_with_failures`]). This is a conservative
//! subset of what a hypothetical flattened 256-dimensional joint decoder
//! could recover, but it is the decoder a coordinator can actually run
//! incrementally, group by group.
//!
//! Analysis entry points: [`NestedOracle`] (O(1)-per-group decodability
//! for Monte-Carlo at M = 196–256 where exhaustive 2^M enumeration is
//! impossible), [`NestedTaskSet::first_loss`], and the compositional
//! closed form [`crate::coding::theory::nested_failure_probability`].

use crate::algebra::form::{BilinearForm, ELEM_DIM};
use crate::coding::fc::{fc_table, DecodeOracle};
use crate::coding::scheme::TaskSet;
use crate::linalg::blocked::kron_coeffs;

/// A two-level nested scheme: `outer` distributes the 2×2 block products
/// of C; each outer product is itself computed distributedly by `inner`.
#[derive(Clone, Debug)]
pub struct NestedTaskSet {
    /// `"<outer name>:<inner name>"` (the CLI's `--nest` spelling).
    pub name: String,
    /// Level-1 scheme over the outer 2×2 blocks of A and B.
    pub outer: TaskSet,
    /// Level-2 scheme applied to every outer product `L_g · R_g`.
    pub inner: TaskSet,
}

impl NestedTaskSet {
    /// Compose two task sets into a nested scheme with
    /// `outer.num_tasks() * inner.num_tasks()` leaf tasks.
    ///
    /// ```
    /// use ft_strassen::coding::nested::NestedTaskSet;
    /// use ft_strassen::coding::scheme::TaskSet;
    ///
    /// let nested = NestedTaskSet::compose(
    ///     TaskSet::strassen_winograd(2),
    ///     TaskSet::strassen_winograd(2),
    /// );
    /// assert_eq!(nested.num_leaves(), 256);
    /// // tolerance compounds multiplicatively: 3 × 3 = 9 leaf failures
    /// // are needed before any pattern defeats the two-stage decoder.
    /// assert_eq!(nested.first_loss(), 9);
    /// ```
    pub fn compose(outer: TaskSet, inner: TaskSet) -> NestedTaskSet {
        assert!(outer.num_tasks() <= 64, "outer mask model supports <= 64 groups");
        assert!(inner.num_tasks() <= 64, "inner mask model supports <= 64 tasks");
        NestedTaskSet {
            name: format!("{}:{}", outer.name, inner.name),
            outer,
            inner,
        }
    }

    /// Number of outer groups M₁.
    pub fn num_groups(&self) -> usize {
        self.outer.num_tasks()
    }

    /// Leaf tasks per group M₂.
    pub fn group_size(&self) -> usize {
        self.inner.num_tasks()
    }

    /// Total leaf tasks M₁·M₂ (the fan-out).
    pub fn num_leaves(&self) -> usize {
        self.num_groups() * self.group_size()
    }

    /// Leaf name `"<outer task>/<inner task>"`, e.g. `"S3/W5"`.
    pub fn leaf_name(&self, g: usize, j: usize) -> String {
        format!("{}/{}", self.outer.tasks[g].name, self.inner.tasks[j].name)
    }

    /// The leaf's encoding coefficients over the 16 two-level blocks of
    /// each operand: the Kronecker products `u_g ⊗ u'_j` and
    /// `v_g ⊗ v'_j` (outer-major block order, matching
    /// [`crate::linalg::blocked::split_blocks16`]).
    pub fn leaf_uv(&self, g: usize, j: usize) -> ([i32; 16], [i32; 16]) {
        let o = &self.outer.tasks[g];
        let i = &self.inner.tasks[j];
        (kron_coeffs(&o.u, &i.u), kron_coeffs(&o.v, &i.v))
    }

    /// The leaf's bilinear form over the 256 two-level elementary
    /// products, flattened row-major: coefficient of
    /// `A_(p,r) · B_(q,s)` at index `(p*4 + r) * 16 + (q*4 + s)`.
    ///
    /// Equal to the Kronecker product of the outer and inner task forms
    /// under that index map — the "composed form" whose rank the algebra
    /// tests pin to `rank(outer span) · rank(inner span)`.
    pub fn leaf_form_flat(&self, g: usize, j: usize) -> Vec<i64> {
        kron_form_flat(&self.outer.tasks[g].form(), &self.inner.tasks[j].form())
    }

    /// Is the failure pattern decodable by the two-stage decoder?
    /// `group_failed[g]` is the failed-leaf mask of group `g`
    /// (bit j = leaf (g, j) failed).
    pub fn decodable_with_failures(&self, group_failed: &[u64]) -> bool {
        assert_eq!(group_failed.len(), self.num_groups());
        let mut outer_failed = 0u64;
        for (g, &mask) in group_failed.iter().enumerate() {
            if !self.inner.decodable_with_failures(mask) {
                outer_failed |= 1 << g;
            }
        }
        self.outer.decodable_with_failures(outer_failed)
    }

    /// Smallest number of leaf failures for which some pattern defeats
    /// the two-stage decoder — exactly the **product** of the per-level
    /// [`crate::coding::fc::FcTable::first_loss`] values: defeating the
    /// outer span needs at least `first_loss(outer)` unrecoverable
    /// groups, and making one group unrecoverable needs at least
    /// `first_loss(inner)` leaf failures inside it (and the minimal
    /// fatal pattern achieves both bounds simultaneously).
    pub fn first_loss(&self) -> usize {
        fc_table(&self.outer).first_loss() * fc_table(&self.inner).first_loss()
    }
}

/// Flattened Kronecker product of two bilinear forms (256 coefficients,
/// see [`NestedTaskSet::leaf_form_flat`] for the index map). Also maps
/// output targets: the two-level C block `((I,k),(J,l))` of a nested
/// multiply is the composed form `kron_form_flat(C_IJ, c_kl)`.
pub fn kron_form_flat(outer: &BilinearForm, inner: &BilinearForm) -> Vec<i64> {
    let mut flat = vec![0i64; ELEM_DIM * ELEM_DIM];
    for p in 0..4 {
        for q in 0..4 {
            let co = outer.coeffs[p * 4 + q] as i64;
            if co == 0 {
                continue;
            }
            for r in 0..4 {
                for s in 0..4 {
                    let ci = inner.coeffs[r * 4 + s] as i64;
                    if ci != 0 {
                        flat[(p * 4 + r) * ELEM_DIM + (q * 4 + s)] = co * ci;
                    }
                }
            }
        }
    }
    flat
}

/// Fast two-level decodability oracle: one per-level [`DecodeOracle`]
/// built once, then O(M₁) per query — the Monte-Carlo inner loop for
/// fan-outs (196–256 leaves) where the flat 2^M enumeration of
/// [`crate::coding::fc::DecodabilityTable`] is out of reach.
#[derive(Clone, Debug)]
pub struct NestedOracle {
    outer: DecodeOracle,
    inner: DecodeOracle,
    m1: usize,
    m2: usize,
}

impl NestedOracle {
    pub fn build(set: &NestedTaskSet) -> NestedOracle {
        NestedOracle {
            outer: DecodeOracle::build(&set.outer),
            inner: DecodeOracle::build(&set.inner),
            m1: set.num_groups(),
            m2: set.group_size(),
        }
    }

    pub fn num_groups(&self) -> usize {
        self.m1
    }

    pub fn group_size(&self) -> usize {
        self.m2
    }

    pub fn num_leaves(&self) -> usize {
        self.m1 * self.m2
    }

    /// Can group `g`'s product be recovered given its failed-leaf mask?
    #[inline]
    pub fn group_decodable(&self, failed_mask: u64) -> bool {
        self.inner.is_decodable(failed_mask)
    }

    /// Is the outer span decodable given the failed-GROUP mask?
    #[inline]
    pub fn outer_decodable(&self, group_failed_mask: u64) -> bool {
        self.outer.is_decodable(group_failed_mask)
    }

    /// Full two-stage decodability over per-group failed-leaf masks.
    pub fn is_decodable(&self, group_failed: &[u64]) -> bool {
        debug_assert_eq!(group_failed.len(), self.m1);
        let mut outer_failed = 0u64;
        for (g, &mask) in group_failed.iter().enumerate() {
            if !self.inner.is_decodable(mask) {
                outer_failed |= 1 << g;
            }
        }
        self.outer.is_decodable(outer_failed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::form::Target;
    use crate::algebra::gauss::{rank, rank_mod_p};
    use crate::algorithms::strassen;

    fn sw2_squared() -> NestedTaskSet {
        NestedTaskSet::compose(TaskSet::strassen_winograd(2), TaskSet::strassen_winograd(2))
    }

    #[test]
    fn compose_shapes_and_names() {
        let n = sw2_squared();
        assert_eq!(n.num_groups(), 16);
        assert_eq!(n.group_size(), 16);
        assert_eq!(n.num_leaves(), 256);
        assert_eq!(n.leaf_name(0, 8), "S1/W2");
        let m = NestedTaskSet::compose(
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(0),
        );
        assert_eq!(m.num_leaves(), 196);
    }

    #[test]
    fn no_failures_decodable_and_single_group_wipeout_tolerated() {
        let n = sw2_squared();
        let clean = vec![0u64; 16];
        assert!(n.decodable_with_failures(&clean));
        // Wipe out ANY single group entirely (16 leaf failures): the
        // outer scheme tolerates any single product loss.
        for g in 0..16 {
            let mut masks = clean.clone();
            masks[g] = (1 << 16) - 1;
            assert!(n.decodable_with_failures(&masks), "group {g} wipeout fatal");
        }
    }

    #[test]
    fn scattered_sub_threshold_failures_tolerated() {
        let n = sw2_squared();
        // Two leaf failures in every group: below the inner first_loss
        // (3), so every group recovers and the outer span is full.
        let masks = vec![0b11u64; 16];
        assert!(n.decodable_with_failures(&masks));
    }

    #[test]
    fn fatal_pattern_at_first_loss() {
        let n = sw2_squared();
        // sw+2psmm's first fatal triple is {S1, S2, W5} = {0, 1, 11}
        // at either level... find one fatal triple exhaustively instead
        // of hard-coding it.
        let inner_fc = fc_table(&n.inner);
        assert_eq!(inner_fc.first_loss(), 3);
        let mut fatal_inner = None;
        'outer: for a in 0..16u32 {
            for b in (a + 1)..16 {
                for c in (b + 1)..16 {
                    let mask = (1u64 << a) | (1 << b) | (1 << c);
                    if !n.inner.decodable_with_failures(mask) {
                        fatal_inner = Some(mask);
                        break 'outer;
                    }
                }
            }
        }
        let fatal_inner = fatal_inner.expect("some fatal triple exists");
        // Kill three groups (a fatal outer triple) with a fatal inner
        // triple each: 9 leaf failures, undecodable.
        let mut fatal_outer = None;
        'outer2: for a in 0..16u32 {
            for b in (a + 1)..16 {
                for c in (b + 1)..16 {
                    let mask = (1u64 << a) | (1 << b) | (1 << c);
                    if !n.outer.decodable_with_failures(mask) {
                        fatal_outer = Some([a as usize, b as usize, c as usize]);
                        break 'outer2;
                    }
                }
            }
        }
        let groups = fatal_outer.expect("some fatal outer triple exists");
        let mut masks = vec![0u64; 16];
        for &g in &groups {
            masks[g] = fatal_inner;
        }
        assert!(!n.decodable_with_failures(&masks));
        assert_eq!(n.first_loss(), 9);
    }

    #[test]
    fn first_loss_is_product_and_at_least_per_level_minimum() {
        for (outer, inner) in [
            (TaskSet::strassen_winograd(2), TaskSet::strassen_winograd(2)),
            (TaskSet::strassen_winograd(0), TaskSet::strassen_winograd(2)),
            (TaskSet::replication(&strassen(), 2), TaskSet::strassen_winograd(0)),
        ] {
            let d1 = fc_table(&outer).first_loss();
            let d2 = fc_table(&inner).first_loss();
            let n = NestedTaskSet::compose(outer, inner);
            assert_eq!(n.first_loss(), d1 * d2, "{}", n.name);
            assert!(n.first_loss() >= d1.max(d2), "{}", n.name);
        }
    }

    #[test]
    fn oracle_matches_direct_decodability() {
        let n = NestedTaskSet::compose(
            TaskSet::replication(&strassen(), 2),
            TaskSet::strassen_winograd(0),
        );
        let oracle = NestedOracle::build(&n);
        assert_eq!(oracle.num_leaves(), 14 * 14);
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for _ in 0..200 {
            let masks: Vec<u64> = (0..n.num_groups())
                .map(|_| next() & next() & ((1 << n.group_size()) - 1))
                .collect();
            assert_eq!(
                oracle.is_decodable(&masks),
                n.decodable_with_failures(&masks),
                "masks {masks:?}"
            );
        }
    }

    #[test]
    fn leaf_uv_is_kronecker_of_level_encodings() {
        let n = sw2_squared();
        let (u, v) = n.leaf_uv(2, 11); // S3 ⊗ W5
        let o = &n.outer.tasks[2];
        let i = &n.inner.tasks[11];
        for p in 0..4 {
            for r in 0..4 {
                assert_eq!(u[p * 4 + r], o.u[p] * i.u[r]);
                assert_eq!(v[p * 4 + r], o.v[p] * i.v[r]);
            }
        }
    }

    #[test]
    fn composed_form_rank_is_product_of_level_ranks() {
        // span{a_g ⊗ b_j} = span{a_g} ⊗ span{b_j}, so the rank of the
        // 256-dim composed forms is the product of the per-level ranks.
        for (outer, inner) in [
            (TaskSet::replication(&strassen(), 1), TaskSet::replication(&strassen(), 1)),
            (TaskSet::strassen_winograd(2), TaskSet::replication(&strassen(), 1)),
            (TaskSet::strassen_winograd(0), TaskSet::strassen_winograd(0)),
        ] {
            let r1 = rank(&outer.forms());
            let r2 = rank(&inner.forms());
            let n = NestedTaskSet::compose(outer, inner);
            let rows: Vec<Vec<i64>> = (0..n.num_groups())
                .flat_map(|g| (0..n.group_size()).map(move |j| (g, j)))
                .map(|(g, j)| n.leaf_form_flat(g, j))
                .collect();
            assert_eq!(rank_mod_p(&rows), r1 * r2, "{}", n.name);
        }
    }

    #[test]
    fn composed_targets_lie_in_leaf_span() {
        // Every two-level output block C_(I,k),(J,l) = C_IJ ⊗ c_kl must
        // be decodable from the full leaf set: appending all 16 composed
        // targets leaves the rank unchanged.
        let n = NestedTaskSet::compose(
            TaskSet::replication(&strassen(), 1),
            TaskSet::strassen_winograd(0),
        );
        let mut rows: Vec<Vec<i64>> = (0..n.num_groups())
            .flat_map(|g| (0..n.group_size()).map(move |j| (g, j)))
            .map(|(g, j)| n.leaf_form_flat(g, j))
            .collect();
        let base = rank_mod_p(&rows);
        for to in Target::ALL {
            for ti in Target::ALL {
                rows.push(kron_form_flat(&to.form(), &ti.form()));
            }
        }
        assert_eq!(rank_mod_p(&rows), base, "composed targets escape the span");
    }
}
