//! Task sets: the concrete distributed configurations the paper compares.
//!
//! A [`TaskSet`] is the full specification the coordinator dispatches:
//! one task per compute node, each task a rank-1 encoded multiplication
//! `(Σ u M)(Σ v B)` with a name and a bilinear form. Builders cover the
//! paper's six Fig.-2 configurations:
//!
//! | name                | nodes | builder |
//! |---------------------|-------|---------|
//! | Strassen, 1 copy    | 7     | `replication(&strassen(), 1)` |
//! | Strassen, 2 copies  | 14    | `replication(&strassen(), 2)` |
//! | Strassen, 3 copies  | 21    | `replication(&strassen(), 3)` |
//! | S+W, no PSMM        | 14    | `strassen_winograd(0)` |
//! | S+W, 1 PSMM         | 15    | `strassen_winograd(1)` |
//! | S+W, 2 PSMM         | 16    | `strassen_winograd(2)` |

use crate::algebra::form::{BilinearForm, Target};
use crate::algebra::gauss::SpanBasis;
use crate::algorithms::scheme::BilinearScheme;
use crate::algorithms::{strassen, winograd};

/// One worker task: a named rank-1 encoded block multiplication.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Task {
    pub name: String,
    /// Left encoding over [M11, M12, M21, M22].
    pub u: [i32; 4],
    /// Right encoding over [B11, B12, B21, B22].
    pub v: [i32; 4],
}

impl Task {
    pub fn form(&self) -> BilinearForm {
        BilinearForm::from_uv(&self.u, &self.v)
    }
}

/// A complete node configuration.
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub name: String,
    pub tasks: Vec<Task>,
}

impl TaskSet {
    /// `c`-copy replication of a single Strassen-like algorithm: every
    /// product dispatched to `c` distinct nodes (the paper's baseline).
    pub fn replication(scheme: &BilinearScheme, c: usize) -> TaskSet {
        assert!(c >= 1);
        let mut tasks = Vec::with_capacity(scheme.num_products() * c);
        for copy in 0..c {
            for (i, p) in scheme.products.iter().enumerate() {
                let base = format!("{}{}", scheme.name[..1].to_uppercase(), i + 1);
                let name =
                    if c == 1 { base } else { format!("{base}#{}", copy + 1) };
                tasks.push(Task { name, u: p.u, v: p.v });
            }
        }
        TaskSet { name: format!("{} x{}", scheme.name, c), tasks }
    }

    /// The paper's proposed configuration: Strassen's and Winograd's
    /// products side by side plus `psmms` (0, 1 or 2) parity
    /// multiplications selected by the computer-aided search.
    pub fn strassen_winograd(psmms: usize) -> TaskSet {
        assert!(psmms <= 2, "paper evaluates at most 2 PSMMs");
        let s = strassen();
        let w = winograd();
        let mut tasks: Vec<Task> = Vec::with_capacity(14 + psmms);
        for (i, p) in s.products.iter().enumerate() {
            tasks.push(Task { name: format!("S{}", i + 1), u: p.u, v: p.v });
        }
        for (i, p) in w.products.iter().enumerate() {
            tasks.push(Task { name: format!("W{}", i + 1), u: p.u, v: p.v });
        }
        // The paper's exact parity multiplications (§IV):
        //   PSMM-1 = S3 + W4 = M21 (B12 - B22)
        //   PSMM-2 = copy of W2 = M12 B21
        // The generic search (`search::psmm::select_psmms`) finds these
        // among several equal-coverage alternatives (e.g. S2 + W5); we
        // pin the paper's choice so the published configuration is
        // reproduced bit-for-bit (tests assert the alternatives cover the
        // same failure pairs).
        const PAPER_PSMMS: [([i32; 4], [i32; 4]); 2] =
            [([0, 0, 1, 0], [0, 1, 0, -1]), ([0, 1, 0, 0], [0, 0, 1, 0])];
        for (i, (u, v)) in PAPER_PSMMS.iter().take(psmms).enumerate() {
            tasks.push(Task { name: format!("P{}", i + 1), u: *u, v: *v });
        }
        TaskSet { name: format!("S+W +{psmms} PSMM"), tasks }
    }

    /// The paper's §V generalization: ANY pair of Strassen-like
    /// algorithms, with PSMMs selected by the computer-aided search
    /// (greedy max-pair-coverage over the Algorithm-1 parity list plus
    /// replicas). `strassen_winograd` is this construction specialized
    /// to the paper's published PSMM choices.
    pub fn pair(
        a: &BilinearScheme,
        b: &BilinearScheme,
        psmms: usize,
    ) -> TaskSet {
        use crate::search::psmm::{select_psmms, Psmm};
        use crate::search::searchlp::SearchOptions;
        let mut tasks: Vec<Task> = Vec::new();
        let prefix = |name: &str| name[..1].to_uppercase();
        for (i, p) in a.products.iter().enumerate() {
            tasks.push(Task { name: format!("{}{}", prefix(a.name), i + 1), u: p.u, v: p.v });
        }
        for (i, p) in b.products.iter().enumerate() {
            // Disambiguate same-letter pairs (e.g. strassen + strassen').
            let letter = if prefix(b.name) == prefix(a.name) {
                format!("{}'", prefix(b.name))
            } else {
                prefix(b.name)
            };
            tasks.push(Task { name: format!("{letter}{}", i + 1), u: p.u, v: p.v });
        }
        if psmms > 0 {
            let forms: Vec<BilinearForm> = tasks.iter().map(|t| t.form()).collect();
            let selected = select_psmms(&forms, psmms, &SearchOptions::default());
            for (i, psmm) in selected.into_iter().enumerate() {
                let (u, v) = match psmm {
                    Psmm::Parity(p) => (p.u, p.v),
                    Psmm::Replica(idx) => (tasks[idx].u, tasks[idx].v),
                };
                tasks.push(Task { name: format!("P{}", i + 1), u, v });
            }
        }
        TaskSet {
            name: format!("{}+{} +{psmms} PSMM", a.name, b.name),
            tasks,
        }
    }

    /// All six Fig.-2 configurations, in the paper's legend order.
    pub fn fig2_schemes() -> Vec<TaskSet> {
        vec![
            TaskSet::replication(&strassen(), 1),
            TaskSet::replication(&strassen(), 2),
            TaskSet::strassen_winograd(0),
            TaskSet::strassen_winograd(1),
            TaskSet::strassen_winograd(2),
            TaskSet::replication(&strassen(), 3),
        ]
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Bilinear forms of all tasks, in dispatch order.
    pub fn forms(&self) -> Vec<BilinearForm> {
        self.tasks.iter().map(|t| t.form()).collect()
    }

    /// Task names as string slices (for rendering).
    pub fn names(&self) -> Vec<&str> {
        self.tasks.iter().map(|t| t.name.as_str()).collect()
    }

    /// Is the output decodable when the nodes in `failed_mask` are lost?
    /// (bit i = task i failed).
    pub fn decodable_with_failures(&self, failed_mask: u64) -> bool {
        let mut basis = SpanBasis::new();
        for (i, t) in self.tasks.iter().enumerate() {
            if failed_mask & (1 << i) == 0 {
                basis.insert(&t.form());
            }
        }
        Target::ALL.iter().all(|t| basis.contains(&t.form()))
    }

    /// Exhaustive FC(k) table: entry k = number of k-failure combinations
    /// that make C unrecoverable (the quantity in the paper's eq. (9)).
    pub fn fc_table(&self) -> Vec<u64> {
        crate::coding::fc::fc_table(self).counts
    }

    /// Precompute decodability for every failure pattern (fast lookups
    /// for Monte-Carlo and the e2e benches). M <= 24 only.
    pub fn decodability_table(&self) -> crate::coding::fc::DecodabilityTable {
        crate::coding::fc::DecodabilityTable::build(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replication_sizes() {
        assert_eq!(TaskSet::replication(&strassen(), 1).num_tasks(), 7);
        assert_eq!(TaskSet::replication(&strassen(), 2).num_tasks(), 14);
        assert_eq!(TaskSet::replication(&strassen(), 3).num_tasks(), 21);
    }

    #[test]
    fn proposed_sizes_match_paper() {
        // "2x7 + 2 = 16 compute nodes compared to 3x7 = 21".
        assert_eq!(TaskSet::strassen_winograd(0).num_tasks(), 14);
        assert_eq!(TaskSet::strassen_winograd(1).num_tasks(), 15);
        assert_eq!(TaskSet::strassen_winograd(2).num_tasks(), 16);
    }

    #[test]
    fn no_failures_always_decodable() {
        for ts in TaskSet::fig2_schemes() {
            assert!(ts.decodable_with_failures(0), "{}", ts.name);
        }
    }

    #[test]
    fn single_copy_fails_on_any_loss() {
        let ts = TaskSet::replication(&strassen(), 1);
        for i in 0..7 {
            assert!(!ts.decodable_with_failures(1 << i));
        }
    }

    #[test]
    fn two_copy_survives_any_single_loss() {
        let ts = TaskSet::replication(&strassen(), 2);
        for i in 0..14 {
            assert!(ts.decodable_with_failures(1 << i));
        }
        // but not both copies of the same product
        assert!(!ts.decodable_with_failures((1 << 0) | (1 << 7)));
    }

    #[test]
    fn proposed_with_2psmm_survives_paper_pairs() {
        let ts = TaskSet::strassen_winograd(2);
        // (S3, W5) = indices (2, 11); (S7, W2) = (6, 8).
        assert!(ts.decodable_with_failures((1 << 2) | (1 << 11)));
        assert!(ts.decodable_with_failures((1 << 6) | (1 << 8)));
    }

    #[test]
    fn proposed_without_psmm_fails_paper_pairs() {
        let ts = TaskSet::strassen_winograd(0);
        assert!(!ts.decodable_with_failures((1 << 2) | (1 << 11)));
        assert!(!ts.decodable_with_failures((1 << 6) | (1 << 8)));
    }

    #[test]
    fn generic_pair_builder_matches_paper_configuration_shape() {
        // strassen + winograd through the generic §V path.
        let ts = TaskSet::pair(&strassen(), &winograd(), 2);
        assert_eq!(ts.num_tasks(), 16);
        // first failures tolerated exactly like the published config
        let fc = crate::coding::fc::fc_table(&ts);
        assert_eq!(fc.counts[1], 0);
        assert_eq!(fc.counts[2], 0, "2 searched PSMMs cover all pairs");
    }

    #[test]
    fn pair_with_naive8_is_fault_tolerant_too() {
        // A different Strassen-like pair (the paper's §V: "applicable to
        // any pair"): strassen + naive8 = 15 products, joint rank 8+.
        let ts = TaskSet::pair(&strassen(), &crate::algorithms::naive8(), 0);
        assert_eq!(ts.num_tasks(), 15);
        let fc = crate::coding::fc::fc_table(&ts);
        assert_eq!(fc.counts[1], 0, "any single failure recoverable");
        // strassen + naive8 is weaker than strassen + winograd at k=2 or
        // not — whatever it is, the full set must decode:
        assert!(ts.decodable_with_failures(0));
    }

    #[test]
    fn pair_same_scheme_reduces_to_replication() {
        // pair(strassen, strassen) == 2-copy replication semantically.
        let ts = TaskSet::pair(&strassen(), &strassen(), 0);
        let rep = TaskSet::replication(&strassen(), 2);
        assert_eq!(ts.num_tasks(), rep.num_tasks());
        let (a, b) = (crate::coding::fc::fc_table(&ts), crate::coding::fc::fc_table(&rep));
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn task_names_unique() {
        for ts in TaskSet::fig2_schemes() {
            let mut names: Vec<_> = ts.names();
            names.sort();
            let before = names.len();
            names.dedup();
            assert_eq!(names.len(), before, "{}: duplicate task names", ts.name);
        }
    }
}
