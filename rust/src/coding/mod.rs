//! The fault-tolerance coding layer: task sets, decoders, failure
//! combinatorics and the analytical model behind Fig. 2.
//!
//! * [`scheme`] — [`scheme::TaskSet`]: the concrete node configurations the
//!   paper compares (c-copy replication of one algorithm; joint
//!   Strassen+Winograd with 0/1/2 PSMMs).
//! * [`decoder`] — the exact span decoder (Gaussian elimination over ℚ)
//!   and the paper's operational peeling decoder over searched local
//!   relations; they are proven equivalent on every failure pattern of
//!   every built-in task set (see tests).
//! * [`fc`] — exhaustive FC(k) tables ("k-failure combinations such that
//!   C cannot be recovered", eq. (9) input) over all 2^M patterns.
//! * [`theory`] — the closed forms: eq. (10) for replication FC(k) and
//!   eq. (9) for P_f.

pub mod decoder;
pub mod fc;
pub mod scheme;
pub mod theory;

pub use decoder::{DecodeOutcome, PeelingDecoder, SpanDecoder};
pub use fc::{fc_table, FcTable};
pub use scheme::TaskSet;
pub use theory::{failure_probability, replication_fc};
