//! The fault-tolerance coding layer: task sets, decoders, failure
//! combinatorics and the analytical model behind Fig. 2.
//!
//! * [`scheme`] — [`scheme::TaskSet`]: the concrete node configurations the
//!   paper compares (c-copy replication of one algorithm; joint
//!   Strassen+Winograd with 0/1/2 PSMMs).
//! * [`decoder`] — the exact span decoder (Gaussian elimination over ℚ)
//!   and the paper's operational peeling decoder over searched local
//!   relations; they are proven equivalent on every failure pattern of
//!   every built-in task set (see tests).
//! * [`fc`] — exhaustive FC(k) tables ("k-failure combinations such that
//!   C cannot be recovered", eq. (9) input) over all 2^M patterns.
//! * [`theory`] — the closed forms: eq. (10) for replication FC(k),
//!   eq. (9) for P_f, and the compositional nested P_f.
//! * [`nested`] — two-level nested schemes
//!   ([`nested::NestedTaskSet`]): compose two task sets so every
//!   level-1 product is itself distributed via a level-2 scheme
//!   (fan-out M₁·M₂ = 196–256), decoded in two stages.

pub mod decoder;
pub mod fc;
pub mod nested;
pub mod scheme;
pub mod theory;

pub use decoder::{DecodeOutcome, PeelingDecoder, SpanDecoder};
pub use fc::{fc_table, FcTable};
pub use nested::{NestedOracle, NestedTaskSet};
pub use scheme::TaskSet;
pub use theory::{failure_probability, nested_failure_probability, replication_fc};
