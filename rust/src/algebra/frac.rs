//! Exact rational arithmetic on `i128` numerator/denominator pairs.
//!
//! The Gaussian elimination in [`crate::algebra::gauss`] runs over ℚ; the
//! matrices involved are at most 20×16 with entries that start in
//! {-1, 0, 1}, so `i128` with eager gcd reduction never overflows in
//! practice (debug builds additionally check every operation).

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// An exact rational number `num / den`, always in reduced form with
/// `den > 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Frac {
    num: i128,
    den: i128,
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Frac {
    /// Construct `num / den`, reducing to canonical form.
    ///
    /// Panics if `den == 0`.
    pub fn new(num: i128, den: i128) -> Self {
        assert!(den != 0, "Frac with zero denominator");
        let g = gcd(num, den).max(1);
        let sign = if den < 0 { -1 } else { 1 };
        Frac { num: sign * num / g, den: sign * den / g }
    }

    /// The integer `n` as a fraction.
    pub const fn int(n: i128) -> Self {
        Frac { num: n, den: 1 }
    }

    pub const ZERO: Frac = Frac { num: 0, den: 1 };
    pub const ONE: Frac = Frac { num: 1, den: 1 };

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn numerator(&self) -> i128 {
        self.num
    }

    pub fn denominator(&self) -> i128 {
        self.den
    }

    /// Multiplicative inverse. Panics on zero.
    pub fn recip(&self) -> Self {
        assert!(self.num != 0, "reciprocal of zero");
        Frac::new(self.den, self.num)
    }

    /// Nearest `f64` value (for handing decode weights to the runtime).
    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// True if the value is an integer.
    pub fn is_integer(&self) -> bool {
        self.den == 1
    }
}

impl fmt::Debug for Frac {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl fmt::Display for Frac {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl From<i32> for Frac {
    fn from(n: i32) -> Self {
        Frac::int(n as i128)
    }
}

impl From<i64> for Frac {
    fn from(n: i64) -> Self {
        Frac::int(n as i128)
    }
}

impl Add for Frac {
    type Output = Frac;
    fn add(self, rhs: Frac) -> Frac {
        // Reduce cross terms first to keep magnitudes small.
        let g = gcd(self.den, rhs.den).max(1);
        let lcm = self.den / g * rhs.den;
        Frac::new(
            self.num * (rhs.den / g) + rhs.num * (self.den / g),
            lcm,
        )
    }
}

impl AddAssign for Frac {
    fn add_assign(&mut self, rhs: Frac) {
        *self = *self + rhs;
    }
}

impl Sub for Frac {
    type Output = Frac;
    fn sub(self, rhs: Frac) -> Frac {
        self + (-rhs)
    }
}

impl Neg for Frac {
    type Output = Frac;
    fn neg(self) -> Frac {
        Frac { num: -self.num, den: self.den }
    }
}

impl Mul for Frac {
    type Output = Frac;
    fn mul(self, rhs: Frac) -> Frac {
        // Cross-reduce before multiplying to avoid overflow.
        let g1 = gcd(self.num, rhs.den).max(1);
        let g2 = gcd(rhs.num, self.den).max(1);
        Frac::new(
            (self.num / g1) * (rhs.num / g2),
            (self.den / g2) * (rhs.den / g1),
        )
    }
}

impl Div for Frac {
    type Output = Frac;
    fn div(self, rhs: Frac) -> Frac {
        self * rhs.recip()
    }
}

impl PartialOrd for Frac {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Frac {
    fn cmp(&self, other: &Self) -> Ordering {
        // den > 0 invariant makes cross-multiplication order-preserving.
        (self.num * other.den).cmp(&(other.num * self.den))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_reduces() {
        assert_eq!(Frac::new(2, 4), Frac::new(1, 2));
        assert_eq!(Frac::new(-2, -4), Frac::new(1, 2));
        assert_eq!(Frac::new(2, -4), Frac::new(-1, 2));
        assert_eq!(Frac::new(0, 5), Frac::ZERO);
    }

    #[test]
    #[should_panic(expected = "zero denominator")]
    fn zero_denominator_panics() {
        let _ = Frac::new(1, 0);
    }

    #[test]
    fn arithmetic() {
        let a = Frac::new(1, 2);
        let b = Frac::new(1, 3);
        assert_eq!(a + b, Frac::new(5, 6));
        assert_eq!(a - b, Frac::new(1, 6));
        assert_eq!(a * b, Frac::new(1, 6));
        assert_eq!(a / b, Frac::new(3, 2));
        assert_eq!(-a, Frac::new(-1, 2));
    }

    #[test]
    fn ordering() {
        assert!(Frac::new(1, 3) < Frac::new(1, 2));
        assert!(Frac::new(-1, 2) < Frac::ZERO);
        assert!(Frac::new(7, 7) == Frac::ONE);
    }

    #[test]
    fn display() {
        assert_eq!(Frac::new(3, 6).to_string(), "1/2");
        assert_eq!(Frac::int(-4).to_string(), "-4");
    }

    #[test]
    fn recip_and_f64() {
        assert_eq!(Frac::new(2, 3).recip(), Frac::new(3, 2));
        assert!((Frac::new(1, 4).to_f64() - 0.25).abs() < 1e-15);
        assert!(Frac::int(5).is_integer());
        assert!(!Frac::new(5, 2).is_integer());
    }

    #[test]
    fn addition_keeps_magnitudes_reduced() {
        // Harmonic partial sum H_30 ≈ 3.9950 (lcm(1..30) ≈ 2.3e12 stays
        // comfortably inside i128 with eager reduction).
        let mut x = Frac::ZERO;
        for i in 1..=30i128 {
            x += Frac::new(1, i);
        }
        assert!(x > Frac::new(39, 10) && x < Frac::int(4));
    }
}
