//! Bilinear forms over the 16 elementary block products (paper Table I).
//!
//! The left operand `M` and right operand `B` are each split into four
//! blocks indexed `11, 12, 21, 22` (row-major order `0..4`). An
//! elementary product is `M_p · B_q`; a *bilinear form* assigns an integer
//! coefficient to each of the 16 elementary products. Every worker task
//! and every output block of the paper is such a form:
//!
//! * `S1 = (M11 + M22)(B11 + B22)` has coefficient +1 on the four
//!   products `{M11,M22} × {B11,B22}`,
//! * the target `C11 = M11·B11 + M12·B21`.
//!
//! Forms that factor as `u(M) · v(B)` (rank-1 coefficient matrices) are
//! exactly the ones a single worker can compute with one block
//! multiplication — this is the membership test of Algorithm 1's parity
//! (PSMM) branch.

use std::fmt;
use std::ops::{Add, Mul, Neg, Sub};

/// Number of elementary block products: 4 M-blocks × 4 B-blocks.
pub const ELEM_DIM: usize = 16;

/// Human-readable block labels in index order.
pub const BLOCK_NAMES: [&str; 4] = ["11", "12", "21", "22"];

/// Flat index of the elementary product `M_p · B_q`.
#[inline]
pub const fn elem_index(p: usize, q: usize) -> usize {
    p * 4 + q
}

/// An integer-coefficient bilinear form over the 16 elementary products.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct BilinearForm {
    /// Coefficient of `M_p · B_q` at `[p * 4 + q]`.
    pub coeffs: [i32; ELEM_DIM],
}

impl BilinearForm {
    /// The zero form.
    pub const ZERO: BilinearForm = BilinearForm { coeffs: [0; ELEM_DIM] };

    /// The single elementary product `M_p · B_q`.
    pub fn elementary(p: usize, q: usize) -> Self {
        let mut coeffs = [0; ELEM_DIM];
        coeffs[elem_index(p, q)] = 1;
        BilinearForm { coeffs }
    }

    /// The rank-1 form `(Σ_p u[p] M_p) · (Σ_q v[q] B_q)` — i.e. what one
    /// worker node computes from encoded operands.
    pub fn from_uv(u: &[i32; 4], v: &[i32; 4]) -> Self {
        let mut coeffs = [0; ELEM_DIM];
        for p in 0..4 {
            for q in 0..4 {
                coeffs[elem_index(p, q)] = u[p] * v[q];
            }
        }
        BilinearForm { coeffs }
    }

    pub fn is_zero(&self) -> bool {
        self.coeffs.iter().all(|&c| c == 0)
    }

    /// Number of non-zero coefficients.
    pub fn support_size(&self) -> usize {
        self.coeffs.iter().filter(|&&c| c != 0).count()
    }

    /// If this form is a *single* elementary product with coefficient ±1,
    /// return `(p, q, sign)`.
    pub fn as_elementary(&self) -> Option<(usize, usize, i32)> {
        let mut found = None;
        for p in 0..4 {
            for q in 0..4 {
                let c = self.coeffs[elem_index(p, q)];
                if c != 0 {
                    if found.is_some() || c.abs() != 1 {
                        return None;
                    }
                    found = Some((p, q, c));
                }
            }
        }
        found
    }

    /// Rank-1 factorization over ℤ: if the 4×4 coefficient matrix equals
    /// an outer product `u vᵀ` with integer vectors (gcd-normalized, the
    /// leading nonzero of `u` positive), return `(u, v)`.
    ///
    /// Exactly the forms with such a factorization can be *computed by a
    /// single worker* as one encoded block multiplication, so this is the
    /// validity test for PSMM candidates found by Algorithm 1.
    pub fn rank_one_factor(&self) -> Option<([i32; 4], [i32; 4])> {
        if self.is_zero() {
            return None;
        }
        // Find the first row with a nonzero entry; it must be proportional
        // to every other nonzero row.
        let row = |p: usize| -> [i32; 4] {
            [
                self.coeffs[elem_index(p, 0)],
                self.coeffs[elem_index(p, 1)],
                self.coeffs[elem_index(p, 2)],
                self.coeffs[elem_index(p, 3)],
            ]
        };
        let pivot = (0..4).find(|&p| row(p).iter().any(|&c| c != 0))?;
        let v_raw = row(pivot);
        // gcd-normalize v.
        let g = v_raw.iter().fold(0i32, |a, &b| gcd_i32(a, b)).max(1);
        let mut v = [0i32; 4];
        for q in 0..4 {
            v[q] = v_raw[q] / g;
        }
        // Make the first nonzero of v positive (canonical sign).
        let lead = v.iter().find(|&&c| c != 0).copied().unwrap();
        if lead < 0 {
            for q in 0..4 {
                v[q] = -v[q];
            }
        }
        // Solve u[p] * v = row(p) for each p.
        let vq = v.iter().position(|&c| c != 0).unwrap();
        let mut u = [0i32; 4];
        for p in 0..4 {
            let r = row(p);
            if r[vq] % v[vq] != 0 {
                return None;
            }
            u[p] = r[vq] / v[vq];
            for q in 0..4 {
                if u[p] * v[q] != r[q] {
                    return None;
                }
            }
        }
        Some((u, v))
    }

    /// The paper's hexadecimal support notation: one nibble per M-block
    /// (M11, M12, M21, M22), bit 3..0 = B11, B12, B21, B22. Only the
    /// support (presence of a term) is encoded, as in the paper's
    /// `C11 = 0x8040` example (which uses the transposed labeling; see
    /// DESIGN.md §3.1 — the codec itself is identical).
    pub fn hex_support(&self) -> String {
        let mut s = String::with_capacity(6);
        s.push_str("0x");
        for p in 0..4 {
            let mut nib = 0u8;
            for q in 0..4 {
                if self.coeffs[elem_index(p, q)] != 0 {
                    nib |= 1 << (3 - q);
                }
            }
            s.push(char::from_digit(nib as u32, 16).unwrap());
        }
        s
    }

    /// All 16 coefficients as `f64` (runtime decode weights etc.).
    pub fn to_f64(&self) -> [f64; ELEM_DIM] {
        let mut out = [0.0; ELEM_DIM];
        for (o, &c) in out.iter_mut().zip(self.coeffs.iter()) {
            *o = c as f64;
        }
        out
    }
}

fn gcd_i32(a: i32, b: i32) -> i32 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Add for BilinearForm {
    type Output = BilinearForm;
    fn add(self, rhs: BilinearForm) -> BilinearForm {
        let mut coeffs = [0; ELEM_DIM];
        for i in 0..ELEM_DIM {
            coeffs[i] = self.coeffs[i] + rhs.coeffs[i];
        }
        BilinearForm { coeffs }
    }
}

impl Sub for BilinearForm {
    type Output = BilinearForm;
    fn sub(self, rhs: BilinearForm) -> BilinearForm {
        let mut coeffs = [0; ELEM_DIM];
        for i in 0..ELEM_DIM {
            coeffs[i] = self.coeffs[i] - rhs.coeffs[i];
        }
        BilinearForm { coeffs }
    }
}

impl Neg for BilinearForm {
    type Output = BilinearForm;
    fn neg(self) -> BilinearForm {
        let mut coeffs = [0; ELEM_DIM];
        for i in 0..ELEM_DIM {
            coeffs[i] = -self.coeffs[i];
        }
        BilinearForm { coeffs }
    }
}

impl Mul<i32> for BilinearForm {
    type Output = BilinearForm;
    fn mul(self, s: i32) -> BilinearForm {
        let mut coeffs = [0; ELEM_DIM];
        for i in 0..ELEM_DIM {
            coeffs[i] = self.coeffs[i] * s;
        }
        BilinearForm { coeffs }
    }
}

impl fmt::Debug for BilinearForm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl fmt::Display for BilinearForm {
    /// Render like `M11*B11 + M12*B21 - M22*B22`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_zero() {
            return write!(f, "0");
        }
        let mut first = true;
        for p in 0..4 {
            for q in 0..4 {
                let c = self.coeffs[elem_index(p, q)];
                if c == 0 {
                    continue;
                }
                if first {
                    if c < 0 {
                        write!(f, "-")?;
                    }
                    first = false;
                } else {
                    write!(f, " {} ", if c < 0 { "-" } else { "+" })?;
                }
                if c.abs() != 1 {
                    write!(f, "{}*", c.abs())?;
                }
                write!(f, "M{}B{}", BLOCK_NAMES[p], BLOCK_NAMES[q])?;
            }
        }
        Ok(())
    }
}

/// The four output blocks of `C = M · B`, as bilinear-form targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Target {
    C11,
    C12,
    C21,
    C22,
}

impl Target {
    pub const ALL: [Target; 4] = [Target::C11, Target::C12, Target::C21, Target::C22];

    /// Row-major index 0..4.
    pub fn index(&self) -> usize {
        match self {
            Target::C11 => 0,
            Target::C12 => 1,
            Target::C21 => 2,
            Target::C22 => 3,
        }
    }

    pub fn from_index(i: usize) -> Target {
        Target::ALL[i]
    }

    /// The target's bilinear form: `C_ij = Σ_k M_ik · B_kj`.
    pub fn form(&self) -> BilinearForm {
        let (i, j) = match self {
            Target::C11 => (0, 0),
            Target::C12 => (0, 1),
            Target::C21 => (1, 0),
            Target::C22 => (1, 1),
        };
        // M block (i,k) has index 2i + k; B block (k,j) has index 2k + j.
        let mut form = BilinearForm::ZERO;
        for k in 0..2 {
            form = form + BilinearForm::elementary(2 * i + k, 2 * k + j);
        }
        form
    }

    pub fn name(&self) -> &'static str {
        match self {
            Target::C11 => "C11",
            Target::C12 => "C12",
            Target::C21 => "C21",
            Target::C22 => "C22",
        }
    }
}

impl fmt::Display for Target {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementary_and_support() {
        let e = BilinearForm::elementary(2, 1); // M21 * B12
        assert_eq!(e.support_size(), 1);
        assert_eq!(e.as_elementary(), Some((2, 1, 1)));
        assert_eq!((-e).as_elementary(), Some((2, 1, -1)));
        assert_eq!((e * 2).as_elementary(), None);
    }

    #[test]
    fn from_uv_expands_outer_product() {
        // S1 = (M11 + M22)(B11 + B22)
        let s1 = BilinearForm::from_uv(&[1, 0, 0, 1], &[1, 0, 0, 1]);
        assert_eq!(s1.support_size(), 4);
        assert_eq!(s1.coeffs[elem_index(0, 0)], 1);
        assert_eq!(s1.coeffs[elem_index(0, 3)], 1);
        assert_eq!(s1.coeffs[elem_index(3, 0)], 1);
        assert_eq!(s1.coeffs[elem_index(3, 3)], 1);
    }

    #[test]
    fn target_forms_match_block_matmul() {
        // C11 = M11 B11 + M12 B21
        let c11 = Target::C11.form();
        assert_eq!(c11.coeffs[elem_index(0, 0)], 1);
        assert_eq!(c11.coeffs[elem_index(1, 2)], 1);
        assert_eq!(c11.support_size(), 2);
        // C22 = M21 B12 + M22 B22
        let c22 = Target::C22.form();
        assert_eq!(c22.coeffs[elem_index(2, 1)], 1);
        assert_eq!(c22.coeffs[elem_index(3, 3)], 1);
    }

    #[test]
    fn hex_support_codec() {
        // Our convention: C11 = M11B11 + M12B21 -> nibbles [8, 2, 0, 0].
        assert_eq!(Target::C11.form().hex_support(), "0x8200");
        assert_eq!(Target::C12.form().hex_support(), "0x4100");
        assert_eq!(Target::C21.form().hex_support(), "0x0082");
        assert_eq!(Target::C22.form().hex_support(), "0x0041");
    }

    #[test]
    fn rank_one_factorization_roundtrip() {
        let u = [1, 0, -1, 1];
        let v = [0, 1, 0, -1];
        let f = BilinearForm::from_uv(&u, &v);
        let (fu, fv) = f.rank_one_factor().expect("rank one");
        assert_eq!(BilinearForm::from_uv(&fu, &fv), f);
    }

    #[test]
    fn rank_one_rejects_rank_two() {
        // C11 = M11B11 + M12B21 is rank 2 and NOT one-worker computable.
        assert!(Target::C11.form().rank_one_factor().is_none());
    }

    #[test]
    fn rank_one_detects_psmm1() {
        // PSMM-1 = S3 + W4 = M21 (B12 - B22) (paper §IV).
        let s3 = BilinearForm::from_uv(&[1, 0, 0, 0], &[0, 1, 0, -1]);
        let w4 = BilinearForm::from_uv(&[1, 0, -1, 0], &[0, -1, 0, 1]);
        let p1 = s3 + w4;
        let (u, v) = p1.rank_one_factor().expect("PSMM-1 is one product");
        // Canonical factor: leading nonzero of v positive -> v = B12 - B22.
        assert_eq!(u, [0, 0, 1, 0]);
        assert_eq!(v, [0, 1, 0, -1]);
        assert_eq!(p1, BilinearForm::from_uv(&[0, 0, 1, 0], &[0, 1, 0, -1]));
    }

    #[test]
    fn arithmetic_and_display() {
        let a = BilinearForm::elementary(0, 0);
        let b = BilinearForm::elementary(1, 2);
        let f = a + b - b;
        assert_eq!(f, a);
        assert_eq!(a.to_string(), "M11B11");
        assert_eq!((a - b).to_string(), "M11B11 - M12B21");
        assert_eq!(((a + b) * 2).to_string(), "2*M11B11 + 2*M12B21");
        assert_eq!(BilinearForm::ZERO.to_string(), "0");
    }

    #[test]
    fn zero_has_no_factor() {
        assert!(BilinearForm::ZERO.rank_one_factor().is_none());
    }

    #[test]
    fn to_f64_roundtrip() {
        let f = BilinearForm::from_uv(&[1, -1, 0, 0], &[1, 1, 0, 0]);
        let v = f.to_f64();
        for i in 0..ELEM_DIM {
            assert_eq!(v[i], f.coeffs[i] as f64);
        }
    }
}
