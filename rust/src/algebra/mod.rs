//! Exact bilinear-form algebra — the substrate under the search and
//! coding layers.
//!
//! Every sub-matrix multiplication of the paper (S1..S7, W1..W7, the
//! PSMMs) and every output target (C11..C22) is a *bilinear form*: an
//! integer coefficient vector over the 16 elementary block products
//! `M_p · B_q` (Table I of the paper). Decodability questions ("can C be
//! reconstructed from this subset of finished workers?") are exact linear
//! algebra over ℚ on these vectors; no floating point is involved, so
//! the FC(k) tables and the Fig. 2 curves are bit-reproducible.

pub mod form;
pub mod fp;
pub mod frac;
pub mod gauss;

pub use form::{BilinearForm, Target};
pub use fp::{Fp, Fp31};
pub use frac::Frac;
pub use gauss::{solve_in_span, span_contains, SpanBasis};
