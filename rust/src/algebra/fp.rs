//! Prime-field scalars `Fp<P>` with Barrett reduction — the exact
//! backend that turns the decoder's "small dyadic rational weights"
//! invariant into a zero-tolerance theorem (`tests/scalar_conformance.rs`),
//! and the substrate for finite-field coded-MM workloads (straggler
//! codes over small fields; see PAPERS.md).
//!
//! `P` must be an odd prime below 2³¹, so every product of canonical
//! residues fits in `u64` (`a·b < 2⁶²`) and one Barrett step with the
//! precomputed `⌊2⁶⁴/P⌋` brings it back under `2P`. The default
//! instantiation [`Fp31`] uses the Mersenne prime `2³¹ − 1` — the same
//! modulus as the rank checks in [`crate::algebra::gauss`].

use std::fmt;
use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub, SubAssign};

use crate::linalg::scalar::Scalar;

/// An element of the prime field ℤ/Pℤ, stored as the canonical residue
/// in `[0, P)`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fp<const P: u64>(u64);

/// The default prime field: `P = 2³¹ − 1` (Mersenne), products fit
/// comfortably in `u64` and every dyadic decode denominator is
/// invertible (`gcd(2, P) = 1`).
pub type Fp31 = Fp<2_147_483_647>;

impl<const P: u64> Fp<P> {
    /// Barrett constant `⌊2⁶⁴ / P⌋`, computed at compile time per
    /// instantiation.
    const BARRETT_M: u64 = (u64::MAX as u128 / P as u128) as u64;

    /// Reduce `x < 2⁶²` modulo `P` with one Barrett multiply: the
    /// estimated quotient `q = ⌊x·M/2⁶⁴⌋` undershoots the true quotient
    /// by at most 1, so a single conditional subtract finishes.
    #[inline]
    fn reduce(x: u128) -> u64 {
        debug_assert!(x < 1u128 << 62, "Barrett input out of range");
        let q = ((x * Self::BARRETT_M as u128) >> 64) as u64;
        let mut r = (x as u64).wrapping_sub(q.wrapping_mul(P));
        while r >= P {
            r -= P;
        }
        r
    }

    /// The residue of `v` (already-canonical values pass through).
    #[inline]
    pub fn new(v: u64) -> Self {
        debug_assert!(P > 2 && P < (1 << 31), "Fp modulus must be an odd prime below 2^31");
        Fp(if v < P { v } else { v % P })
    }

    /// Canonical residue in `[0, P)`.
    #[inline]
    pub fn value(self) -> u64 {
        self.0
    }

    /// The field's modulus.
    pub const fn modulus() -> u64 {
        P
    }

    /// `self^e` by square-and-multiply.
    pub fn pow(self, mut e: u64) -> Self {
        let mut base = self;
        let mut acc = Fp::<P>(1 % P);
        while e > 0 {
            if e & 1 == 1 {
                acc *= base;
            }
            base *= base;
            e >>= 1;
        }
        acc
    }

    /// Multiplicative inverse via Fermat (`self^(P-2)`). Panics on zero.
    pub fn inv(self) -> Self {
        assert!(self.0 != 0, "inverse of zero in Fp<{P}>");
        self.pow(P - 2)
    }
}

impl<const P: u64> Add for Fp<P> {
    type Output = Fp<P>;
    #[inline]
    fn add(self, rhs: Fp<P>) -> Fp<P> {
        let s = self.0 + rhs.0; // < 2P < 2^32: no overflow
        Fp(if s >= P { s - P } else { s })
    }
}

impl<const P: u64> Sub for Fp<P> {
    type Output = Fp<P>;
    #[inline]
    fn sub(self, rhs: Fp<P>) -> Fp<P> {
        Fp(if self.0 >= rhs.0 { self.0 - rhs.0 } else { self.0 + P - rhs.0 })
    }
}

impl<const P: u64> Neg for Fp<P> {
    type Output = Fp<P>;
    #[inline]
    fn neg(self) -> Fp<P> {
        Fp(if self.0 == 0 { 0 } else { P - self.0 })
    }
}

impl<const P: u64> Mul for Fp<P> {
    type Output = Fp<P>;
    #[inline]
    fn mul(self, rhs: Fp<P>) -> Fp<P> {
        Fp(Self::reduce(self.0 as u128 * rhs.0 as u128))
    }
}

impl<const P: u64> AddAssign for Fp<P> {
    #[inline]
    fn add_assign(&mut self, rhs: Fp<P>) {
        *self = *self + rhs;
    }
}

impl<const P: u64> SubAssign for Fp<P> {
    #[inline]
    fn sub_assign(&mut self, rhs: Fp<P>) {
        *self = *self - rhs;
    }
}

impl<const P: u64> MulAssign for Fp<P> {
    #[inline]
    fn mul_assign(&mut self, rhs: Fp<P>) {
        *self = *self * rhs;
    }
}

impl<const P: u64> fmt::Display for Fp<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl<const P: u64> fmt::Debug for Fp<P> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} (mod {P})", self.0)
    }
}

impl<const P: u64> Scalar for Fp<P> {
    // One name for every modulus: const generics cannot format P into
    // a `&'static str` on stable.
    const BACKEND_NAME: &'static str = "fp";
    const IS_EXACT: bool = true;

    fn zero() -> Self {
        Fp(0)
    }

    fn one() -> Self {
        Fp(1 % P)
    }

    fn from_i64(v: i64) -> Self {
        // P < 2^31 fits i64, so rem_euclid lands in [0, P).
        Fp(v.rem_euclid(P as i64) as u64)
    }

    fn exact_div(self, d: i64) -> Self {
        let d = Self::from_i64(d);
        assert!(d.0 != 0, "exact_div by a multiple of the field modulus {P}");
        self * d.inv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;
    use crate::testkit;

    const P: u64 = 2_147_483_647;

    #[test]
    fn canonical_construction_and_values() {
        assert_eq!(Fp31::new(0).value(), 0);
        assert_eq!(Fp31::new(P).value(), 0);
        assert_eq!(Fp31::new(P + 5).value(), 5);
        assert_eq!(Fp31::from_i64(-1).value(), P - 1);
        assert_eq!(Fp31::modulus(), P);
    }

    #[test]
    fn barrett_matches_naive_remainder_on_random_products() {
        // The property that makes the whole backend trustworthy: the
        // Barrett multiply equals the u128 schoolbook remainder on
        // arbitrary residue pairs.
        testkit::check("fp_barrett_mul", &testkit::PropConfig::default(), |rng| {
            let a = rng.next_u64() % P;
            let b = rng.next_u64() % P;
            let want = ((a as u128 * b as u128) % P as u128) as u64;
            let got = (Fp31::new(a) * Fp31::new(b)).value();
            if got != want {
                return Err(format!("{a} * {b}: got {got}, want {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn field_axioms_hold_on_random_triples() {
        testkit::check("fp_field_axioms", &testkit::PropConfig::default(), |rng| {
            let x = Fp31::new(rng.next_u64() % P);
            let y = Fp31::new(rng.next_u64() % P);
            let z = Fp31::new(rng.next_u64() % P);
            if (x + y) + z != x + (y + z) || (x * y) * z != x * (y * z) {
                return Err("associativity failed".into());
            }
            if x * (y + z) != x * y + x * z {
                return Err("distributivity failed".into());
            }
            if x + (-x) != Fp31::zero() || x - y != x + (-y) {
                return Err("additive inverse failed".into());
            }
            if x != Fp31::zero() && x * x.inv() != Fp31::one() {
                return Err(format!("inverse failed for {x}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pow_matches_repeated_multiplication() {
        let mut rng = Rng::seeded(9);
        let x = Fp31::new(rng.next_u64() % P);
        let mut acc = Fp31::one();
        for e in 0..20u64 {
            assert_eq!(x.pow(e), acc, "x^{e}");
            acc *= x;
        }
    }

    #[test]
    fn exact_div_is_multiplication_by_the_inverse() {
        for d in [1i64, 2, -2, 8, 1024, 7] {
            let y = Fp31::from_i64(12345);
            let x = y * Fp31::from_i64(d);
            assert_eq!(x.exact_div(d), y, "d = {d}");
        }
    }

    #[test]
    #[should_panic(expected = "inverse of zero")]
    fn zero_has_no_inverse() {
        let _ = Fp31::zero().inv();
    }

    #[test]
    fn small_prime_instantiation_also_works() {
        // A second modulus exercises the const-generic machinery (the
        // Barrett constant is per-instantiation).
        type F7 = Fp<7>;
        let mut seen = [false; 7];
        for v in 0..7u64 {
            seen[(F7::new(v) * F7::new(3)).value() as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "x -> 3x must permute Z/7");
    }
}
