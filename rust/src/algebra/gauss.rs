//! Exact Gaussian elimination over ℚ on bilinear forms.
//!
//! The decodability oracle of the coding layer: a set of finished worker
//! products spans the output iff every `C_ij` target lies in the ℚ-span
//! of their bilinear forms. [`SpanBasis`] maintains a row-reduced basis
//! *incrementally* so the coordinator can re-check decodability in
//! O(dim²) as each worker finishes (the L3 hot path — see
//! EXPERIMENTS.md §Perf).

use super::form::{BilinearForm, ELEM_DIM};
use super::frac::Frac;

/// A row-echelon basis of a subspace of ℚ^16, maintained incrementally.
///
/// Each stored row is normalized to a leading 1 at its pivot column, and
/// rows are kept mutually reduced (reduced row-echelon form), so
/// membership tests are a single elimination pass.
#[derive(Clone, Debug, Default)]
pub struct SpanBasis {
    /// `(pivot_column, row)` sorted by pivot column.
    rows: Vec<(usize, [Frac; ELEM_DIM])>,
}

fn to_frac_row(form: &BilinearForm) -> [Frac; ELEM_DIM] {
    let mut row = [Frac::ZERO; ELEM_DIM];
    for (r, &c) in row.iter_mut().zip(form.coeffs.iter()) {
        *r = Frac::int(c as i128);
    }
    row
}

impl SpanBasis {
    pub fn new() -> Self {
        SpanBasis { rows: Vec::with_capacity(ELEM_DIM) }
    }

    /// Current dimension of the spanned subspace.
    pub fn rank(&self) -> usize {
        self.rows.len()
    }

    /// Reduce `row` against the basis in place; returns the column of the
    /// first surviving nonzero entry, if any.
    fn reduce(&self, row: &mut [Frac; ELEM_DIM]) -> Option<usize> {
        for (pivot, basis_row) in &self.rows {
            let factor = row[*pivot];
            if !factor.is_zero() {
                for i in *pivot..ELEM_DIM {
                    row[i] = row[i] - factor * basis_row[i];
                }
            }
        }
        row.iter().position(|c| !c.is_zero())
    }

    /// Insert a form into the basis. Returns `true` if it increased the
    /// rank (i.e. was not already in the span).
    pub fn insert(&mut self, form: &BilinearForm) -> bool {
        let mut row = to_frac_row(form);
        let Some(pivot) = self.reduce(&mut row) else {
            return false;
        };
        // Normalize to leading 1.
        let lead = row[pivot];
        for c in row.iter_mut() {
            *c = *c / lead;
        }
        // Back-substitute into existing rows to keep RREF.
        for (_, existing) in self.rows.iter_mut() {
            let factor = existing[pivot];
            if !factor.is_zero() {
                for i in 0..ELEM_DIM {
                    existing[i] = existing[i] - factor * row[i];
                }
            }
        }
        let at = self.rows.partition_point(|(p, _)| *p < pivot);
        self.rows.insert(at, (pivot, row));
        true
    }

    /// Is `form` in the span of the inserted forms?
    pub fn contains(&self, form: &BilinearForm) -> bool {
        let mut row = to_frac_row(form);
        self.reduce(&mut row).is_none()
    }
}

/// Does `target` lie in the ℚ-span of `forms`?
pub fn span_contains(forms: &[BilinearForm], target: &BilinearForm) -> bool {
    let mut basis = SpanBasis::new();
    for f in forms {
        basis.insert(f);
    }
    basis.contains(target)
}

/// Rank of a set of forms.
pub fn rank(forms: &[BilinearForm]) -> usize {
    let mut basis = SpanBasis::new();
    for f in forms {
        basis.insert(f);
    }
    basis.rank()
}

/// Rank of a set of integer rows over the prime field GF(p),
/// p = 2³¹ − 1 (Mersenne).
///
/// Always a *lower bound* on the rank over ℚ, and equal to it unless p
/// divides one of the pivot minors — astronomically unlikely for the
/// small ±1-product coefficients used here. The nested-scheme tests use
/// this for 256-dimensional composed (Kronecker) forms, where the
/// fraction-free i128 elimination of `coding::fc` would overflow.
pub fn rank_mod_p(rows: &[Vec<i64>]) -> usize {
    const P: i64 = 2_147_483_647; // 2^31 - 1, prime
    fn inv_mod(a: i64) -> i64 {
        // Fermat: a^(P-2) mod P.
        let (mut base, mut exp, mut acc) = (a as i128, P - 2, 1i128);
        let p = P as i128;
        while exp > 0 {
            if exp & 1 == 1 {
                acc = acc * base % p;
            }
            base = base * base % p;
            exp >>= 1;
        }
        acc as i64
    }
    if rows.is_empty() {
        return 0;
    }
    let width = rows[0].len();
    let mut m: Vec<Vec<i64>> = rows
        .iter()
        .map(|r| {
            assert_eq!(r.len(), width, "ragged rows");
            r.iter().map(|&x| x.rem_euclid(P)).collect()
        })
        .collect();
    let mut rank = 0;
    for col in 0..width {
        let Some(pivot) = (rank..m.len()).find(|&r| m[r][col] != 0) else {
            continue;
        };
        m.swap(rank, pivot);
        let inv = inv_mod(m[rank][col]) as i128;
        for c in col..width {
            m[rank][c] = (m[rank][c] as i128 * inv % P as i128) as i64;
        }
        for r in (rank + 1)..m.len() {
            let f = m[r][col] as i128;
            if f != 0 {
                for c in col..width {
                    let v = (m[r][c] as i128 - f * m[rank][c] as i128) % P as i128;
                    m[r][c] = v.rem_euclid(P as i128) as i64;
                }
            }
        }
        rank += 1;
        if rank == m.len() {
            break;
        }
    }
    rank
}

/// Express `target` as a rational combination of `forms`:
/// returns `w` with `Σ w[i] · forms[i] = target`, or `None` if `target`
/// is not in the span. Uses full Gaussian elimination on the augmented
/// system (columns = forms, rows = the 16 elementary products).
pub fn solve_in_span(forms: &[BilinearForm], target: &BilinearForm) -> Option<Vec<Frac>> {
    solve_in_span_multi(forms, std::slice::from_ref(target))
        .pop()
        .flatten()
}

/// Multi-RHS variant: ONE elimination shared by all targets (the decode
/// hot path solves all four C blocks at once — see EXPERIMENTS.md §Perf).
/// Returns per-target weights; a target outside the span yields `None`
/// in its slot (the single-target wrapper maps that to `None` overall).
pub fn solve_in_span_multi(
    forms: &[BilinearForm],
    targets: &[BilinearForm],
) -> Vec<Option<Vec<Frac>>> {
    let n = forms.len();
    let t = targets.len();
    let width = n + t;
    // Augmented matrix: ELEM_DIM rows, n form columns + t RHS columns.
    let mut m: Vec<Vec<Frac>> = (0..ELEM_DIM)
        .map(|r| {
            let mut row: Vec<Frac> = (0..n)
                .map(|c| Frac::int(forms[c].coeffs[r] as i128))
                .collect();
            row.extend(targets.iter().map(|tg| Frac::int(tg.coeffs[r] as i128)));
            row
        })
        .collect();

    let rows = ELEM_DIM;
    let mut pivots: Vec<(usize, usize)> = Vec::new(); // (row, col)
    let mut rank_row = 0;
    for col in 0..n {
        let Some(p) = (rank_row..rows).find(|&r| !m[r][col].is_zero()) else {
            continue;
        };
        m.swap(rank_row, p);
        let lead = m[rank_row][col];
        for c in col..width {
            m[rank_row][c] = m[rank_row][c] / lead;
        }
        for r in 0..rows {
            if r != rank_row && !m[r][col].is_zero() {
                let f = m[r][col];
                for c in col..width {
                    m[r][c] = m[r][c] - f * m[rank_row][c];
                }
            }
        }
        pivots.push((rank_row, col));
        rank_row += 1;
        if rank_row == rows {
            break;
        }
    }
    let mut out = Vec::with_capacity(t);
    'target: for ti in 0..t {
        // Inconsistent if any zero-row has a nonzero RHS for this target.
        for r in rank_row..rows {
            if !m[r][n + ti].is_zero() {
                out.push(None);
                continue 'target;
            }
        }
        let mut w = vec![Frac::ZERO; n];
        for &(r, c) in &pivots {
            w[c] = m[r][n + ti];
        }
        out.push(Some(w));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algebra::form::Target;

    fn s(u: [i32; 4], v: [i32; 4]) -> BilinearForm {
        BilinearForm::from_uv(&u, &v)
    }

    /// Strassen's seven products.
    fn strassen() -> Vec<BilinearForm> {
        vec![
            s([1, 0, 0, 1], [1, 0, 0, 1]),  // S1
            s([0, 0, 1, 1], [1, 0, 0, 0]),  // S2
            s([1, 0, 0, 0], [0, 1, 0, -1]), // S3
            s([0, 0, 0, 1], [-1, 0, 1, 0]), // S4
            s([1, 1, 0, 0], [0, 0, 0, 1]),  // S5
            s([-1, 0, 1, 0], [1, 1, 0, 0]), // S6
            s([0, 1, 0, -1], [0, 0, 1, 1]), // S7
        ]
    }

    #[test]
    fn strassen_has_rank_seven_and_spans_all_targets() {
        let forms = strassen();
        assert_eq!(rank(&forms), 7);
        for t in Target::ALL {
            assert!(span_contains(&forms, &t.form()), "{t} not spanned");
        }
    }

    #[test]
    fn six_products_cannot_span() {
        let mut forms = strassen();
        forms.pop();
        // With S7 missing, C11 = S1+S4-S5+S7 is unrecoverable.
        assert!(!span_contains(&forms, &Target::C11.form()));
    }

    #[test]
    fn solve_recovers_paper_eq1() {
        // C11 = S1 + S4 - S5 + S7 (paper eq. (1)).
        let forms = strassen();
        let w = solve_in_span(&forms, &Target::C11.form()).unwrap();
        let expect = [1, 0, 0, 1, -1, 0, 1];
        for (i, e) in expect.iter().enumerate() {
            assert_eq!(w[i], Frac::int(*e as i128), "weight {i}");
        }
    }

    #[test]
    fn solve_detects_unsolvable() {
        let forms = vec![s([1, 0, 0, 0], [1, 0, 0, 0])];
        assert!(solve_in_span(&forms, &Target::C11.form()).is_none());
    }

    #[test]
    fn solve_verifies_combination() {
        let forms = strassen();
        for t in Target::ALL {
            let w = solve_in_span(&forms, &t.form()).unwrap();
            let mut acc = BilinearForm::ZERO;
            for (wi, f) in w.iter().zip(forms.iter()) {
                assert!(wi.is_integer(), "Strassen weights are integers");
                acc = acc + *f * (wi.numerator() as i32);
            }
            assert_eq!(acc, t.form());
        }
    }

    #[test]
    fn multi_rhs_matches_single_solves() {
        use crate::algebra::gauss::solve_in_span_multi;
        let forms = strassen();
        let targets: Vec<BilinearForm> = Target::ALL.iter().map(|t| t.form()).collect();
        let multi = solve_in_span_multi(&forms, &targets);
        for (t, sol) in Target::ALL.iter().zip(multi.iter()) {
            assert_eq!(sol.as_ref(), solve_in_span(&forms, &t.form()).as_ref());
        }
        // unsolvable slot is None while solvable ones stay Some
        let partial = vec![forms[0], forms[1]];
        let mixed = solve_in_span_multi(
            &partial,
            &[forms[0], Target::C11.form()],
        );
        assert!(mixed[0].is_some());
        assert!(mixed[1].is_none());
    }

    #[test]
    fn rank_mod_p_matches_exact_rank_on_forms() {
        let forms = strassen();
        let rows: Vec<Vec<i64>> = forms
            .iter()
            .map(|f| f.coeffs.iter().map(|&c| c as i64).collect())
            .collect();
        assert_eq!(rank_mod_p(&rows), rank(&forms));
        // Degenerate cases.
        assert_eq!(rank_mod_p(&[]), 0);
        assert_eq!(rank_mod_p(&[vec![0, 0, 0]]), 0);
        assert_eq!(rank_mod_p(&[vec![0, -3, 6], vec![0, 1, -2], vec![5, 0, 0]]), 2);
    }

    #[test]
    fn incremental_insert_matches_batch_rank() {
        let forms = strassen();
        let mut basis = SpanBasis::new();
        let mut inserted = 0;
        for f in &forms {
            if basis.insert(f) {
                inserted += 1;
            }
        }
        assert_eq!(inserted, 7);
        assert_eq!(basis.rank(), 7);
        // Re-inserting changes nothing.
        assert!(!basis.insert(&forms[0]));
    }

    #[test]
    fn contains_rejects_outside_vector() {
        let mut basis = SpanBasis::new();
        basis.insert(&s([1, 0, 0, 0], [1, 0, 0, 0]));
        assert!(basis.contains(&s([1, 0, 0, 0], [1, 0, 0, 0])));
        assert!(!basis.contains(&s([0, 1, 0, 0], [1, 0, 0, 0])));
    }

    #[test]
    fn full_elementary_basis_spans_everything() {
        let mut basis = SpanBasis::new();
        for p in 0..4 {
            for q in 0..4 {
                basis.insert(&BilinearForm::elementary(p, q));
            }
        }
        assert_eq!(basis.rank(), ELEM_DIM);
        for t in Target::ALL {
            assert!(basis.contains(&t.form()));
        }
    }
}
